//! Bench: serve-while-training — the session front-end over the
//! continuous slot pool.
//!
//! Two tiers per scale, both driving the same deterministic
//! traffic-replay trace through [`ServeMux`] on the device-KV backend:
//!
//! - **replay** (training off): fixed params at version 0 — the pure
//!   serving ceiling. Reports request throughput, tokens/sec, slot
//!   occupancy and p50/p99 TTFT / time-to-retire (sweep units).
//! - **trained** (training clock on): a synthetic publish clock advances
//!   the served params version every `PUBLISH_EVERY` sweeps, exactly the
//!   cadence a concurrent trainer's `ParamBus` publishes at. On top of
//!   the replay columns this tier reports the served-params staleness
//!   distribution: per-completion lag = publish version at retirement −
//!   oldest version any of its tokens sampled under (p50/p99/max).
//! - **failover**: serve the trace to roughly half its turns, then kill
//!   the seat — abandon the pool with its in-flight KV, rebuild a board
//!   from the delivered-turn set on a fresh pool (exactly the
//!   supervisor's session-migration move) and drain the remainder.
//!   Reports what a migration costs: sessions migrated, in-flight tokens
//!   abandoned, and the end-to-end sweep count against the unkilled
//!   trained tier.
//!
//! The summary also prices the fixed-round counterfactual: serving the
//! same turns in fixed gen_batch rounds would hold every slot for the
//! full `resp_len` sweeps per round — continuous serving occupancy must
//! match or beat that tier. Results are dumped to `BENCH_serving.json`
//! (override with `ASYNC_RLHF_BENCH_OUT`).
//! `cargo bench --bench serving`.

use std::collections::HashSet;

use async_rlhf::data::{Task, TaskGen};
use async_rlhf::gen::continuous::{ContinuousEngine, DeviceBackend, PoolCfg};
use async_rlhf::gen::SampleOpts;
use async_rlhf::runtime::{Engine, ParamView};
use async_rlhf::serve::frontend::{run_replay, ServeMux};
use async_rlhf::serve::session::SessionBoard;
use async_rlhf::serve::traffic::{turn_uid, TrafficCfg, TrafficGen};
use async_rlhf::util::bench::{artifact_dir_or_skip, bench, pct};
use async_rlhf::util::json::Json;
use async_rlhf::util::rng::Pcg32;

const SESSIONS: u64 = 8;
const TURNS: u64 = 2;
const ARRIVAL_RATE: f64 = 0.5;
const K: usize = 2;
/// Trained tier: sweeps between synthetic trainer publishes.
const PUBLISH_EVERY: u64 = 8;
/// Loud-failure bound on a single trace (see `run_replay`).
const MAX_SWEEPS: u64 = 200_000;

/// Accumulators across the timed iterations of one tier.
#[derive(Default)]
struct Acc {
    requests: u64,
    tokens: u64,
    slot_steps: u64,
    ttft: Vec<u64>,
    retire: Vec<u64>,
    /// Served-params staleness samples (trained tier only).
    lag: Vec<u64>,
}

struct TierResult {
    tier: &'static str,
    mean_secs: f64,
    req_per_sec: f64,
    tok_per_sec: f64,
    occupancy: f64,
    p50_ttft: f64,
    p99_ttft: f64,
    p50_retire: f64,
    p99_retire: f64,
    p50_lag: f64,
    p99_lag: f64,
    max_lag: f64,
}

fn traffic(seed: u64) -> TrafficGen {
    TrafficGen::new(TrafficCfg {
        sessions: SESSIONS,
        turns: TURNS,
        arrival_rate: ARRIVAL_RATE,
        seed,
    })
}

/// One trained-tier trace: drive the mux to completion while the publish
/// clock ticks, folding latency + staleness samples into `acc`.
fn run_trained(
    engine: &Engine,
    params: &[f32],
    taskgen: &TaskGen,
    pool: PoolCfg,
    opts: SampleOpts,
    seed: u64,
    acc: &mut Acc,
) {
    let slots = pool.slots as u64;
    let mut backend = DeviceBackend::new(engine).expect("device backend");
    let tr = traffic(seed);
    let board = SessionBoard::new(&tr, K, 0, 1, &HashSet::new())
        .expect("session board");
    let mut mux = ServeMux::new(pool, board);
    let mut rng = Pcg32::new(seed, 0x5e7e);
    while !mux.is_done() {
        assert!(
            mux.sweep() < MAX_SWEEPS,
            "trained tier stalled: sessions {:?} incomplete",
            mux.board().incomplete()
        );
        let version = mux.sweep() / PUBLISH_EVERY;
        let pv = ParamView::cached("bench_serve", version, params);
        let events = mux
            .step(&mut backend, taskgen, pv, version, opts, &mut rng)
            .expect("mux sweep");
        for (c, ev) in events {
            acc.ttft.push(ev.ttft);
            acc.retire.push(ev.retire);
            acc.lag.push(version.saturating_sub(c.version_min));
            if ev.turn_done {
                acc.requests += 1;
            }
        }
    }
    let st = mux.stats();
    acc.tokens += st.tokens;
    acc.slot_steps += slots * st.sweeps;
}

/// Per-iteration migration cost from the failover tier.
#[derive(Default)]
struct FailoverCost {
    sessions_migrated: u64,
    inflight_tokens_abandoned: u64,
    sweeps: u64,
}

/// One failover-tier trace: serve at fixed params until roughly half the
/// trace's turns have completed, then kill the seat — drop the mux (and
/// every in-flight token with it), rebuild a board over the same residue
/// from the delivered-turn set on a fresh pool, and drain the remainder.
/// This is the supervisor's migration move at the unit seam, priced.
#[allow(clippy::too_many_arguments)]
fn run_failover(
    engine: &Engine,
    params: &[f32],
    taskgen: &TaskGen,
    pool: PoolCfg,
    opts: SampleOpts,
    seed: u64,
    acc: &mut Acc,
    cost: &mut FailoverCost,
) {
    let slots = pool.slots as u64;
    let pv = ParamView::cached("bench_serve", 0, params);
    let tr = traffic(seed);
    let mut delivered: HashSet<u64> = HashSet::new();

    // phase 1: the doomed seat serves the front half of the trace
    let mut backend = DeviceBackend::new(engine).expect("device backend");
    let board = SessionBoard::new(&tr, K, 0, 1, &HashSet::new())
        .expect("session board");
    let mut mux = ServeMux::new(pool, board);
    let mut rng = Pcg32::new(seed, 0xfa11);
    let half = SESSIONS * TURNS / 2;
    while (delivered.len() as u64) < half && !mux.is_done() {
        assert!(
            mux.sweep() < MAX_SWEEPS,
            "failover tier stalled pre-kill: sessions {:?} incomplete",
            mux.board().incomplete()
        );
        let events = mux
            .step(&mut backend, taskgen, pv, 0, opts, &mut rng)
            .expect("mux sweep");
        for (_, ev) in events {
            acc.ttft.push(ev.ttft);
            acc.retire.push(ev.retire);
            if ev.turn_done {
                acc.requests += 1;
                delivered.insert(turn_uid(ev.session, ev.turn, TURNS));
            }
        }
    }
    // the kill: everything still decoding is lost with the seat
    cost.inflight_tokens_abandoned += mux.inflight_tokens();
    cost.sessions_migrated += mux.board().incomplete().len() as u64;
    let st = mux.stats();
    acc.tokens += st.tokens;
    acc.slot_steps += slots * st.sweeps;
    cost.sweeps += st.sweeps;
    drop(mux);

    // phase 2: the survivor rebuilds its schedule from the delivered set
    // and serves what is left (incl. re-serving the abandoned turns)
    let mut backend = DeviceBackend::new(engine).expect("device backend");
    let board = SessionBoard::for_lanes(&tr, K, &[0], 1, &delivered)
        .expect("migrated board");
    let mut mux = ServeMux::new(pool, board);
    let mut rng = Pcg32::new(seed, 0xfa12);
    while !mux.is_done() {
        assert!(
            mux.sweep() < MAX_SWEEPS,
            "failover tier stalled post-kill: sessions {:?} incomplete",
            mux.board().incomplete()
        );
        let events = mux
            .step(&mut backend, taskgen, pv, 0, opts, &mut rng)
            .expect("mux sweep");
        for (_, ev) in events {
            acc.ttft.push(ev.ttft);
            acc.retire.push(ev.retire);
            if ev.turn_done {
                acc.requests += 1;
            }
        }
    }
    let st = mux.stats();
    acc.tokens += st.tokens;
    acc.slot_steps += slots * st.sweeps;
    cost.sweeps += st.sweeps;
}

fn tier_result(tier: &'static str, mean_secs: f64, iters: usize, acc: &mut Acc) -> TierResult {
    let span = (mean_secs * iters as f64).max(1e-12);
    TierResult {
        tier,
        mean_secs,
        req_per_sec: acc.requests as f64 / span,
        tok_per_sec: acc.tokens as f64 / span,
        occupancy: acc.tokens as f64 / acc.slot_steps.max(1) as f64,
        p50_ttft: pct(&mut acc.ttft, 0.50),
        p99_ttft: pct(&mut acc.ttft, 0.99),
        p50_retire: pct(&mut acc.retire, 0.50),
        p99_retire: pct(&mut acc.retire, 0.99),
        p50_lag: pct(&mut acc.lag, 0.50),
        p99_lag: pct(&mut acc.lag, 0.99),
        max_lag: acc.lag.iter().copied().max().unwrap_or(0) as f64,
    }
}

fn main() {
    println!("== serving: session front-end over the continuous slot pool ==");
    let mut models = Vec::new();
    for model in ["tldr_s", "tldr_m", "tldr_l"] {
        let Some(dir) = artifact_dir_or_skip(model) else {
            continue;
        };
        let engine = Engine::load(&dir).expect("load engine");
        if !ContinuousEngine::supported(&engine) {
            println!(
                "SKIP {model}: bundle lacks prefill_dev/decode_dev \
                 (rebuild artifacts)"
            );
            continue;
        }
        let cfg = engine.manifest.config.clone();
        let params = engine.init_policy().expect("init params");
        let taskgen = TaskGen::new(
            Task::from_name(&cfg.task).unwrap(),
            cfg.prompt_len,
            cfg.resp_len,
            42,
        );
        let pool = PoolCfg {
            slots: cfg.gen_batch,
            prompt_len: cfg.prompt_len,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            max_cohorts: 4,
            admit_min: 1,
        };
        let opts = SampleOpts { temperature: 0.7, greedy: false };
        let pv = ParamView::cached("bench_serve", 0, &params);

        // warm the executables + settle the untuple capability outside
        // the measurement
        let mut backend = DeviceBackend::new(&engine).expect("device backend");
        run_replay(
            &mut backend, &taskgen, &traffic(0), pool, K, opts, pv, 0,
            MAX_SWEEPS,
        )
        .expect("warm replay");
        drop(backend);
        if engine.client_untuples() != Some(true) {
            println!("SKIP {model}: PJRT client returns root tuples");
            continue;
        }

        let mut results: Vec<TierResult> = Vec::new();

        // --- replay tier: training off, fixed params ---
        let mut acc = Acc::default();
        let mut seed = 0u64;
        let r = bench(&format!("{model}/replay"), 0, 5, || {
            seed += 1;
            let mut backend =
                DeviceBackend::new(&engine).expect("device backend");
            let rep = run_replay(
                &mut backend, &taskgen, &traffic(seed), pool, K, opts, pv,
                seed, MAX_SWEEPS,
            )
            .expect("replay drains");
            acc.requests += rep.requests;
            acc.tokens += rep.tokens;
            acc.slot_steps += pool.slots as u64 * rep.stats.sweeps;
            acc.ttft.extend(rep.ttft);
            acc.retire.extend(rep.retire);
        });
        results.push(tier_result("replay", r.mean() as f64, r.iters, &mut acc));

        // --- trained tier: publish clock advances the served version ---
        let mut acc = Acc::default();
        let mut seed = 100u64;
        let r = bench(&format!("{model}/trained"), 0, 5, || {
            seed += 1;
            run_trained(&engine, &params, &taskgen, pool, opts, seed, &mut acc);
        });
        let trained_toks = acc.tokens;
        let trained_reqs = acc.requests;
        results.push(tier_result("trained", r.mean() as f64, r.iters, &mut acc));

        // --- failover tier: mid-trace seat kill + session migration ---
        let mut acc = Acc::default();
        let mut cost = FailoverCost::default();
        let mut seed = 200u64;
        let r = bench(&format!("{model}/failover"), 0, 5, || {
            seed += 1;
            run_failover(
                &engine, &params, &taskgen, pool, opts, seed, &mut acc,
                &mut cost,
            );
        });
        let fail_iters = (r.iters as u64).max(1) as f64;
        let fail = (
            cost.sessions_migrated as f64 / fail_iters,
            cost.inflight_tokens_abandoned as f64 / fail_iters,
            cost.sweeps as f64 / fail_iters,
        );
        results.push(tier_result(
            "failover",
            r.mean() as f64,
            r.iters,
            &mut acc,
        ));

        println!("\n{model} ({} params):", engine.manifest.param_count);
        println!(
            "  {:<8} {:>9}  {:>7}  {:>8}  {:>6}  {:>10}  {:>12}  {:>14}",
            "tier", "mean_s", "req/s", "tok/s", "occup", "ttft p50/99",
            "retire p50/99", "lag p50/99/max"
        );
        for t in &results {
            println!(
                "  {:<8} {:>9.4}  {:>7.1}  {:>8.0}  {:>6.3}  {:>4.0} /{:>4.0}  \
                 {:>5.0} /{:>5.0}  {:>4.0} /{:>4.0} /{:>4.0}",
                t.tier,
                t.mean_secs,
                t.req_per_sec,
                t.tok_per_sec,
                t.occupancy,
                t.p50_ttft,
                t.p99_ttft,
                t.p50_retire,
                t.p99_retire,
                t.p50_lag,
                t.p99_lag,
                t.max_lag,
            );
        }

        // fixed-round counterfactual: the same turns served in fixed
        // gen_batch rounds hold every slot resp_len sweeps per round
        let candidates = trained_reqs * K as u64;
        let rounds = candidates.div_ceil(cfg.gen_batch as u64);
        let fixed_slot_steps =
            rounds * cfg.resp_len as u64 * cfg.gen_batch as u64;
        let occ_fixed = trained_toks as f64 / fixed_slot_steps.max(1) as f64;
        let occ_cont = results[1].occupancy;
        println!(
            "  serving occupancy {:.3} vs fixed-round tier {:.3} [{}]",
            occ_cont,
            occ_fixed,
            if occ_cont >= occ_fixed { "OK" } else { "REGRESSION" }
        );
        println!(
            "  failover cost/iter: {:.1} sessions migrated, {:.0} in-flight \
             tokens abandoned, {:.0} sweeps end-to-end",
            fail.0, fail.1, fail.2
        );
        models.push((
            model,
            engine.manifest.param_count,
            results,
            occ_fixed,
            fail,
        ));
    }

    // --- machine-readable dump for the perf trajectory ---
    let report = Json::obj(vec![(
        "models",
        Json::Obj(
            models
                .iter()
                .map(|(model, params, results, occ_fixed, fail)| {
                    (
                        model.to_string(),
                        Json::obj(vec![
                            ("param_count", Json::num(*params as f64)),
                            ("occupancy_fixed_round", Json::num(*occ_fixed)),
                            (
                                "failover",
                                Json::obj(vec![
                                    ("sessions_migrated", Json::num(fail.0)),
                                    (
                                        "inflight_tokens_abandoned",
                                        Json::num(fail.1),
                                    ),
                                    ("sweeps", Json::num(fail.2)),
                                ]),
                            ),
                            (
                                "tiers",
                                Json::Obj(
                                    results
                                        .iter()
                                        .map(|t| {
                                            (
                                                t.tier.to_string(),
                                                Json::obj(vec![
                                                    ("mean_secs", Json::num(t.mean_secs)),
                                                    ("req_per_sec", Json::num(t.req_per_sec)),
                                                    ("tok_per_sec", Json::num(t.tok_per_sec)),
                                                    ("occupancy", Json::num(t.occupancy)),
                                                    ("p50_ttft", Json::num(t.p50_ttft)),
                                                    ("p99_ttft", Json::num(t.p99_ttft)),
                                                    ("p50_retire", Json::num(t.p50_retire)),
                                                    ("p99_retire", Json::num(t.p99_retire)),
                                                    ("p50_lag", Json::num(t.p50_lag)),
                                                    ("p99_lag", Json::num(t.p99_lag)),
                                                    ("max_lag", Json::num(t.max_lag)),
                                                ]),
                                            )
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        ),
    )]);
    let out_path = std::env::var("ASYNC_RLHF_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_serving.json".into());
    std::fs::write(&out_path, report.to_string()).expect("write bench json");
    println!("wrote {out_path}");
}
