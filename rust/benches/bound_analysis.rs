//! Bench: schedule-simulation sweep — paper Fig 6 + A.2/A.3 analysis.
//!
//! Pure clock simulation (no executables): idle time and speedup across
//! gen:train ratios, plus the paper's own published phase costs pushed
//! through the same analyzer.

use async_rlhf::sim::{analyze, classify, simulate_async, simulate_sync, Bound, StepCosts};

fn main() {
    println!("== bound_analysis (paper Fig 6 + A.2/A.3) ==");
    println!(
        "{:>9} {:>18} {:>10} {:>10} {:>10} {:>9}",
        "gen:train", "regime", "sync_s", "async_s", "speedup", "gen_idle"
    );
    let steps = 200;
    for ratio in [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let costs = StepCosts::new(ratio, 0.05, 1.0);
        let sync = simulate_sync(&costs, steps);
        let asy = simulate_async(&costs, steps);
        let regime = match classify(&costs) {
            Bound::GenerationBound => "generation-bound",
            Bound::TrainingBound => "training-bound",
            Bound::Balanced => "balanced",
        };
        println!(
            "{ratio:>9.3} {regime:>18} {:>10.1} {:>10.1} {:>9.1}% {:>8.1}%",
            sync.wall,
            asy.wall,
            (sync.wall / asy.wall - 1.0) * 100.0,
            100.0 * asy.gen_idle / asy.wall,
        );
    }

    println!("\npaper-published phase costs through the same analyzer:");
    for (name, gen, train, steps) in [
        ("№Robots 8xH100 (A.2)", 21.0, 33.0, 233u64),
        ("GSM8k 4xL40s (A.3)", 12.2, 12.9, 512),
    ] {
        let a = analyze(&StepCosts::new(gen, 0.1, train), steps);
        println!(
            "  {name:<22} sync {:>7.1}min  ideal-async {:>7.1}min  ({:+.0}%)",
            a.sync_wall / 60.0,
            a.ideal_wall / 60.0,
            a.ideal_speedup_pct
        );
    }
    println!(
        "\npaper-shape check: speedup maximal when balanced (ratio 1.0), \
         idle grows with imbalance"
    );
}
