//! Bench: staleness ladder — queue depth K × workers M on the small
//! artifact, through the unified pipeline.
//!
//! Runs the `experiments::staleness_ladder` sweep (K ∈ {0,1,2,4} ×
//! M ∈ {1,2} by default) with a short step budget and dumps win-rate,
//! KL, measured mean/max staleness vs the proven bound, trainer idle
//! time and wall clock per config to `BENCH_staleness.json` (override
//! the path with `ASYNC_RLHF_BENCH_OUT`), so the off-policy
//! quality/throughput trade-off is part of the recorded perf trajectory.

use async_rlhf::config::{Algo, ExpConfig};
use async_rlhf::coordinator;
use async_rlhf::experiments::staleness_ladder::{bench_json, sweep};
use async_rlhf::util::bench::artifact_dir_or_skip;

fn main() {
    println!("== staleness ladder: K x M through the pipeline ==");
    let model = std::env::var("ASYNC_RLHF_BENCH_MODEL")
        .unwrap_or_else(|_| "tldr_s".into());
    let Some(_) = artifact_dir_or_skip(&model) else {
        return;
    };

    let cfg = ExpConfig {
        model: model.clone(),
        algo: Algo::Dpo,
        steps: 12,
        sft_steps: 60,
        rm_steps: 40,
        eval_prompts: 32,
        run_dir: std::env::temp_dir().join("async_rlhf_bench_staleness"),
        ..ExpConfig::default()
    };
    let prep = coordinator::prepare(&cfg, false).expect("prepare");

    let points = sweep(&cfg, &prep, &[0, 1, 2, 4], &[1, 2], false)
        .expect("staleness sweep");
    println!(
        "{:>8} {:>9} {:>8} {:>11} {:>10} {:>6} {:>8} {:>8}",
        "config", "win_rate", "kl_ppl", "mean_stale", "max_stale", "bound",
        "idle_s", "wall_s"
    );
    for p in &points {
        println!(
            "K={} M={} {:>9.3} {:>8.4} {:>11.2} {:>10} {:>6} {:>8.2} {:>8.1}",
            p.k_bound,
            p.workers,
            p.win_rate,
            p.kl_ppl,
            p.mean_staleness,
            p.max_staleness,
            p.bound,
            p.idle_secs,
            p.wall_secs,
        );
    }

    let report = bench_json(&model, cfg.steps, &points);
    let out_path = std::env::var("ASYNC_RLHF_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_staleness.json".into());
    std::fs::write(&out_path, report.to_string()).expect("write bench json");
    println!("wrote {out_path}");
}
