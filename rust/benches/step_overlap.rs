//! Bench: sync vs async RLHF step time — the timing half of paper Fig 1.
//!
//! Measures mean wall-clock per optimizer step for synchronous
//! (generate-then-train) vs asynchronous (overlapped) coordination on the
//! same executables. The async step should approach
//! max(gen, score+train) while sync pays the sum.

use async_rlhf::config::{Algo, ExpConfig, Mode};
use async_rlhf::coordinator;
use async_rlhf::metrics::Phase;
use async_rlhf::util::bench::artifact_dir_or_skip;

fn main() {
    println!("== step_overlap (paper Fig 1 timing): sync vs async ==");
    let model = std::env::var("ASYNC_RLHF_BENCH_MODEL")
        .unwrap_or_else(|_| "tldr_s".into());
    let Some(_) = artifact_dir_or_skip(&model) else {
        return;
    };

    let mut cfg = ExpConfig {
        model: model.clone(),
        algo: Algo::Dpo,
        steps: 12,
        sft_steps: 60,
        rm_steps: 40,
        run_dir: std::env::temp_dir().join("async_rlhf_bench"),
        ..ExpConfig::default()
    };
    let prep = coordinator::prepare(&cfg, false).expect("prepare");

    let mut results = Vec::new();
    for mode in [Mode::Sync, Mode::Async] {
        cfg.mode = mode;
        let out = coordinator::run(&cfg, &prep, false).expect("run");
        let totals = out.timeline.totals();
        let wall = out.timeline.wall();
        let per_step = wall / cfg.steps as f64;
        println!(
            "{:<6} wall {:>7.2}s  per-step {:>6.3}s  gen {:>6.2}s  \
             score {:>6.2}s  train {:>6.2}s",
            mode.name(),
            wall,
            per_step,
            totals.get(&Phase::Generate).unwrap_or(&0.0),
            totals.get(&Phase::Score).unwrap_or(&0.0),
            totals.get(&Phase::Train).unwrap_or(&0.0),
        );
        results.push((mode, wall, totals));
    }

    if let [(_, sync_wall, st), (_, async_wall, _)] = &results[..] {
        let speedup = (sync_wall / async_wall - 1.0) * 100.0;
        println!("\nasync speedup vs sync: {speedup:+.1}%");
        let gen = st.get(&Phase::Generate).copied().unwrap_or(0.0);
        let rest = st.get(&Phase::Score).copied().unwrap_or(0.0)
            + st.get(&Phase::Train).copied().unwrap_or(0.0);
        let ideal = gen.max(rest);
        println!(
            "ideal async wall (max of phases): {ideal:.2}s -> overhead {:+.2}s",
            async_wall - ideal
        );
        println!(
            "paper-shape check (async faster): [{}]",
            if speedup > 0.0 { "OK" } else { "SLOWER" }
        );
    }
}
