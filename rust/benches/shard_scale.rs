//! Bench: shard scale — trainer data-parallel width S × pipeline mode.
//!
//! Runs the full RLHF loop at S ∈ {1, 2, 4} trainer shards under each
//! pipeline mode (sync, async, serve) on the same prepared artifact and
//! reports the headline round-train throughput: steps/sec over wall
//! clock plus the train- and publish-phase seconds from the trainer
//! timeline, alongside episode count and measured max staleness vs the
//! sharded bound's fan-out term. Combos whose train-batch geometry does
//! not tile across S (batch dim % S != 0) are skipped with a printed
//! note — the shard pool refuses them loudly rather than padding.
//!
//! Results are dumped to `BENCH_shard_scale.json` (override the path
//! with `ASYNC_RLHF_BENCH_OUT`; pick the artifact with
//! `ASYNC_RLHF_BENCH_MODEL`). `cargo bench --bench shard_scale`.

use async_rlhf::config::{ExpConfig, GenEngine, Mode};
use async_rlhf::coordinator;
use async_rlhf::gen::continuous::ContinuousEngine;
use async_rlhf::metrics::Phase;
use async_rlhf::util::bench::artifact_dir_or_skip;
use async_rlhf::util::json::Json;

const SHARDS: [usize; 3] = [1, 2, 4];
const MODES: [(Mode, &str); 3] = [
    (Mode::Sync, "sync"),
    (Mode::Async, "async"),
    (Mode::Serve, "serve"),
];

struct Point {
    mode: &'static str,
    shards: usize,
    label: String,
    steps: u64,
    episodes: u64,
    wall_secs: f64,
    steps_per_sec: f64,
    train_secs: f64,
    publish_secs: f64,
    max_staleness: f64,
}

fn main() {
    println!("== shard scale: trainer shards S x pipeline mode ==");
    let model = std::env::var("ASYNC_RLHF_BENCH_MODEL")
        .unwrap_or_else(|_| "tldr_s".into());
    let Some(_) = artifact_dir_or_skip(&model) else {
        return;
    };

    let base = ExpConfig {
        model: model.clone(),
        steps: 8,
        sft_steps: 60,
        rm_steps: 40,
        eval_prompts: 32,
        run_dir: std::env::temp_dir().join("async_rlhf_bench_shard_scale"),
        ..ExpConfig::default()
    };
    let prep = coordinator::prepare(&base, false).expect("prepare");
    let serve_ok = ContinuousEngine::supported(&prep.engine);

    let mut points: Vec<Point> = Vec::new();
    for (mode, mode_name) in MODES {
        if mode == Mode::Serve && !serve_ok {
            println!(
                "SKIP serve: bundle lacks prefill_dev/decode_dev \
                 (rebuild artifacts)"
            );
            continue;
        }
        for shards in SHARDS {
            let mut cfg = base.clone();
            cfg.mode = mode;
            cfg.trainer_shards = shards;
            if mode == Mode::Serve {
                // serve multiplexes sessions onto the continuous slot pool
                cfg.gen_engine = GenEngine::Continuous;
            }
            let label = cfg.label();
            let out = match coordinator::run(&cfg, &prep, false) {
                Ok(out) => out,
                // non-tiling geometry (batch dim % S != 0) is the one
                // expected refusal; anything else should still surface
                Err(e) => {
                    println!("SKIP {label}: {e:#}");
                    continue;
                }
            };
            let totals = out.timeline.totals();
            let wall = out.timeline.wall().max(1e-12);
            let max_staleness = out
                .log
                .series("staleness")
                .iter()
                .map(|&(_, v)| v as f64)
                .fold(0.0, f64::max);
            points.push(Point {
                mode: mode_name,
                shards,
                label,
                steps: cfg.steps,
                episodes: out.episodes,
                wall_secs: wall,
                steps_per_sec: cfg.steps as f64 / wall,
                train_secs: *totals.get(&Phase::Train).unwrap_or(&0.0),
                publish_secs: *totals.get(&Phase::Publish).unwrap_or(&0.0),
                max_staleness,
            });
        }
    }

    println!(
        "{:>6} {:>3} {:>6} {:>9} {:>9} {:>9} {:>10} {:>10}",
        "mode", "S", "steps", "wall_s", "steps/s", "train_s", "publish_s",
        "max_stale"
    );
    for p in &points {
        println!(
            "{:>6} {:>3} {:>6} {:>9.2} {:>9.3} {:>9.2} {:>10.3} {:>10.0}",
            p.mode,
            p.shards,
            p.steps,
            p.wall_secs,
            p.steps_per_sec,
            p.train_secs,
            p.publish_secs,
            p.max_staleness,
        );
    }

    // --- machine-readable dump for the perf trajectory ---
    let report = Json::obj(vec![
        ("model", Json::str(&model)),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("mode", Json::str(p.mode)),
                            ("shards", Json::num(p.shards as f64)),
                            ("label", Json::str(&p.label)),
                            ("steps", Json::num(p.steps as f64)),
                            ("episodes", Json::num(p.episodes as f64)),
                            ("wall_secs", Json::num(p.wall_secs)),
                            ("steps_per_sec", Json::num(p.steps_per_sec)),
                            ("train_secs", Json::num(p.train_secs)),
                            ("publish_secs", Json::num(p.publish_secs)),
                            ("max_staleness", Json::num(p.max_staleness)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let out_path = std::env::var("ASYNC_RLHF_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_shard_scale.json".into());
    std::fs::write(&out_path, report.to_string()).expect("write bench json");
    println!("wrote {out_path}");
}
