//! Bench: hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf).
//!
//! Times each executable class in isolation (prefill, decode step, RM
//! score, logprob, fused train step) plus the host-side costs (sampling,
//! param publication snapshot) so regressions are attributable to a layer.
//!
//! Each parameterised executable is measured twice: with fresh host params
//! (the seed behaviour — full upload every call) and with the device
//! cache (upload once per version). The train step is additionally
//! profiled for host↔device *bytes per update* on both paths, and the
//! whole run is dumped to `BENCH_hot_path.json` (override the path with
//! `ASYNC_RLHF_BENCH_OUT`) so future PRs can track the perf trajectory.

use async_rlhf::config::Algo;
use async_rlhf::coordinator::trainer::{
    assemble, label_round, make_resident, round_prompts, train_on_batch,
    LabelScratch, LabelledRound, Round, ROUND_ORIGIN,
};
use async_rlhf::data::{Task, TaskGen};
use async_rlhf::gen::{fused::FusedEngine, sampler, Generator, SampleOpts};
use async_rlhf::runtime::{
    scalar_f32, CallArg, Engine, HostTensor, ParamView, TrainState,
};
use async_rlhf::util::bench::{artifact_dir_or_skip, bench};
use async_rlhf::util::json::Json;
use async_rlhf::util::rng::Pcg32;

fn main() {
    println!("== hot_path: per-executable and host-side costs ==");
    let model = std::env::var("ASYNC_RLHF_BENCH_MODEL")
        .unwrap_or_else(|_| "tldr_s".into());
    let Some(dir) = artifact_dir_or_skip(&model) else {
        return;
    };
    let engine = Engine::load(&dir).expect("load");
    engine.warmup().expect("warmup");
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().expect("params");
    let n = engine.manifest.param_count;
    let (b, s, p, v) = (cfg.gen_batch, cfg.seq_len, cfg.prompt_len, cfg.vocab);

    let taskgen = TaskGen::new(
        Task::from_name(&cfg.task).unwrap(),
        cfg.prompt_len,
        cfg.resp_len,
        1,
    );
    let mut prompt_flat = Vec::with_capacity(b * p);
    for ex in taskgen.batch(0, b) {
        prompt_flat.extend_from_slice(&ex.prompt);
    }
    let toks: Vec<i32> = vec![1; b * s];
    let mask: Vec<f32> = vec![1.0; b * s];
    let cached = ParamView::cached("bench", 0, &params);

    // --- executable calls: fresh (seed path) vs device-cached params ---
    bench(&format!("{model}/prefill (fresh params)"), 2, 10, || {
        engine
            .call_with(
                "prefill",
                &[
                    CallArg::Param(ParamView::fresh(&params)),
                    CallArg::I32(&prompt_flat),
                ],
            )
            .unwrap();
    });
    bench(&format!("{model}/prefill (cached params)"), 2, 10, || {
        engine
            .call_with(
                "prefill",
                &[CallArg::Param(cached), CallArg::I32(&prompt_flat)],
            )
            .unwrap();
    });

    let kv = engine
        .call_with(
            "prefill",
            &[CallArg::Param(cached), CallArg::I32(&prompt_flat)],
        )
        .unwrap()
        .into_iter()
        .next()
        .unwrap();
    let step_tok = vec![5i32; b];
    bench(&format!("{model}/decode_step (literal kv)"), 2, 10, || {
        engine
            .call_with(
                "decode",
                &[
                    CallArg::Param(cached),
                    CallArg::from(&kv),
                    CallArg::I32(&step_tok),
                    CallArg::ScalarI32(p as i32),
                ],
            )
            .unwrap();
    });

    bench(&format!("{model}/generate (fused round)"), 1, 5, || {
        engine
            .call_with(
                "generate",
                &[
                    CallArg::Param(cached),
                    CallArg::I32(&prompt_flat),
                    CallArg::ScalarI32(7),
                    CallArg::ScalarF32(0.7),
                ],
            )
            .unwrap();
    });

    bench(&format!("{model}/score_rm (cached params)"), 2, 10, || {
        engine
            .call_with(
                "score_rm",
                &[
                    CallArg::Param(cached),
                    CallArg::I32(&toks),
                    CallArg::F32(&mask),
                ],
            )
            .unwrap();
    });

    bench(&format!("{model}/logprob (fresh params)"), 2, 10, || {
        engine
            .call_with(
                "logprob",
                &[
                    CallArg::Param(ParamView::fresh(&params)),
                    CallArg::I32(&toks),
                    CallArg::F32(&mask),
                ],
            )
            .unwrap();
    });
    bench(&format!("{model}/logprob (cached params)"), 2, 10, || {
        engine
            .call_with(
                "logprob",
                &[
                    CallArg::Param(cached),
                    CallArg::I32(&toks),
                    CallArg::F32(&mask),
                ],
            )
            .unwrap();
    });

    // --- train step: seed path vs device-resident path, bytes accounted ---
    let bp = cfg.train_pairs;
    let pair_toks: Vec<i32> = vec![1; bp * s];
    let pair_mask: Vec<f32> = vec![1.0; bp * s];
    let rlp: Vec<f32> = vec![-1.0; bp];
    let train_batch = vec![
        HostTensor::I32(pair_toks.clone()),
        HostTensor::F32(pair_mask.clone()),
        HostTensor::I32(pair_toks.clone()),
        HostTensor::F32(pair_mask.clone()),
        HostTensor::F32(rlp.clone()),
        HostTensor::F32(rlp.clone()),
    ];
    let steps = 10u64;

    // snapshot the per-executable phase before profiling train traffic
    let mut all_stats = engine.stats();
    let exec_cache_counters = engine.param_cache_counters();

    // seed path: full host params/m/v round-trip through `call` each update
    engine.reset_stats();
    bench(&format!("{model}/train_dpo (seed host path)"), 2, steps as usize, || {
        engine
            .call(
                "train_dpo",
                &[
                    HostTensor::F32(params.clone()),
                    HostTensor::F32(vec![0.0; n]),
                    HostTensor::F32(vec![0.0; n]),
                    scalar_f32(1.0),
                    scalar_f32(3e-4),
                    HostTensor::I32(pair_toks.clone()),
                    HostTensor::F32(pair_mask.clone()),
                    HostTensor::I32(pair_toks.clone()),
                    HostTensor::F32(pair_mask.clone()),
                    HostTensor::F32(rlp.clone()),
                    HostTensor::F32(rlp.clone()),
                ],
            )
            .unwrap();
    });
    let (seed_up, seed_down) = engine.transfer_totals();
    for (name, st) in engine.stats() {
        all_stats.insert(format!("{name} [seed train path]"), st);
    }
    let seed_calls = 2 + steps; // warmup included in the byte totals
    let seed_bytes_per_step = (seed_up + seed_down) / seed_calls;

    // device-resident path: batch uploaded once, params/m/v stay on device,
    // only the metrics vector comes back per update
    engine.reset_stats();
    let mut state = TrainState::new(params.clone());
    let dev_batch = engine.upload_inputs("train_dpo", 5, &train_batch).unwrap();
    bench(&format!("{model}/train_dpo (device resident)"), 2, steps as usize, || {
        state
            .train_step_uploaded(&engine, "train_dpo", 3e-4, &dev_batch)
            .unwrap();
    });
    let (dev_up, dev_down) = engine.transfer_totals();
    for (name, st) in engine.stats() {
        all_stats.insert(format!("{name} [device train path]"), st);
    }
    let dev_bytes_per_step = (dev_up + dev_down) / seed_calls;
    let reduction = 1.0 - dev_bytes_per_step as f64 / seed_bytes_per_step.max(1) as f64;
    println!(
        "\ntrain-step host<->device traffic: seed {seed_bytes_per_step} B/step, \
         device-resident {dev_bytes_per_step} B/step ({:.1}% less)",
        reduction * 100.0
    );

    // --- round labelling traffic: seed (3x token upload) vs resident ---
    // The seed path uploads a round's [B*S] token tensor three separate
    // times (logprob, score_rm, train batch); the resident path stages it
    // once under the ROUND_ORIGIN bucket and shares the device buffer.
    let mut round_label = Vec::new();
    let mut pairwise_dpo = Vec::new();
    // the generate bench above settled whether the client untuples; the
    // resident path is only live (and only worth measuring) when it does
    if engine.buffer_path_ready("logprob_dev") {
        let rm_params = engine.init_rm().expect("rm params");
        let (examples, prompts) = round_prompts(&taskgen, 0, b, 2);
        let mut rng = Pcg32::new(11, 0);
        let gen = FusedEngine::default()
            .generate(
                &engine,
                ParamView::cached("bench", 0, &params),
                &prompts,
                SampleOpts { temperature: 0.7, greedy: false },
                &mut rng,
            )
            .expect("generate round");
        let round = Round {
            gen,
            examples,
            start_index: 0,
            params_version: 0,
            tok_version_min: 0,
            tok_version_mean: 0.0,
            gen_secs: 0.0,
            gen_span: (0.0, 0.0),
        };
        let mut scratch = LabelScratch::default();
        let mut tstate = TrainState::new(params.clone());
        let rm = Some((&engine, &rm_params[..]));
        let mut run_path = |algo: Algo, resident: bool| -> (u64, u64) {
            let mut staged = if resident {
                make_resident(
                    &engine,
                    &round.gen,
                    None,
                    rm,
                    false,
                    async_rlhf::coordinator::trainer::algo_stages_blp(algo),
                    &mut scratch,
                )
                .expect("stage round")
            } else {
                None
            };
            let labels = label_round(
                &engine, &round, &params, rm, 2, -1.0, false, &mut scratch,
                staged.as_mut(),
            )
            .expect("label");
            let lr = LabelledRound {
                round: Round {
                    gen: round.gen.clone(),
                    examples: round.examples.clone(),
                    start_index: 0,
                    params_version: 0,
                    tok_version_min: 0,
                    tok_version_mean: 0.0,
                    gen_secs: 0.0,
                    gen_span: (0.0, 0.0),
                },
                labels,
                resident: staged,
            };
            let batch = assemble(&engine, algo, std::slice::from_ref(&lr), 2)
                .expect("assemble");
            train_on_batch(&engine, &mut tstate, &batch, 3e-4, 1)
                .expect("train");
            engine.transfer_totals()
        };
        // warm the ref/rm param caches + train state off the measurement
        run_path(Algo::Ppo, false);
        engine.reset_stats();
        let (seed_up, _) = run_path(Algo::Ppo, false);
        let seed_stats = engine.stats();
        engine.reset_stats();
        let (res_up, _) = run_path(Algo::Ppo, true);
        let res_stats = engine.stats();
        let token_bytes = (4 * b * s) as u64;
        let tok_uploads = |stats: &std::collections::BTreeMap<
            String,
            async_rlhf::runtime::CallStats,
        >| {
            // origins whose uploads include the [B*S] token tensor
            ["logprob", "score_rm", "train_ppo", ROUND_ORIGIN]
                .iter()
                .filter(|&&k| match (k, stats.get(k)) {
                    // train_ppo always uploads blp+rlp (2 token-sized f32
                    // tensors); only a THIRD token-sized tensor means the
                    // tokens themselves went up again
                    ("train_ppo", Some(st)) => st.bytes_up >= 3 * token_bytes,
                    (_, Some(st)) => st.bytes_up >= token_bytes,
                    _ => false,
                })
                .count() as u64
        };
        let (seed_n, res_n) = (tok_uploads(&seed_stats), tok_uploads(&res_stats));
        println!(
            "\nround labelling traffic (PPO-shaped, one round): \
             seed {seed_up} B up ({seed_n}x token upload), \
             resident {res_up} B up ({res_n}x token upload)"
        );
        for (name, st) in res_stats {
            if st.bytes_up > 0 || st.bytes_down > 0 {
                all_stats.insert(format!("{name} [resident round]"), st);
            }
        }
        round_label = vec![
            ("seed_bytes_up", Json::num(seed_up as f64)),
            ("resident_bytes_up", Json::num(res_up as f64)),
            ("token_uploads_seed", Json::num(seed_n as f64)),
            ("token_uploads_resident", Json::num(res_n as f64)),
        ];

        // --- pairwise (DPO) bytes per batch: host assembly vs gather ---
        // The host path uploads 4 [Bp,S] best/worst tensors (+ 2 [Bp]
        // margins) per DPO batch; the gather path uploads the [2*Bp]
        // pair-index vector and reads everything else off the resident
        // round. Measured, not asserted — the JSON records the win.
        if engine.buffer_path_ready("gather_pairs") {
            engine.reset_stats();
            let (host_total, _) = run_path(Algo::Dpo, false);
            let host_stats = engine.stats();
            engine.reset_stats();
            let (gather_total, _) = run_path(Algo::Dpo, true);
            let gather_stats = engine.stats();
            let up = |stats: &std::collections::BTreeMap<
                String,
                async_rlhf::runtime::CallStats,
            >,
                      k: &str| {
                stats.get(k).map_or(0, |st| st.bytes_up)
            };
            let host_batch = up(&host_stats, "train_dpo");
            let gather_batch =
                up(&gather_stats, "train_dpo") + up(&gather_stats, "gather_pairs");
            let idx_bytes = (4 * 2 * cfg.train_pairs) as u64;
            println!(
                "\npairwise (DPO) train-batch uploads: host assembly \
                 {host_batch} B, pair gather {gather_batch} B \
                 (index vector {idx_bytes} B); cycle totals \
                 {host_total} B vs {gather_total} B up"
            );
            pairwise_dpo = vec![
                ("host_batch_bytes_up", Json::num(host_batch as f64)),
                ("gather_batch_bytes_up", Json::num(gather_batch as f64)),
                ("index_vector_bytes", Json::num(idx_bytes as f64)),
                ("host_cycle_bytes_up", Json::num(host_total as f64)),
                ("gather_cycle_bytes_up", Json::num(gather_total as f64)),
            ];
            for (name, st) in gather_stats {
                if st.bytes_up > 0 || st.bytes_down > 0 {
                    all_stats.insert(format!("{name} [pair gather]"), st);
                }
            }
        } else {
            println!(
                "\nSKIP pairwise gather traffic: bundle lacks gather_pairs"
            );
        }
    } else {
        println!(
            "\nSKIP round-labelling traffic: needs logprob_dev artifacts \
             and an untupling PJRT client"
        );
    }

    // --- host-side costs ---
    let logits: Vec<f32> = (0..b * v).map(|i| (i % 17) as f32 * 0.1).collect();
    bench("host/sample_batch_row_loop", 10, 50, || {
        let mut rng = Pcg32::new(7, 7);
        for i in 0..b {
            let row = &logits[i * v..(i + 1) * v];
            let _ = sampler::sample(row, 0.7, false, &mut rng);
        }
    });

    bench("host/param_publish_clone", 10, 50, || {
        let copy = params.clone();
        std::hint::black_box(&copy);
    });
    let arc: std::sync::Arc<[f32]> = std::sync::Arc::from(&params[..]);
    bench("host/param_publish_arc_swap", 10, 50, || {
        let fetched = arc.clone();
        std::hint::black_box(&fetched);
    });

    // per-artifact cumulative stats gathered during this bench
    println!("\ncumulative engine stats:");
    for (name, st) in &all_stats {
        println!(
            "  {:<40} calls {:>4}  total {:>8.3}s  up {:>10} B  down {:>10} B",
            name, st.calls, st.total_secs, st.bytes_up, st.bytes_down
        );
    }
    let (hits, misses) = exec_cache_counters;
    println!("param cache (executable phase): {hits} hits, {misses} misses");

    // --- machine-readable dump for the perf trajectory ---
    let artifacts = Json::Obj(
        all_stats
            .iter()
            .map(|(name, st)| {
                (
                    name.clone(),
                    Json::obj(vec![
                        ("calls", Json::num(st.calls as f64)),
                        ("total_secs", Json::num(st.total_secs)),
                        ("bytes_up", Json::num(st.bytes_up as f64)),
                        ("bytes_down", Json::num(st.bytes_down as f64)),
                    ]),
                )
            })
            .collect(),
    );
    let report = Json::obj(vec![
        ("model", Json::str(&model)),
        ("param_count", Json::num(n as f64)),
        (
            "train_step_bytes",
            Json::obj(vec![
                ("seed_path_per_step", Json::num(seed_bytes_per_step as f64)),
                ("device_resident_per_step", Json::num(dev_bytes_per_step as f64)),
                ("reduction", Json::num(reduction)),
            ]),
        ),
        (
            "param_cache",
            Json::obj(vec![
                ("hits", Json::num(hits as f64)),
                ("misses", Json::num(misses as f64)),
            ]),
        ),
        ("round_label_bytes", Json::obj(round_label)),
        ("pairwise_dpo_bytes", Json::obj(pairwise_dpo)),
        ("artifacts", artifacts),
    ]);
    let out_path = std::env::var("ASYNC_RLHF_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hot_path.json".into());
    std::fs::write(&out_path, report.to_string()).expect("write bench json");
    println!("wrote {out_path}");
}
