//! Bench: hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf).
//!
//! Times each executable class in isolation (prefill, decode step, RM
//! score, logprob, fused train step) plus the host-side costs (sampling,
//! batch assembly buffers, param publication clone) so regressions are
//! attributable to a layer.

use async_rlhf::data::{Task, TaskGen};
use async_rlhf::gen::sampler;
use async_rlhf::runtime::{scalar_f32, scalar_i32, Engine, HostTensor};
use async_rlhf::util::bench::{artifact_dir_or_skip, bench};
use async_rlhf::util::rng::Pcg32;

fn main() {
    println!("== hot_path: per-executable and host-side costs ==");
    let model = std::env::var("ASYNC_RLHF_BENCH_MODEL")
        .unwrap_or_else(|_| "tldr_s".into());
    let Some(dir) = artifact_dir_or_skip(&model) else {
        return;
    };
    let engine = Engine::load(&dir).expect("load");
    engine.warmup().expect("warmup");
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().expect("params");
    let n = engine.manifest.param_count;
    let (b, s, p, v) = (cfg.gen_batch, cfg.seq_len, cfg.prompt_len, cfg.vocab);

    let taskgen = TaskGen::new(
        Task::from_name(&cfg.task).unwrap(),
        cfg.prompt_len,
        cfg.resp_len,
        1,
    );
    let mut prompt_flat = Vec::with_capacity(b * p);
    for ex in taskgen.batch(0, b) {
        prompt_flat.extend_from_slice(&ex.prompt);
    }
    let toks: Vec<i32> = vec![1; b * s];
    let mask: Vec<f32> = vec![1.0; b * s];

    // --- executable calls ---
    bench(&format!("{model}/prefill"), 2, 10, || {
        engine
            .call(
                "prefill",
                &[
                    HostTensor::F32(params.clone()),
                    HostTensor::I32(prompt_flat.clone()),
                ],
            )
            .unwrap();
    });

    let kv = engine
        .call(
            "prefill",
            &[
                HostTensor::F32(params.clone()),
                HostTensor::I32(prompt_flat.clone()),
            ],
        )
        .unwrap()
        .into_iter()
        .next()
        .unwrap();
    bench(&format!("{model}/decode_step (literal kv)"), 2, 10, || {
        engine
            .call(
                "decode",
                &[
                    HostTensor::F32(params.clone()),
                    kv.clone(),
                    HostTensor::I32(vec![5; b]),
                    scalar_i32(p as i32),
                ],
            )
            .unwrap();
    });

    bench(&format!("{model}/generate (fused round)"), 1, 5, || {
        engine
            .call(
                "generate",
                &[
                    HostTensor::F32(params.clone()),
                    HostTensor::I32(prompt_flat.clone()),
                    scalar_i32(7),
                    scalar_f32(0.7),
                ],
            )
            .unwrap();
    });

    bench(&format!("{model}/score_rm"), 2, 10, || {
        engine
            .call(
                "score_rm",
                &[
                    HostTensor::F32(params.clone()),
                    HostTensor::I32(toks.clone()),
                    HostTensor::F32(mask.clone()),
                ],
            )
            .unwrap();
    });

    bench(&format!("{model}/logprob"), 2, 10, || {
        engine
            .call(
                "logprob",
                &[
                    HostTensor::F32(params.clone()),
                    HostTensor::I32(toks.clone()),
                    HostTensor::F32(mask.clone()),
                ],
            )
            .unwrap();
    });

    let bp = cfg.train_pairs;
    let pair_toks: Vec<i32> = vec![1; bp * s];
    let pair_mask: Vec<f32> = vec![1.0; bp * s];
    let rlp: Vec<f32> = vec![-1.0; bp];
    bench(&format!("{model}/train_dpo (fused)"), 2, 10, || {
        engine
            .call(
                "train_dpo",
                &[
                    HostTensor::F32(params.clone()),
                    HostTensor::F32(vec![0.0; n]),
                    HostTensor::F32(vec![0.0; n]),
                    scalar_f32(1.0),
                    scalar_f32(3e-4),
                    HostTensor::I32(pair_toks.clone()),
                    HostTensor::F32(pair_mask.clone()),
                    HostTensor::I32(pair_toks.clone()),
                    HostTensor::F32(pair_mask.clone()),
                    HostTensor::F32(rlp.clone()),
                    HostTensor::F32(rlp.clone()),
                ],
            )
            .unwrap();
    });

    // --- host-side costs ---
    let logits: Vec<f32> = (0..b * v).map(|i| (i % 17) as f32 * 0.1).collect();
    bench("host/sample_batch_row_loop", 10, 50, || {
        let mut rng = Pcg32::new(7, 7);
        for i in 0..b {
            let row = &logits[i * v..(i + 1) * v];
            let _ = sampler::sample(row, 0.7, false, &mut rng);
        }
    });

    bench("host/param_publish_clone", 10, 50, || {
        let copy = params.clone();
        std::hint::black_box(&copy);
    });

    // per-artifact cumulative stats gathered during this bench
    println!("\ncumulative engine stats:");
    for (name, st) in engine.stats() {
        println!(
            "  {:<22} calls {:>4}  total {:>8.3}s  mean {:>8.4}s",
            name,
            st.calls,
            st.total_secs,
            st.total_secs / st.calls.max(1) as f64
        );
    }
}
