//! Bench: generation-engine speed — paper Fig 14 / Appendix C.1, extended
//! with the device-KV tier and the continuous in-flight batching pool.
//!
//! Five tiers over the same compiled model, per scale: fused (one call per
//! round), device (step-wise, KV chained device-to-device), cached
//! (step-wise, KV round-tripping through PJRT literals — the vLLM-vs-HF
//! middle tier as measured), naive (full recompute, HF analogue), and
//! continuous (slot pool with EOS retirement + mid-flight admission over
//! the same prefill_dev/decode_dev artifacts as the device tier). Besides
//! wall-clock and host↔device traffic (bytes/token from the engine's
//! per-artifact `CallStats`), every tier reports slot-pool efficiency:
//! occupancy (useful tokens per slot-sweep), padding_waste (1 −
//! occupancy: the fraction of slot-steps burned on retired/PAD rows —
//! this is the number continuous batching exists to shrink), p50/p99
//! tokens-to-retire tail latency, and decode-call amplification per
//! sweep (the honesty column for the cohort design: each live cohort
//! costs one decode_dev call per sweep). The device tier must move
//! strictly fewer bytes/token than the literal cached tier, and the
//! continuous tier must match or beat every fixed tier's occupancy.
//! Results are dumped to `BENCH_gen_speed.json` (override with
//! `ASYNC_RLHF_BENCH_OUT`) so the perf trajectory is tracked alongside
//! `BENCH_hot_path.json`. `cargo bench --bench gen_speed`.

use async_rlhf::data::{Task, TaskGen};
use async_rlhf::gen::continuous::{
    AdmitSeq, ContinuousEngine, DeviceBackend, Pool, PoolCfg,
};
use async_rlhf::gen::{
    cached::CachedEngine, device::DeviceCachedEngine, fused::FusedEngine,
    naive::NaiveEngine, Generator, SampleOpts,
};
use async_rlhf::runtime::{Engine, ParamView};
use async_rlhf::util::bench::{artifact_dir_or_skip, bench, pct};
use async_rlhf::util::json::Json;
use async_rlhf::util::rng::Pcg32;

struct TierResult {
    tier: &'static str,
    mean_secs: f64,
    tok_per_sec: f64,
    bytes_up_per_tok: f64,
    bytes_down_per_tok: f64,
    /// Useful tokens per slot-sweep (1.0 = every slot sampled a live
    /// response token on every sweep it was held).
    occupancy: f64,
    /// 1 − occupancy: slot-steps spent sweeping retired or PAD rows.
    padding_waste: f64,
    /// Tokens-to-retire tail latency (sweeps a sequence held its slot).
    p50_retire_steps: f64,
    p99_retire_steps: f64,
    /// Device calls per sampling sweep — the continuous tier pays one
    /// decode_dev per live cohort per sweep; fused amortizes a whole
    /// round into one call.
    decode_calls_per_sweep: f64,
}

/// Per-tier accumulators across the timed iterations.
#[derive(Default)]
struct Acc {
    tokens: u64,
    slot_steps: u64,
    sweeps: u64,
    calls: u64,
    retire: Vec<u64>,
}

impl Acc {
    fn occupancy(&self) -> f64 {
        self.tokens as f64 / self.slot_steps.max(1) as f64
    }

    fn calls_per_sweep(&self) -> f64 {
        self.calls as f64 / self.sweeps.max(1) as f64
    }
}

/// One continuous-pool run: admit a sequential prompt stream into the
/// slot pool until `target_retired` sequences have retired, folding the
/// pool's occupancy/latency accounting into `acc`.
fn run_continuous(
    engine: &Engine,
    pv: ParamView<'_>,
    taskgen: &TaskGen,
    opts: SampleOpts,
    seed: u64,
    target_retired: u64,
    acc: &mut Acc,
) {
    let cfg = &engine.manifest.config;
    let b = cfg.gen_batch;
    let mut backend = DeviceBackend::new(engine).expect("device backend");
    let mut pool = Pool::new(PoolCfg {
        slots: b,
        prompt_len: cfg.prompt_len,
        seq_len: cfg.seq_len,
        vocab: cfg.vocab,
        max_cohorts: 4,
        admit_min: 1,
    });
    let mut admission = taskgen
        .admission(0, b as u64, b as u64, 1)
        .map(|a| AdmitSeq { index: a.index, dup: a.dup, prompt: a.prompt });
    let mut rng = Pcg32::new(seed, 0);
    while pool.stats().retired < target_retired {
        pool.step(&mut backend, pv, 0, &mut admission, opts, &mut rng)
            .expect("pool step");
    }
    for c in pool.drain_completed() {
        acc.retire.push(c.steps as u64);
    }
    let st = pool.stats();
    acc.tokens += st.tokens;
    acc.sweeps += st.sweeps;
    acc.slot_steps += b as u64 * st.sweeps;
    acc.calls += st.decode_calls;
}

fn main() {
    println!(
        "== gen_speed (paper Fig 14): fused/device/cached/naive/continuous =="
    );
    let mut models = Vec::new();
    for model in ["tldr_s", "tldr_m", "tldr_l"] {
        let Some(dir) = artifact_dir_or_skip(model) else {
            continue;
        };
        let engine = Engine::load(&dir).expect("load engine");
        let cfg = engine.manifest.config.clone();
        let params = engine.init_policy().expect("init params");
        let taskgen = TaskGen::new(
            Task::from_name(&cfg.task).unwrap(),
            cfg.prompt_len,
            cfg.resp_len,
            42,
        );
        let prompts: Vec<Vec<i32>> = taskgen
            .batch(0, cfg.gen_batch)
            .iter()
            .map(|e| e.prompt.clone())
            .collect();
        let opts = SampleOpts { temperature: 0.7, greedy: false };

        // one device-cached param set shared by all engines: the measured
        // gap is forward-pass structure + KV transfer, never param upload
        let pv = ParamView::cached("bench_policy", 0, &params);
        let fused_engine = FusedEngine::default();
        let cached_engine = CachedEngine::default();
        let device_engine = DeviceCachedEngine::default();
        let mut tiers: Vec<(&'static str, &dyn Generator)> =
            vec![("fused", &fused_engine), ("cached", &cached_engine)];
        if DeviceCachedEngine::supported(&engine) {
            tiers.insert(1, ("device", &device_engine));
        } else {
            println!(
                "SKIP {model}/device: bundle lacks prefill_dev/decode_dev \
                 (rebuild artifacts)"
            );
        }
        tiers.push(("naive", &NaiveEngine));

        let mut results: Vec<TierResult> = Vec::new();
        for (tier, gen) in tiers {
            // warm the executables + param cache outside the measurement,
            // then account only the timed iterations' traffic
            let mut seed = 0u64;
            let mut rng = Pcg32::new(seed, 0);
            gen.generate(&engine, pv, &prompts, opts, &mut rng).unwrap();
            if tier == "device" && engine.client_untuples() != Some(true) {
                // the warmup round settled the capability: under the
                // root-tuple fallback this tier degrades to per-step
                // round-trips — don't record that as "device" in the
                // tracked perf trajectory
                println!(
                    "SKIP {model}/device: PJRT client returns root tuples"
                );
                continue;
            }
            engine.reset_stats();
            let mut acc = Acc::default();
            let b = cfg.gen_batch as u64;
            let r = bench(&format!("{model}/{tier}"), 0, 5, || {
                seed += 1;
                let mut rng = Pcg32::new(seed, 0);
                let out = gen
                    .generate(&engine, pv, &prompts, opts, &mut rng)
                    .unwrap();
                let steps = out.steps as u64;
                acc.sweeps += steps;
                acc.slot_steps += b * steps;
                // fused folds the whole round into one device call; the
                // step-wise tiers pay one call per sweep
                acc.calls += if tier == "fused" { 1 } else { steps };
                for m in &out.resp_mask {
                    let t = m.iter().filter(|&&x| x == 1.0).count() as u64;
                    acc.tokens += t;
                    // a row retires when its last response token lands;
                    // until then it holds its batch slot
                    acc.retire.push(t);
                }
            });
            let (up, down) = engine.transfer_totals();
            let toks = acc.tokens.max(1) as f64;
            let occ = acc.occupancy();
            results.push(TierResult {
                tier,
                mean_secs: r.mean() as f64,
                tok_per_sec: toks / (r.mean() as f64 * r.iters as f64).max(1e-12),
                bytes_up_per_tok: up as f64 / toks,
                bytes_down_per_tok: down as f64 / toks,
                occupancy: occ,
                padding_waste: 1.0 - occ,
                p50_retire_steps: pct(&mut acc.retire, 0.50),
                p99_retire_steps: pct(&mut acc.retire, 0.99),
                decode_calls_per_sweep: acc.calls_per_sweep(),
            });
        }

        // --- continuous tier: slot pool, EOS retirement, mid-flight
        // admission over the device-KV artifacts ---
        if ContinuousEngine::supported(&engine) {
            let target = 2 * cfg.gen_batch as u64; // two rounds' worth
            let mut warm = Acc::default();
            run_continuous(&engine, pv, &taskgen, opts, 0, target, &mut warm);
            if engine.client_untuples() != Some(true) {
                println!(
                    "SKIP {model}/continuous: PJRT client returns root tuples"
                );
            } else {
                engine.reset_stats();
                let mut acc = Acc::default();
                let mut seed = 0u64;
                let r = bench(&format!("{model}/continuous"), 0, 5, || {
                    seed += 1;
                    run_continuous(
                        &engine, pv, &taskgen, opts, seed, target, &mut acc,
                    );
                });
                let (up, down) = engine.transfer_totals();
                let toks = acc.tokens.max(1) as f64;
                let occ = acc.occupancy();
                results.push(TierResult {
                    tier: "continuous",
                    mean_secs: r.mean() as f64,
                    tok_per_sec: toks
                        / (r.mean() as f64 * r.iters as f64).max(1e-12),
                    bytes_up_per_tok: up as f64 / toks,
                    bytes_down_per_tok: down as f64 / toks,
                    occupancy: occ,
                    padding_waste: 1.0 - occ,
                    p50_retire_steps: pct(&mut acc.retire, 0.50),
                    p99_retire_steps: pct(&mut acc.retire, 0.99),
                    decode_calls_per_sweep: acc.calls_per_sweep(),
                });
            }
        } else {
            println!(
                "SKIP {model}/continuous: bundle lacks \
                 prefill_dev/decode_dev (rebuild artifacts)"
            );
        }

        println!("\n{model} ({} params):", engine.manifest.param_count);
        println!(
            "  {:<10} {:>9}  {:>9}  {:>10}  {:>10}  {:>6}  {:>6}  {:>5}  \
             {:>5}  {:>6}",
            "tier", "mean_s", "tok/s", "B_up/tok", "B_dn/tok", "occup",
            "waste", "p50", "p99", "c/swp"
        );
        for r in &results {
            println!(
                "  {:<10} {:>9.4}  {:>9.0}  {:>10.0}  {:>10.0}  {:>6.3}  \
                 {:>6.3}  {:>5.0}  {:>5.0}  {:>6.2}",
                r.tier,
                r.mean_secs,
                r.tok_per_sec,
                r.bytes_up_per_tok,
                r.bytes_down_per_tok,
                r.occupancy,
                r.padding_waste,
                r.p50_retire_steps,
                r.p99_retire_steps,
                r.decode_calls_per_sweep,
            );
        }
        let by_tier = |t: &str| results.iter().find(|r| r.tier == t);
        if let (Some(dev), Some(cached)) = (by_tier("device"), by_tier("cached"))
        {
            let dev_total = dev.bytes_up_per_tok + dev.bytes_down_per_tok;
            let cached_total =
                cached.bytes_up_per_tok + cached.bytes_down_per_tok;
            println!(
                "  device-KV moves {:.1}% of the literal tier's bytes/token \
                 [{}]",
                100.0 * dev_total / cached_total.max(1e-12),
                if dev_total < cached_total { "OK" } else { "REGRESSION" }
            );
        }
        if let Some(cont) = by_tier("continuous") {
            let fixed_best = results
                .iter()
                .filter(|r| r.tier != "continuous")
                .map(|r| r.occupancy)
                .fold(0.0f64, f64::max);
            println!(
                "  continuous occupancy {:.3} vs best fixed {:.3} [{}]",
                cont.occupancy,
                fixed_best,
                if cont.occupancy >= fixed_best { "OK" } else { "REGRESSION" }
            );
        }
        models.push((model, engine.manifest.param_count, results));
    }

    if models.len() >= 2 {
        let gap = |rs: &[TierResult]| -> Option<f64> {
            let f = rs.iter().find(|r| r.tier == "fused")?;
            let n = rs.iter().find(|r| r.tier == "naive")?;
            Some(n.mean_secs / f.mean_secs)
        };
        if let (Some(first), Some(last)) =
            (gap(&models[0].2), gap(&models[models.len() - 1].2))
        {
            println!(
                "\npaper-shape check (gap grows with scale): \
                 {first:.2}x -> {last:.2}x  [{}]",
                if last > first { "OK" } else { "INVERTED" }
            );
        }
    }

    // --- machine-readable dump for the perf trajectory ---
    let report = Json::obj(vec![(
        "models",
        Json::Obj(
            models
                .iter()
                .map(|(model, params, results)| {
                    (
                        model.to_string(),
                        Json::obj(vec![
                            ("param_count", Json::num(*params as f64)),
                            (
                                "tiers",
                                Json::Obj(
                                    results
                                        .iter()
                                        .map(|r| {
                                            (
                                                r.tier.to_string(),
                                                Json::obj(vec![
                                                    (
                                                        "mean_secs",
                                                        Json::num(r.mean_secs),
                                                    ),
                                                    (
                                                        "tok_per_sec",
                                                        Json::num(r.tok_per_sec),
                                                    ),
                                                    (
                                                        "bytes_up_per_tok",
                                                        Json::num(
                                                            r.bytes_up_per_tok,
                                                        ),
                                                    ),
                                                    (
                                                        "bytes_down_per_tok",
                                                        Json::num(
                                                            r.bytes_down_per_tok,
                                                        ),
                                                    ),
                                                    (
                                                        "occupancy",
                                                        Json::num(r.occupancy),
                                                    ),
                                                    (
                                                        "padding_waste",
                                                        Json::num(
                                                            r.padding_waste,
                                                        ),
                                                    ),
                                                    (
                                                        "p50_retire_steps",
                                                        Json::num(
                                                            r.p50_retire_steps,
                                                        ),
                                                    ),
                                                    (
                                                        "p99_retire_steps",
                                                        Json::num(
                                                            r.p99_retire_steps,
                                                        ),
                                                    ),
                                                    (
                                                        "decode_calls_per_sweep",
                                                        Json::num(
                                                            r.decode_calls_per_sweep,
                                                        ),
                                                    ),
                                                ]),
                                            )
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        ),
    )]);
    let out_path = std::env::var("ASYNC_RLHF_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_gen_speed.json".into());
    std::fs::write(&out_path, report.to_string()).expect("write bench json");
    println!("wrote {out_path}");
}
