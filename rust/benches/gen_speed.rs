//! Bench: generation-engine speed — paper Fig 14 / Appendix C.1.
//!
//! Cached (vLLM analogue) vs naive full-recompute (HF analogue) batch
//! generation time across model scales; the cached/naive gap should grow
//! with model size. `cargo bench --bench gen_speed`.

use async_rlhf::data::{Task, TaskGen};
use async_rlhf::gen::{cached::CachedEngine, fused::FusedEngine, naive::NaiveEngine, Generator, SampleOpts};
use async_rlhf::runtime::{Engine, ParamView};
use async_rlhf::util::bench::{artifact_dir_or_skip, bench};
use async_rlhf::util::rng::Pcg32;

fn main() {
    println!("== gen_speed (paper Fig 14): cached vs naive engines ==");
    let mut rows = Vec::new();
    for model in ["tldr_s", "tldr_m", "tldr_l"] {
        let Some(dir) = artifact_dir_or_skip(model) else {
            continue;
        };
        let engine = Engine::load(&dir).expect("load engine");
        let cfg = engine.manifest.config.clone();
        let params = engine.init_policy().expect("init params");
        let taskgen = TaskGen::new(
            Task::from_name(&cfg.task).unwrap(),
            cfg.prompt_len,
            cfg.resp_len,
            42,
        );
        let prompts: Vec<Vec<i32>> = taskgen
            .batch(0, cfg.gen_batch)
            .iter()
            .map(|e| e.prompt.clone())
            .collect();
        let opts = SampleOpts { temperature: 0.7, greedy: false };

        // one device-cached param set shared by all engines: the measured
        // gap is forward-pass structure, not param upload traffic
        let pv = ParamView::cached("bench_policy", 0, &params);
        let run = |gen: &dyn Generator, label: &str| {
            let mut seed = 0u64;
            bench(&format!("{model}/{label}"), 1, 5, || {
                seed += 1;
                let mut rng = Pcg32::new(seed, 0);
                gen.generate(&engine, pv, &prompts, opts, &mut rng)
                    .unwrap();
            })
        };
        let fused_engine = FusedEngine::default();
        let fused = run(&fused_engine, "fused");
        let cached = run(&CachedEngine, "cached");
        let naive = run(&NaiveEngine, "naive");
        rows.push((
            model,
            engine.manifest.param_count,
            fused.mean(),
            cached.mean(),
            naive.mean(),
        ));
    }
    println!(
        "\nmodel     params      fused_s   cached_s  naive_s   naive/fused"
    );
    for (m, p, f, c, n) in &rows {
        println!(
            "{m:<9} {p:>10}  {f:>8.4}  {c:>8.4}  {n:>8.4}  {:>6.2}x",
            n / f
        );
    }
    if rows.len() >= 2 {
        let first = rows[0].4 / rows[0].2;
        let last = rows[rows.len() - 1].4 / rows[rows.len() - 1].2;
        println!(
            "\npaper-shape check (gap grows with scale): {:.2}x -> {:.2}x  [{}]",
            first,
            last,
            if last > first { "OK" } else { "INVERTED" }
        );
    }
}
