//! Bench: generation-engine speed — paper Fig 14 / Appendix C.1, extended
//! with the device-KV tier.
//!
//! Four tiers over the same compiled model, per scale: fused (one call per
//! round), device (step-wise, KV chained device-to-device), cached
//! (step-wise, KV round-tripping through PJRT literals — the vLLM-vs-HF
//! middle tier as measured), naive (full recompute, HF analogue). Besides
//! wall-clock, each tier's host↔device traffic is taken from the engine's
//! per-artifact `CallStats` and reported as bytes/token — the device tier
//! must move strictly fewer bytes/token than the literal cached tier
//! (that is the point of KV chaining). Results are dumped to
//! `BENCH_gen_speed.json` (override with `ASYNC_RLHF_BENCH_OUT`) so the
//! perf trajectory is tracked alongside `BENCH_hot_path.json`.
//! `cargo bench --bench gen_speed`.

use async_rlhf::data::{Task, TaskGen};
use async_rlhf::gen::{
    cached::CachedEngine, device::DeviceCachedEngine, fused::FusedEngine,
    naive::NaiveEngine, Generator, SampleOpts,
};
use async_rlhf::runtime::{Engine, ParamView};
use async_rlhf::util::bench::{artifact_dir_or_skip, bench};
use async_rlhf::util::json::Json;
use async_rlhf::util::rng::Pcg32;

struct TierResult {
    tier: &'static str,
    mean_secs: f64,
    tok_per_sec: f64,
    bytes_up_per_tok: f64,
    bytes_down_per_tok: f64,
}

fn main() {
    println!("== gen_speed (paper Fig 14): fused/device/cached/naive ==");
    let mut models = Vec::new();
    for model in ["tldr_s", "tldr_m", "tldr_l"] {
        let Some(dir) = artifact_dir_or_skip(model) else {
            continue;
        };
        let engine = Engine::load(&dir).expect("load engine");
        let cfg = engine.manifest.config.clone();
        let params = engine.init_policy().expect("init params");
        let taskgen = TaskGen::new(
            Task::from_name(&cfg.task).unwrap(),
            cfg.prompt_len,
            cfg.resp_len,
            42,
        );
        let prompts: Vec<Vec<i32>> = taskgen
            .batch(0, cfg.gen_batch)
            .iter()
            .map(|e| e.prompt.clone())
            .collect();
        let opts = SampleOpts { temperature: 0.7, greedy: false };

        // one device-cached param set shared by all engines: the measured
        // gap is forward-pass structure + KV transfer, never param upload
        let pv = ParamView::cached("bench_policy", 0, &params);
        let fused_engine = FusedEngine::default();
        let mut tiers: Vec<(&'static str, &dyn Generator)> =
            vec![("fused", &fused_engine), ("cached", &CachedEngine)];
        if DeviceCachedEngine::supported(&engine) {
            tiers.insert(1, ("device", &DeviceCachedEngine));
        } else {
            println!(
                "SKIP {model}/device: bundle lacks prefill_dev/decode_dev \
                 (rebuild artifacts)"
            );
        }
        tiers.push(("naive", &NaiveEngine));

        let mut results: Vec<TierResult> = Vec::new();
        for (tier, gen) in tiers {
            // warm the executables + param cache outside the measurement,
            // then account only the timed iterations' traffic
            let mut seed = 0u64;
            let mut rng = Pcg32::new(seed, 0);
            gen.generate(&engine, pv, &prompts, opts, &mut rng).unwrap();
            if tier == "device" && engine.client_untuples() != Some(true) {
                // the warmup round settled the capability: under the
                // root-tuple fallback this tier degrades to per-step
                // round-trips — don't record that as "device" in the
                // tracked perf trajectory
                println!(
                    "SKIP {model}/device: PJRT client returns root tuples"
                );
                continue;
            }
            engine.reset_stats();
            let mut tokens = 0u64;
            let r = bench(&format!("{model}/{tier}"), 0, 5, || {
                seed += 1;
                let mut rng = Pcg32::new(seed, 0);
                let out = gen
                    .generate(&engine, pv, &prompts, opts, &mut rng)
                    .unwrap();
                tokens += out
                    .resp_mask
                    .iter()
                    .map(|m| m.iter().filter(|&&x| x == 1.0).count() as u64)
                    .sum::<u64>();
            });
            let (up, down) = engine.transfer_totals();
            let toks = tokens.max(1) as f64;
            results.push(TierResult {
                tier,
                mean_secs: r.mean() as f64,
                tok_per_sec: toks / (r.mean() as f64 * r.iters as f64).max(1e-12),
                bytes_up_per_tok: up as f64 / toks,
                bytes_down_per_tok: down as f64 / toks,
            });
        }

        println!("\n{model} ({} params):", engine.manifest.param_count);
        println!(
            "  {:<8} {:>9}  {:>10}  {:>12}  {:>12}",
            "tier", "mean_s", "tok/s", "B_up/tok", "B_down/tok"
        );
        for r in &results {
            println!(
                "  {:<8} {:>9.4}  {:>10.0}  {:>12.0}  {:>12.0}",
                r.tier,
                r.mean_secs,
                r.tok_per_sec,
                r.bytes_up_per_tok,
                r.bytes_down_per_tok
            );
        }
        let by_tier = |t: &str| results.iter().find(|r| r.tier == t);
        if let (Some(dev), Some(cached)) = (by_tier("device"), by_tier("cached"))
        {
            let dev_total = dev.bytes_up_per_tok + dev.bytes_down_per_tok;
            let cached_total =
                cached.bytes_up_per_tok + cached.bytes_down_per_tok;
            println!(
                "  device-KV moves {:.1}% of the literal tier's bytes/token \
                 [{}]",
                100.0 * dev_total / cached_total.max(1e-12),
                if dev_total < cached_total { "OK" } else { "REGRESSION" }
            );
        }
        models.push((model, engine.manifest.param_count, results));
    }

    if models.len() >= 2 {
        let gap = |rs: &[TierResult]| -> Option<f64> {
            let f = rs.iter().find(|r| r.tier == "fused")?;
            let n = rs.iter().find(|r| r.tier == "naive")?;
            Some(n.mean_secs / f.mean_secs)
        };
        if let (Some(first), Some(last)) =
            (gap(&models[0].2), gap(&models[models.len() - 1].2))
        {
            println!(
                "\npaper-shape check (gap grows with scale): \
                 {first:.2}x -> {last:.2}x  [{}]",
                if last > first { "OK" } else { "INVERTED" }
            );
        }
    }

    // --- machine-readable dump for the perf trajectory ---
    let report = Json::obj(vec![(
        "models",
        Json::Obj(
            models
                .iter()
                .map(|(model, params, results)| {
                    (
                        model.to_string(),
                        Json::obj(vec![
                            ("param_count", Json::num(*params as f64)),
                            (
                                "tiers",
                                Json::Obj(
                                    results
                                        .iter()
                                        .map(|r| {
                                            (
                                                r.tier.to_string(),
                                                Json::obj(vec![
                                                    (
                                                        "mean_secs",
                                                        Json::num(r.mean_secs),
                                                    ),
                                                    (
                                                        "tok_per_sec",
                                                        Json::num(r.tok_per_sec),
                                                    ),
                                                    (
                                                        "bytes_up_per_tok",
                                                        Json::num(
                                                            r.bytes_up_per_tok,
                                                        ),
                                                    ),
                                                    (
                                                        "bytes_down_per_tok",
                                                        Json::num(
                                                            r.bytes_down_per_tok,
                                                        ),
                                                    ),
                                                ]),
                                            )
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        ),
    )]);
    let out_path = std::env::var("ASYNC_RLHF_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_gen_speed.json".into());
    std::fs::write(&out_path, report.to_string()).expect("write bench json");
    println!("wrote {out_path}");
}
