//! Generation engines (paper §2.3 / Fig 14 substitution, DESIGN.md §3).
//!
//! Four engines over the *same* compiled model, forming a three-tier
//! decode-cost ladder plus the fully-fused production path:
//!
//! - [`naive::NaiveEngine`] — the HuggingFace-transformers analogue: the
//!   full padded sequence is re-forwarded for every new token. Per-token
//!   cost is O(S^2) — the quadratic recompute that makes training-library
//!   generation infeasible at scale (paper Fig 14, bottom tier).
//! - [`cached::CachedEngine`] — the vLLM analogue: one prefill over the
//!   prompt, then incremental single-token decode against a KV cache,
//!   with early exit once every row has terminated. Per-token *compute*
//!   is O(S), but the cache round-trips host↔device through PJRT
//!   literals every step — deliberately so: this is the Fig-14 middle
//!   tier being measured.
//! - [`device::DeviceCachedEngine`] — the same step-wise loop with the KV
//!   cache chained device-to-device through the untupled
//!   `prefill_dev`/`decode_dev` twins: per step only the sampled tokens
//!   go up and the logits come down, the cache never touches the host.
//! - [`fused::FusedEngine`] — the production hot path: the whole sampling
//!   loop fused into ONE `generate` executable (KV cache inside the XLA
//!   while-loop), one PJRT call per round (EXPERIMENTS.md §Perf).
//!
//! The naive, cached, and device-cached engines walk the same host RNG
//! stream — and the `*_dev` twins alias the same HLO as their tupled
//! namesakes — so with equal seeds all three emit *bitwise-identical*
//! sequences and behaviour logprobs (integration-tested invariants). The
//! fused engine samples on-device (threefry); its correctness anchor is
//! the blp-vs-logprob invariant shared by all engines.
//!
//! Engine selection is a runtime knob (`--gen-engine`,
//! [`crate::config::GenEngine`]); `benches/gen_speed.rs` tracks the
//! tokens/sec and bytes/token of every tier in `BENCH_gen_speed.json`.

pub mod cached;
pub mod continuous;
pub mod device;
pub mod fused;
pub mod naive;
pub mod sampler;

use anyhow::Result;

use crate::runtime::{DeviceBuffer, Engine, ParamView};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

/// A generation round's output tensors still resident on the producing
/// engine's device: flattened `[B*S]` tokens, response mask and behaviour
/// logprobs, exactly the fused `generate` executable's three outputs.
/// Cloning shares the underlying PJRT buffers (cheap `Rc` bump).
///
/// Device buffers belong to the engine that created them, so these are
/// only useful to same-thread/same-engine consumers: the sync trainer
/// chains them into its round staging (zero token uploads per round);
/// async rounds cross the worker→trainer thread boundary as plain host
/// data instead.
pub struct GenBuffers {
    pub tokens: DeviceBuffer,
    pub resp_mask: DeviceBuffer,
    pub blp: DeviceBuffer,
}

/// One generation round over the fixed gen_batch.
#[derive(Debug, Clone)]
pub struct GenBatch {
    /// Full sequences [B][S]: prompt ++ sampled response (incl. EOS) ++ PAD.
    pub tokens: Vec<Vec<i32>>,
    /// 1.0 exactly on response positions incl. EOS.
    pub resp_mask: Vec<Vec<f32>>,
    /// Behaviour token logprobs under the generating params, aligned with
    /// `tokens` (0 outside the response).
    pub blp: Vec<Vec<f32>>,
    /// Whether each row terminated with EOS within resp_len.
    pub terminated: Vec<bool>,
    /// Decode steps actually executed (< resp_len with early exit).
    pub steps: usize,
}

impl GenBatch {
    /// Flatten tokens and response mask into row-major `[B*S]` buffers
    /// (cleared first) — the layout every executable input consumes. The
    /// single definition keeps the staging, labelling, assembly and eval
    /// flattenings from drifting apart.
    pub fn flatten_into(&self, toks: &mut Vec<i32>, mask: &mut Vec<f32>) {
        toks.clear();
        mask.clear();
        let n: usize = self.tokens.iter().map(Vec::len).sum();
        toks.reserve(n);
        mask.reserve(n);
        for (t, m) in self.tokens.iter().zip(&self.resp_mask) {
            toks.extend_from_slice(t);
            mask.extend_from_slice(m);
        }
    }

    /// Response tokens of row `i` (everything after the prompt, incl. EOS,
    /// excl. PAD).
    pub fn response(&self, i: usize, prompt_len: usize) -> &[i32] {
        let toks = &self.tokens[i];
        let end = self.resp_mask[i]
            .iter()
            .rposition(|&m| m == 1.0)
            .map(|p| p + 1)
            .unwrap_or(prompt_len);
        &toks[prompt_len..end]
    }
}

/// Flatten fixed-length token rows into the row-major scratch buffer
/// (cleared first) — the one definition of the `[B, L]` flattening every
/// step-wise engine feeds `prefill`/`forward_full`. Callers hold the
/// scratch (typically a `RefCell<Vec<i32>>` on the engine) so repeated
/// rounds reuse one allocation.
pub fn flatten_prompts(rows: &[Vec<i32>], len: usize, scratch: &mut Vec<i32>) {
    scratch.clear();
    scratch.reserve(rows.len() * len);
    for row in rows {
        assert_eq!(row.len(), len, "rows must be fixed-length ({len})");
        scratch.extend_from_slice(&row[..len]);
    }
}

/// Sampling parameters for one generation round.
#[derive(Debug, Clone, Copy)]
pub struct SampleOpts {
    pub temperature: f32,
    pub greedy: bool,
}

impl Default for SampleOpts {
    fn default() -> Self {
        SampleOpts { temperature: 0.7, greedy: false }
    }
}

pub trait Generator {
    fn name(&self) -> &'static str;

    /// Generate responses for exactly `gen_batch` prompts using `params`
    /// (host, device-cached by version, or already resident — see
    /// [`ParamView`]). Cached views upload the params once per version,
    /// not once per PJRT call.
    fn generate(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<GenBatch>;

    /// Like [`Generator::generate`], additionally returning the round's
    /// output tensors as device-resident [`GenBuffers`] when the engine
    /// produced them on the buffer path (the fused engine on an untupling
    /// client). Same host result either way — the buffers are a bonus the
    /// sync trainer chains into its round staging. Engines whose outputs
    /// are host-assembled (the step-wise tiers) keep this default and
    /// return `None`.
    fn generate_staged(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<(GenBatch, Option<GenBuffers>)> {
        Ok((self.generate(engine, params, prompts, opts, rng)?, None))
    }
}

/// Shared decode-loop state machine: token bookkeeping, EOS termination,
/// mask/blp recording. Engines feed it one logits matrix per step.
pub(crate) struct DecodeState {
    pub tokens: Vec<Vec<i32>>,
    pub resp_mask: Vec<Vec<f32>>,
    pub blp: Vec<Vec<f32>>,
    pub done: Vec<bool>,
}

impl DecodeState {
    pub fn new(prompts: &[Vec<i32>], prompt_len: usize, seq_len: usize) -> Self {
        let b = prompts.len();
        let mut tokens = Vec::with_capacity(b);
        for p in prompts {
            assert_eq!(p.len(), prompt_len, "prompts must be fixed-length");
            let mut row = p.clone();
            row.resize(seq_len, tk::PAD);
            tokens.push(row);
        }
        DecodeState {
            tokens,
            resp_mask: vec![vec![0.0; seq_len]; b],
            blp: vec![vec![0.0; seq_len]; b],
            done: vec![false; b],
        }
    }

    /// Consume logits for position `pos` (i.e. logits predicting the token
    /// AT `pos`), sample one token per row, record mask/blp/termination.
    /// Returns the sampled tokens (PAD for finished rows).
    pub fn step(
        &mut self,
        pos: usize,
        logits: &[f32],
        vocab: usize,
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Vec<i32> {
        let b = self.tokens.len();
        debug_assert_eq!(logits.len(), b * vocab);
        let mut sampled = vec![tk::PAD; b];
        for i in 0..b {
            // one rng draw per row per step, even when finished, so every
            // engine walks the stream identically (see module docs) — but
            // finished rows advance the stream without paying the O(V)
            // softmax whose result they would discard
            if self.done[i] {
                sampler::skip_draw(rng);
                continue;
            }
            let row = &logits[i * vocab..(i + 1) * vocab];
            let (tok, lp) = sampler::sample(row, opts.temperature, opts.greedy, rng);
            let tok = tok as i32;
            self.tokens[i][pos] = tok;
            self.resp_mask[i][pos] = 1.0;
            self.blp[i][pos] = lp;
            sampled[i] = tok;
            if tok == tk::EOS {
                self.done[i] = true;
            }
        }
        sampled
    }

    pub fn all_done(&self) -> bool {
        self.done.iter().all(|&d| d)
    }

    pub fn finish(self, steps: usize) -> GenBatch {
        GenBatch {
            terminated: self.done.clone(),
            tokens: self.tokens,
            resp_mask: self.resp_mask,
            blp: self.blp,
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_state_records_response() {
        let prompts = vec![vec![tk::BOS, 30], vec![tk::BOS, 31]];
        let mut st = DecodeState::new(&prompts, 2, 6);
        let vocab = 64;
        let mut rng = Pcg32::new(0, 0);
        // force tokens: row0 -> 40, row1 -> EOS
        let mut logits = vec![0.0f32; 2 * vocab];
        logits[40] = 50.0;
        logits[vocab + tk::EOS as usize] = 50.0;
        let toks =
            st.step(2, &logits, vocab, SampleOpts { temperature: 0.7, greedy: true }, &mut rng);
        assert_eq!(toks, vec![40, tk::EOS]);
        assert!(st.done[1] && !st.done[0]);
        assert_eq!(st.resp_mask[1][2], 1.0);
        // next step: row1 is finished, stays PAD
        let toks = st.step(3, &logits, vocab, SampleOpts { temperature: 0.7, greedy: true }, &mut rng);
        assert_eq!(toks[1], tk::PAD);
        assert_eq!(st.tokens[1][3], tk::PAD);
        assert_eq!(st.resp_mask[1][3], 0.0);
    }

    #[test]
    fn done_row_rng_skip_leaves_stream_walk_unchanged() {
        // The retired-row fast path (skip_draw instead of a full sample)
        // must leave the RNG stream — and therefore every subsequently
        // emitted token — bitwise identical to the old walk that ran the
        // O(V) softmax on done rows and discarded it.
        let vocab = 64;
        let opts = SampleOpts { temperature: 0.7, greedy: false };
        let prompts = vec![vec![tk::BOS, 30], vec![tk::BOS, 31]];
        let mut st = DecodeState::new(&prompts, 2, 8);
        let mut rng = Pcg32::new(99, 7);
        // reference walk: sample every row by hand (the pre-skip behaviour)
        let mut ref_rng = Pcg32::new(99, 7);
        let mut logits = vec![0.0f32; 2 * vocab];
        logits[vocab + tk::EOS as usize] = 50.0; // row1 terminates at once
        for pos in 2..8 {
            let toks = st.step(pos, &logits, vocab, opts, &mut rng);
            let mut ref_toks = Vec::new();
            for i in 0..2 {
                let row = &logits[i * vocab..(i + 1) * vocab];
                let (tok, _) =
                    sampler::sample(row, opts.temperature, opts.greedy, &mut ref_rng);
                ref_toks.push(tok as i32);
            }
            // live rows must emit exactly what the reference walk samples
            if !st.done[0] || toks[0] != tk::PAD {
                assert_eq!(toks[0], ref_toks[0], "row0 diverged at pos {pos}");
            }
        }
        // ... and the two streams must end at the same state
        assert_eq!(rng.next_u64(), ref_rng.next_u64());
    }

    #[test]
    fn flatten_prompts_is_row_major_and_reuses_scratch() {
        let rows = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let mut scratch = vec![9; 100];
        flatten_prompts(&rows, 3, &mut scratch);
        assert_eq!(scratch, vec![1, 2, 3, 4, 5, 6]);
        // scratch is cleared, not appended
        flatten_prompts(&rows, 3, &mut scratch);
        assert_eq!(scratch.len(), 6);
    }

    #[test]
    #[should_panic(expected = "fixed-length")]
    fn flatten_prompts_rejects_ragged_rows() {
        let rows = vec![vec![1, 2, 3], vec![4, 5]];
        flatten_prompts(&rows, 3, &mut Vec::new());
    }

    #[test]
    fn genbatch_response_slicing() {
        let gb = GenBatch {
            tokens: vec![vec![1, 30, 40, 41, tk::EOS, 0]],
            resp_mask: vec![vec![0., 0., 1., 1., 1., 0.]],
            blp: vec![vec![0.0; 6]],
            terminated: vec![true],
            steps: 3,
        };
        assert_eq!(gb.response(0, 2), &[40, 41, tk::EOS]);
    }
}
