//! Fused on-device generation engine — the production hot path.
//!
//! The entire sampling loop (prefill + per-token decode + categorical
//! sampling + EOS freezing + behaviour-logprob recording) is compiled into
//! ONE `generate` executable; the KV cache lives inside the XLA while-loop
//! and never touches the host. One PJRT call per round, versus resp_len
//! calls (each round-tripping the multi-MB cache) for the step-wise
//! [`super::cached::CachedEngine`]. Before/after numbers: EXPERIMENTS.md
//! §Perf.
//!
//! The `generate` artifact is untupled, so the call runs on the buffer
//! path: params come from the engine's device cache (uploaded only on
//! version bumps) and only the three sampled outputs are downloaded. On
//! an untupling client [`Generator::generate_staged`] additionally hands
//! those three outputs back as device-resident [`GenBuffers`], which the
//! sync trainer chains into its round staging — the round's tokens then
//! never re-upload (the bytes *down* are identical on both paths; the
//! host always needs the sampled round).
//!
//! Sampling happens in XLA (threefry), seeded per round from the caller's
//! PRNG — runs remain deterministic per seed, but token streams differ
//! from the host-sampled engines (which are mutually identical); the
//! correctness anchor is the blp-vs-logprob invariant, tested for all
//! engines.

use std::cell::RefCell;

use anyhow::Result;

use super::{flatten_prompts, GenBatch, GenBuffers, Generator, SampleOpts};
use crate::runtime::{CallArg, Engine, ParamView};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

#[derive(Default)]
pub struct FusedEngine {
    /// Flattened-prompt scratch, reused across rounds: one allocation per
    /// engine instead of one per call.
    scratch: RefCell<Vec<i32>>,
}

/// Reassemble the executable's three flattened outputs into a [`GenBatch`]
/// (row split, EOS-termination scan) — shared by both transport paths so
/// they cannot drift.
fn batch_from_flat(
    toks_flat: Vec<i32>,
    mask_flat: Vec<f32>,
    blp_flat: Vec<f32>,
    s: usize,
    p: usize,
) -> GenBatch {
    let tokens: Vec<Vec<i32>> =
        toks_flat.chunks_exact(s).map(<[i32]>::to_vec).collect();
    let resp_mask: Vec<Vec<f32>> =
        mask_flat.chunks_exact(s).map(<[f32]>::to_vec).collect();
    let blp: Vec<Vec<f32>> =
        blp_flat.chunks_exact(s).map(<[f32]>::to_vec).collect();
    let terminated: Vec<bool> = tokens
        .iter()
        .zip(&resp_mask)
        .map(|(t, m)| {
            t.iter()
                .zip(m)
                .any(|(&tok, &mm)| tok == tk::EOS && mm == 1.0)
        })
        .collect();
    GenBatch {
        tokens,
        resp_mask,
        blp,
        terminated,
        steps: s - p, // fixed-length loop: no early exit on device
    }
}

impl FusedEngine {
    /// One fused round. `want_buffers` additionally keeps the outputs
    /// device-resident (untupling clients only — before the capability is
    /// known, and under the root-tuple fallback, `call_with` is the
    /// cheaper transport and the one that settles the capability).
    fn run(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
        want_buffers: bool,
    ) -> Result<(GenBatch, Option<GenBuffers>)> {
        let cfg = &engine.manifest.config;
        let (b, p, s) = (cfg.gen_batch, cfg.prompt_len, cfg.seq_len);
        assert_eq!(prompts.len(), b, "gen_batch is fixed at {b}");
        // temperature <= 0 selects greedy argmax inside the executable
        let temp = if opts.greedy { -1.0 } else { opts.temperature };
        let seed = (rng.next_u32() >> 1) as i32; // non-negative seed
        let mut prompt_flat = self.scratch.borrow_mut();
        flatten_prompts(prompts, p, &mut prompt_flat);
        let args = [
            CallArg::Param(params),
            CallArg::I32(&prompt_flat),
            CallArg::ScalarI32(seed),
            CallArg::ScalarF32(temp),
        ];
        if want_buffers && engine.buffer_path_ready("generate") {
            let outs = engine.execute_buffers("generate", &args)?;
            // the host needs the whole round regardless (gold scoring,
            // pair selection, metrics): bytes down match call_with
            let toks_flat = engine.download(&outs[0])?.into_i32()?;
            let mask_flat = engine.download(&outs[1])?.into_f32()?;
            let blp_flat = engine.download(&outs[2])?.into_f32()?;
            let gen = batch_from_flat(toks_flat, mask_flat, blp_flat, s, p);
            let mut it = outs.into_iter();
            let buffers = GenBuffers {
                tokens: it.next().unwrap(),
                resp_mask: it.next().unwrap(),
                blp: it.next().unwrap(),
            };
            Ok((gen, Some(buffers)))
        } else {
            let out = engine.call_with("generate", &args)?;
            let mut it = out.into_iter();
            let toks_flat = it.next().unwrap().into_i32()?;
            let mask_flat = it.next().unwrap().into_f32()?;
            let blp_flat = it.next().unwrap().into_f32()?;
            Ok((batch_from_flat(toks_flat, mask_flat, blp_flat, s, p), None))
        }
    }
}

impl Generator for FusedEngine {
    fn name(&self) -> &'static str {
        "fused"
    }

    fn generate(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<GenBatch> {
        self.run(engine, params, prompts, opts, rng, false)
            .map(|(gen, _)| gen)
    }

    fn generate_staged(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<(GenBatch, Option<GenBuffers>)> {
        self.run(engine, params, prompts, opts, rng, true)
    }
}
