//! Fused on-device generation engine — the production hot path.
//!
//! The entire sampling loop (prefill + per-token decode + categorical
//! sampling + EOS freezing + behaviour-logprob recording) is compiled into
//! ONE `generate` executable; the KV cache lives inside the XLA while-loop
//! and never touches the host. One PJRT call per round, versus resp_len
//! calls (each round-tripping the multi-MB cache) for the step-wise
//! [`super::cached::CachedEngine`]. Before/after numbers: EXPERIMENTS.md
//! §Perf.
//!
//! Sampling happens in XLA (threefry), seeded per round from the caller's
//! PRNG — runs remain deterministic per seed, but token streams differ
//! from the host-sampled engines (which are mutually identical); the
//! correctness anchor is the blp-vs-logprob invariant, tested for all
//! engines.

use anyhow::Result;

use super::{GenBatch, Generator, SampleOpts};
use crate::runtime::{scalar_f32, scalar_i32, Engine, HostTensor};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

#[derive(Default)]
pub struct FusedEngine;

impl Generator for FusedEngine {
    fn name(&self) -> &'static str {
        "fused"
    }

    fn generate(
        &self,
        engine: &Engine,
        params: &[f32],
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<GenBatch> {
        let cfg = &engine.manifest.config;
        let (b, p, s) = (cfg.gen_batch, cfg.prompt_len, cfg.seq_len);
        assert_eq!(prompts.len(), b, "gen_batch is fixed at {b}");
        let mut prompt_flat = Vec::with_capacity(b * p);
        for row in prompts {
            assert_eq!(row.len(), p, "prompts must be fixed-length");
            prompt_flat.extend_from_slice(&row[..p]);
        }
        // temperature <= 0 selects greedy argmax inside the executable
        let temp = if opts.greedy { -1.0 } else { opts.temperature };
        let seed = (rng.next_u32() >> 1) as i32; // non-negative seed
        let out = engine.call(
            "generate",
            &[
                HostTensor::F32(params.to_vec()),
                HostTensor::I32(prompt_flat),
                scalar_i32(seed),
                scalar_f32(temp),
            ],
        )?;
        let mut it = out.into_iter();
        let toks_flat = it.next().unwrap().into_i32()?;
        let mask_flat = it.next().unwrap().into_f32()?;
        let blp_flat = it.next().unwrap().into_f32()?;

        let mut tokens = Vec::with_capacity(b);
        let mut resp_mask = Vec::with_capacity(b);
        let mut blp = Vec::with_capacity(b);
        let mut terminated = Vec::with_capacity(b);
        for i in 0..b {
            let t = toks_flat[i * s..(i + 1) * s].to_vec();
            let m = mask_flat[i * s..(i + 1) * s].to_vec();
            terminated.push(
                t.iter()
                    .zip(&m)
                    .any(|(&tok, &mm)| tok == tk::EOS && mm == 1.0),
            );
            tokens.push(t);
            resp_mask.push(m);
            blp.push(blp_flat[i * s..(i + 1) * s].to_vec());
        }
        Ok(GenBatch {
            tokens,
            resp_mask,
            blp,
            terminated,
            steps: s - p, // fixed-length loop: no early exit on device
        })
    }
}
