//! KV-cache incremental-decode engine (step-wise).
//!
//! One `prefill` call builds the cache for all fixed-length prompts; each
//! subsequent `decode` call advances every row by one token with the host
//! sampling in between. Early exit once all rows have terminated.
//!
//! This engine is the middle tier of the Fig-14 comparison: linear decode
//! (vs the naive engine's quadratic recompute) but it pays a host<->device
//! round-trip of the KV cache per token through the PJRT literal API —
//! deliberately left on the host-literal path. The params, though, come
//! from the device cache: a cached [`ParamView`] uploads once per round
//! (first call), not once per token. The top tier,
//! [`super::fused::FusedEngine`], moves the whole loop on-device
//! (EXPERIMENTS.md §Perf).

use std::cell::RefCell;

use anyhow::Result;

use super::{flatten_prompts, DecodeState, GenBatch, Generator, SampleOpts};
use crate::runtime::{CallArg, Engine, ParamView};
use crate::util::rng::Pcg32;

#[derive(Default)]
pub struct CachedEngine {
    /// Flattened-prompt scratch, reused across rounds (one allocation per
    /// engine — the same shape as the fused engine's).
    scratch: RefCell<Vec<i32>>,
}

impl Generator for CachedEngine {
    fn name(&self) -> &'static str {
        "cached"
    }

    fn generate(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<GenBatch> {
        let cfg = &engine.manifest.config;
        let (b, p, s, v) = (cfg.gen_batch, cfg.prompt_len, cfg.seq_len, cfg.vocab);
        assert_eq!(prompts.len(), b, "gen_batch is fixed at {b}");

        let mut st = DecodeState::new(prompts, p, s);

        // prefill: prompt -> kv cache + logits for position p
        let mut prompt_flat = self.scratch.borrow_mut();
        flatten_prompts(prompts, p, &mut prompt_flat);
        let out = engine.call_with(
            "prefill",
            &[CallArg::Param(params), CallArg::I32(&prompt_flat)],
        )?;
        drop(prompt_flat);
        let mut it = out.into_iter();
        let mut kv = it.next().unwrap();
        let mut logits = it.next().unwrap().into_f32()?;

        let mut steps = 0;
        for pos in p..s {
            steps += 1;
            let sampled = st.step(pos, &logits, v, opts, rng);
            if st.all_done() || pos + 1 == s {
                break;
            }
            // decode: token at `pos` -> logits for pos+1, updated cache
            let out = engine.call_with(
                "decode",
                &[
                    CallArg::Param(params),
                    CallArg::from(&kv),
                    CallArg::I32(&sampled),
                    CallArg::ScalarI32(pos as i32),
                ],
            )?;
            let mut it = out.into_iter();
            logits = it.next().unwrap().into_f32()?;
            kv = it.next().unwrap();
        }
        Ok(st.finish(steps))
    }
}
