//! Full-recompute generation engine — the HF-transformers analogue.
//!
//! Every new token re-forwards the entire padded sequence through
//! `forward_full` and slices the logits at the current position: O(S) work
//! per token -> O(S^2) per response, versus the cached engine's O(S).
//! This is the baseline whose gap to the cached engine reproduces paper
//! Fig 14 / Appendix C.1 (vLLM is 12-20x faster than transformers, and the
//! gap grows superlinearly with model size). Params still come from the
//! device cache (cached [`ParamView`]s upload once per round, not once
//! per token) so the measured gap is forward-pass cost, not param I/O.

use anyhow::Result;

use super::{flatten_prompts, DecodeState, GenBatch, Generator, SampleOpts};
use crate::runtime::{CallArg, Engine, ParamView};
use crate::util::rng::Pcg32;

#[derive(Default)]
pub struct NaiveEngine;

impl Generator for NaiveEngine {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn generate(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<GenBatch> {
        let cfg = &engine.manifest.config;
        let (b, p, s, v) = (cfg.gen_batch, cfg.prompt_len, cfg.seq_len, cfg.vocab);
        assert_eq!(prompts.len(), b, "gen_batch is fixed at {b}");

        let mut st = DecodeState::new(prompts, p, s);
        let mut steps = 0;
        let mut toks_flat = Vec::with_capacity(b * s);
        let mut logits = Vec::with_capacity(b * v);
        for pos in p..s {
            steps += 1;
            // recompute the whole sequence to get logits at pos-1 (which
            // predict the token at pos) — the training-library way
            flatten_prompts(&st.tokens, s, &mut toks_flat);
            let out = engine.call_with(
                "forward_full",
                &[CallArg::Param(params), CallArg::I32(&toks_flat)],
            )?;
            let logits_all = out.into_iter().next().unwrap().into_f32()?;
            // slice [B, S, V] at position pos-1
            logits.clear();
            for i in 0..b {
                let base = i * s * v + (pos - 1) * v;
                logits.extend_from_slice(&logits_all[base..base + v]);
            }
            st.step(pos, &logits, v, opts, rng);
            if st.all_done() {
                break;
            }
        }
        Ok(st.finish(steps))
    }
}
