//! Token sampling from host logits.
//!
//! The sampling distribution is temperature-scaled softmax (paper Tables
//! 4/7: temperature 0.7); the recorded *behaviour logprob* is the
//! untempered log-softmax at the sampled token — i.e. log pi_theta(y|x) of
//! the generating parameters, matching what the `logprob` executable
//! computes, so on-policy IS ratios are exactly 1 (a tested invariant).

use crate::util::rng::Pcg32;

/// Numerically-stable log-softmax value at index `idx`.
pub fn log_softmax_at(logits: &[f32], idx: usize) -> f32 {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
    logits[idx] - lse
}

/// Sample one token. Returns (token, untempered logprob of that token).
/// `greedy` ignores temperature and takes the argmax (used by pass@1 eval).
///
/// Always consumes exactly one uniform draw from `rng`, so different
/// engines walking the same rng stream produce identical sequences.
pub fn sample(
    logits: &[f32],
    temperature: f32,
    greedy: bool,
    rng: &mut Pcg32,
) -> (usize, f32) {
    let u = rng.gen_f64(); // consumed unconditionally (see docstring)
    let tok = if greedy {
        argmax(logits)
    } else {
        sample_temp(logits, temperature, u)
    };
    (tok, log_softmax_at(logits, tok))
}

/// Advance `rng` by exactly the draws [`sample`] consumes (one uniform)
/// WITHOUT touching any logits — the O(1) stand-in for rows whose sample
/// would be discarded anyway (retired slots). Engines that walk a shared
/// stream stay bitwise-aligned as long as every row consumes one call to
/// either function per step.
pub fn skip_draw(rng: &mut Pcg32) {
    let _ = rng.gen_f64();
}

pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best
}

fn sample_temp(logits: &[f32], temperature: f32, u: f64) -> usize {
    let t = temperature.max(1e-4);
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - m) / t) as f64).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= total;
    }
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i;
        }
    }
    probs.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_takes_argmax() {
        let mut rng = Pcg32::new(0, 0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let (tok, lp) = sample(&logits, 0.7, true, &mut rng);
        assert_eq!(tok, 1);
        assert!(lp < 0.0);
    }

    #[test]
    fn logprob_is_untempered() {
        let logits = vec![1.0, 2.0, 3.0];
        let lp = log_softmax_at(&logits, 2);
        let expect = 3.0
            - ((1.0f32).exp() + (2.0f32).exp() + (3.0f32).exp()).ln();
        assert!((lp - expect).abs() < 1e-5);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Pcg32::new(1, 0);
        let logits = vec![0.0, 1.0, 0.5];
        let n = 1000;
        let hits = (0..n)
            .filter(|_| sample(&logits, 0.05, false, &mut rng).0 == 1)
            .count();
        assert!(hits > n * 95 / 100, "hits={hits}");
    }

    #[test]
    fn high_temperature_spreads() {
        let mut rng = Pcg32::new(2, 0);
        let logits = vec![0.0, 1.0, 0.5];
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[sample(&logits, 10.0, false, &mut rng).0] += 1;
        }
        assert!(counts.iter().all(|&c| c > 500), "{counts:?}");
    }

    #[test]
    fn sampling_frequencies_match_distribution() {
        let mut rng = Pcg32::new(3, 0);
        let logits = vec![0.0f32, (2.0f32).ln()]; // probs 1/3, 2/3 at t=1
        let n = 30_000;
        let ones = (0..n)
            .filter(|_| sample(&logits, 1.0, false, &mut rng).0 == 1)
            .count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 2.0 / 3.0).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn rng_consumption_is_constant() {
        // greedy and sampled paths consume the same number of draws
        let mut a = Pcg32::new(9, 0);
        let mut b = Pcg32::new(9, 0);
        let logits = vec![0.0, 1.0];
        sample(&logits, 0.7, true, &mut a);
        sample(&logits, 0.7, false, &mut b);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn skip_draw_walks_stream_like_sample() {
        // skip_draw must consume exactly what sample consumes, so a
        // stream interleaving skips (retired rows) with real samples is
        // indistinguishable from one that sampled every row
        let mut a = Pcg32::new(17, 3);
        let mut b = Pcg32::new(17, 3);
        let logits = vec![0.3, -1.0, 2.2, 0.0];
        for i in 0..32 {
            if i % 3 == 0 {
                skip_draw(&mut a);
                sample(&logits, 0.7, false, &mut b);
            } else {
                let (ta, _) = sample(&logits, 0.7, false, &mut a);
                let (tb, _) = sample(&logits, 0.7, false, &mut b);
                assert_eq!(ta, tb, "streams diverged at step {i}");
            }
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
