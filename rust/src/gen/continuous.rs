//! Continuous in-flight batching — slot-based decode with EOS retirement
//! and mid-flight prompt admission (PipelineRL's schedule over this
//! crate's artifacts).
//!
//! Every other tier decodes fixed `[B, S]` rounds: a row that emits EOS at
//! token 5 still rides the loop until the slowest row finishes, and a
//! round only hands off when its last member does. Here the `[B]`-wide KV
//! cache is a **slot pool**: a row that terminates retires immediately
//! into a completion queue and its slot is re-admitted with a fresh prompt
//! mid-flight, so the pool's occupancy (useful tokens per slot-step) stays
//! near 1 instead of decaying along the round's tail.
//!
//! ## Cohorts: exact decoding under one scalar `pos`
//!
//! The compiled `decode_step` takes a single scalar position: it writes
//! k/v at `pos` for ALL rows and attends with the shared mask
//! `pos_ids <= pos`, and the model's positions are learned absolute
//! embeddings — so rows at different decode frontiers cannot share one
//! call, and an admitted prompt cannot be re-based at the pool's current
//! position without changing its distribution. Instead of new Python-side
//! artifacts, admission is **cohort-based**: every admission batch is
//! prefilled in its own `prefill_dev` call and owns its own device-resident
//! KV cache; per pool sweep, each live cohort advances with one
//! `decode_dev` call at its own frontier. Rows outside a cohort are fed
//! PAD in that cohort's call — their rows of that cache are dead weight
//! the cohort never samples from. The number of concurrently live cohorts
//! (= extra decode calls and cache copies per sweep) is capped by
//! [`PoolCfg::max_cohorts`]; admission waits when the cap is reached.
//! With admission disabled (one cohort at full occupancy) the pool is
//! call-for-call the [`super::device::DeviceCachedEngine`] loop and emits
//! bitwise-identical sequences at equal seeds (integration-tested).
//!
//! ## RNG discipline and per-token versions
//!
//! Every sweep draws exactly one uniform per slot in row order — a live
//! row samples from its cohort's logits, a free row advances the stream
//! with [`sampler::skip_draw`] — the same walk the fixed tiers take over
//! done rows. Each sampled token is stamped with the policy version that
//! produced its logits, so when the streaming caller swaps freshly
//! published weights in *between* decode steps (PipelineRL's second
//! half), the recorded per-token `blp` is a true behaviour logprob under
//! version mixing and [`Completed`] carries min/max/mean version for the
//! trainer's per-token staleness accounting.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use super::{sampler, GenBatch, Generator, SampleOpts};
use crate::runtime::{CallArg, DeviceBuffer, Engine, ParamView};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

/// Geometry and admission policy of one slot pool.
#[derive(Debug, Clone, Copy)]
pub struct PoolCfg {
    /// Pool width B (the artifact's fixed gen_batch).
    pub slots: usize,
    pub prompt_len: usize,
    pub seq_len: usize,
    pub vocab: usize,
    /// Concurrently live cohorts allowed (>= 1). Each live cohort costs
    /// one `decode_dev` call per sweep and one KV-cache copy on device;
    /// 1 disables mid-flight admission in everything but name (fresh
    /// prompts only enter once the whole pool has drained).
    pub max_cohorts: usize,
    /// Admit only once at least this many slots are free (>= 1): batches
    /// admissions so a cohort's prefill is amortized over more rows.
    pub admit_min: usize,
}

/// One admission request: duplicate `dup` of prompt-stream index `index`.
#[derive(Debug, Clone)]
pub struct AdmitSeq {
    pub index: u64,
    pub dup: usize,
    /// Fixed-length prompt (`prompt_len` tokens).
    pub prompt: Vec<i32>,
}

/// One retired sequence, in the same canonical `[S]` layout as a
/// [`GenBatch`] row: prompt ++ response (incl. EOS) ++ PAD.
#[derive(Debug, Clone)]
pub struct Completed {
    pub index: u64,
    pub dup: usize,
    pub tokens: Vec<i32>,
    pub resp_mask: Vec<f32>,
    pub blp: Vec<f32>,
    /// Whether the row ended with EOS (vs running out of positions).
    pub terminated: bool,
    /// Sweeps this sequence held its slot == response tokens emitted —
    /// the tokens-to-retire tail-latency sample.
    pub steps: usize,
    /// Oldest / newest policy version any of its tokens sampled under.
    pub version_min: u64,
    pub version_max: u64,
    /// Sum of per-token versions (response-token-weighted means).
    pub version_sum: f64,
}

/// Occupancy / call accounting for one pool's lifetime.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Sampling sweeps executed (the fixed tiers' `steps` equivalent).
    pub sweeps: u64,
    /// `decode_dev` calls — `sweeps` × live cohorts; the cohort-cap cost.
    pub decode_calls: u64,
    /// `prefill_dev` calls — one per admitted cohort.
    pub prefill_calls: u64,
    /// Response tokens emitted (incl. EOS).
    pub tokens: u64,
    pub admitted: u64,
    pub retired: u64,
}

impl PoolStats {
    /// Useful-token fraction of the slot-steps spent: `tokens / (B ×
    /// sweeps)`. The fixed tiers' occupancy decays along each round's
    /// tail (retired rows keep sweeping); the pool re-admits instead.
    pub fn occupancy(&self, slots: usize) -> f64 {
        let denom = (slots as u64 * self.sweeps).max(1) as f64;
        self.tokens as f64 / denom
    }
}

/// The decode transport a [`Pool`] drives: prefill an admission batch into
/// a fresh cohort cache, advance one cohort by one position, drop a
/// drained cohort's cache. Split out so the slot lifecycle (admission,
/// retirement, RNG bookkeeping) is testable without PJRT artifacts.
pub trait DecodeBackend {
    /// Prefill a full `[B, P]` prompt matrix (rows outside the admitted
    /// set are PAD filler) into a new cohort cache; returns the cache id
    /// and the `[B, V]` logits predicting position P.
    fn prefill(
        &mut self,
        params: ParamView<'_>,
        prompt_flat: &[i32],
    ) -> Result<(usize, Vec<f32>)>;

    /// One decode step for cohort cache `cache` at position `pos` with
    /// per-row input tokens `toks` (PAD outside the cohort); returns the
    /// `[B, V]` logits predicting `pos + 1`.
    fn decode(
        &mut self,
        params: ParamView<'_>,
        cache: usize,
        toks: &[i32],
        pos: usize,
    ) -> Result<Vec<f32>>;

    /// The cohort drained; its cache may be freed.
    fn retire_cache(&mut self, cache: usize);
}

/// [`DecodeBackend`] over the `prefill_dev`/`decode_dev` buffer-path
/// twins: each cohort's KV cache is a [`DeviceBuffer`] chained
/// device-to-device across its decode steps, exactly the
/// [`super::device::DeviceCachedEngine`] transport. On a root-tuple PJRT
/// client `execute_buffers` itself degrades to host round-trips (warned
/// once by the engine) — slower, still byte-for-byte correct.
pub struct DeviceBackend<'e> {
    engine: &'e Engine,
    caches: Vec<Option<DeviceBuffer>>,
}

impl<'e> DeviceBackend<'e> {
    pub fn new(engine: &'e Engine) -> Result<DeviceBackend<'e>> {
        if !ContinuousEngine::supported(engine) {
            bail!(
                "artifact bundle '{}' lacks prefill_dev/decode_dev — rebuild \
                 artifacts (python -m compile.aot --force) to use the \
                 continuous engine",
                engine.config_name()
            );
        }
        Ok(DeviceBackend { engine, caches: Vec::new() })
    }
}

impl DecodeBackend for DeviceBackend<'_> {
    fn prefill(
        &mut self,
        params: ParamView<'_>,
        prompt_flat: &[i32],
    ) -> Result<(usize, Vec<f32>)> {
        let mut out = self.engine.execute_buffers(
            "prefill_dev",
            &[CallArg::Param(params), CallArg::I32(prompt_flat)],
        )?;
        let logits = self.engine.download(&out[1])?.into_f32()?;
        let kv = out.swap_remove(0);
        let id = match self.caches.iter().position(Option::is_none) {
            Some(free) => {
                self.caches[free] = Some(kv);
                free
            }
            None => {
                self.caches.push(Some(kv));
                self.caches.len() - 1
            }
        };
        Ok((id, logits))
    }

    fn decode(
        &mut self,
        params: ParamView<'_>,
        cache: usize,
        toks: &[i32],
        pos: usize,
    ) -> Result<Vec<f32>> {
        let kv = self.caches[cache].as_ref().expect("live cohort cache");
        let mut out = self.engine.execute_buffers(
            "decode_dev",
            &[
                CallArg::Param(params),
                CallArg::Device(kv),
                CallArg::I32(toks),
                CallArg::ScalarI32(pos as i32),
            ],
        )?;
        let logits = self.engine.download(&out[0])?.into_f32()?;
        self.caches[cache] = Some(out.swap_remove(1));
        Ok(logits)
    }

    fn retire_cache(&mut self, cache: usize) {
        self.caches[cache] = None;
    }
}

/// In-flight state of one slot.
struct SeqState {
    index: u64,
    dup: usize,
    tokens: Vec<i32>,
    resp_mask: Vec<f32>,
    blp: Vec<f32>,
    cohort: u64,
    steps: usize,
    version_min: u64,
    version_max: u64,
    version_sum: f64,
}

/// One admission batch sharing a decode frontier and a KV cache.
struct Cohort {
    id: u64,
    cache: usize,
    /// Position the current `logits` predict.
    pos: usize,
    logits: Vec<f32>,
    /// Policy version that produced `logits` — the stamp for tokens
    /// sampled from them (NOT necessarily the pool's current version:
    /// weights may have swapped since the call).
    logits_version: u64,
    live: usize,
    /// Per-sweep decode input being assembled: this sweep's sampled token
    /// for the cohort's rows (including a row that retired ON this sweep,
    /// whose final EOS still feeds the call — the fixed tiers do the
    /// same), PAD elsewhere.
    pending: Vec<i32>,
}

/// The slot pool: B slots, up to `max_cohorts` live cohorts, a completion
/// queue. Drive it with [`Pool::step`]; each call is one sweep —
/// sample/retire, advance every live cohort by one decode step, then
/// admit into freed slots.
pub struct Pool {
    cfg: PoolCfg,
    slots: Vec<Option<SeqState>>,
    cohorts: Vec<Cohort>,
    next_cohort: u64,
    completed: Vec<Completed>,
    stats: PoolStats,
    prompt_scratch: Vec<i32>,
}

impl Pool {
    pub fn new(cfg: PoolCfg) -> Pool {
        assert!(cfg.slots >= 1, "pool needs at least one slot");
        assert!(
            cfg.prompt_len < cfg.seq_len,
            "no response positions (prompt_len >= seq_len)"
        );
        assert!(cfg.max_cohorts >= 1 && cfg.admit_min >= 1);
        Pool {
            slots: (0..cfg.slots).map(|_| None).collect(),
            cohorts: Vec::new(),
            next_cohort: 0,
            completed: Vec::new(),
            stats: PoolStats::default(),
            prompt_scratch: Vec::new(),
            cfg,
        }
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Live (in-flight) sequences.
    pub fn in_flight(&self) -> usize {
        self.cohorts.iter().map(|c| c.live).sum()
    }

    /// Response tokens held by the in-flight sequences — the decode work
    /// that dies with the engine-local KV if this pool is abandoned
    /// (supervision's `inflight_tokens_abandoned` accounting).
    pub fn inflight_tokens(&self) -> u64 {
        self.slots.iter().flatten().map(|s| s.steps as u64).sum()
    }

    /// Nothing in flight — only admission can make the next step do work.
    pub fn is_drained(&self) -> bool {
        self.cohorts.is_empty()
    }

    /// Take all retired sequences accumulated since the last drain.
    pub fn drain_completed(&mut self) -> Vec<Completed> {
        std::mem::take(&mut self.completed)
    }

    /// One pool sweep: sample every live slot at its cohort's frontier
    /// (one RNG draw per slot, free slots skip-draw), retire EOS /
    /// end-of-sequence rows, advance surviving cohorts by one decode
    /// step, then admit fresh prompts from `admission` into freed slots
    /// (subject to the cohort cap and admission watermark). `params` /
    /// `version` are re-read every call, so the streaming caller swaps a
    /// newly published policy in between decode steps by simply passing
    /// the fresh view.
    pub fn step(
        &mut self,
        backend: &mut dyn DecodeBackend,
        params: ParamView<'_>,
        version: u64,
        admission: &mut dyn Iterator<Item = AdmitSeq>,
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<()> {
        let (b, p, s, v) = (
            self.cfg.slots,
            self.cfg.prompt_len,
            self.cfg.seq_len,
            self.cfg.vocab,
        );

        // --- sampling sweep (skipped while nothing is in flight: the
        // very first step admits before any logits exist) ---
        if !self.cohorts.is_empty() {
            self.stats.sweeps += 1;
            for c in &mut self.cohorts {
                c.pending.fill(tk::PAD);
            }
            for i in 0..b {
                let Some(seq) = self.slots[i].as_mut() else {
                    // free slots keep the stream walk identical to the
                    // fixed tiers' done rows: one draw, no softmax
                    sampler::skip_draw(rng);
                    continue;
                };
                let c = self
                    .cohorts
                    .iter_mut()
                    .find(|c| c.id == seq.cohort)
                    .expect("live row's cohort");
                let pos = c.pos;
                let row = &c.logits[i * v..(i + 1) * v];
                let (tok, lp) =
                    sampler::sample(row, opts.temperature, opts.greedy, rng);
                let tok = tok as i32;
                seq.tokens[pos] = tok;
                seq.resp_mask[pos] = 1.0;
                seq.blp[pos] = lp;
                seq.steps += 1;
                let ver = c.logits_version;
                seq.version_min = seq.version_min.min(ver);
                seq.version_max = seq.version_max.max(ver);
                seq.version_sum += ver as f64;
                c.pending[i] = tok;
                self.stats.tokens += 1;
                if tok == tk::EOS || pos + 1 == s {
                    c.live -= 1;
                    let seq = self.slots[i].take().expect("retiring live row");
                    self.completed.push(Completed {
                        index: seq.index,
                        dup: seq.dup,
                        tokens: seq.tokens,
                        resp_mask: seq.resp_mask,
                        blp: seq.blp,
                        terminated: tok == tk::EOS,
                        steps: seq.steps,
                        version_min: seq.version_min,
                        version_max: seq.version_max,
                        version_sum: seq.version_sum,
                    });
                    self.stats.retired += 1;
                }
            }
        }

        // --- drop drained cohorts (their caches free immediately) ---
        let backend_ref = &mut *backend;
        self.cohorts.retain(|c| {
            if c.live == 0 {
                backend_ref.retire_cache(c.cache);
                false
            } else {
                true
            }
        });

        // --- advance every surviving cohort by one decode step ---
        for c in &mut self.cohorts {
            debug_assert!(
                c.pos + 1 < s,
                "rows at the last position must have retired in the sweep"
            );
            c.logits = backend.decode(params, c.cache, &c.pending, c.pos)?;
            c.pos += 1;
            c.logits_version = version;
            self.stats.decode_calls += 1;
        }

        // --- admission into freed slots ---
        if self.cohorts.len() < self.cfg.max_cohorts {
            let free: Vec<usize> =
                (0..b).filter(|&i| self.slots[i].is_none()).collect();
            if free.len() >= self.cfg.admit_min {
                let mut admitted: Vec<(usize, AdmitSeq)> =
                    Vec::with_capacity(free.len());
                for &slot in &free {
                    match admission.next() {
                        Some(a) => admitted.push((slot, a)),
                        None => break,
                    }
                }
                if !admitted.is_empty() {
                    self.prompt_scratch.clear();
                    self.prompt_scratch.resize(b * p, tk::PAD);
                    for (slot, a) in &admitted {
                        assert_eq!(
                            a.prompt.len(),
                            p,
                            "prompts must be fixed-length"
                        );
                        self.prompt_scratch[slot * p..(slot + 1) * p]
                            .copy_from_slice(&a.prompt);
                    }
                    let (cache, logits) =
                        backend.prefill(params, &self.prompt_scratch)?;
                    self.stats.prefill_calls += 1;
                    let id = self.next_cohort;
                    self.next_cohort += 1;
                    let live = admitted.len();
                    for (slot, a) in admitted {
                        let mut tokens = a.prompt;
                        tokens.resize(s, tk::PAD);
                        self.slots[slot] = Some(SeqState {
                            index: a.index,
                            dup: a.dup,
                            tokens,
                            resp_mask: vec![0.0; s],
                            blp: vec![0.0; s],
                            cohort: id,
                            steps: 0,
                            version_min: u64::MAX,
                            version_max: 0,
                            version_sum: 0.0,
                        });
                        self.stats.admitted += 1;
                    }
                    self.cohorts.push(Cohort {
                        id,
                        cache,
                        pos: p,
                        logits,
                        logits_version: version,
                        live,
                        pending: vec![tk::PAD; b],
                    });
                }
            }
        }
        Ok(())
    }
}

/// Groups retired sequences back into trainer rounds: a round needs
/// `gen_batch / k` distinct prompts with all `k` completions each.
/// Completions arrive in retirement order (a prompt's duplicates can
/// retire sweeps apart, interleaved with other prompts); groups become
/// ready when their k-th member lands and rounds are emitted in group
/// readiness order, duplicates sorted back into admission (`dup`) order.
pub struct RoundAssembler {
    k: usize,
    n_prompts: usize,
    pending: Vec<(u64, Vec<Completed>)>,
    ready: VecDeque<(u64, Vec<Completed>)>,
}

impl RoundAssembler {
    pub fn new(gen_batch: usize, k: usize) -> RoundAssembler {
        assert!(
            k >= 1 && gen_batch % k == 0,
            "gen_batch must be divisible by k"
        );
        RoundAssembler {
            k,
            n_prompts: gen_batch / k,
            pending: Vec::new(),
            ready: VecDeque::new(),
        }
    }

    pub fn push(&mut self, c: Completed) {
        let pos = match self.pending.iter().position(|(i, _)| *i == c.index) {
            Some(pos) => pos,
            None => {
                self.pending.push((c.index, Vec::with_capacity(self.k)));
                self.pending.len() - 1
            }
        };
        self.pending[pos].1.push(c);
        assert!(
            self.pending[pos].1.len() <= self.k,
            "more than k completions for one prompt (admission bug)"
        );
        if self.pending[pos].1.len() == self.k {
            let (index, mut group) = self.pending.remove(pos);
            group.sort_by_key(|c| c.dup);
            self.ready.push_back((index, group));
        }
    }

    /// `gen_batch / k` ready groups — one round — if available.
    pub fn pop_round(&mut self) -> Option<Vec<(u64, Vec<Completed>)>> {
        if self.ready.len() < self.n_prompts {
            return None;
        }
        Some(self.ready.drain(..self.n_prompts).collect())
    }

    /// Completions buffered but not yet part of an emitted round.
    pub fn buffered(&self) -> usize {
        self.pending.iter().map(|(_, g)| g.len()).sum::<usize>()
            + self.ready.iter().map(|(_, g)| g.len()).sum::<usize>()
    }
}

/// The round-mode face of the pool: a [`Generator`] that fills all B
/// slots once (one cohort, admission disabled thereafter) and drains —
/// call-for-call the `device` tier's loop, bitwise-equal at equal seeds.
/// The streaming face (mid-flight admission + between-step policy swaps)
/// is driven directly through [`Pool::step`] by the async worker pool.
#[derive(Default)]
pub struct ContinuousEngine;

impl ContinuousEngine {
    /// Same artifact requirement as the device tier: the buffer-path
    /// `prefill_dev`/`decode_dev` twins.
    pub fn supported(engine: &Engine) -> bool {
        engine.manifest.has_artifact("prefill_dev")
            && engine.manifest.has_artifact("decode_dev")
    }
}

impl Generator for ContinuousEngine {
    fn name(&self) -> &'static str {
        "continuous"
    }

    fn generate(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<GenBatch> {
        let cfg = &engine.manifest.config;
        let (b, p, s, v) =
            (cfg.gen_batch, cfg.prompt_len, cfg.seq_len, cfg.vocab);
        assert_eq!(prompts.len(), b, "gen_batch is fixed at {b}");
        let mut backend = DeviceBackend::new(engine)?;
        let mut pool = Pool::new(PoolCfg {
            slots: b,
            prompt_len: p,
            seq_len: s,
            vocab: v,
            // one cohort at full occupancy: the device-tier equivalence
            // configuration (admission runs dry after the initial fill)
            max_cohorts: 1,
            admit_min: b,
        });
        let mut admission =
            prompts.iter().cloned().enumerate().map(|(i, prompt)| AdmitSeq {
                index: i as u64,
                dup: 0,
                prompt,
            });
        while pool.stats().retired < b as u64 {
            pool.step(&mut backend, params, 0, &mut admission, opts, rng)?;
        }
        let mut tokens = vec![Vec::new(); b];
        let mut resp_mask = vec![Vec::new(); b];
        let mut blp = vec![Vec::new(); b];
        let mut terminated = vec![false; b];
        for c in pool.drain_completed() {
            let i = c.index as usize;
            tokens[i] = c.tokens;
            resp_mask[i] = c.resp_mask;
            blp[i] = c.blp;
            terminated[i] = c.terminated;
        }
        debug_assert!(tokens.iter().all(|t| t.len() == s), "row unfilled");
        Ok(GenBatch {
            tokens,
            resp_mask,
            blp,
            terminated,
            steps: pool.stats().sweeps as usize,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const B: usize = 4;
    const P: usize = 2;
    const S: usize = 8;
    const V: usize = 16;

    /// Scripted backend: no PJRT, logits force token `script(row, pos)`
    /// at every position (consumed with greedy sampling for exactness).
    struct Scripted<F: FnMut(usize, usize) -> i32> {
        script: F,
        next_cache: usize,
        live_caches: usize,
        max_live_caches: usize,
        prefills: usize,
        decodes: usize,
    }

    impl<F: FnMut(usize, usize) -> i32> Scripted<F> {
        fn new(script: F) -> Self {
            Scripted {
                script,
                next_cache: 0,
                live_caches: 0,
                max_live_caches: 0,
                prefills: 0,
                decodes: 0,
            }
        }

        fn logits_for(&mut self, pos: usize) -> Vec<f32> {
            let mut l = vec![0.0f32; B * V];
            for row in 0..B {
                let tok = (self.script)(row, pos);
                l[row * V + tok as usize] = 80.0;
            }
            l
        }
    }

    impl<F: FnMut(usize, usize) -> i32> DecodeBackend for Scripted<F> {
        fn prefill(
            &mut self,
            _params: ParamView<'_>,
            prompt_flat: &[i32],
        ) -> Result<(usize, Vec<f32>)> {
            assert_eq!(prompt_flat.len(), B * P);
            self.prefills += 1;
            self.live_caches += 1;
            self.max_live_caches = self.max_live_caches.max(self.live_caches);
            let id = self.next_cache;
            self.next_cache += 1;
            Ok((id, self.logits_for(P)))
        }

        fn decode(
            &mut self,
            _params: ParamView<'_>,
            _cache: usize,
            toks: &[i32],
            pos: usize,
        ) -> Result<Vec<f32>> {
            assert_eq!(toks.len(), B);
            self.decodes += 1;
            Ok(self.logits_for(pos + 1))
        }

        fn retire_cache(&mut self, _cache: usize) {
            self.live_caches -= 1;
        }
    }

    fn cfg(max_cohorts: usize, admit_min: usize) -> PoolCfg {
        PoolCfg {
            slots: B,
            prompt_len: P,
            seq_len: S,
            vocab: V,
            max_cohorts,
            admit_min,
        }
    }

    fn admit_stream(n: usize) -> impl Iterator<Item = AdmitSeq> {
        (0..n).map(|i| AdmitSeq {
            index: i as u64,
            dup: 0,
            prompt: vec![tk::BOS, 30 + i as i32],
        })
    }

    const GREEDY: SampleOpts = SampleOpts { temperature: 0.7, greedy: true };

    /// Drive until `n` sequences retire (panics if the pool stalls).
    fn run_until<F: FnMut(usize, usize) -> i32>(
        pool: &mut Pool,
        backend: &mut Scripted<F>,
        admission: &mut dyn Iterator<Item = AdmitSeq>,
        n: u64,
    ) -> Vec<Completed> {
        let mut rng = Pcg32::new(7, 0);
        let mut out = Vec::new();
        for _ in 0..10_000 {
            pool.step(backend, ParamView::fresh(&[]), 0, admission, GREEDY, &mut rng)
                .unwrap();
            out.extend(pool.drain_completed());
            if pool.stats().retired >= n {
                return out;
            }
        }
        panic!("pool stalled: {} of {n} retired", pool.stats().retired);
    }

    #[test]
    fn continuous_eos_on_first_decode_step_retires_immediately() {
        // row 0 terminates on its very first sample; its slot frees while
        // the rest of the cohort keeps decoding
        let mut backend = Scripted::new(|row, pos| {
            if row == 0 && pos == P {
                tk::EOS
            } else {
                7
            }
        });
        let mut pool = Pool::new(cfg(1, 1));
        let mut admission = admit_stream(B);
        // step 1: admission only; step 2: first sweep retires row 0
        let mut rng = Pcg32::new(7, 0);
        pool.step(&mut backend, ParamView::fresh(&[]), 0, &mut admission, GREEDY, &mut rng)
            .unwrap();
        assert_eq!(pool.in_flight(), B);
        assert_eq!(pool.stats().sweeps, 0, "admission step sweeps nothing");
        pool.step(&mut backend, ParamView::fresh(&[]), 0, &mut admission, GREEDY, &mut rng)
            .unwrap();
        let done = pool.drain_completed();
        assert_eq!(done.len(), 1);
        let c = &done[0];
        assert_eq!(c.index, 0);
        assert!(c.terminated);
        assert_eq!(c.steps, 1, "EOS on the first decode step");
        assert_eq!(c.tokens[P], tk::EOS);
        assert_eq!(c.resp_mask[P], 1.0);
        assert_eq!(&c.resp_mask[P + 1..], &[0.0; S - P - 1][..]);
        assert_eq!(pool.in_flight(), B - 1);
    }

    #[test]
    fn continuous_eos_on_last_position_terminates_others_truncate() {
        // row 1 emits EOS exactly at position S-1; every other row runs
        // out of positions there and retires unterminated
        let mut backend = Scripted::new(|row, pos| {
            if row == 1 && pos == S - 1 {
                tk::EOS
            } else {
                7
            }
        });
        let mut pool = Pool::new(cfg(1, B));
        let mut admission = admit_stream(B);
        let done = run_until(&mut pool, &mut backend, &mut admission, B as u64);
        assert_eq!(done.len(), B);
        for c in &done {
            assert_eq!(c.steps, S - P, "all rows held to the last position");
            assert_eq!(c.terminated, c.index == 1, "only row 1 saw EOS");
            assert_eq!(c.tokens[S - 1], if c.index == 1 { tk::EOS } else { 7 });
            assert_eq!(c.resp_mask[S - 1], 1.0);
        }
        // the terminal sweep retired everyone: no decode happened for it
        assert_eq!(pool.stats().sweeps as usize, S - P);
        assert_eq!(pool.stats().decode_calls as usize, S - P - 1);
    }

    #[test]
    fn continuous_all_slots_retiring_in_same_sweep_drains_pool() {
        let mut backend = Scripted::new(|_, pos| {
            if pos == P + 2 {
                tk::EOS
            } else {
                7
            }
        });
        let mut pool = Pool::new(cfg(1, B));
        let mut admission = admit_stream(B);
        let done = run_until(&mut pool, &mut backend, &mut admission, B as u64);
        assert_eq!(done.len(), B);
        assert!(done.iter().all(|c| c.terminated && c.steps == 3));
        assert!(pool.is_drained(), "cohort must drop with its last row");
        assert_eq!(backend.live_caches, 0, "drained cohort's cache freed");
    }

    #[test]
    fn continuous_admission_refills_freed_slots_without_drops_or_dups() {
        // responses of wildly mixed lengths; 3 pools' worth of prompts
        // stream through B slots — every admitted index retires exactly
        // once and carries its own prompt
        let n = 3 * B;
        let mut backend = Scripted::new(|row, pos| {
            // row-dependent EOS: lengths 1, 3, 5, 2 (mod slot)
            let len = [1usize, 3, 5, 2][row % 4];
            if pos >= P + len - 1 {
                tk::EOS
            } else {
                7
            }
        });
        let mut pool = Pool::new(cfg(4, 1));
        let mut admission = admit_stream(n);
        let done = run_until(&mut pool, &mut backend, &mut admission, n as u64);
        let mut seen: Vec<u64> = done.iter().map(|c| c.index).collect();
        seen.sort_unstable();
        assert_eq!(
            seen,
            (0..n as u64).collect::<Vec<_>>(),
            "each admitted prompt retires exactly once"
        );
        for c in &done {
            assert_eq!(
                c.tokens[..P],
                [tk::BOS, 30 + c.index as i32],
                "slot reuse must not leak another sequence's prompt"
            );
            assert!(c.terminated);
        }
        // mid-flight admission actually happened: more cohorts than the
        // one initial fill, and at some point several were live at once
        assert!(pool.stats().prefill_calls > 1, "no mid-flight admission");
        assert!(backend.max_live_caches > 1, "cohorts never overlapped");
        // occupancy: every sweep fed at least one live row
        assert!(pool.stats().tokens >= pool.stats().sweeps);
    }

    #[test]
    fn continuous_max_cohorts_caps_live_caches_and_admission_waits() {
        let n = 4 * B;
        let mut backend = Scripted::new(|row, pos| {
            let len = [1usize, 6, 4, 2][row % 4];
            if pos >= P + len - 1 {
                tk::EOS
            } else {
                7
            }
        });
        let mut pool = Pool::new(cfg(2, 1));
        let mut admission = admit_stream(n);
        let done = run_until(&mut pool, &mut backend, &mut admission, n as u64);
        assert_eq!(done.len(), n);
        assert!(
            backend.max_live_caches <= 2,
            "cohort cap exceeded: {} caches live",
            backend.max_live_caches
        );
        // the decode-call amplification is bounded by the cap
        assert!(pool.stats().decode_calls <= 2 * pool.stats().sweeps);
    }

    #[test]
    fn continuous_admit_min_batches_admissions() {
        // with admit_min = B, freed slots wait until the whole pool has
        // drained — so every cohort is a full-width prefill
        let n = 2 * B;
        let mut backend = Scripted::new(|row, pos| {
            let len = [1usize, 2, 3, 4][row % 4];
            if pos >= P + len - 1 {
                tk::EOS
            } else {
                7
            }
        });
        let mut pool = Pool::new(cfg(4, B));
        let mut admission = admit_stream(n);
        let done = run_until(&mut pool, &mut backend, &mut admission, n as u64);
        assert_eq!(done.len(), n);
        assert_eq!(pool.stats().prefill_calls, 2, "one full cohort per fill");
        assert_eq!(backend.max_live_caches, 1);
    }

    #[test]
    fn continuous_rng_walks_one_draw_per_slot_per_sweep() {
        // the pool's stream walk must be exactly sweeps × B draws —
        // bitwise the fixed tiers' discipline — regardless of retirement
        // and admission churn
        let n = 2 * B;
        let mut backend = Scripted::new(|row, pos| {
            let len = [1usize, 3, 2, 4][row % 4];
            if pos >= P + len - 1 {
                tk::EOS
            } else {
                7
            }
        });
        let mut pool = Pool::new(cfg(2, 1));
        let mut admission = admit_stream(n);
        let mut rng = Pcg32::new(123, 9);
        let mut steps_taken = 0u64;
        while pool.stats().retired < n as u64 {
            pool.step(
                &mut backend,
                ParamView::fresh(&[]),
                0,
                &mut admission,
                GREEDY,
                &mut rng,
            )
            .unwrap();
            steps_taken += 1;
            assert!(steps_taken < 1000, "stalled");
        }
        let mut ref_rng = Pcg32::new(123, 9);
        for _ in 0..pool.stats().sweeps * B as u64 {
            sampler::skip_draw(&mut ref_rng);
        }
        assert_eq!(rng.next_u64(), ref_rng.next_u64());
    }

    #[test]
    fn continuous_version_stamps_follow_logits_provenance() {
        // bump the version between steps: tokens sampled from logits
        // computed under version v must stamp v, not the pool's current
        // version — the stamp is the behaviour policy of that token
        let mut backend = Scripted::new(|_, _| 7);
        let mut pool = Pool::new(cfg(1, B));
        let mut admission = admit_stream(B);
        let mut rng = Pcg32::new(5, 5);
        // admit under version 0, then advance under increasing versions
        let mut version = 0u64;
        while pool.stats().retired < B as u64 {
            pool.step(
                &mut backend,
                ParamView::fresh(&[]),
                version,
                &mut admission,
                GREEDY,
                &mut rng,
            )
            .unwrap();
            version += 1;
        }
        let done = pool.drain_completed();
        for c in &done {
            // first token's logits came from the version-0 prefill; the
            // last from the freshest decode — a true min/max spread
            assert_eq!(c.version_min, 0);
            assert_eq!(c.version_max, (S - P - 1) as u64);
            assert_eq!(c.steps, S - P);
            let expect_sum: f64 = (0..(S - P) as u64).map(|x| x as f64).sum();
            assert!((c.version_sum - expect_sum).abs() < 1e-9);
        }
    }

    #[test]
    fn continuous_round_assembler_groups_k_completions_in_dup_order() {
        let mk = |index: u64, dup: usize| Completed {
            index,
            dup,
            tokens: vec![0; S],
            resp_mask: vec![0.0; S],
            blp: vec![0.0; S],
            terminated: true,
            steps: 1,
            version_min: 0,
            version_max: 0,
            version_sum: 0.0,
        };
        // gen_batch 4, k 2 → rounds of 2 prompt groups
        let mut asm = RoundAssembler::new(4, 2);
        // retirement order interleaves prompts and flips dup order
        asm.push(mk(10, 1));
        asm.push(mk(11, 0));
        asm.push(mk(12, 0));
        assert!(asm.pop_round().is_none(), "no group complete yet");
        asm.push(mk(12, 1)); // group 12 completes FIRST
        asm.push(mk(10, 0)); // then group 10
        let round = asm.pop_round().expect("two groups ready");
        let indices: Vec<u64> = round.iter().map(|(index, _)| *index).collect();
        assert_eq!(indices, vec![12, 10], "groups emit in readiness order");
        for (_, group) in &round {
            assert_eq!(group.len(), 2);
            assert!(group[0].dup < group[1].dup, "dups sorted back in order");
        }
        // group 11 still waits for its sibling
        assert_eq!(asm.buffered(), 1);
        assert!(asm.pop_round().is_none());
    }
}
