//! Device-resident KV-cache incremental-decode engine — the third tier of
//! the step-wise generation ladder.
//!
//! Same decode loop as [`super::cached::CachedEngine`] (one `prefill` over
//! the prompts, then one single-token `decode` per position with the host
//! sampling in between), but executed through the buffer-path twins
//! `prefill_dev`/`decode_dev`: the KV cache comes back as a
//! [`DeviceBuffer`] and is chained straight into the next decode call as a
//! `CallArg::Device` input. Per step, the host↔device traffic is one
//! `[B]` token upload + one scalar + one `[B, V]` logits download — the
//! multi-MB cache never touches the host (on untupling PJRT clients; a
//! fallback client degrades to per-step round-trips with a one-shot
//! warning from the engine, still byte-for-byte correct).
//!
//! Because the twins alias the *same HLO file* as the tupled artifacts
//! (aot.py re-registers the lowering under `untupled=true`), the logits
//! are bitwise-identical to the literal engine's, and both engines walk
//! the same host RNG stream — so with equal seeds the emitted
//! sequences/masks/blp are exactly equal (integration-tested). The
//! literal `CachedEngine` stays selectable as the Fig-14 middle-tier
//! baseline; this engine is what production would run when the
//! measurement no longer needs the literal round-trip.

use std::cell::RefCell;

use anyhow::{bail, Result};

use super::{flatten_prompts, DecodeState, GenBatch, Generator, SampleOpts};
use crate::runtime::{CallArg, DeviceBuffer, Engine, ParamView};
use crate::util::rng::Pcg32;

#[derive(Default)]
pub struct DeviceCachedEngine {
    /// Flattened-prompt scratch, reused across rounds (one allocation per
    /// engine — the same shape as the fused engine's).
    scratch: RefCell<Vec<i32>>,
}

impl DeviceCachedEngine {
    /// Whether `engine`'s bundle ships the buffer-path twins this engine
    /// needs (older artifact directories predate them).
    pub fn supported(engine: &Engine) -> bool {
        engine.manifest.has_artifact("prefill_dev")
            && engine.manifest.has_artifact("decode_dev")
    }
}

impl Generator for DeviceCachedEngine {
    fn name(&self) -> &'static str {
        "device"
    }

    fn generate(
        &self,
        engine: &Engine,
        params: ParamView<'_>,
        prompts: &[Vec<i32>],
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<GenBatch> {
        if !Self::supported(engine) {
            bail!(
                "artifact bundle '{}' lacks prefill_dev/decode_dev — rebuild \
                 artifacts (python -m compile.aot --force) or use the \
                 literal cached engine",
                engine.config_name()
            );
        }
        let cfg = &engine.manifest.config;
        let (b, p, s, v) = (cfg.gen_batch, cfg.prompt_len, cfg.seq_len, cfg.vocab);
        assert_eq!(prompts.len(), b, "gen_batch is fixed at {b}");

        let mut st = DecodeState::new(prompts, p, s);

        // prefill: prompt -> device-resident kv cache + logits for pos p.
        // Only the logits are downloaded; the cache stays where it is.
        let prompt_flat = {
            let mut scratch = self.scratch.borrow_mut();
            flatten_prompts(prompts, p, &mut scratch);
            scratch
        };
        let mut out = engine.execute_buffers(
            "prefill_dev",
            &[CallArg::Param(params), CallArg::I32(&prompt_flat)],
        )?;
        drop(prompt_flat);
        let mut logits = engine.download(&out[1])?.into_f32()?;
        let mut kv: DeviceBuffer = out.swap_remove(0);

        let mut steps = 0;
        for pos in p..s {
            steps += 1;
            let sampled = st.step(pos, &logits, v, opts, rng);
            if st.all_done() || pos + 1 == s {
                break;
            }
            // decode: token at `pos` -> logits for pos+1, updated cache.
            // The cache is chained device-to-device via CallArg::Device.
            let mut out = engine.execute_buffers(
                "decode_dev",
                &[
                    CallArg::Param(params),
                    CallArg::Device(&kv),
                    CallArg::I32(&sampled),
                    CallArg::ScalarI32(pos as i32),
                ],
            )?;
            logits = engine.download(&out[0])?.into_f32()?;
            kv = out.swap_remove(1);
        }
        Ok(st.finish(steps))
    }
}
