//! Asynchronous RLHF (paper Fig 2 bottom, Algorithm 1): Cleanba-style
//! one-step off-policy training.
//!
//! Two OS threads, each owning its own PJRT backend (the `xla` crate's
//! client is not `Send`, which conveniently mirrors the paper's separate
//! generation/training processes):
//!
//! - **generation worker**: pulls the freshest published policy, generates
//!   one round, hands it to the trainer over a rendezvous queue. The
//!   rendezvous is the staleness guarantee: the worker generates round
//!   i+1 while round i trains, and never runs further ahead, so training
//!   data is always exactly one policy version behind (θ_{t+1} is updated
//!   with data from θ_t — paper §3.5, Cleanba).
//! - **trainer (this thread)**: pops a round, labels it (reward + reference
//!   logprobs), takes the update(s), publishes the new params.
//!
//! Parameter publication is a latest-wins `Arc<[f32]>` slot: the trainer
//! downloads its device-resident params once per publish, snapshots them
//! into an `Arc`, and the swap itself is a pointer move — the worker
//! clones the `Arc`, not the parameters. The worker's engine re-uploads
//! the policy to its device only when the published version actually
//! changed (the A.2 "passing policy parameters" cost is paid per publish,
//! never per call).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::trainer::{
    assemble, generate_round, round_metrics, rounds_per_batch, sample_opts,
    staleness, stage_and_label, train_on_batch, LabelScratch, LabelledRound,
    Round,
};
use super::RunOutput;
use crate::config::ExpConfig;
use crate::coordinator::pretrain::RLHF_RANGE;
use crate::data::{Task, TaskGen};
use crate::metrics::{Phase, RunLog, Timeline};
use crate::runtime::{Engine, ParamView, TrainState};
use crate::util::rng::Pcg32;

/// Messages from the generation worker.
struct GenMsg {
    round: Round,
}

/// Latest-wins published-policy slot. The trainer overwrites, the worker
/// reads whatever is freshest; intermediate versions are simply dropped
/// (Algorithm 1 only ever wants θ_i, never the history).
pub(crate) struct ParamSlot {
    /// Fast-path hint so the worker can skip the lock when nothing new
    /// was published. Updated after the slot contents.
    hint: AtomicU64,
    latest: Mutex<(u64, Arc<[f32]>)>,
}

impl ParamSlot {
    pub(crate) fn new(version: u64, params: Arc<[f32]>) -> ParamSlot {
        ParamSlot {
            hint: AtomicU64::new(version),
            latest: Mutex::new((version, params)),
        }
    }

    /// Publish `params` as `version`: one pointer swap under the lock.
    pub(crate) fn publish(&self, version: u64, params: Arc<[f32]>) {
        *self.latest.lock().unwrap() = (version, params);
        self.hint.store(version, Ordering::Release);
    }

    /// The freshest publication newer than `have`, if any.
    pub(crate) fn fetch(&self, have: u64) -> Option<(u64, Arc<[f32]>)> {
        if self.hint.load(Ordering::Acquire) <= have {
            return None;
        }
        let guard = self.latest.lock().unwrap();
        if guard.0 <= have {
            return None;
        }
        Some((guard.0, guard.1.clone()))
    }
}

pub fn run(cfg: &ExpConfig, prep: &super::Prepared, verbose: bool) -> Result<RunOutput> {
    let engine: &Engine = &prep.engine;
    let taskgen: &TaskGen = &prep.taskgen;
    let sft_params = prep.sft_params.clone();
    let origin = Instant::now();
    let mut timeline = Timeline::shared_origin(origin);
    let mut log = RunLog::new();
    log.set_meta("label", cfg.label());

    // -- channels ----------------------------------------------------------
    // Rendezvous round queue (bound 0): the worker's `send` blocks until
    // the trainer is ready to take the round. This is what enforces
    // *one-step* off-policy: the worker can generate round i+1 (with the
    // params published after round i-1's update) WHILE the trainer trains
    // round i, but can never start round i+2 before round i+1 is handed
    // over — so training data is at most one policy version stale. A
    // bound-1 queue would admit staleness 2 (one round queued + one in
    // flight), which the integration tests reject.
    let (round_tx, round_rx) = mpsc::sync_channel::<GenMsg>(0);
    // Latest-wins param slot, seeded with the SFT checkpoint at version 0.
    let slot = Arc::new(ParamSlot::new(0, Arc::from(&sft_params[..])));
    let stop = Arc::new(AtomicBool::new(false));

    // -- generation worker ---------------------------------------------------
    let worker = {
        let stop = stop.clone();
        let slot = slot.clone();
        let artifact_dir = cfg.artifact_dir();
        let init_params: Arc<[f32]> = Arc::from(&sft_params[..]);
        let taskgen = TaskGen::new(
            taskgen.task,
            taskgen.prompt_len,
            taskgen.resp_len,
            cfg.seed,
        );
        let opts = sample_opts(cfg);
        let k = cfg.k_samples;
        let seed = cfg.seed;
        let gen_engine = cfg.gen_engine;
        std::thread::Builder::new()
            .name("gen-worker".into())
            .spawn(move || -> Result<(f64, u64)> {
                // own engine, own PJRT client (separate "GPU")
                let engine = Engine::load(&artifact_dir)?;
                let generator = gen_engine.build();
                let mut rng = Pcg32::new(seed, 0xa57c);
                let mut params = init_params;
                let mut version = 0u64;
                let mut cursor = RLHF_RANGE;
                let gen_bs = engine.manifest.config.gen_batch as u64;
                let mut gen_total = 0.0f64;
                let mut rounds_done = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // pick up the freshest published policy (Algorithm 1:
                    // "update generation model θ <- θ_i"); the cached view
                    // below re-uploads to device only on a version change
                    if let Some((v, p)) = slot.fetch(version) {
                        version = v;
                        params = p;
                    }
                    let round = generate_round(
                        &engine,
                        generator.as_ref(),
                        ParamView::cached("policy", version, &params),
                        version,
                        &taskgen,
                        cursor,
                        k,
                        opts,
                        &mut rng,
                        origin,
                    )?;
                    cursor += gen_bs / k as u64;
                    gen_total += round.gen_secs;
                    rounds_done += 1;
                    // rendezvous: blocks until the trainer takes the
                    // round — the one-step off-policy bound
                    if round_tx.send(GenMsg { round }).is_err() {
                        break;
                    }
                }
                Ok((gen_total, rounds_done))
            })
            .expect("spawn gen-worker")
    };

    // -- trainer loop ---------------------------------------------------------
    let mut state = TrainState::new(sft_params.clone());
    let mut scratch = LabelScratch::default();
    let rpb = rounds_per_batch(cfg.k_samples);
    let mut episodes = 0u64;
    let mut step = 0u64;
    let mut version = 0u64;
    let gen_bs = engine.manifest.config.gen_batch as u64;
    let mut staleness_sum = 0u64;
    let result = (|| -> Result<()> {
        while step < cfg.steps {
            let mut rounds = Vec::with_capacity(rpb);
            for _ in 0..rpb {
                let t_wait = origin.elapsed().as_secs_f64();
                let msg = round_rx
                    .recv()
                    .map_err(|_| anyhow!("generation worker died"))?;
                let t_got = origin.elapsed().as_secs_f64();
                timeline.push_span(Phase::Idle, t_wait, t_got);
                timeline.push_span(
                    Phase::Generate,
                    msg.round.gen_span.0,
                    msg.round.gen_span.1,
                );
                episodes += gen_bs;
                // the round crossed the thread boundary as host data:
                // stage it on the trainer's device once (when eligible),
                // label off the shared buffers (scoring cost)
                let (resident, labels) = timeline.record(Phase::Score, || {
                    stage_and_label(
                        engine,
                        &msg.round,
                        &sft_params,
                        prep.rm_scorer(),
                        cfg,
                        &mut scratch,
                    )
                })?;
                rounds.push(LabelledRound {
                    round: msg.round,
                    labels,
                    resident,
                });
            }

            let batch = assemble(engine, cfg.algo, &rounds, cfg.k_samples)?;
            let all_metrics = timeline.record(Phase::Train, || {
                train_on_batch(
                    engine,
                    &mut state,
                    &batch,
                    cfg.lr,
                    cfg.updates_per_batch,
                )
            })?;
            version += cfg.updates_per_batch as u64;
            step += 1;

            // publish the new policy: device -> host once per publish,
            // then a latest-wins pointer swap
            timeline.record(Phase::Publish, || -> Result<()> {
                let host = state.params_host(engine)?;
                slot.publish(version, Arc::from(host));
                Ok(())
            })?;

            let data_version = rounds
                .iter()
                .map(|r| r.round.params_version)
                .max()
                .unwrap();
            let stale = staleness(version, data_version);
            staleness_sum += stale;

            let labels = &rounds[0].labels;
            let mut row = round_metrics(labels);
            let m = all_metrics.last().unwrap();
            row.push(("loss", m[0]));
            row.push(("staleness", stale as f32));
            log.push(step, episodes, timeline.wall(), &row);
            if verbose && step % 8 == 0 {
                eprintln!(
                    "[async {}] step {step}/{} episodes {episodes} \
                     win {:.3} kl-ppl {:.4} staleness {stale}",
                    cfg.algo,
                    cfg.steps,
                    log.recent_mean("win_rate", 8).unwrap_or(0.0),
                    log.recent_mean("kl_ppl", 8).unwrap_or(0.0),
                );
            }
        }
        Ok(())
    })();

    // shut the worker down
    stop.store(true, Ordering::Relaxed);
    drop(round_rx);
    let worker_out = worker.join().map_err(|_| anyhow!("worker panicked"))?;
    result?;
    let (gen_total, gen_rounds) = worker_out?;
    log.set_meta("gen_total_secs", format!("{gen_total:.3}"));
    log.set_meta("gen_rounds", gen_rounds);
    log.set_meta(
        "mean_staleness",
        format!("{:.3}", staleness_sum as f64 / cfg.steps.max(1) as f64),
    );

    // suppress unused warning for math-only runs
    let _ = Task::from_name(&engine.manifest.config.task);

    Ok(RunOutput {
        final_params: state.into_params(engine)?,
        log,
        timeline,
        episodes,
    })
}

#[cfg(test)]
mod tests {
    use super::ParamSlot;
    use std::sync::Arc;

    #[test]
    fn param_slot_is_latest_wins() {
        let slot = ParamSlot::new(0, Arc::from(&[0.0f32][..]));
        assert!(slot.fetch(0).is_none(), "nothing newer than the seed");
        for v in 1..=5u64 {
            slot.publish(v, Arc::from(&[v as f32][..]));
        }
        // a reader at version 0 sees only the freshest publication
        let (v, p) = slot.fetch(0).expect("new version visible");
        assert_eq!(v, 5);
        assert_eq!(&p[..], &[5.0]);
        // and nothing newer than what it now has
        assert!(slot.fetch(5).is_none());
    }

    #[test]
    fn param_slot_fetch_is_cheap_pointer_clone() {
        let big: Arc<[f32]> = Arc::from(vec![1.0f32; 1024].into_boxed_slice());
        let slot = ParamSlot::new(1, big.clone());
        let (_, p) = slot.fetch(0).unwrap();
        assert!(Arc::ptr_eq(&p, &big), "fetch must share, not copy");
    }
}
