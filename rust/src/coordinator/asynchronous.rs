//! Asynchronous RLHF (paper Fig 2 bottom, Algorithm 1): off-policy
//! training overlapped with generation.
//!
//! Thin constructor over the unified [`pipeline`] trainer loop: the
//! asynchronous schedule is [`pipeline::run`] fed by a [`WorkerPool`] of
//! `cfg.gen_workers` generation threads (each owning its own PJRT
//! backend) behind a bounded round queue of depth `cfg.staleness_bound`.
//!
//! The defaults — one worker, queue depth 0 (a rendezvous handover) —
//! are exactly the paper's Cleanba-style one-step off-policy coordinator:
//! the worker generates round i+1 while round i trains and never runs
//! further ahead, so θ_{t+1} is updated with data from θ_t (§3.5). Larger
//! `--staleness-bound K` admits up to K queued rounds (staleness ≤ K+1
//! policy versions); more `--gen-workers` add generation throughput, one
//! in-flight round of staleness each. See `pipeline` for the invariant.

use anyhow::Result;

use super::checkpoint::Checkpoint;
use super::pipeline::{self, RoundSource, WorkerPool};
use super::RunOutput;
use crate::config::ExpConfig;

/// Run asynchronous RLHF with the supervised worker pool described by
/// `cfg.gen_workers` / `cfg.staleness_bound` (restart, retry, watchdog
/// and fault-injection knobs ride along in the config). A `--resume`
/// restart re-enters each lane's prompt cursor under a fresh RNG epoch:
/// exactly-once delivery, not bitwise replay.
pub fn run(
    cfg: &ExpConfig,
    prep: &super::Prepared,
    verbose: bool,
) -> Result<RunOutput> {
    pipeline::run(
        cfg,
        prep,
        |origin, resume: Option<&Checkpoint>| {
            let src: Box<dyn RoundSource> =
                Box::new(WorkerPool::spawn(cfg, prep, origin, resume)?);
            Ok(src)
        },
        verbose,
    )
}
