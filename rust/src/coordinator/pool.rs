//! WorkerPool: M supervised generation workers feeding the trainer over
//! a bounded round queue of depth K — the asynchronous end of the
//! [`RoundSource`] design space (paper §3.5/Algorithm 1).
//!
//! Split out of `pipeline.rs` as a pure code move: the trainer loop and
//! the [`ParamBus`] publication cell live there; this module owns the
//! worker seats, their supervision (respawn / lane re-striding /
//! restart-exhausted takeover / heartbeat watchdog), and the lane ledger
//! that makes crash recovery exactly-once. The serve-while-training
//! [`SessionSource`] in `pipeline.rs` reuses the seat plumbing defined
//! here ([`SpawnCtx`], [`SeatShared`], [`Supervision`], fault injection,
//! exit reports).
//!
//! [`SessionSource`]: super::pipeline::SessionSource

use std::collections::{HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::checkpoint::{Checkpoint, SourceState};
use super::pipeline::{cursor_stride, ParamBus, RoundSource, TrainerCx};
use super::pretrain::RLHF_RANGE;
use super::trainer::{
    generate_round, sample_opts, Round, SourcedRound, ROUND_ORIGIN,
};
use super::Prepared;
use crate::config::{ExpConfig, FaultKind, FaultPlan, GenEngine};
use crate::data::{Task, TaskGen};
use crate::gen::continuous::{
    AdmitSeq, Completed, DeviceBackend, Pool, PoolCfg, RoundAssembler,
};
use crate::gen::{GenBatch, SampleOpts};
use crate::metrics::{Phase, RunLog, Timeline};
use crate::runtime::{Engine, ParamView, RetryPolicy, RETRY_STREAM};
use crate::util::bitset::{AtomicBitSet, BitSet};
use crate::util::rng::Pcg32;

/// One round crossing the worker → trainer queue, tagged with the lane
/// (prompt-partition stripe) it came from so the trainer's
/// [`LaneAccounts`] can enforce exactly-once delivery across respawns.
pub(crate) struct GenMsg {
    pub(crate) round: Round,
    pub(crate) lane: usize,
    /// Continuous engine only: the prompt indices retired into this round
    /// (continuous lanes retire out of admission order, so block-cursor
    /// accounting does not apply).
    pub(crate) indices: Option<Vec<u64>>,
}

/// Structured exit report of one worker seat: sent on every exit path —
/// clean retirement, engine error, or caught panic.
pub(crate) struct WorkerExit {
    pub(crate) slot: usize,
    pub(crate) outcome: Result<(f64, u64)>,
}

/// Supervisor-side control block of one worker seat: the lanes it owns
/// (a word-array bitset, so pools are no longer capped at 64 seats) and
/// its last heartbeat, in milliseconds since the trainer timeline origin.
pub(crate) struct SlotCtl {
    pub(crate) lanes: AtomicBitSet,
    pub(crate) beat_ms: AtomicU64,
    /// Response tokens currently in flight inside the seat's slot pool
    /// (continuous engines; stays 0 on round-synchronous seats). The
    /// supervisor `swap(0)`s it when the seat's work is abandoned, so
    /// `inflight_tokens_abandoned` prices the decode work a takeover
    /// throws away with the engine-local KV.
    pub(crate) inflight_tok: AtomicU64,
}

impl SlotCtl {
    pub(crate) fn new(lanes: AtomicBitSet, now_ms: u64) -> SlotCtl {
        SlotCtl {
            lanes,
            beat_ms: AtomicU64::new(now_ms),
            inflight_tok: AtomicU64::new(0),
        }
    }
}

pub(crate) fn beat(ctl: &SlotCtl, origin: Instant) {
    ctl.beat_ms
        .store(origin.elapsed().as_millis() as u64, Ordering::SeqCst);
}

/// The lane a worker should generate for next: the one whose cursor is
/// furthest behind (ties to the lowest lane), so an heir that inherited
/// orphaned lanes round-robins them instead of starving one.
fn pick_lane(mask: &BitSet, ledger: &[AtomicU64]) -> Result<usize> {
    mask.ones()
        .min_by_key(|&l| (ledger[l].load(Ordering::SeqCst), l))
        .ok_or_else(|| {
            anyhow!(
                "worker scheduled with an empty lane mask — supervision \
                 should have retired this seat"
            )
        })
}

/// Successor of `idx` in one lane's admission sequence (blocks of
/// `stride` consecutive indices starting at `start`, hopping `hop`
/// between blocks).
fn lane_next(idx: u64, start: u64, stride: u64, hop: u64) -> u64 {
    let rel = idx - start;
    let (block, off) = (rel / hop, rel % hop);
    debug_assert!(off < stride, "index off the lane's admission sequence");
    if off + 1 < stride {
        idx + 1
    } else {
        start + (block + 1) * hop
    }
}

pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The one format every supervision event is rendered through:
/// `[supervisor] gen-worker-<seat> <event>: <detail>`. Events are short
/// stable verbs (`respawn`, `takeover`, `restride`, `migrate`, `stalled`,
/// `heartbeat-resumed`); the detail is free-form. Log scraping matches the
/// prefix, never the prose.
pub(crate) fn supervisor_line(seat: usize, event: &str, detail: &str) -> String {
    format!("[supervisor] gen-worker-{seat} {event}: {detail}")
}

pub(crate) fn supervisor_log(seat: usize, event: &str, detail: &str) {
    eprintln!("{}", supervisor_line(seat, event, detail));
}

/// What the shared supervision decided for a dead seat.
pub(crate) enum Recovery {
    /// Restart budget remains: respawn the seat in place.
    Respawn,
    /// Budget exhausted but a survivor exists: the dead seat's work moves
    /// to `heir` (lane re-stride / session migration).
    Takeover { heir: usize },
}

/// Restart, incarnation and degradation bookkeeping shared by both
/// supervisors ([`WorkerPool`] and the serve-mode `SessionSource`): the
/// respawn-or-takeover decision, the heartbeat watchdog transitions and
/// the failover telemetry land here once instead of twice.
pub(crate) struct Supervision {
    /// Per-slot incarnation: respawns (and resume epochs) shift the
    /// replacement's RNG streams so a replayed prompt block still samples
    /// fresh tokens instead of re-walking the dead worker's stream.
    pub(crate) incarnations: Vec<u64>,
    restarts_used: Vec<usize>,
    max_restarts: usize,
    pub(crate) worker_restarts: u64,
    pub(crate) worker_errors: Vec<String>,
    stalled_now: Vec<bool>,
    ever_stalled: Vec<bool>,
    /// Seats permanently retired by a takeover; while any is set the pool
    /// runs at degraded capacity.
    pub(crate) lost: Vec<bool>,
    pub(crate) lanes_reassigned: u64,
    pub(crate) sessions_migrated: u64,
    pub(crate) inflight_tokens_abandoned: u64,
    pub(crate) degraded_capacity_steps: u64,
}

impl Supervision {
    pub(crate) fn new(m: usize, epoch0: u64, max_restarts: usize) -> Supervision {
        Supervision {
            incarnations: vec![epoch0; m],
            restarts_used: vec![0; m],
            max_restarts,
            worker_restarts: 0,
            worker_errors: Vec::new(),
            stalled_now: vec![false; m],
            ever_stalled: vec![false; m],
            lost: vec![false; m],
            lanes_reassigned: 0,
            sessions_migrated: 0,
            inflight_tokens_abandoned: 0,
            degraded_capacity_steps: 0,
        }
    }

    pub(crate) fn degraded(&self) -> bool {
        self.lost.iter().any(|&b| b)
    }

    /// Record a seat death and decide its recovery. `heir` is the caller's
    /// takeover target (`None` when no survivor remains); `stranded` is
    /// appended to the no-survivor error so callers can name what a failed
    /// pool leaves behind (serve mode names its sessions).
    pub(crate) fn on_death(
        &mut self,
        w: usize,
        err: &anyhow::Error,
        heir: Option<usize>,
        stranded: &str,
    ) -> Result<Recovery> {
        self.worker_errors.push(format!("gen-worker-{w}: {err:#}"));
        if self.restarts_used[w] < self.max_restarts {
            self.restarts_used[w] += 1;
            self.worker_restarts += 1;
            self.incarnations[w] += 1;
            supervisor_log(
                w,
                "respawn",
                &format!(
                    "died: {err:#}; restarting on a fresh engine \
                     (restart {}/{})",
                    self.restarts_used[w], self.max_restarts
                ),
            );
            return Ok(Recovery::Respawn);
        }
        match heir {
            Some(h) => {
                self.lost[w] = true;
                Ok(Recovery::Takeover { heir: h })
            }
            None => bail!(
                "gen-worker-{w} died with no restarts left and no surviving \
                 workers: {err:#}{stranded}"
            ),
        }
    }

    /// Bump a takeover heir's incarnation before its respawn over the
    /// merged lanes. NOT charged to any restart budget: the heir did
    /// nothing wrong — it retired cleanly so its admission schedule could
    /// be rebuilt.
    pub(crate) fn on_takeover_respawn(&mut self, h: usize) {
        self.incarnations[h] += 1;
    }

    /// Heartbeat watchdog pass: flag seats silent past `stall_timeout`,
    /// log stall/resume transitions. `live(w)` tells the watchdog which
    /// seats are expected to beat (dead / retired seats are skipped).
    pub(crate) fn watchdog(
        &mut self,
        ctl: &[SlotCtl],
        live: impl Fn(usize) -> bool,
        origin: Instant,
        stall_timeout: f64,
    ) {
        let now_ms = origin.elapsed().as_millis() as u64;
        for (w, c) in ctl.iter().enumerate() {
            if !live(w) {
                self.stalled_now[w] = false;
                continue;
            }
            let age = now_ms.saturating_sub(c.beat_ms.load(Ordering::SeqCst));
            let stalled = age as f64 / 1000.0 > stall_timeout;
            if stalled && !self.stalled_now[w] {
                self.stalled_now[w] = true;
                self.ever_stalled[w] = true;
                supervisor_log(
                    w,
                    "stalled",
                    &format!(
                        "silent for {:.1}s (--stall-timeout-secs {:.1})",
                        age as f64 / 1000.0,
                        stall_timeout
                    ),
                );
            } else if !stalled && self.stalled_now[w] {
                self.stalled_now[w] = false;
                supervisor_log(w, "heartbeat-resumed", "beats flowing again");
            }
        }
    }

    /// Fold the shared supervision counters into the run metas.
    pub(crate) fn meta(&self, log: &mut RunLog) {
        log.set_meta("worker_restarts", self.worker_restarts);
        log.set_meta(
            "stalled_workers",
            self.ever_stalled.iter().filter(|&&b| b).count(),
        );
        log.set_meta("lanes_reassigned", self.lanes_reassigned);
        log.set_meta("sessions_migrated", self.sessions_migrated);
        log.set_meta(
            "inflight_tokens_abandoned",
            self.inflight_tokens_abandoned,
        );
        log.set_meta("degraded_capacity_steps", self.degraded_capacity_steps);
        if !self.worker_errors.is_empty() {
            log.set_meta("worker_errors", self.worker_errors.join(" | "));
        }
    }
}

pub(crate) enum Accept {
    Fresh,
    Duplicate,
}

/// Trainer-side delivery accounting, per lane. The worker-side ledger
/// advances only *after* a successful handover (at-least-once); these
/// accounts turn that into exactly-once by dropping replays — and by
/// failing loudly on a *hole*, which no recovery path can legally
/// produce.
struct LaneAccounts {
    stride: u64,
    hop: u64,
    starts: Vec<u64>,
    /// Next index the trainer is owed per lane: block start for
    /// round-synchronous engines, delivered frontier for continuous.
    expected: Vec<u64>,
    /// Continuous engines: indices delivered above the frontier.
    delivered: Vec<HashSet<u64>>,
    duplicates: u64,
}

impl LaneAccounts {
    fn new(starts: Vec<u64>, stride: u64, hop: u64) -> LaneAccounts {
        let n = starts.len();
        LaneAccounts {
            stride,
            hop,
            expected: starts.clone(),
            starts,
            delivered: vec![HashSet::new(); n],
            duplicates: 0,
        }
    }

    fn resume(
        starts: Vec<u64>,
        stride: u64,
        hop: u64,
        cursors: &[u64],
        skip: &[Vec<u64>],
    ) -> LaneAccounts {
        let mut a = LaneAccounts::new(starts, stride, hop);
        a.expected = cursors.to_vec();
        for (lane, s) in skip.iter().enumerate() {
            a.delivered[lane] = s.iter().copied().collect();
        }
        a
    }

    fn accept(&mut self, msg: &GenMsg) -> Result<Accept> {
        match &msg.indices {
            Some(indices) => self.accept_indices(msg.lane, indices),
            None => self.accept_block(msg.lane, msg.round.start_index),
        }
    }

    /// Round-synchronous engines: a round is one whole block; the lane
    /// cursor either matches (fresh), trails (replay after a respawn —
    /// dropped), or was skipped (a lost round: loud failure).
    fn accept_block(&mut self, lane: usize, start: u64) -> Result<Accept> {
        let exp = self.expected[lane];
        if start == exp {
            self.expected[lane] = exp + self.hop;
            Ok(Accept::Fresh)
        } else if start < exp {
            self.duplicates += 1;
            Ok(Accept::Duplicate)
        } else {
            bail!(
                "prompt partition violated: lane {lane} jumped from index \
                 {exp} to {start} — a round was lost without recovery"
            )
        }
    }

    /// Continuous engines: a round is a set of retired prompt indices. A
    /// respawned worker's skip set must make every round all-fresh or
    /// all-replay; a mixed round means the skip set missed a delivery.
    fn accept_indices(&mut self, lane: usize, indices: &[u64]) -> Result<Accept> {
        let fresh = indices
            .iter()
            .filter(|&&i| {
                i >= self.expected[lane] && !self.delivered[lane].contains(&i)
            })
            .count();
        if fresh == 0 {
            self.duplicates += 1;
            return Ok(Accept::Duplicate);
        }
        if fresh < indices.len() {
            bail!(
                "continuous round on lane {lane} mixes {fresh} fresh and {} \
                 replayed prompt indices — the respawn skip set missed a \
                 delivery",
                indices.len() - fresh
            );
        }
        self.delivered[lane].extend(indices.iter().copied());
        // advance the frontier across everything now contiguous
        while self.delivered[lane].remove(&self.expected[lane]) {
            self.expected[lane] = lane_next(
                self.expected[lane],
                self.starts[lane],
                self.stride,
                self.hop,
            );
        }
        Ok(Accept::Fresh)
    }
}

/// Everything needed to (re)spawn a worker seat, owned so replacement
/// threads can be built mid-run without borrowing the config.
#[derive(Clone)]
pub(crate) struct SpawnCtx {
    pub(crate) artifact_dir: PathBuf,
    pub(crate) task: Task,
    pub(crate) prompt_len: usize,
    pub(crate) resp_len: usize,
    pub(crate) seed: u64,
    pub(crate) opts: SampleOpts,
    pub(crate) k: usize,
    pub(crate) gen_engine: GenEngine,
    pub(crate) max_cohorts: usize,
    pub(crate) admit_min: usize,
    pub(crate) stride: u64,
    pub(crate) hop: u64,
    pub(crate) retries: u32,
    pub(crate) stall_timeout: f64,
    pub(crate) fault: Option<FaultPlan>,
    pub(crate) origin: Instant,
    pub(crate) continuous: bool,
}

/// The shared handles a worker seat runs against. Seat `w` reads the
/// published policy from its own [`ParamBus`] seat `w` — the fan-out
/// gives every subscriber a private latest-wins cell, so one slow reader
/// never contends with the rest of the pool.
#[derive(Clone)]
pub(crate) struct SeatShared {
    pub(crate) tx: mpsc::SyncSender<GenMsg>,
    pub(crate) bus: Arc<ParamBus>,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) ledger: Arc<Vec<AtomicU64>>,
    pub(crate) ctl: Arc<Vec<SlotCtl>>,
    pub(crate) fault_fired: Arc<AtomicBool>,
    pub(crate) retry_count: Arc<AtomicU64>,
}

/// M generation worker threads, each owning its own PJRT backend (the
/// `xla` crate's client is not `Send`, which conveniently mirrors the
/// paper's separate generation/training processes), feeding the trainer
/// over a bounded queue of depth K:
///
/// - each **worker** pulls the freshest published policy, generates one
///   round, and hands it over `send`, which blocks while the queue is
///   full — that back-pressure is the staleness guarantee;
/// - the **trainer** pops rounds; with K = 0 the queue is a rendezvous
///   and `M = 1, K = 0` reproduces the seed Cleanba coordinator exactly
///   (θ_{t+1} updated with data from θ_t, paper §3.5).
///
/// Workers partition the prompt stream by striding: worker `w` starts at
/// `RLHF_RANGE + w·stride` and hops `M·stride` per round, so pools of any
/// width consume disjoint, contiguously-tiling prompt ranges.
///
/// Parameter publication is a latest-wins seat on the shared
/// [`ParamBus`]: the trainer loop downloads its device-resident params
/// once per publish, snapshots them into an `Arc`, and fans the pointer
/// out to every subscriber seat — workers clone the `Arc`, not the
/// parameters, and re-upload to their device only when the version
/// actually changed (the A.2 "passing policy parameters" cost is paid
/// per publish, never per call).
pub struct WorkerPool {
    rx: mpsc::Receiver<GenMsg>,
    /// The pool's own sender clone: keeps the queue open for respawned
    /// workers, and makes trainer-side `Disconnected` impossible mid-run.
    tx: Option<mpsc::SyncSender<GenMsg>>,
    exit_rx: mpsc::Receiver<WorkerExit>,
    exit_tx: mpsc::Sender<WorkerExit>,
    bus: Arc<ParamBus>,
    stop: Arc<AtomicBool>,
    /// Per-lane next-cursor, advanced by workers *after* handover.
    ledger: Arc<Vec<AtomicU64>>,
    ctl: Arc<Vec<SlotCtl>>,
    fault_fired: Arc<AtomicBool>,
    retry_count: Arc<AtomicU64>,
    ctx: SpawnCtx,
    /// One seat per worker slot; `None` = dead (reaped or re-strided).
    seats: Vec<Option<JoinHandle<()>>>,
    sup: Supervision,
    /// Takeover in flight: the merged lane mask a forcibly-retired heir
    /// respawns over once its clean exit is reaped. Continuous admission
    /// is built at spawn, so a live heir cannot absorb lanes mid-flight —
    /// migration is respawn-on-a-different-seat.
    pending_respawn: Vec<Option<BitSet>>,
    accounts: LaneAccounts,
    /// Rounds accepted while draining a dead worker's queue, served
    /// before new receives.
    pending: VecDeque<GenMsg>,
    /// Per-slot accumulated (gen_secs, rounds) across incarnations.
    totals: Vec<(f64, u64)>,
    gen_bs: u64,
    received: u64,
    /// Receive slice between supervision passes.
    poll: Duration,
}

impl WorkerPool {
    /// Spawn `cfg.gen_workers` supervised workers over a queue of depth
    /// `cfg.staleness_bound`. `origin` is the trainer timeline's clock so
    /// worker gen-spans are directly comparable; `bus` is the trainer
    /// loop's publish fan-out, already seeded (from the checkpoint's
    /// policy at its version under `--resume`, else the SFT params at
    /// version 0) — worker `w` subscribes to bus seat `w`. With `resume`,
    /// lanes re-enter the checkpoint's cursors and worker RNG streams
    /// shift to a fresh epoch (async resume is exactly-once, not bitwise
    /// — live worker threads cannot be snapshotted mid-call).
    pub fn spawn(
        cfg: &ExpConfig,
        prep: &Prepared,
        origin: Instant,
        resume: Option<&Checkpoint>,
        bus: Arc<ParamBus>,
    ) -> Result<WorkerPool> {
        let m = cfg.gen_workers.max(1);
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        let stride = cursor_stride(gen_bs, cfg.k_samples);
        let hop = stride * m as u64;
        let continuous = cfg.gen_engine == GenEngine::Continuous;
        let starts: Vec<u64> =
            (0..m).map(|w| RLHF_RANGE + w as u64 * stride).collect();

        let (accounts, epoch0, received) = match resume {
            Some(c) => {
                let s = &c.source;
                if s.kind != "pool" {
                    bail!(
                        "--resume: checkpoint was written by a '{}' round \
                         source but this run is async (worker pool)",
                        s.kind
                    );
                }
                if s.cursors.len() != m {
                    bail!(
                        "--resume: checkpoint has {} worker lanes but \
                         --gen-workers is {m}",
                        s.cursors.len()
                    );
                }
                let skip: Vec<Vec<u64>> = if s.skip.len() == m {
                    s.skip.clone()
                } else if s.skip.is_empty() {
                    vec![Vec::new(); m]
                } else {
                    bail!(
                        "--resume: checkpoint has {} skip lists for {m} \
                         lanes",
                        s.skip.len()
                    );
                };
                (
                    LaneAccounts::resume(
                        starts.clone(),
                        stride,
                        hop,
                        &s.cursors,
                        &skip,
                    ),
                    // past every RNG stream this run already consumed
                    s.epoch + 1,
                    s.generated,
                )
            }
            None => (LaneAccounts::new(starts, stride, hop), 0, 0),
        };

        let (tx, rx) = mpsc::sync_channel::<GenMsg>(cfg.staleness_bound);
        let (exit_tx, exit_rx) = mpsc::channel::<WorkerExit>();
        let stop = Arc::new(AtomicBool::new(false));
        let ledger: Arc<Vec<AtomicU64>> = Arc::new(
            accounts.expected.iter().map(|&c| AtomicU64::new(c)).collect(),
        );
        let now_ms = origin.elapsed().as_millis() as u64;
        let ctl: Arc<Vec<SlotCtl>> = Arc::new(
            (0..m)
                .map(|w| SlotCtl::new(AtomicBitSet::single(w, m), now_ms))
                .collect(),
        );
        let ctx = SpawnCtx {
            artifact_dir: cfg.artifact_dir(),
            task: prep.taskgen.task,
            prompt_len: prep.taskgen.prompt_len,
            resp_len: prep.taskgen.resp_len,
            seed: cfg.seed,
            opts: sample_opts(cfg),
            k: cfg.k_samples,
            gen_engine: cfg.gen_engine,
            max_cohorts: cfg.max_cohorts,
            admit_min: cfg.admit_min,
            stride,
            hop,
            retries: cfg.engine_retries,
            stall_timeout: cfg.stall_timeout_secs,
            fault: cfg.inject_fault,
            origin,
            continuous,
        };
        let poll = Duration::from_secs_f64(
            (cfg.stall_timeout_secs / 4.0).clamp(0.010, 0.050),
        );
        let mut pool = WorkerPool {
            rx,
            tx: Some(tx),
            exit_rx,
            exit_tx,
            bus,
            stop,
            ledger,
            ctl,
            fault_fired: Arc::new(AtomicBool::new(false)),
            retry_count: Arc::new(AtomicU64::new(0)),
            ctx,
            seats: (0..m).map(|_| None).collect(),
            sup: Supervision::new(m, epoch0, cfg.max_worker_restarts),
            pending_respawn: (0..m).map(|_| None).collect(),
            accounts,
            pending: VecDeque::new(),
            totals: vec![(0.0, 0); m],
            gen_bs,
            received,
            poll,
        };
        for w in 0..m {
            pool.spawn_seat(w)?;
        }
        Ok(pool)
    }

    /// The shared handles a seat thread runs against.
    fn shared(&self) -> Result<SeatShared> {
        let tx = self.tx.clone().ok_or_else(|| {
            anyhow!(
                "worker pool queue already torn down while (re)spawning a \
                 seat — finish() ran before supervision stopped"
            )
        })?;
        Ok(SeatShared {
            tx,
            bus: self.bus.clone(),
            stop: self.stop.clone(),
            ledger: self.ledger.clone(),
            ctl: self.ctl.clone(),
            fault_fired: self.fault_fired.clone(),
            retry_count: self.retry_count.clone(),
        })
    }

    /// (Re)spawn seat `w` at its current incarnation. The body runs under
    /// `catch_unwind`; every exit path reports a [`WorkerExit`].
    fn spawn_seat(&mut self, w: usize) -> Result<()> {
        let ctx = self.ctx.clone();
        let sh = self.shared()?;
        let exit_tx = self.exit_tx.clone();
        let incarnation = self.sup.incarnations[w];
        // every owned continuous lane resumes from the trainer-accepted
        // frontier, skipping out-of-order deliveries above it — one
        // (lane, frontier, skip) triple per lane, so a takeover heir
        // re-admits its inherited lanes from their exact accepted state
        let resume: Vec<(usize, u64, HashSet<u64>)> = self.ctl[w]
            .lanes
            .snapshot()
            .ones()
            .map(|l| {
                (l, self.accounts.expected[l], self.accounts.delivered[l].clone())
            })
            .collect();
        beat(&self.ctl[w], self.ctx.origin);
        let handle = std::thread::Builder::new()
            .name(format!("gen-worker-{w}"))
            .spawn(move || {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if ctx.continuous {
                        seat_continuous(&ctx, &sh, w, incarnation, resume)
                    } else {
                        seat_rounds(&ctx, &sh, w, incarnation)
                    }
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow!("panicked: {}", panic_message(p.as_ref())))
                });
                // best-effort: at teardown the receiver may already be gone
                let _ = exit_tx.send(WorkerExit { slot: w, outcome });
            })
            .map_err(|e| anyhow!("spawn gen-worker-{w}: {e}"))?;
        self.seats[w] = Some(handle);
        Ok(())
    }

    /// Reap dead seats (respawn / re-stride / fail) and run the heartbeat
    /// watchdog. Called from `next` between receive slices.
    fn supervise(&mut self) -> Result<()> {
        while let Ok(exit) = self.exit_rx.try_recv() {
            let w = exit.slot;
            if let Some(h) = self.seats[w].take() {
                let _ = h.join();
            }
            match exit.outcome {
                Ok((secs, rounds)) => {
                    self.totals[w].0 += secs;
                    self.totals[w].1 += rounds;
                    // a clean exit is legitimate at teardown, after its
                    // lanes were re-strided away, or as the forced
                    // retirement of a takeover heir (whose pending mask
                    // respawns it here)
                    let retired = self.ctl[w].lanes.is_empty();
                    if !self.stop.load(Ordering::SeqCst) {
                        if !retired {
                            self.handle_death(
                                w,
                                anyhow!("exited cleanly mid-run (queue closed?)"),
                            )?;
                        } else if let Some(mask) = self.pending_respawn[w].take()
                        {
                            self.respawn_with_lanes(w, mask)?;
                        }
                    }
                }
                Err(e) => self.handle_death(w, e)?,
            }
        }
        let seats = &self.seats;
        self.sup.watchdog(
            &self.ctl,
            |w| seats[w].is_some(),
            self.ctx.origin,
            self.ctx.stall_timeout,
        );
        Ok(())
    }

    /// Absorb every queued round into the accounts (fresh ones buffer in
    /// `pending`). Must run before computing a respawn position: a round
    /// sitting in the queue at worker death is not yet accounted, and a
    /// replacement spawned without it would replay it as a partial
    /// duplicate.
    fn drain_queue(&mut self) -> Result<()> {
        while let Ok(msg) = self.rx.try_recv() {
            if let Accept::Fresh = self.accounts.accept(&msg)? {
                self.pending.push_back(msg);
            }
        }
        Ok(())
    }

    fn handle_death(&mut self, w: usize, err: anyhow::Error) -> Result<()> {
        self.drain_queue()?;
        // an heir that died while its takeover respawn was pending still
        // owns the merged mask — restore it before deciding recovery
        if let Some(mask) = self.pending_respawn[w].take() {
            self.ctl[w].lanes.merge(&mask);
        }
        let lanes = self.ctl[w].lanes.snapshot();
        // the dead worker may have generated without completing the
        // handover: rewind-proof the ledger to the accepted frontier
        for l in lanes.ones() {
            self.ledger[l].fetch_max(self.accounts.expected[l], Ordering::SeqCst);
        }
        // its in-flight decode work died with the engine-local KV
        self.sup.inflight_tokens_abandoned +=
            self.ctl[w].inflight_tok.swap(0, Ordering::SeqCst);
        let heir = (0..self.seats.len()).find(|&h| {
            h != w && (self.seats[h].is_some() || self.pending_respawn[h].is_some())
        });
        match self.sup.on_death(w, &err, heir, "")? {
            Recovery::Respawn => self.spawn_seat(w),
            Recovery::Takeover { heir: h } => {
                self.ctl[w].lanes.clear();
                self.sup.lanes_reassigned += lanes.count() as u64;
                if !self.ctx.continuous {
                    // round-synchronous seats re-read their mask every
                    // round: a live heir absorbs the lanes mid-flight
                    self.ctl[h].lanes.merge(&lanes);
                    supervisor_log(
                        w,
                        "restride",
                        &format!(
                            "died with no restarts left: {err:#}; lanes \
                             {lanes} re-strided onto gen-worker-{h}"
                        ),
                    );
                    return Ok(());
                }
                // continuous admission is built at spawn, so the heir is
                // forced through a clean retire-and-respawn: clearing its
                // mask breaks its sweep loop; its clean exit then respawns
                // it over the merged mask from the accepted frontier
                supervisor_log(
                    w,
                    "takeover",
                    &format!(
                        "died with no restarts left: {err:#}; lanes {lanes} \
                         queued for takeover by gen-worker-{h} \
                         (retire-and-respawn)"
                    ),
                );
                match self.pending_respawn[h].as_mut() {
                    // heir already retiring for another takeover: widen it
                    Some(pending) => {
                        for l in lanes.ones() {
                            pending.set(l);
                        }
                    }
                    None => {
                        let mut merged = self.ctl[h].lanes.snapshot();
                        for l in lanes.ones() {
                            merged.set(l);
                        }
                        self.ctl[h].lanes.clear();
                        self.pending_respawn[h] = Some(merged);
                    }
                }
                Ok(())
            }
        }
    }

    /// Complete a continuous takeover: the heir retired cleanly (its mask
    /// was cleared under it), so drain its queue backlog, repair the
    /// ledger across every merged lane, price its own abandoned in-flight
    /// work, and respawn it — at a bumped incarnation, over the merged
    /// mask, re-admitting each lane from the trainer-accepted frontier +
    /// skip set. Exactly the state a same-seat respawn replays from:
    /// migration is respawn-on-a-different-seat.
    fn respawn_with_lanes(&mut self, h: usize, mask: BitSet) -> Result<()> {
        self.drain_queue()?;
        for l in mask.ones() {
            self.ledger[l].fetch_max(self.accounts.expected[l], Ordering::SeqCst);
        }
        self.sup.inflight_tokens_abandoned +=
            self.ctl[h].inflight_tok.swap(0, Ordering::SeqCst);
        // the mask was cleared to force the retire, so merge == assign
        self.ctl[h].lanes.merge(&mask);
        self.sup.on_takeover_respawn(h);
        supervisor_log(
            h,
            "takeover",
            &format!(
                "inheriting lanes {mask}; re-admitting from the \
                 trainer-accepted frontier"
            ),
        );
        self.spawn_seat(h)
    }

    fn deliver(
        &mut self,
        msg: GenMsg,
        timeline: &mut Timeline,
        t_wait: f64,
    ) -> SourcedRound {
        let t_got = timeline.origin().elapsed().as_secs_f64();
        timeline.push_span(Phase::Idle, t_wait, t_got);
        timeline.push_span(
            Phase::Generate,
            msg.round.gen_span.0,
            msg.round.gen_span.1,
        );
        self.received += 1;
        if self.sup.degraded() {
            // rounds delivered while a seat is permanently lost: the
            // takeover's throughput cost, measured per delivery
            self.sup.degraded_capacity_steps += 1;
        }
        // worker rounds crossed the thread boundary as host data: the
        // trainer re-stages them (the async mode's one upload per round)
        SourcedRound { round: msg.round, staged: None }
    }
}

impl RoundSource for WorkerPool {
    fn label(&self) -> &'static str {
        "async"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { timeline, .. } = cx;
        let t_wait = timeline.origin().elapsed().as_secs_f64();
        loop {
            // rounds rescued from a dead worker's queue go first
            if let Some(msg) = self.pending.pop_front() {
                return Ok(self.deliver(msg, timeline, t_wait));
            }
            self.supervise()?;
            match self.rx.recv_timeout(self.poll) {
                Ok(msg) => match self.accounts.accept(&msg)? {
                    Accept::Fresh => {
                        return Ok(self.deliver(msg, timeline, t_wait))
                    }
                    // a respawned worker replaying its at-least-once
                    // window: drop, it is already trained on
                    Accept::Duplicate => continue,
                },
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                    "round queue disconnected while the pool holds a \
                     sender — this is a bug"
                ),
            }
        }
    }

    fn episodes(&self) -> u64 {
        // counted at handover: rounds still in flight inside a worker
        // (or queued) are not episodes yet
        self.received * self.gen_bs
    }

    fn snapshot(&self) -> Option<SourceState> {
        // rounds rescued from a dead worker's queue are already accepted
        // into the accounts but not yet trained: a snapshot here would
        // mark them delivered and lose them on resume — defer until the
        // trainer drains them (the run loop retries next step)
        if !self.pending.is_empty() {
            return None;
        }
        // otherwise always a clean boundary: cursors are the
        // trainer-accepted frontier, and rounds in flight (or queued)
        // simply regenerate after resume, where the accounts dedupe them
        let skip = if self.ctx.continuous {
            self.accounts
                .delivered
                .iter()
                .map(|s| {
                    let mut v: Vec<u64> = s.iter().copied().collect();
                    v.sort_unstable();
                    v
                })
                .collect()
        } else {
            vec![Vec::new(); self.accounts.expected.len()]
        };
        Some(SourceState {
            kind: "pool".into(),
            rng: None,
            generated: self.received,
            cursors: self.accounts.expected.clone(),
            skip,
            epoch: self.sup.incarnations.iter().copied().max().unwrap_or(0),
        })
    }

    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()> {
        let mut pool = *self;
        pool.stop.store(true, Ordering::SeqCst);
        // dropping the trainer's channel ends release workers blocked in
        // `send`, so join cannot deadlock
        drop(pool.tx.take());
        drop(pool.rx);
        for seat in pool.seats.iter_mut() {
            if let Some(h) = seat.take() {
                // seat bodies run under catch_unwind: join only fails if
                // the exit-report send itself panicked
                let _ = h.join();
            }
        }
        // mid-run failures were already surfaced (and recovered or
        // escalated) by `supervise`; teardown absorbs what remains into
        // the run metas instead of failing a finished run
        while let Ok(exit) = pool.exit_rx.try_recv() {
            match exit.outcome {
                Ok((secs, rounds)) => {
                    pool.totals[exit.slot].0 += secs;
                    pool.totals[exit.slot].1 += rounds;
                }
                Err(e) => pool
                    .sup
                    .worker_errors
                    .push(format!("gen-worker-{}: {e:#}", exit.slot)),
            }
        }
        let mut gen_total = 0.0f64;
        let mut rounds_total = 0u64;
        for (w, (secs, rounds)) in pool.totals.iter().enumerate() {
            log.set_meta(&format!("gen_secs_w{w}"), format!("{secs:.3}"));
            log.set_meta(&format!("gen_rounds_w{w}"), rounds);
            gen_total += secs;
            rounds_total += rounds;
        }
        log.set_meta("gen_total_secs", format!("{gen_total:.3}"));
        log.set_meta("gen_rounds", rounds_total);
        pool.sup.meta(log);
        log.set_meta("engine_retries", pool.retry_count.load(Ordering::SeqCst));
        log.set_meta("dropped_duplicate_rounds", pool.accounts.duplicates);
        Ok(())
    }
}

/// Scripted-fault check at the top of a worker round: fires exactly once
/// per run (`fault_fired`), so a respawned replacement does not re-fault.
/// `Panic` and `Stall` act immediately; `EngineErr` arms the caller's
/// next attempt-0 engine call to fail.
pub(crate) fn maybe_inject(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    rounds_done: u64,
    inject_err: &mut bool,
) {
    let Some(f) = &ctx.fault else { return };
    if f.worker != w
        || rounds_done != f.round
        || sh.fault_fired.swap(true, Ordering::SeqCst)
    {
        return;
    }
    match f.kind {
        FaultKind::Panic => panic!(
            "injected fault: scripted panic in gen-worker-{w} at round {}",
            f.round
        ),
        FaultKind::Stall => std::thread::sleep(Duration::from_secs_f64(
            ctx.stall_timeout * 2.0,
        )),
        FaultKind::EngineErr => *inject_err = true,
    }
}

/// Body of a round-synchronous worker seat (cached / device / naive
/// generators): fetch the freshest policy, generate one round on the
/// lane furthest behind, hand it over, advance the lane ledger.
///
/// Worker `w` at incarnation 0 keeps the seed coordinator's RNG stream
/// (`0xa57c + w`) so M=1 pools replay the seed bitwise; respawns and
/// resume epochs shift the stream so replayed prompts resample fresh.
fn seat_rounds(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    incarnation: u64,
) -> Result<(f64, u64)> {
    // own engine, own PJRT client (separate "GPU")
    let engine = Engine::load(&ctx.artifact_dir)?;
    let taskgen = TaskGen::new(ctx.task, ctx.prompt_len, ctx.resp_len, ctx.seed);
    let stream = w as u64 + (incarnation << 20);
    let mut rng = Pcg32::new(ctx.seed, 0xa57c + stream);
    let mut retry_rng = Pcg32::new(ctx.seed, RETRY_STREAM + stream);
    let policy = RetryPolicy::new(ctx.retries);
    let generator = ctx.gen_engine.build();
    let (mut version, mut params) = sh.bus.latest(w);
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut inject_err = false;
    loop {
        beat(&sh.ctl[w], ctx.origin);
        if sh.stop.load(Ordering::SeqCst) {
            break;
        }
        let mask = sh.ctl[w].lanes.snapshot();
        if mask.is_empty() {
            break; // lanes re-strided away: retire cleanly
        }
        // pick up the freshest published policy (Algorithm 1: "update
        // generation model θ <- θ_i"); the cached view below re-uploads
        // to device only on a version change
        if let Some((v, p)) = sh.bus.fetch(w, version) {
            version = v;
            params = p;
        }
        let lane = pick_lane(&mask, &sh.ledger)?;
        let cursor = sh.ledger[lane].load(Ordering::SeqCst);
        maybe_inject(ctx, sh, w, rounds_done, &mut inject_err);
        let round = policy.run(
            &mut retry_rng,
            |_| {
                sh.retry_count.fetch_add(1, Ordering::SeqCst);
                engine.note_retry(ROUND_ORIGIN);
            },
            |attempt| {
                if inject_err && attempt == 0 {
                    bail!(
                        "injected fault: scripted engine error in \
                         gen-worker-{w}"
                    );
                }
                generate_round(
                    &engine,
                    generator.as_ref(),
                    ParamView::cached("policy", version, &params),
                    version,
                    &taskgen,
                    cursor,
                    ctx.k,
                    ctx.opts,
                    &mut rng,
                    ctx.origin,
                )
            },
        )?;
        inject_err = false;
        gen_total += round.gen_secs;
        beat(&sh.ctl[w], ctx.origin);
        // blocks while K rounds are queued — the staleness bound's
        // back-pressure
        if sh.tx.send(GenMsg { round, lane, indices: None }).is_err() {
            break;
        }
        rounds_done += 1;
        // advance ONLY after the handover (at-least-once): a crash before
        // this store regenerates the round; a crash after the send leaves
        // a duplicate the trainer's accounts drop
        sh.ledger[lane].store(cursor + ctx.hop, Ordering::SeqCst);
    }
    Ok((gen_total, rounds_done))
}

/// One lane's admission position inside an [`Interleave`]: the next
/// (index, dup) to admit, walking the lane's strided sequence from the
/// trainer-accepted frontier and skipping out-of-order deliveries.
struct LanePos {
    lane: usize,
    start: u64,
    idx: u64,
    dup: usize,
    skip: HashSet<u64>,
}

/// Round-robin interleave of the per-lane admission streams a continuous
/// seat owns (a takeover heir owns several). Each lane yields whole
/// prompt groups (`k` duplicates of one index, exactly
/// `TaskGen::admission` order) before the cursor rotates, so an inherited
/// lane is neither starved behind the native one nor allowed to split a
/// sibling group across rotations. With a single lane this degenerates to
/// the plain admission sequence — the bitwise seed contract holds.
struct Interleave<'a> {
    gen: &'a TaskGen,
    stride: u64,
    hop: u64,
    k: usize,
    lanes: Vec<LanePos>,
    cur: usize,
}

impl<'a> Interleave<'a> {
    fn new(
        gen: &'a TaskGen,
        stride: u64,
        hop: u64,
        k: usize,
        resume: Vec<(usize, u64, HashSet<u64>)>,
    ) -> Interleave<'a> {
        let lanes = resume
            .into_iter()
            .map(|(lane, frontier, skip)| LanePos {
                lane,
                start: RLHF_RANGE + lane as u64 * stride,
                idx: frontier,
                dup: 0,
                skip,
            })
            .collect();
        Interleave { gen, stride, hop, k, lanes, cur: 0 }
    }

    fn lane_ids(&self) -> Vec<usize> {
        self.lanes.iter().map(|p| p.lane).collect()
    }
}

impl Iterator for Interleave<'_> {
    type Item = AdmitSeq;

    fn next(&mut self) -> Option<AdmitSeq> {
        if self.lanes.is_empty() {
            return None;
        }
        let n = self.lanes.len();
        let (stride, hop) = (self.stride, self.hop);
        let p = &mut self.lanes[self.cur];
        // already-delivered indices (the respawn skip set) admit nothing
        while p.skip.contains(&p.idx) {
            p.idx = lane_next(p.idx, p.start, stride, hop);
        }
        let item = AdmitSeq {
            index: p.idx,
            dup: p.dup,
            prompt: self.gen.example(p.idx).prompt,
        };
        p.dup += 1;
        if p.dup == self.k {
            p.dup = 0;
            p.idx = lane_next(p.idx, p.start, stride, hop);
            self.cur = (self.cur + 1) % n;
        }
        Some(item)
    }
}

/// Streaming body of a continuous-engine worker seat: drive the slot
/// pool one sweep at a time, re-reading the published policy slot
/// *between decode steps* (PipelineRL's inflight weight swap — in-flight
/// sequences keep their KV cache and finish under the new weights,
/// stamping their remaining tokens with the new version), feeding retired
/// sequences through per-lane [`RoundAssembler`]s and handing assembled
/// rounds over the same bounded queue as the round-synchronous workers —
/// the staleness back-pressure simply pauses the pool mid-flight while
/// `send` blocks.
///
/// `resume` holds one (lane, frontier, skip) triple per owned lane: a
/// respawned incarnation — or a takeover heir inheriting a dead seat's
/// lanes — re-enters each lane at the trainer-accepted frontier, skipping
/// the out-of-order indices already delivered above it, so every
/// post-respawn round is all-fresh.
fn seat_continuous(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    incarnation: u64,
    resume: Vec<(usize, u64, HashSet<u64>)>,
) -> Result<(f64, u64)> {
    let engine = Engine::load(&ctx.artifact_dir)?;
    let taskgen = TaskGen::new(ctx.task, ctx.prompt_len, ctx.resp_len, ctx.seed);
    let stream = w as u64 + (incarnation << 20);
    let mut rng = Pcg32::new(ctx.seed, 0xa57c + stream);
    let mut retry_rng = Pcg32::new(ctx.seed, RETRY_STREAM + stream);
    let policy = RetryPolicy::new(ctx.retries);
    let mcfg = engine.manifest.config.clone();
    let mut backend = DeviceBackend::new(&engine)?;
    let mut pool = Pool::new(PoolCfg {
        slots: mcfg.gen_batch,
        prompt_len: mcfg.prompt_len,
        seq_len: mcfg.seq_len,
        vocab: mcfg.vocab,
        max_cohorts: ctx.max_cohorts,
        admit_min: ctx.admit_min,
    });
    // the same strided prompt partition the round-based workers walk
    // (lane l: blocks of `stride` indices, hopping M·stride, each index
    // k times), consumed one prompt per freed slot — one stream per
    // owned lane, interleaved by prompt group
    let mut admission =
        Interleave::new(&taskgen, ctx.stride, ctx.hop, ctx.k, resume);
    let lane_ids = admission.lane_ids();
    let mut assemblers: Vec<RoundAssembler> = lane_ids
        .iter()
        .map(|_| RoundAssembler::new(mcfg.gen_batch, ctx.k))
        .collect();
    let (mut version, mut params) = sh.bus.latest(w);
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut inject_err = false;
    let mut t_round = ctx.origin.elapsed().as_secs_f64();
    loop {
        beat(&sh.ctl[w], ctx.origin);
        if sh.stop.load(Ordering::SeqCst) || sh.ctl[w].lanes.is_empty() {
            // stop, lanes re-strided away, or a forced takeover retire:
            // exit cleanly; the supervisor respawns the heir over the
            // merged mask (buffered partials regenerate there and dedupe)
            break;
        }
        if let Some((v, p)) = sh.bus.fetch(w, version) {
            version = v;
            params = p;
        }
        maybe_inject(ctx, sh, w, rounds_done, &mut inject_err);
        policy.run(
            &mut retry_rng,
            |_| {
                sh.retry_count.fetch_add(1, Ordering::SeqCst);
                engine.note_retry(ROUND_ORIGIN);
            },
            |attempt| {
                if inject_err && attempt == 0 {
                    bail!(
                        "injected fault: scripted engine error in \
                         gen-worker-{w}"
                    );
                }
                pool.step(
                    &mut backend,
                    ParamView::cached("policy", version, &params),
                    version,
                    &mut admission,
                    ctx.opts,
                    &mut rng,
                )
            },
        )?;
        inject_err = false;
        // what a death right now would abandon with the engine-local KV
        sh.ctl[w].inflight_tok.store(pool.inflight_tokens(), Ordering::SeqCst);
        for c in pool.drain_completed() {
            // route each retirement to its lane's own assembler: rounds
            // stay single-lane, so the per-lane accounts partition holds
            // even when this seat owns inherited lanes
            let lane = ((c.index - RLHF_RANGE) % ctx.hop) / ctx.stride;
            let pos = lane_ids
                .iter()
                .position(|&l| l as u64 == lane)
                .ok_or_else(|| {
                    anyhow!(
                        "retired index {} belongs to lane {lane}, which \
                         gen-worker-{w} does not own",
                        c.index
                    )
                })?;
            assemblers[pos].push(c);
        }
        for (pos, assembler) in assemblers.iter_mut().enumerate() {
            while let Some(groups) = assembler.pop_round() {
                let indices: Vec<u64> = groups.iter().map(|(i, _)| *i).collect();
                let t_now = ctx.origin.elapsed().as_secs_f64();
                let round = round_from_groups(groups, &taskgen, (t_round, t_now));
                gen_total += t_now - t_round;
                rounds_done += 1;
                beat(&sh.ctl[w], ctx.origin);
                // blocks while K rounds are queued — the staleness bound's
                // back-pressure; in-flight sequences wait between sweeps
                if sh
                    .tx
                    .send(GenMsg {
                        round,
                        lane: lane_ids[pos],
                        indices: Some(indices),
                    })
                    .is_err()
                {
                    return Ok((gen_total, rounds_done));
                }
                // blocked-send time belongs to the queue, not generation
                t_round = ctx.origin.elapsed().as_secs_f64();
            }
        }
    }
    Ok((gen_total, rounds_done))
}

/// Assemble a trainer [`Round`] from `gen_batch / k` retired prompt
/// groups (each `k` completions, in dup order) — the continuous engine's
/// counterpart of `generate_round`'s fixed-round output. Examples are
/// regenerated from the pure task stream by index; per-token version
/// provenance aggregates into the round's staleness fields.
pub(crate) fn round_from_groups(
    groups: Vec<(u64, Vec<Completed>)>,
    taskgen: &TaskGen,
    span: (f64, f64),
) -> Round {
    let n: usize = groups.iter().map(|(_, g)| g.len()).sum();
    let mut tokens = Vec::with_capacity(n);
    let mut resp_mask = Vec::with_capacity(n);
    let mut blp = Vec::with_capacity(n);
    let mut terminated = Vec::with_capacity(n);
    let mut examples = Vec::with_capacity(groups.len());
    let start_index = groups.first().map(|(i, _)| *i).unwrap_or(0);
    let mut steps_max = 0usize;
    let mut ver_min = u64::MAX;
    let mut ver_max = 0u64;
    let mut ver_sum = 0.0f64;
    let mut tok_count = 0u64;
    for (index, group) in groups {
        examples.push(taskgen.example(index));
        for c in group {
            steps_max = steps_max.max(c.steps);
            ver_min = ver_min.min(c.version_min);
            ver_max = ver_max.max(c.version_max);
            ver_sum += c.version_sum;
            tok_count += c.steps as u64;
            tokens.push(c.tokens);
            resp_mask.push(c.resp_mask);
            blp.push(c.blp);
            terminated.push(c.terminated);
        }
    }
    Round {
        gen: GenBatch { tokens, resp_mask, blp, terminated, steps: steps_max },
        examples,
        start_index,
        // newest token version: keeps the per-round staleness bound's
        // "freshest data age" meaning under version mixing
        params_version: ver_max,
        tok_version_min: ver_min.min(ver_max),
        tok_version_mean: if tok_count > 0 {
            ver_sum / tok_count as f64
        } else {
            ver_max as f64
        },
        gen_secs: span.1 - span.0,
        gen_span: span,
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;
    use std::sync::atomic::AtomicU64;

    use anyhow::anyhow;

    use super::{
        lane_next, pick_lane, round_from_groups, supervisor_line, Accept,
        Interleave, LaneAccounts, Recovery, Supervision, RLHF_RANGE,
    };
    use crate::data::{Task, TaskGen};
    use crate::gen::continuous::Completed;
    use crate::util::bitset::BitSet;

    #[test]
    fn supervisor_lines_have_one_stable_scrapable_format() {
        assert_eq!(
            supervisor_line(3, "respawn", "restart 1/2"),
            "[supervisor] gen-worker-3 respawn: restart 1/2"
        );
        // every event renders through the same prefix + colon shape, so
        // log scrapers match structure, never prose
        for ev in
            ["respawn", "takeover", "restride", "migrate", "stalled", "heartbeat-resumed"]
        {
            let line = supervisor_line(7, ev, "some detail");
            assert!(line.starts_with("[supervisor] gen-worker-7 "), "{line}");
            assert!(line.ends_with(": some detail"), "{line}");
            assert!(line.contains(&format!(" {ev}: ")), "{line}");
        }
    }

    #[test]
    fn supervision_spends_the_budget_then_takes_over_then_fails_loudly() {
        let mut sup = Supervision::new(2, 0, 1);
        // first death of seat 1: budget remains, respawn at a fresh
        // incarnation
        let r = sup.on_death(1, &anyhow!("boom"), Some(0), "").unwrap();
        assert!(matches!(r, Recovery::Respawn));
        assert_eq!(sup.incarnations, vec![0, 1]);
        assert_eq!(sup.worker_restarts, 1);
        assert!(!sup.degraded());
        // second death: budget spent, a survivor exists — takeover
        let r = sup.on_death(1, &anyhow!("boom"), Some(0), "").unwrap();
        assert!(matches!(r, Recovery::Takeover { heir: 0 }));
        assert!(sup.lost[1] && sup.degraded());
        // heir respawn bumps the incarnation without charging the budget
        sup.on_takeover_respawn(0);
        assert_eq!(sup.incarnations, vec![1, 2]);
        assert_eq!(sup.worker_restarts, 1);
        // last seat dies with no survivor: loud, naming seat and stranded
        // work (serve mode passes its session list here)
        let e = sup
            .on_death(0, &anyhow!("boom"), None, "; serving sessions [3]")
            .unwrap_err()
            .to_string();
        assert!(e.contains("gen-worker-0"), "{e}");
        assert!(e.contains("no surviving workers"), "{e}");
        assert!(e.ends_with("; serving sessions [3]"), "{e}");
        // every death was recorded in the worker_errors meta format
        assert_eq!(sup.worker_errors.len(), 3);
        assert!(sup.worker_errors.iter().all(|s| s.contains(": boom")));
    }

    #[test]
    fn interleaved_admission_matches_single_lane_order_bitwise() {
        // one lane, no skip: exactly TaskGen::admission from the frontier
        let tg = TaskGen::new(Task::Tldr, 8, 4, 3);
        let r = RLHF_RANGE;
        let resume = vec![(0usize, r, HashSet::new())];
        let got: Vec<(u64, usize)> = Interleave::new(&tg, 2, 4, 2, resume)
            .take(8)
            .map(|a| (a.index, a.dup))
            .collect();
        let want: Vec<(u64, usize)> = tg
            .admission(r, 2, 4, 2)
            .take(8)
            .map(|a| (a.index, a.dup))
            .collect();
        assert_eq!(got, want, "single-lane interleave must stay bitwise");
        // and the prompts are the pure example stream's
        let a = Interleave::new(&tg, 2, 4, 2, vec![(0, r, HashSet::new())])
            .next()
            .unwrap();
        assert_eq!(a.prompt, tg.example(r).prompt);
    }

    #[test]
    fn interleaved_admission_takeover_round_robins_and_skips_delivered() {
        let tg = TaskGen::new(Task::Tldr, 8, 4, 3);
        let r = RLHF_RANGE;
        // heir owns lane 0 (frontier r, delivered {r+1} above it) and
        // inherited lane 1 (start r+2, frontier r+3: mid-block), stride 2,
        // hop 4, k 1 — groups alternate lanes, skip drops r+1 entirely
        let resume = vec![
            (0usize, r, [r + 1].into_iter().collect::<HashSet<u64>>()),
            (1usize, r + 3, HashSet::new()),
        ];
        let got: Vec<u64> = Interleave::new(&tg, 2, 4, 1, resume)
            .take(6)
            .map(|a| a.index)
            .collect();
        // lane 0: r, (r+1 skipped) r+4, r+5 …  lane 1: r+3, r+6, r+7 …
        assert_eq!(got, vec![r, r + 3, r + 4, r + 6, r + 5, r + 7]);
    }

    #[test]
    fn continuous_round_aggregates_token_version_provenance() {
        let tg = TaskGen::new(Task::Tldr, 8, 4, 1);
        let mk = |index: u64, dup: usize, vmin: u64, vmax: u64, sum: f64| {
            Completed {
                index,
                dup,
                tokens: vec![0; 12],
                resp_mask: vec![0.0; 12],
                blp: vec![0.0; 12],
                terminated: true,
                steps: 2,
                version_min: vmin,
                version_max: vmax,
                version_sum: sum,
            }
        };
        // two prompt groups of k=2, tokens spanning versions 0..=4
        let groups = vec![
            (5u64, vec![mk(5, 0, 0, 2, 2.0), mk(5, 1, 1, 3, 4.0)]),
            (9u64, vec![mk(9, 0, 2, 4, 6.0), mk(9, 1, 2, 2, 4.0)]),
        ];
        let round = round_from_groups(groups, &tg, (1.0, 3.5));
        // per-round anchor = NEWEST token version (freshest data age);
        // per-token fields carry the oldest and the mean
        assert_eq!(round.params_version, 4);
        assert_eq!(round.tok_version_min, 0);
        let expect_mean = (2.0 + 4.0 + 6.0 + 4.0) / 8.0;
        assert!((round.tok_version_mean - expect_mean).abs() < 1e-12);
        assert_eq!(round.start_index, 5);
        assert_eq!(round.gen.tokens.len(), 4, "k rows per prompt group");
        assert_eq!(round.examples.len(), 2, "one example per prompt");
        assert_eq!(round.examples[1].prompt, tg.example(9).prompt);
        assert_eq!(round.gen.steps, 2);
        assert!((round.gen_secs - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pick_lane_prefers_the_lane_furthest_behind() {
        let ledger: Vec<AtomicU64> =
            [30u64, 10, 20].into_iter().map(AtomicU64::new).collect();
        // owning all three lanes: the lowest cursor wins
        assert_eq!(pick_lane(&BitSet::from_mask(0b111), &ledger).unwrap(), 1);
        // ownership masks restrict the choice
        assert_eq!(pick_lane(&BitSet::from_mask(0b101), &ledger).unwrap(), 2);
        assert_eq!(pick_lane(&BitSet::from_mask(0b001), &ledger).unwrap(), 0);
        // ties go to the lowest lane
        ledger[2].store(10, std::sync::atomic::Ordering::SeqCst);
        assert_eq!(pick_lane(&BitSet::from_mask(0b110), &ledger).unwrap(), 1);
        // an empty mask is a supervision bug, surfaced as an error rather
        // than a panic on the worker seat
        assert!(pick_lane(&BitSet::from_mask(0), &ledger).is_err());
    }

    #[test]
    fn pick_lane_shard_scale_pools_reach_lanes_past_64() {
        // regression for the lifted 64-seat cap: a ledger of 80 lanes,
        // with the heir owning lanes on both sides of the word boundary
        let ledger: Vec<AtomicU64> =
            (0..80u64).map(|l| AtomicU64::new(1000 - l)).collect();
        let mut mask = BitSet::new(80);
        mask.set(3);
        mask.set(77); // cursor 1000 - 77 = 923: furthest behind
        assert_eq!(pick_lane(&mask, &ledger).unwrap(), 77);
        assert_eq!(
            pick_lane(&BitSet::single(70, 80), &ledger).unwrap(),
            70,
            "a single lane above 64 must be schedulable"
        );
    }

    #[test]
    fn lane_next_walks_blocks_and_hops() {
        // lane at start 100, blocks of 3, hop 12:
        // 100 101 102 | 112 113 114 | 124 ...
        assert_eq!(lane_next(100, 100, 3, 12), 101);
        assert_eq!(lane_next(101, 100, 3, 12), 102);
        assert_eq!(lane_next(102, 100, 3, 12), 112);
        assert_eq!(lane_next(114, 100, 3, 12), 124);
        // stride 1 (degenerate geometry): every step is a hop
        assert_eq!(lane_next(100, 100, 1, 2), 102);
    }

    #[test]
    fn lane_accounts_block_mode_dedupes_and_detects_holes() {
        // two lanes, stride 4, hop 8: lane 0 blocks 0,8,16…, lane 1
        // blocks 4,12,20…
        let mut a = LaneAccounts::new(vec![0, 4], 4, 8);
        assert!(matches!(a.accept_block(0, 0).unwrap(), Accept::Fresh));
        assert!(matches!(a.accept_block(1, 4).unwrap(), Accept::Fresh));
        // a respawned worker replaying its last handed-over block
        assert!(matches!(a.accept_block(0, 0).unwrap(), Accept::Duplicate));
        assert_eq!(a.duplicates, 1);
        assert!(matches!(a.accept_block(0, 8).unwrap(), Accept::Fresh));
        // a skipped block can only mean a lost round: loud failure
        let err = a.accept_block(1, 20).unwrap_err().to_string();
        assert!(err.contains("lane 1"), "{err}");
        assert!(err.contains("12"), "names the expected index: {err}");
    }

    #[test]
    fn lane_accounts_continuous_mode_advances_frontier_out_of_order() {
        // one lane at start 0, stride 4, hop 4 (M=1): indices 0,1,2,3,4…
        let mut a = LaneAccounts::new(vec![0], 4, 4);
        // a round retires {1, 3} first (continuous retirement is
        // completion-ordered): frontier stays at 0
        assert!(matches!(a.accept_indices(0, &[1, 3]).unwrap(), Accept::Fresh));
        assert_eq!(a.expected[0], 0);
        assert_eq!(a.delivered[0].len(), 2);
        // {0, 2} closes the gap: frontier sweeps to 4, sets drain
        assert!(matches!(a.accept_indices(0, &[0, 2]).unwrap(), Accept::Fresh));
        assert_eq!(a.expected[0], 4);
        assert!(a.delivered[0].is_empty(), "frontier absorbed the set");
        // full replay is dropped …
        assert!(matches!(
            a.accept_indices(0, &[1, 3]).unwrap(),
            Accept::Duplicate
        ));
        // … but a mixed round means the respawn skip set was wrong
        assert!(a.accept_indices(0, &[3, 4]).is_err());
    }
}
