//! WorkerPool: M supervised generation workers feeding the trainer over
//! a bounded round queue of depth K — the asynchronous end of the
//! [`RoundSource`] design space (paper §3.5/Algorithm 1).
//!
//! Split out of `pipeline.rs` as a pure code move: the trainer loop and
//! the [`ParamBus`] publication cell live there; this module owns the
//! worker seats, their supervision (respawn / lane re-striding /
//! heartbeat watchdog), and the lane ledger that makes crash recovery
//! exactly-once. The serve-while-training [`SessionSource`] in
//! `pipeline.rs` reuses the seat plumbing defined here ([`SpawnCtx`],
//! [`SeatShared`], fault injection, exit reports).
//!
//! [`SessionSource`]: super::pipeline::SessionSource

use std::collections::{HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::checkpoint::{Checkpoint, SourceState};
use super::pipeline::{cursor_stride, ParamBus, RoundSource, TrainerCx};
use super::pretrain::RLHF_RANGE;
use super::trainer::{
    generate_round, sample_opts, Round, SourcedRound, ROUND_ORIGIN,
};
use super::Prepared;
use crate::config::{ExpConfig, FaultKind, FaultPlan, GenEngine};
use crate::data::{Task, TaskGen};
use crate::gen::continuous::{
    AdmitSeq, Completed, DeviceBackend, Pool, PoolCfg, RoundAssembler,
};
use crate::gen::{GenBatch, SampleOpts};
use crate::metrics::{Phase, RunLog, Timeline};
use crate::runtime::{Engine, ParamView, RetryPolicy, RETRY_STREAM};
use crate::util::bitset::{AtomicBitSet, BitSet};
use crate::util::rng::Pcg32;

/// One round crossing the worker → trainer queue, tagged with the lane
/// (prompt-partition stripe) it came from so the trainer's
/// [`LaneAccounts`] can enforce exactly-once delivery across respawns.
pub(crate) struct GenMsg {
    pub(crate) round: Round,
    pub(crate) lane: usize,
    /// Continuous engine only: the prompt indices retired into this round
    /// (continuous lanes retire out of admission order, so block-cursor
    /// accounting does not apply).
    pub(crate) indices: Option<Vec<u64>>,
}

/// Structured exit report of one worker seat: sent on every exit path —
/// clean retirement, engine error, or caught panic.
pub(crate) struct WorkerExit {
    pub(crate) slot: usize,
    pub(crate) outcome: Result<(f64, u64)>,
}

/// Supervisor-side control block of one worker seat: the lanes it owns
/// (a word-array bitset, so pools are no longer capped at 64 seats) and
/// its last heartbeat, in milliseconds since the trainer timeline origin.
pub(crate) struct SlotCtl {
    pub(crate) lanes: AtomicBitSet,
    pub(crate) beat_ms: AtomicU64,
}

pub(crate) fn beat(ctl: &SlotCtl, origin: Instant) {
    ctl.beat_ms
        .store(origin.elapsed().as_millis() as u64, Ordering::SeqCst);
}

/// The lane a worker should generate for next: the one whose cursor is
/// furthest behind (ties to the lowest lane), so an heir that inherited
/// orphaned lanes round-robins them instead of starving one.
fn pick_lane(mask: &BitSet, ledger: &[AtomicU64]) -> Result<usize> {
    mask.ones()
        .min_by_key(|&l| (ledger[l].load(Ordering::SeqCst), l))
        .ok_or_else(|| {
            anyhow!(
                "worker scheduled with an empty lane mask — supervision \
                 should have retired this seat"
            )
        })
}

/// Successor of `idx` in one lane's admission sequence (blocks of
/// `stride` consecutive indices starting at `start`, hopping `hop`
/// between blocks).
fn lane_next(idx: u64, start: u64, stride: u64, hop: u64) -> u64 {
    let rel = idx - start;
    let (block, off) = (rel / hop, rel % hop);
    debug_assert!(off < stride, "index off the lane's admission sequence");
    if off + 1 < stride {
        idx + 1
    } else {
        start + (block + 1) * hop
    }
}

pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

pub(crate) enum Accept {
    Fresh,
    Duplicate,
}

/// Trainer-side delivery accounting, per lane. The worker-side ledger
/// advances only *after* a successful handover (at-least-once); these
/// accounts turn that into exactly-once by dropping replays — and by
/// failing loudly on a *hole*, which no recovery path can legally
/// produce.
struct LaneAccounts {
    stride: u64,
    hop: u64,
    starts: Vec<u64>,
    /// Next index the trainer is owed per lane: block start for
    /// round-synchronous engines, delivered frontier for continuous.
    expected: Vec<u64>,
    /// Continuous engines: indices delivered above the frontier.
    delivered: Vec<HashSet<u64>>,
    duplicates: u64,
}

impl LaneAccounts {
    fn new(starts: Vec<u64>, stride: u64, hop: u64) -> LaneAccounts {
        let n = starts.len();
        LaneAccounts {
            stride,
            hop,
            expected: starts.clone(),
            starts,
            delivered: vec![HashSet::new(); n],
            duplicates: 0,
        }
    }

    fn resume(
        starts: Vec<u64>,
        stride: u64,
        hop: u64,
        cursors: &[u64],
        skip: &[Vec<u64>],
    ) -> LaneAccounts {
        let mut a = LaneAccounts::new(starts, stride, hop);
        a.expected = cursors.to_vec();
        for (lane, s) in skip.iter().enumerate() {
            a.delivered[lane] = s.iter().copied().collect();
        }
        a
    }

    fn accept(&mut self, msg: &GenMsg) -> Result<Accept> {
        match &msg.indices {
            Some(indices) => self.accept_indices(msg.lane, indices),
            None => self.accept_block(msg.lane, msg.round.start_index),
        }
    }

    /// Round-synchronous engines: a round is one whole block; the lane
    /// cursor either matches (fresh), trails (replay after a respawn —
    /// dropped), or was skipped (a lost round: loud failure).
    fn accept_block(&mut self, lane: usize, start: u64) -> Result<Accept> {
        let exp = self.expected[lane];
        if start == exp {
            self.expected[lane] = exp + self.hop;
            Ok(Accept::Fresh)
        } else if start < exp {
            self.duplicates += 1;
            Ok(Accept::Duplicate)
        } else {
            bail!(
                "prompt partition violated: lane {lane} jumped from index \
                 {exp} to {start} — a round was lost without recovery"
            )
        }
    }

    /// Continuous engines: a round is a set of retired prompt indices. A
    /// respawned worker's skip set must make every round all-fresh or
    /// all-replay; a mixed round means the skip set missed a delivery.
    fn accept_indices(&mut self, lane: usize, indices: &[u64]) -> Result<Accept> {
        let fresh = indices
            .iter()
            .filter(|&&i| {
                i >= self.expected[lane] && !self.delivered[lane].contains(&i)
            })
            .count();
        if fresh == 0 {
            self.duplicates += 1;
            return Ok(Accept::Duplicate);
        }
        if fresh < indices.len() {
            bail!(
                "continuous round on lane {lane} mixes {fresh} fresh and {} \
                 replayed prompt indices — the respawn skip set missed a \
                 delivery",
                indices.len() - fresh
            );
        }
        self.delivered[lane].extend(indices.iter().copied());
        // advance the frontier across everything now contiguous
        while self.delivered[lane].remove(&self.expected[lane]) {
            self.expected[lane] = lane_next(
                self.expected[lane],
                self.starts[lane],
                self.stride,
                self.hop,
            );
        }
        Ok(Accept::Fresh)
    }
}

/// Everything needed to (re)spawn a worker seat, owned so replacement
/// threads can be built mid-run without borrowing the config.
#[derive(Clone)]
pub(crate) struct SpawnCtx {
    pub(crate) artifact_dir: PathBuf,
    pub(crate) task: Task,
    pub(crate) prompt_len: usize,
    pub(crate) resp_len: usize,
    pub(crate) seed: u64,
    pub(crate) opts: SampleOpts,
    pub(crate) k: usize,
    pub(crate) gen_engine: GenEngine,
    pub(crate) max_cohorts: usize,
    pub(crate) admit_min: usize,
    pub(crate) stride: u64,
    pub(crate) hop: u64,
    pub(crate) retries: u32,
    pub(crate) stall_timeout: f64,
    pub(crate) fault: Option<FaultPlan>,
    pub(crate) origin: Instant,
    pub(crate) max_restarts: usize,
    pub(crate) continuous: bool,
}

/// The shared handles a worker seat runs against. Seat `w` reads the
/// published policy from its own [`ParamBus`] seat `w` — the fan-out
/// gives every subscriber a private latest-wins cell, so one slow reader
/// never contends with the rest of the pool.
#[derive(Clone)]
pub(crate) struct SeatShared {
    pub(crate) tx: mpsc::SyncSender<GenMsg>,
    pub(crate) bus: Arc<ParamBus>,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) ledger: Arc<Vec<AtomicU64>>,
    pub(crate) ctl: Arc<Vec<SlotCtl>>,
    pub(crate) fault_fired: Arc<AtomicBool>,
    pub(crate) retry_count: Arc<AtomicU64>,
}

/// M generation worker threads, each owning its own PJRT backend (the
/// `xla` crate's client is not `Send`, which conveniently mirrors the
/// paper's separate generation/training processes), feeding the trainer
/// over a bounded queue of depth K:
///
/// - each **worker** pulls the freshest published policy, generates one
///   round, and hands it over `send`, which blocks while the queue is
///   full — that back-pressure is the staleness guarantee;
/// - the **trainer** pops rounds; with K = 0 the queue is a rendezvous
///   and `M = 1, K = 0` reproduces the seed Cleanba coordinator exactly
///   (θ_{t+1} updated with data from θ_t, paper §3.5).
///
/// Workers partition the prompt stream by striding: worker `w` starts at
/// `RLHF_RANGE + w·stride` and hops `M·stride` per round, so pools of any
/// width consume disjoint, contiguously-tiling prompt ranges.
///
/// Parameter publication is a latest-wins seat on the shared
/// [`ParamBus`]: the trainer loop downloads its device-resident params
/// once per publish, snapshots them into an `Arc`, and fans the pointer
/// out to every subscriber seat — workers clone the `Arc`, not the
/// parameters, and re-upload to their device only when the version
/// actually changed (the A.2 "passing policy parameters" cost is paid
/// per publish, never per call).
pub struct WorkerPool {
    rx: mpsc::Receiver<GenMsg>,
    /// The pool's own sender clone: keeps the queue open for respawned
    /// workers, and makes trainer-side `Disconnected` impossible mid-run.
    tx: Option<mpsc::SyncSender<GenMsg>>,
    exit_rx: mpsc::Receiver<WorkerExit>,
    exit_tx: mpsc::Sender<WorkerExit>,
    bus: Arc<ParamBus>,
    stop: Arc<AtomicBool>,
    /// Per-lane next-cursor, advanced by workers *after* handover.
    ledger: Arc<Vec<AtomicU64>>,
    ctl: Arc<Vec<SlotCtl>>,
    fault_fired: Arc<AtomicBool>,
    retry_count: Arc<AtomicU64>,
    ctx: SpawnCtx,
    /// One seat per worker slot; `None` = dead (reaped or re-strided).
    seats: Vec<Option<JoinHandle<()>>>,
    /// Per-slot incarnation: respawns (and resume epochs) shift the
    /// replacement's RNG streams so a replayed prompt block still samples
    /// fresh tokens instead of re-walking the dead worker's stream.
    incarnations: Vec<u64>,
    restarts_used: Vec<usize>,
    accounts: LaneAccounts,
    /// Rounds accepted while draining a dead worker's queue, served
    /// before new receives.
    pending: VecDeque<GenMsg>,
    /// Per-slot accumulated (gen_secs, rounds) across incarnations.
    totals: Vec<(f64, u64)>,
    worker_errors: Vec<String>,
    worker_restarts: u64,
    stalled_now: Vec<bool>,
    ever_stalled: Vec<bool>,
    gen_bs: u64,
    received: u64,
    /// Receive slice between supervision passes.
    poll: Duration,
}

impl WorkerPool {
    /// Spawn `cfg.gen_workers` supervised workers over a queue of depth
    /// `cfg.staleness_bound`. `origin` is the trainer timeline's clock so
    /// worker gen-spans are directly comparable; `bus` is the trainer
    /// loop's publish fan-out, already seeded (from the checkpoint's
    /// policy at its version under `--resume`, else the SFT params at
    /// version 0) — worker `w` subscribes to bus seat `w`. With `resume`,
    /// lanes re-enter the checkpoint's cursors and worker RNG streams
    /// shift to a fresh epoch (async resume is exactly-once, not bitwise
    /// — live worker threads cannot be snapshotted mid-call).
    pub fn spawn(
        cfg: &ExpConfig,
        prep: &Prepared,
        origin: Instant,
        resume: Option<&Checkpoint>,
        bus: Arc<ParamBus>,
    ) -> Result<WorkerPool> {
        let m = cfg.gen_workers.max(1);
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        let stride = cursor_stride(gen_bs, cfg.k_samples);
        let hop = stride * m as u64;
        let continuous = cfg.gen_engine == GenEngine::Continuous;
        let starts: Vec<u64> =
            (0..m).map(|w| RLHF_RANGE + w as u64 * stride).collect();

        let (accounts, epoch0, received) = match resume {
            Some(c) => {
                let s = &c.source;
                if s.kind != "pool" {
                    bail!(
                        "--resume: checkpoint was written by a '{}' round \
                         source but this run is async (worker pool)",
                        s.kind
                    );
                }
                if s.cursors.len() != m {
                    bail!(
                        "--resume: checkpoint has {} worker lanes but \
                         --gen-workers is {m}",
                        s.cursors.len()
                    );
                }
                let skip: Vec<Vec<u64>> = if s.skip.len() == m {
                    s.skip.clone()
                } else if s.skip.is_empty() {
                    vec![Vec::new(); m]
                } else {
                    bail!(
                        "--resume: checkpoint has {} skip lists for {m} \
                         lanes",
                        s.skip.len()
                    );
                };
                (
                    LaneAccounts::resume(
                        starts.clone(),
                        stride,
                        hop,
                        &s.cursors,
                        &skip,
                    ),
                    // past every RNG stream this run already consumed
                    s.epoch + 1,
                    s.generated,
                )
            }
            None => (LaneAccounts::new(starts, stride, hop), 0, 0),
        };

        let (tx, rx) = mpsc::sync_channel::<GenMsg>(cfg.staleness_bound);
        let (exit_tx, exit_rx) = mpsc::channel::<WorkerExit>();
        let stop = Arc::new(AtomicBool::new(false));
        let ledger: Arc<Vec<AtomicU64>> = Arc::new(
            accounts.expected.iter().map(|&c| AtomicU64::new(c)).collect(),
        );
        let now_ms = origin.elapsed().as_millis() as u64;
        let ctl: Arc<Vec<SlotCtl>> = Arc::new(
            (0..m)
                .map(|w| SlotCtl {
                    lanes: AtomicBitSet::single(w, m),
                    beat_ms: AtomicU64::new(now_ms),
                })
                .collect(),
        );
        let ctx = SpawnCtx {
            artifact_dir: cfg.artifact_dir(),
            task: prep.taskgen.task,
            prompt_len: prep.taskgen.prompt_len,
            resp_len: prep.taskgen.resp_len,
            seed: cfg.seed,
            opts: sample_opts(cfg),
            k: cfg.k_samples,
            gen_engine: cfg.gen_engine,
            max_cohorts: cfg.max_cohorts,
            admit_min: cfg.admit_min,
            stride,
            hop,
            retries: cfg.engine_retries,
            stall_timeout: cfg.stall_timeout_secs,
            fault: cfg.inject_fault,
            origin,
            max_restarts: cfg.max_worker_restarts,
            continuous,
        };
        let poll = Duration::from_secs_f64(
            (cfg.stall_timeout_secs / 4.0).clamp(0.010, 0.050),
        );
        let mut pool = WorkerPool {
            rx,
            tx: Some(tx),
            exit_rx,
            exit_tx,
            bus,
            stop,
            ledger,
            ctl,
            fault_fired: Arc::new(AtomicBool::new(false)),
            retry_count: Arc::new(AtomicU64::new(0)),
            ctx,
            seats: (0..m).map(|_| None).collect(),
            incarnations: vec![epoch0; m],
            restarts_used: vec![0; m],
            accounts,
            pending: VecDeque::new(),
            totals: vec![(0.0, 0); m],
            worker_errors: Vec::new(),
            worker_restarts: 0,
            stalled_now: vec![false; m],
            ever_stalled: vec![false; m],
            gen_bs,
            received,
            poll,
        };
        for w in 0..m {
            pool.spawn_seat(w)?;
        }
        Ok(pool)
    }

    /// The shared handles a seat thread runs against.
    fn shared(&self) -> Result<SeatShared> {
        let tx = self.tx.clone().ok_or_else(|| {
            anyhow!(
                "worker pool queue already torn down while (re)spawning a \
                 seat — finish() ran before supervision stopped"
            )
        })?;
        Ok(SeatShared {
            tx,
            bus: self.bus.clone(),
            stop: self.stop.clone(),
            ledger: self.ledger.clone(),
            ctl: self.ctl.clone(),
            fault_fired: self.fault_fired.clone(),
            retry_count: self.retry_count.clone(),
        })
    }

    /// (Re)spawn seat `w` at its current incarnation. The body runs under
    /// `catch_unwind`; every exit path reports a [`WorkerExit`].
    fn spawn_seat(&mut self, w: usize) -> Result<()> {
        let ctx = self.ctx.clone();
        let sh = self.shared()?;
        let exit_tx = self.exit_tx.clone();
        let incarnation = self.incarnations[w];
        // continuous lanes resume from the trainer-accepted frontier,
        // skipping out-of-order deliveries above it
        let resume = (
            self.accounts.expected[w],
            self.accounts.delivered[w].clone(),
        );
        beat(&self.ctl[w], self.ctx.origin);
        let handle = std::thread::Builder::new()
            .name(format!("gen-worker-{w}"))
            .spawn(move || {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if ctx.continuous {
                        let (frontier, skip) = resume;
                        seat_continuous(&ctx, &sh, w, incarnation, frontier, skip)
                    } else {
                        seat_rounds(&ctx, &sh, w, incarnation)
                    }
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow!("panicked: {}", panic_message(p.as_ref())))
                });
                // best-effort: at teardown the receiver may already be gone
                let _ = exit_tx.send(WorkerExit { slot: w, outcome });
            })
            .map_err(|e| anyhow!("spawn gen-worker-{w}: {e}"))?;
        self.seats[w] = Some(handle);
        Ok(())
    }

    /// Reap dead seats (respawn / re-stride / fail) and run the heartbeat
    /// watchdog. Called from `next` between receive slices.
    fn supervise(&mut self) -> Result<()> {
        while let Ok(exit) = self.exit_rx.try_recv() {
            let w = exit.slot;
            if let Some(h) = self.seats[w].take() {
                let _ = h.join();
            }
            match exit.outcome {
                Ok((secs, rounds)) => {
                    self.totals[w].0 += secs;
                    self.totals[w].1 += rounds;
                    // a clean exit is only legitimate at teardown or after
                    // its lanes were re-strided away
                    let retired = self.ctl[w].lanes.is_empty();
                    if !self.stop.load(Ordering::SeqCst) && !retired {
                        self.handle_death(
                            w,
                            anyhow!("exited cleanly mid-run (queue closed?)"),
                        )?;
                    }
                }
                Err(e) => self.handle_death(w, e)?,
            }
        }
        let now_ms = self.ctx.origin.elapsed().as_millis() as u64;
        for w in 0..self.seats.len() {
            if self.seats[w].is_none() {
                self.stalled_now[w] = false;
                continue;
            }
            let age =
                now_ms.saturating_sub(self.ctl[w].beat_ms.load(Ordering::SeqCst));
            let stalled = age as f64 / 1000.0 > self.ctx.stall_timeout;
            if stalled && !self.stalled_now[w] {
                self.stalled_now[w] = true;
                self.ever_stalled[w] = true;
                eprintln!(
                    "[supervisor] gen-worker-{w} silent for {:.1}s \
                     (--stall-timeout-secs {:.1}) — flagged as stalled",
                    age as f64 / 1000.0,
                    self.ctx.stall_timeout
                );
            } else if !stalled && self.stalled_now[w] {
                self.stalled_now[w] = false;
                eprintln!("[supervisor] gen-worker-{w} resumed heartbeats");
            }
        }
        Ok(())
    }

    /// Absorb every queued round into the accounts (fresh ones buffer in
    /// `pending`). Must run before computing a respawn position: a round
    /// sitting in the queue at worker death is not yet accounted, and a
    /// replacement spawned without it would replay it as a partial
    /// duplicate.
    fn drain_queue(&mut self) -> Result<()> {
        while let Ok(msg) = self.rx.try_recv() {
            if let Accept::Fresh = self.accounts.accept(&msg)? {
                self.pending.push_back(msg);
            }
        }
        Ok(())
    }

    fn handle_death(&mut self, w: usize, err: anyhow::Error) -> Result<()> {
        self.drain_queue()?;
        self.worker_errors.push(format!("gen-worker-{w}: {err:#}"));
        let lanes = self.ctl[w].lanes.snapshot();
        // the dead worker may have generated without completing the
        // handover: rewind-proof the ledger to the accepted frontier
        for l in lanes.ones() {
            self.ledger[l].fetch_max(self.accounts.expected[l], Ordering::SeqCst);
        }
        if self.restarts_used[w] < self.ctx.max_restarts {
            self.restarts_used[w] += 1;
            self.worker_restarts += 1;
            self.incarnations[w] += 1;
            eprintln!(
                "[supervisor] gen-worker-{w} died: {err:#}; respawning on a \
                 fresh engine (restart {}/{})",
                self.restarts_used[w], self.ctx.max_restarts
            );
            return self.spawn_seat(w);
        }
        if self.ctx.continuous {
            bail!(
                "gen-worker-{w} is unrecoverable after {} restarts: {err:#}; \
                 a continuous lane's in-flight sequences cannot be \
                 re-strided onto a survivor",
                self.ctx.max_restarts
            );
        }
        let heir =
            (0..self.seats.len()).find(|&h| h != w && self.seats[h].is_some());
        match heir {
            Some(h) => {
                self.ctl[w].lanes.clear();
                self.ctl[h].lanes.merge(&lanes);
                eprintln!(
                    "[supervisor] gen-worker-{w} died with no restarts left: \
                     {err:#}; re-striding its lanes {lanes} onto \
                     gen-worker-{h}"
                );
                Ok(())
            }
            None => bail!(
                "gen-worker-{w} died with no restarts left and no surviving \
                 workers: {err:#}"
            ),
        }
    }

    fn deliver(
        &mut self,
        msg: GenMsg,
        timeline: &mut Timeline,
        t_wait: f64,
    ) -> SourcedRound {
        let t_got = timeline.origin().elapsed().as_secs_f64();
        timeline.push_span(Phase::Idle, t_wait, t_got);
        timeline.push_span(
            Phase::Generate,
            msg.round.gen_span.0,
            msg.round.gen_span.1,
        );
        self.received += 1;
        // worker rounds crossed the thread boundary as host data: the
        // trainer re-stages them (the async mode's one upload per round)
        SourcedRound { round: msg.round, staged: None }
    }
}

impl RoundSource for WorkerPool {
    fn label(&self) -> &'static str {
        "async"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { timeline, .. } = cx;
        let t_wait = timeline.origin().elapsed().as_secs_f64();
        loop {
            // rounds rescued from a dead worker's queue go first
            if let Some(msg) = self.pending.pop_front() {
                return Ok(self.deliver(msg, timeline, t_wait));
            }
            self.supervise()?;
            match self.rx.recv_timeout(self.poll) {
                Ok(msg) => match self.accounts.accept(&msg)? {
                    Accept::Fresh => {
                        return Ok(self.deliver(msg, timeline, t_wait))
                    }
                    // a respawned worker replaying its at-least-once
                    // window: drop, it is already trained on
                    Accept::Duplicate => continue,
                },
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                    "round queue disconnected while the pool holds a \
                     sender — this is a bug"
                ),
            }
        }
    }

    fn episodes(&self) -> u64 {
        // counted at handover: rounds still in flight inside a worker
        // (or queued) are not episodes yet
        self.received * self.gen_bs
    }

    fn snapshot(&self) -> Option<SourceState> {
        // always at a clean boundary: cursors are the trainer-accepted
        // frontier, and rounds in flight (or queued) simply regenerate
        // after resume, where the accounts would dedupe them
        let skip = if self.ctx.continuous {
            self.accounts
                .delivered
                .iter()
                .map(|s| {
                    let mut v: Vec<u64> = s.iter().copied().collect();
                    v.sort_unstable();
                    v
                })
                .collect()
        } else {
            vec![Vec::new(); self.accounts.expected.len()]
        };
        Some(SourceState {
            kind: "pool".into(),
            rng: None,
            generated: self.received,
            cursors: self.accounts.expected.clone(),
            skip,
            epoch: self.incarnations.iter().copied().max().unwrap_or(0),
        })
    }

    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()> {
        let mut pool = *self;
        pool.stop.store(true, Ordering::SeqCst);
        // dropping the trainer's channel ends release workers blocked in
        // `send`, so join cannot deadlock
        drop(pool.tx.take());
        drop(pool.rx);
        for seat in pool.seats.iter_mut() {
            if let Some(h) = seat.take() {
                // seat bodies run under catch_unwind: join only fails if
                // the exit-report send itself panicked
                let _ = h.join();
            }
        }
        // mid-run failures were already surfaced (and recovered or
        // escalated) by `supervise`; teardown absorbs what remains into
        // the run metas instead of failing a finished run
        while let Ok(exit) = pool.exit_rx.try_recv() {
            match exit.outcome {
                Ok((secs, rounds)) => {
                    pool.totals[exit.slot].0 += secs;
                    pool.totals[exit.slot].1 += rounds;
                }
                Err(e) => pool
                    .worker_errors
                    .push(format!("gen-worker-{}: {e:#}", exit.slot)),
            }
        }
        let mut gen_total = 0.0f64;
        let mut rounds_total = 0u64;
        for (w, (secs, rounds)) in pool.totals.iter().enumerate() {
            log.set_meta(&format!("gen_secs_w{w}"), format!("{secs:.3}"));
            log.set_meta(&format!("gen_rounds_w{w}"), rounds);
            gen_total += secs;
            rounds_total += rounds;
        }
        log.set_meta("gen_total_secs", format!("{gen_total:.3}"));
        log.set_meta("gen_rounds", rounds_total);
        log.set_meta("worker_restarts", pool.worker_restarts);
        log.set_meta(
            "stalled_workers",
            pool.ever_stalled.iter().filter(|&&b| b).count(),
        );
        log.set_meta("engine_retries", pool.retry_count.load(Ordering::SeqCst));
        log.set_meta("dropped_duplicate_rounds", pool.accounts.duplicates);
        if !pool.worker_errors.is_empty() {
            log.set_meta("worker_errors", pool.worker_errors.join(" | "));
        }
        Ok(())
    }
}

/// Scripted-fault check at the top of a worker round: fires exactly once
/// per run (`fault_fired`), so a respawned replacement does not re-fault.
/// `Panic` and `Stall` act immediately; `EngineErr` arms the caller's
/// next attempt-0 engine call to fail.
pub(crate) fn maybe_inject(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    rounds_done: u64,
    inject_err: &mut bool,
) {
    let Some(f) = &ctx.fault else { return };
    if f.worker != w
        || rounds_done != f.round
        || sh.fault_fired.swap(true, Ordering::SeqCst)
    {
        return;
    }
    match f.kind {
        FaultKind::Panic => panic!(
            "injected fault: scripted panic in gen-worker-{w} at round {}",
            f.round
        ),
        FaultKind::Stall => std::thread::sleep(Duration::from_secs_f64(
            ctx.stall_timeout * 2.0,
        )),
        FaultKind::EngineErr => *inject_err = true,
    }
}

/// Body of a round-synchronous worker seat (cached / device / naive
/// generators): fetch the freshest policy, generate one round on the
/// lane furthest behind, hand it over, advance the lane ledger.
///
/// Worker `w` at incarnation 0 keeps the seed coordinator's RNG stream
/// (`0xa57c + w`) so M=1 pools replay the seed bitwise; respawns and
/// resume epochs shift the stream so replayed prompts resample fresh.
fn seat_rounds(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    incarnation: u64,
) -> Result<(f64, u64)> {
    // own engine, own PJRT client (separate "GPU")
    let engine = Engine::load(&ctx.artifact_dir)?;
    let taskgen = TaskGen::new(ctx.task, ctx.prompt_len, ctx.resp_len, ctx.seed);
    let stream = w as u64 + (incarnation << 20);
    let mut rng = Pcg32::new(ctx.seed, 0xa57c + stream);
    let mut retry_rng = Pcg32::new(ctx.seed, RETRY_STREAM + stream);
    let policy = RetryPolicy::new(ctx.retries);
    let generator = ctx.gen_engine.build();
    let (mut version, mut params) = sh.bus.latest(w);
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut inject_err = false;
    loop {
        beat(&sh.ctl[w], ctx.origin);
        if sh.stop.load(Ordering::SeqCst) {
            break;
        }
        let mask = sh.ctl[w].lanes.snapshot();
        if mask.is_empty() {
            break; // lanes re-strided away: retire cleanly
        }
        // pick up the freshest published policy (Algorithm 1: "update
        // generation model θ <- θ_i"); the cached view below re-uploads
        // to device only on a version change
        if let Some((v, p)) = sh.bus.fetch(w, version) {
            version = v;
            params = p;
        }
        let lane = pick_lane(&mask, &sh.ledger)?;
        let cursor = sh.ledger[lane].load(Ordering::SeqCst);
        maybe_inject(ctx, sh, w, rounds_done, &mut inject_err);
        let round = policy.run(
            &mut retry_rng,
            |_| {
                sh.retry_count.fetch_add(1, Ordering::SeqCst);
                engine.note_retry(ROUND_ORIGIN);
            },
            |attempt| {
                if inject_err && attempt == 0 {
                    bail!(
                        "injected fault: scripted engine error in \
                         gen-worker-{w}"
                    );
                }
                generate_round(
                    &engine,
                    generator.as_ref(),
                    ParamView::cached("policy", version, &params),
                    version,
                    &taskgen,
                    cursor,
                    ctx.k,
                    ctx.opts,
                    &mut rng,
                    ctx.origin,
                )
            },
        )?;
        inject_err = false;
        gen_total += round.gen_secs;
        beat(&sh.ctl[w], ctx.origin);
        // blocks while K rounds are queued — the staleness bound's
        // back-pressure
        if sh.tx.send(GenMsg { round, lane, indices: None }).is_err() {
            break;
        }
        rounds_done += 1;
        // advance ONLY after the handover (at-least-once): a crash before
        // this store regenerates the round; a crash after the send leaves
        // a duplicate the trainer's accounts drop
        sh.ledger[lane].store(cursor + ctx.hop, Ordering::SeqCst);
    }
    Ok((gen_total, rounds_done))
}

/// Streaming body of a continuous-engine worker seat: drive the slot
/// pool one sweep at a time, re-reading the published policy slot
/// *between decode steps* (PipelineRL's inflight weight swap — in-flight
/// sequences keep their KV cache and finish under the new weights,
/// stamping their remaining tokens with the new version), feeding retired
/// sequences through a [`RoundAssembler`] and handing assembled rounds
/// over the same bounded queue as the round-synchronous workers — the
/// staleness back-pressure simply pauses the pool mid-flight while `send`
/// blocks.
///
/// A respawned incarnation re-enters the lane at the trainer-accepted
/// `frontier`, skipping the out-of-order indices already delivered above
/// it — the admission filter makes every post-respawn round all-fresh.
fn seat_continuous(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    incarnation: u64,
    frontier: u64,
    skip: HashSet<u64>,
) -> Result<(f64, u64)> {
    let engine = Engine::load(&ctx.artifact_dir)?;
    let taskgen = TaskGen::new(ctx.task, ctx.prompt_len, ctx.resp_len, ctx.seed);
    let stream = w as u64 + (incarnation << 20);
    let mut rng = Pcg32::new(ctx.seed, 0xa57c + stream);
    let mut retry_rng = Pcg32::new(ctx.seed, RETRY_STREAM + stream);
    let policy = RetryPolicy::new(ctx.retries);
    let mcfg = engine.manifest.config.clone();
    let mut backend = DeviceBackend::new(&engine)?;
    let mut pool = Pool::new(PoolCfg {
        slots: mcfg.gen_batch,
        prompt_len: mcfg.prompt_len,
        seq_len: mcfg.seq_len,
        vocab: mcfg.vocab,
        max_cohorts: ctx.max_cohorts,
        admit_min: ctx.admit_min,
    });
    // the same strided prompt partition the round-based workers walk
    // (worker w: blocks of `stride` indices, hopping M·stride, each
    // index k times), consumed one prompt per freed slot — re-entered at
    // the block holding the frontier, minus what was already delivered
    let start = RLHF_RANGE + w as u64 * ctx.stride;
    let base = start + ((frontier - start) / ctx.hop) * ctx.hop;
    let mut admission = taskgen
        .admission(base, ctx.stride, ctx.hop, ctx.k)
        .filter(move |a| a.index >= frontier && !skip.contains(&a.index))
        .map(|a| AdmitSeq { index: a.index, dup: a.dup, prompt: a.prompt });
    let mut assembler = RoundAssembler::new(mcfg.gen_batch, ctx.k);
    let (mut version, mut params) = sh.bus.latest(w);
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut inject_err = false;
    let mut t_round = ctx.origin.elapsed().as_secs_f64();
    loop {
        beat(&sh.ctl[w], ctx.origin);
        if sh.stop.load(Ordering::SeqCst) || sh.ctl[w].lanes.is_empty() {
            break;
        }
        if let Some((v, p)) = sh.bus.fetch(w, version) {
            version = v;
            params = p;
        }
        maybe_inject(ctx, sh, w, rounds_done, &mut inject_err);
        policy.run(
            &mut retry_rng,
            |_| {
                sh.retry_count.fetch_add(1, Ordering::SeqCst);
                engine.note_retry(ROUND_ORIGIN);
            },
            |attempt| {
                if inject_err && attempt == 0 {
                    bail!(
                        "injected fault: scripted engine error in \
                         gen-worker-{w}"
                    );
                }
                pool.step(
                    &mut backend,
                    ParamView::cached("policy", version, &params),
                    version,
                    &mut admission,
                    ctx.opts,
                    &mut rng,
                )
            },
        )?;
        inject_err = false;
        for c in pool.drain_completed() {
            assembler.push(c);
        }
        while let Some(groups) = assembler.pop_round() {
            let indices: Vec<u64> = groups.iter().map(|(i, _)| *i).collect();
            let t_now = ctx.origin.elapsed().as_secs_f64();
            let round = round_from_groups(groups, &taskgen, (t_round, t_now));
            gen_total += t_now - t_round;
            rounds_done += 1;
            beat(&sh.ctl[w], ctx.origin);
            // blocks while K rounds are queued — the staleness bound's
            // back-pressure; in-flight sequences wait between sweeps
            if sh
                .tx
                .send(GenMsg { round, lane: w, indices: Some(indices) })
                .is_err()
            {
                return Ok((gen_total, rounds_done));
            }
            // blocked-send time belongs to the queue, not generation
            t_round = ctx.origin.elapsed().as_secs_f64();
        }
    }
    Ok((gen_total, rounds_done))
}

/// Assemble a trainer [`Round`] from `gen_batch / k` retired prompt
/// groups (each `k` completions, in dup order) — the continuous engine's
/// counterpart of `generate_round`'s fixed-round output. Examples are
/// regenerated from the pure task stream by index; per-token version
/// provenance aggregates into the round's staleness fields.
pub(crate) fn round_from_groups(
    groups: Vec<(u64, Vec<Completed>)>,
    taskgen: &TaskGen,
    span: (f64, f64),
) -> Round {
    let n: usize = groups.iter().map(|(_, g)| g.len()).sum();
    let mut tokens = Vec::with_capacity(n);
    let mut resp_mask = Vec::with_capacity(n);
    let mut blp = Vec::with_capacity(n);
    let mut terminated = Vec::with_capacity(n);
    let mut examples = Vec::with_capacity(groups.len());
    let start_index = groups.first().map(|(i, _)| *i).unwrap_or(0);
    let mut steps_max = 0usize;
    let mut ver_min = u64::MAX;
    let mut ver_max = 0u64;
    let mut ver_sum = 0.0f64;
    let mut tok_count = 0u64;
    for (index, group) in groups {
        examples.push(taskgen.example(index));
        for c in group {
            steps_max = steps_max.max(c.steps);
            ver_min = ver_min.min(c.version_min);
            ver_max = ver_max.max(c.version_max);
            ver_sum += c.version_sum;
            tok_count += c.steps as u64;
            tokens.push(c.tokens);
            resp_mask.push(c.resp_mask);
            blp.push(c.blp);
            terminated.push(c.terminated);
        }
    }
    Round {
        gen: GenBatch { tokens, resp_mask, blp, terminated, steps: steps_max },
        examples,
        start_index,
        // newest token version: keeps the per-round staleness bound's
        // "freshest data age" meaning under version mixing
        params_version: ver_max,
        tok_version_min: ver_min.min(ver_max),
        tok_version_mean: if tok_count > 0 {
            ver_sum / tok_count as f64
        } else {
            ver_max as f64
        },
        gen_secs: span.1 - span.0,
        gen_span: span,
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::AtomicU64;

    use super::{lane_next, pick_lane, round_from_groups, Accept, LaneAccounts};
    use crate::data::{Task, TaskGen};
    use crate::gen::continuous::Completed;
    use crate::util::bitset::BitSet;

    #[test]
    fn continuous_round_aggregates_token_version_provenance() {
        let tg = TaskGen::new(Task::Tldr, 8, 4, 1);
        let mk = |index: u64, dup: usize, vmin: u64, vmax: u64, sum: f64| {
            Completed {
                index,
                dup,
                tokens: vec![0; 12],
                resp_mask: vec![0.0; 12],
                blp: vec![0.0; 12],
                terminated: true,
                steps: 2,
                version_min: vmin,
                version_max: vmax,
                version_sum: sum,
            }
        };
        // two prompt groups of k=2, tokens spanning versions 0..=4
        let groups = vec![
            (5u64, vec![mk(5, 0, 0, 2, 2.0), mk(5, 1, 1, 3, 4.0)]),
            (9u64, vec![mk(9, 0, 2, 4, 6.0), mk(9, 1, 2, 2, 4.0)]),
        ];
        let round = round_from_groups(groups, &tg, (1.0, 3.5));
        // per-round anchor = NEWEST token version (freshest data age);
        // per-token fields carry the oldest and the mean
        assert_eq!(round.params_version, 4);
        assert_eq!(round.tok_version_min, 0);
        let expect_mean = (2.0 + 4.0 + 6.0 + 4.0) / 8.0;
        assert!((round.tok_version_mean - expect_mean).abs() < 1e-12);
        assert_eq!(round.start_index, 5);
        assert_eq!(round.gen.tokens.len(), 4, "k rows per prompt group");
        assert_eq!(round.examples.len(), 2, "one example per prompt");
        assert_eq!(round.examples[1].prompt, tg.example(9).prompt);
        assert_eq!(round.gen.steps, 2);
        assert!((round.gen_secs - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pick_lane_prefers_the_lane_furthest_behind() {
        let ledger: Vec<AtomicU64> =
            [30u64, 10, 20].into_iter().map(AtomicU64::new).collect();
        // owning all three lanes: the lowest cursor wins
        assert_eq!(pick_lane(&BitSet::from_mask(0b111), &ledger).unwrap(), 1);
        // ownership masks restrict the choice
        assert_eq!(pick_lane(&BitSet::from_mask(0b101), &ledger).unwrap(), 2);
        assert_eq!(pick_lane(&BitSet::from_mask(0b001), &ledger).unwrap(), 0);
        // ties go to the lowest lane
        ledger[2].store(10, std::sync::atomic::Ordering::SeqCst);
        assert_eq!(pick_lane(&BitSet::from_mask(0b110), &ledger).unwrap(), 1);
        // an empty mask is a supervision bug, surfaced as an error rather
        // than a panic on the worker seat
        assert!(pick_lane(&BitSet::from_mask(0), &ledger).is_err());
    }

    #[test]
    fn pick_lane_shard_scale_pools_reach_lanes_past_64() {
        // regression for the lifted 64-seat cap: a ledger of 80 lanes,
        // with the heir owning lanes on both sides of the word boundary
        let ledger: Vec<AtomicU64> =
            (0..80u64).map(|l| AtomicU64::new(1000 - l)).collect();
        let mut mask = BitSet::new(80);
        mask.set(3);
        mask.set(77); // cursor 1000 - 77 = 923: furthest behind
        assert_eq!(pick_lane(&mask, &ledger).unwrap(), 77);
        assert_eq!(
            pick_lane(&BitSet::single(70, 80), &ledger).unwrap(),
            70,
            "a single lane above 64 must be schedulable"
        );
    }

    #[test]
    fn lane_next_walks_blocks_and_hops() {
        // lane at start 100, blocks of 3, hop 12:
        // 100 101 102 | 112 113 114 | 124 ...
        assert_eq!(lane_next(100, 100, 3, 12), 101);
        assert_eq!(lane_next(101, 100, 3, 12), 102);
        assert_eq!(lane_next(102, 100, 3, 12), 112);
        assert_eq!(lane_next(114, 100, 3, 12), 124);
        // stride 1 (degenerate geometry): every step is a hop
        assert_eq!(lane_next(100, 100, 1, 2), 102);
    }

    #[test]
    fn lane_accounts_block_mode_dedupes_and_detects_holes() {
        // two lanes, stride 4, hop 8: lane 0 blocks 0,8,16…, lane 1
        // blocks 4,12,20…
        let mut a = LaneAccounts::new(vec![0, 4], 4, 8);
        assert!(matches!(a.accept_block(0, 0).unwrap(), Accept::Fresh));
        assert!(matches!(a.accept_block(1, 4).unwrap(), Accept::Fresh));
        // a respawned worker replaying its last handed-over block
        assert!(matches!(a.accept_block(0, 0).unwrap(), Accept::Duplicate));
        assert_eq!(a.duplicates, 1);
        assert!(matches!(a.accept_block(0, 8).unwrap(), Accept::Fresh));
        // a skipped block can only mean a lost round: loud failure
        let err = a.accept_block(1, 20).unwrap_err().to_string();
        assert!(err.contains("lane 1"), "{err}");
        assert!(err.contains("12"), "names the expected index: {err}");
    }

    #[test]
    fn lane_accounts_continuous_mode_advances_frontier_out_of_order() {
        // one lane at start 0, stride 4, hop 4 (M=1): indices 0,1,2,3,4…
        let mut a = LaneAccounts::new(vec![0], 4, 4);
        // a round retires {1, 3} first (continuous retirement is
        // completion-ordered): frontier stays at 0
        assert!(matches!(a.accept_indices(0, &[1, 3]).unwrap(), Accept::Fresh));
        assert_eq!(a.expected[0], 0);
        assert_eq!(a.delivered[0].len(), 2);
        // {0, 2} closes the gap: frontier sweeps to 4, sets drain
        assert!(matches!(a.accept_indices(0, &[0, 2]).unwrap(), Accept::Fresh));
        assert_eq!(a.expected[0], 4);
        assert!(a.delivered[0].is_empty(), "frontier absorbed the set");
        // full replay is dropped …
        assert!(matches!(
            a.accept_indices(0, &[1, 3]).unwrap(),
            Accept::Duplicate
        ));
        // … but a mixed round means the respawn skip set was wrong
        assert!(a.accept_indices(0, &[3, 4]).is_err());
    }
}
