//! Synchronous RLHF (paper Fig 2 top / Fig 12 top): generate, then train,
//! on the same resources — generation idles while training and vice versa.
//!
//! Also implements the off-policyness ladder of §3.2: generate N
//! mini-batches with the current policy, then take N sequential updates.
//! N=1 is fully on-policy; larger N makes later updates increasingly
//! off-policy (the data's behaviour policy is N-1 updates stale by the
//! last minibatch).
//!
//! Generation and training share one engine here, so the policy params
//! never leave the device: generation reads the trainer's live device
//! buffer directly (`TrainState::param_view`).

use anyhow::Result;

use super::trainer::{
    assemble, generate_round, label_round, round_metrics, rounds_per_batch,
    sample_opts, staleness, train_on_batch, LabelScratch, Labels, Round,
};
use super::RunOutput;
use crate::config::ExpConfig;
use crate::coordinator::pretrain::RLHF_RANGE;
use crate::data::TaskGen;
use crate::gen::fused::FusedEngine;
use crate::metrics::{Phase, RunLog, Timeline};
use crate::runtime::{Engine, TrainState};
use crate::util::rng::Pcg32;

/// Run synchronous RLHF. The SFT checkpoint in `prep` is both the initial
/// policy and the KL reference.
pub fn run(cfg: &ExpConfig, prep: &super::Prepared, verbose: bool) -> Result<RunOutput> {
    let engine: &Engine = &prep.engine;
    let taskgen: &TaskGen = &prep.taskgen;
    let sft_params = prep.sft_params.clone();
    let generator = FusedEngine::default();
    let mut rng = Pcg32::new(cfg.seed, 0x5c);
    let mut state = TrainState::new(sft_params.clone());
    let mut scratch = LabelScratch::default();
    let mut log = RunLog::new();
    log.set_meta("label", cfg.label());
    let mut timeline = Timeline::new();
    let origin = timeline.origin();

    let gen_bs = engine.manifest.config.gen_batch as u64;
    let rpb = rounds_per_batch(cfg.k_samples);
    let n = cfg.n_minibatches;
    let mut cursor = RLHF_RANGE;
    let mut episodes = 0u64;
    let mut step = 0u64;
    let mut version = 0u64;

    'outer: while step < cfg.steps {
        // ---- generation phase: N minibatches of data, frozen policy ----
        let mut batches: Vec<Vec<(Round, Labels)>> = Vec::with_capacity(n);
        for _ in 0..n {
            let mut rounds = Vec::with_capacity(rpb);
            for _ in 0..rpb {
                let round = timeline.record(Phase::Generate, || {
                    generate_round(
                        engine,
                        &generator,
                        state.param_view("policy", version),
                        version,
                        taskgen,
                        cursor,
                        cfg.k_samples,
                        sample_opts(cfg),
                        &mut rng,
                        origin,
                    )
                })?;
                cursor += (gen_bs / cfg.k_samples as u64).max(1);
                episodes += gen_bs;
                let labels = timeline.record(Phase::Score, || {
                    label_round(
                        engine,
                        &round,
                        &sft_params,
                        prep.rm_scorer(),
                        cfg.k_samples,
                        cfg.eos_penalty,
                        cfg.gold_reward,
                        &mut scratch,
                    )
                })?;
                rounds.push((round, labels));
            }
            batches.push(rounds);
        }

        // ---- training phase: N sequential updates on the frozen data ----
        for rounds in &batches {
            let batch = assemble(engine, cfg.algo, rounds, cfg.k_samples)?;
            let all_metrics = timeline.record(Phase::Train, || {
                train_on_batch(
                    engine,
                    &mut state,
                    &batch,
                    cfg.lr,
                    cfg.updates_per_batch,
                )
            })?;
            version += cfg.updates_per_batch as u64;
            step += 1;

            let (_, labels) = &rounds[0];
            let mut row = round_metrics(labels);
            let m = all_metrics.last().unwrap();
            row.push(("loss", m[0]));
            row.push((
                "staleness",
                staleness(version, labels_version(rounds)) as f32,
            ));
            log.push(step, episodes, timeline.wall(), &row);
            if verbose && step % 8 == 0 {
                eprintln!(
                    "[sync {}] step {step}/{} episodes {episodes} \
                     win {:.3} kl-ppl {:.4} loss {:.4}",
                    cfg.algo,
                    cfg.steps,
                    log.recent_mean("win_rate", 8).unwrap_or(0.0),
                    log.recent_mean("kl_ppl", 8).unwrap_or(0.0),
                    m[0],
                );
            }
            if step >= cfg.steps {
                break 'outer;
            }
        }
    }

    Ok(RunOutput {
        final_params: state.into_params(engine)?,
        log,
        timeline,
        episodes,
    })
}

fn labels_version(rounds: &[(Round, Labels)]) -> u64 {
    rounds.iter().map(|(r, _)| r.params_version).max().unwrap_or(0)
}
