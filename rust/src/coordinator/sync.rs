//! Synchronous RLHF (paper Fig 2 top / Fig 12 top): generate, then train,
//! on the same resources — generation idles while training and vice versa.
//!
//! Also implements the off-policyness ladder of §3.2: generate N
//! mini-batches with the current policy, then take N sequential updates.
//! N=1 is fully on-policy; larger N makes later updates increasingly
//! off-policy (the data's behaviour policy is N-1 updates stale by the
//! last minibatch).
//!
//! Generation and training share one engine here, so the policy params
//! never leave the device: generation reads the trainer's live device
//! buffer directly (`TrainState::param_view`).

use anyhow::Result;

use super::trainer::{
    assemble, generate_round, round_metrics, rounds_per_batch, sample_opts,
    staleness, stage_and_label, train_on_batch, LabelScratch, LabelledRound,
};
use super::RunOutput;
use crate::config::ExpConfig;
use crate::coordinator::pretrain::RLHF_RANGE;
use crate::data::TaskGen;
use crate::metrics::{Phase, RunLog, Timeline};
use crate::runtime::{Engine, TrainState};
use crate::util::rng::Pcg32;

/// Run synchronous RLHF. The SFT checkpoint in `prep` is both the initial
/// policy and the KL reference.
pub fn run(cfg: &ExpConfig, prep: &super::Prepared, verbose: bool) -> Result<RunOutput> {
    let engine: &Engine = &prep.engine;
    let taskgen: &TaskGen = &prep.taskgen;
    let sft_params = prep.sft_params.clone();
    let generator = cfg.gen_engine.build();
    let mut rng = Pcg32::new(cfg.seed, 0x5c);
    let mut state = TrainState::new(sft_params.clone());
    let mut scratch = LabelScratch::default();
    let mut log = RunLog::new();
    log.set_meta("label", cfg.label());
    let mut timeline = Timeline::new();
    let origin = timeline.origin();

    let gen_bs = engine.manifest.config.gen_batch as u64;
    let rpb = rounds_per_batch(cfg.k_samples);
    let n = cfg.n_minibatches;
    let mut cursor = RLHF_RANGE;
    let mut episodes = 0u64;
    let mut step = 0u64;
    let mut version = 0u64;

    'outer: while step < cfg.steps {
        // ---- generation phase: N minibatches of data, frozen policy ----
        let mut batches: Vec<Vec<LabelledRound>> = Vec::with_capacity(n);
        for _ in 0..n {
            let mut rounds = Vec::with_capacity(rpb);
            for _ in 0..rpb {
                let round = timeline.record(Phase::Generate, || {
                    generate_round(
                        engine,
                        generator.as_ref(),
                        state.param_view("policy", version),
                        version,
                        taskgen,
                        cursor,
                        cfg.k_samples,
                        sample_opts(cfg),
                        &mut rng,
                        origin,
                    )
                })?;
                cursor += (gen_bs / cfg.k_samples as u64).max(1);
                episodes += gen_bs;
                // stage the round's tensors on device once (when
                // eligible), then label off the shared buffers; staging
                // is part of the scoring cost
                let (resident, labels) = timeline.record(Phase::Score, || {
                    stage_and_label(
                        engine,
                        &round,
                        &sft_params,
                        prep.rm_scorer(),
                        cfg,
                        &mut scratch,
                    )
                })?;
                rounds.push(LabelledRound { round, labels, resident });
            }
            batches.push(rounds);
        }

        // ---- training phase: N sequential updates on the frozen data ----
        for rounds in &batches {
            let batch = assemble(engine, cfg.algo, rounds, cfg.k_samples)?;
            let all_metrics = timeline.record(Phase::Train, || {
                train_on_batch(
                    engine,
                    &mut state,
                    &batch,
                    cfg.lr,
                    cfg.updates_per_batch,
                )
            })?;
            version += cfg.updates_per_batch as u64;
            step += 1;

            let labels = &rounds[0].labels;
            let mut row = round_metrics(labels);
            let m = all_metrics.last().unwrap();
            row.push(("loss", m[0]));
            row.push((
                "staleness",
                staleness(version, labels_version(rounds)) as f32,
            ));
            log.push(step, episodes, timeline.wall(), &row);
            if verbose && step % 8 == 0 {
                eprintln!(
                    "[sync {}] step {step}/{} episodes {episodes} \
                     win {:.3} kl-ppl {:.4} loss {:.4}",
                    cfg.algo,
                    cfg.steps,
                    log.recent_mean("win_rate", 8).unwrap_or(0.0),
                    log.recent_mean("kl_ppl", 8).unwrap_or(0.0),
                    m[0],
                );
            }
            if step >= cfg.steps {
                break 'outer;
            }
        }
    }

    Ok(RunOutput {
        final_params: state.into_params(engine)?,
        log,
        timeline,
        episodes,
    })
}

fn labels_version(rounds: &[LabelledRound]) -> u64 {
    rounds
        .iter()
        .map(|r| r.round.params_version)
        .max()
        .unwrap_or(0)
}
