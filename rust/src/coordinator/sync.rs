//! Synchronous RLHF (paper Fig 2 top / Fig 12 top): generate, then train,
//! on the same resources — generation idles while training and vice versa.
//!
//! Thin constructor over the unified [`pipeline`] trainer loop: the
//! synchronous schedule is [`pipeline::run`] fed by an
//! [`InlineSource`], which generates on the trainer's own engine (the
//! policy params never leave the device — generation reads the trainer's
//! live device buffer via `TrainState::param_view`) and implements the
//! off-policyness ladder of §3.2: generate N mini-batches with the
//! current policy, then take N sequential updates. N=1 is fully
//! on-policy; larger N makes later updates increasingly off-policy (the
//! data's behaviour policy is N−1 updates stale by the last minibatch).

use anyhow::Result;

use super::checkpoint::Checkpoint;
use super::pipeline::{self, InlineSource, RoundSource};
use super::RunOutput;
use crate::config::ExpConfig;

/// Run synchronous RLHF. The SFT checkpoint in `prep` is both the initial
/// policy and the KL reference. A `--resume` restart re-enters the inline
/// source's RNG and prompt cursors exactly, so sync kill-and-resume is
/// bitwise identical to an uninterrupted run.
pub fn run<'p>(
    cfg: &ExpConfig,
    prep: &'p super::Prepared,
    verbose: bool,
) -> Result<RunOutput> {
    pipeline::run(
        cfg,
        prep,
        |_origin, resume: Option<&Checkpoint>| {
            let src: Box<dyn RoundSource + 'p> =
                Box::new(InlineSource::new(cfg, prep, resume)?);
            Ok(src)
        },
        verbose,
    )
}
