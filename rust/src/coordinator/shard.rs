//! Sharded data-parallel training: S trainer shards, one PJRT client
//! each, combined by a deterministic tree all-reduce.
//!
//! ## Execution model
//!
//! [`ShardPool::spawn`] starts S OS threads (`trainer-shard-{rank}`),
//! each loading its own [`Engine`] (the PJRT client is thread-local, so
//! every shard owns a device context and a resident param cache of its
//! own). Per train step, [`ShardPool::train`] slices every host batch
//! tensor along dim 0 — rank r takes rows `[r·d0/S, (r+1)·d0/S)` — and
//! ships one [`ShardJob`] per rank. The train artifacts are compiled at
//! a fixed batch dim, so each shard *tiles* its slice S times to fill
//! the executable's d0 rows: a mean-reduced loss over the tiled rows
//! equals the mean over the slice, which is exactly the per-shard term
//! the all-reduce averages.
//!
//! Each shard fetches the current policy from its [`ParamBus`] seat
//! (seats `[seat0, seat0 + S)`), runs the batch's T optimizer updates
//! locally, and hands back its updated `(params, m, v)` triple plus
//! per-update metric rows. The trainer barriers all S replies, indexes
//! them **by rank** (never completion order), and averages everything
//! through [`reduce::tree_average`] — a fixed adjacent-pairs summation
//! tree, so the combined state is a bitwise-deterministic function of
//! the shard outputs at any S. The averaged triple becomes the next
//! step's [`TrainState`] on the main engine.
//!
//! ## What S = 1 means
//!
//! One shard slices `[0, d0)` (the whole batch), tiles ×1 (a no-op) and
//! [`reduce::tree_average`] at one part is an exact identity — the
//! sharded path at S = 1 is bitwise-identical to the unsharded trainer
//! given the same inputs (integration-tested against real executables).
//!
//! ## Failure model
//!
//! Shard threads run under `catch_unwind`; a panic or per-job error is
//! reported as an `Err` reply naming the rank, which [`ShardPool::train`]
//! propagates — the step fails loudly rather than training on a partial
//! reduce. Teardown ([`ShardPool::finish`], mirrored by `Drop`) closes
//! the job channels and joins every thread.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::pipeline::ParamBus;
use super::pool::panic_message;
use super::trainer::{BatchSlot, TrainBatch};
use crate::runtime::reduce;
use crate::runtime::{Engine, HostTensor, TrainState};

/// One rank's share of one train step: its batch slice (already tiled to
/// the executable geometry) plus the optimizer-state snapshot every
/// shard starts the step from.
struct ShardJob {
    artifact: &'static str,
    tensors: Vec<HostTensor>,
    m: Arc<[f32]>,
    v: Arc<[f32]>,
    opt_step: u64,
    /// The policy version this step trains at; the shard cross-checks it
    /// against its bus seat (the barrier makes them equal — see module
    /// doc on the staleness fan-out term, which real runs never exhibit).
    params_version: u64,
    lr: f32,
    t_updates: usize,
}

/// One rank's step result: the locally-updated optimizer triple and the
/// metric vector of each of the T updates.
struct ShardOut {
    params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    metrics: Vec<Vec<f32>>,
}

struct ShardReply {
    rank: usize,
    out: Result<ShardOut>,
}

/// S supervised trainer-shard threads plus the rank-indexed reduce that
/// combines their per-step outputs.
pub struct ShardPool {
    /// Per-rank job channels (capacity 1: train ships all S jobs before
    /// blocking on replies, so a full barrier is two passes, no deadlock).
    jobs: Vec<mpsc::SyncSender<ShardJob>>,
    replies: mpsc::Receiver<ShardReply>,
    handles: Vec<JoinHandle<()>>,
    shards: usize,
}

impl ShardPool {
    /// Validate the batch geometry against `artifact`'s manifest and
    /// start one shard thread per rank, subscribed to bus seats
    /// `[seat0, seat0 + shards)`.
    pub fn spawn(
        artifact_dir: PathBuf,
        engine: &Engine,
        artifact: &'static str,
        shards: usize,
        bus: Arc<ParamBus>,
        seat0: usize,
    ) -> Result<ShardPool> {
        // S = 1 is legal (slice = whole batch, reduce = identity): the
        // pipeline never builds it — `--trainer-shards 1` keeps the
        // in-thread trainer — but the bitwise-equivalence test drives
        // the sharded machinery at S = 1 against `train_on_batch`
        assert!(shards >= 1, "a shard pool needs at least one rank");
        assert!(
            seat0 + shards <= bus.seats(),
            "shard seats [{seat0}, {}) exceed the bus ({} seats)",
            seat0 + shards,
            bus.seats()
        );
        // every loss input after (params, m, v, step, lr) is sliced along
        // dim 0, so each batch dim must split evenly over the shards
        let spec = engine.manifest.artifact(artifact)?;
        for (i, input) in spec.inputs.iter().enumerate().skip(5) {
            let d0 = input.shape.first().copied().unwrap_or(1);
            if d0 % shards != 0 {
                bail!(
                    "--trainer-shards {shards} does not divide train input \
                     `{}` (input {i} of `{artifact}`): batch dim {d0} = \
                     {shards} x {} + {} rows",
                    input.name,
                    d0 / shards,
                    d0 % shards
                );
            }
        }

        let (reply_tx, replies) = mpsc::channel::<ShardReply>();
        let mut jobs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for rank in 0..shards {
            let (job_tx, job_rx) = mpsc::sync_channel::<ShardJob>(1);
            let dir = artifact_dir.clone();
            let bus = bus.clone();
            let tx = reply_tx.clone();
            let seat = seat0 + rank;
            let handle = std::thread::Builder::new()
                .name(format!("trainer-shard-{rank}"))
                .spawn(move || {
                    let caught = catch_unwind(AssertUnwindSafe(|| {
                        shard_seat(&dir, rank, seat, &bus, &job_rx, &tx)
                    }));
                    if let Err(p) = caught {
                        let _ = tx.send(ShardReply {
                            rank,
                            out: Err(anyhow!(
                                "trainer-shard-{rank} panicked: {}",
                                panic_message(&*p)
                            )),
                        });
                    }
                })
                .with_context(|| format!("spawning trainer-shard-{rank}"))?;
            jobs.push(job_tx);
            handles.push(handle);
        }
        Ok(ShardPool { jobs, replies, handles, shards })
    }

    /// One sharded train step: slice + ship, barrier on all S replies,
    /// tree-average the shard triples and metric rows, install the
    /// averaged state on the main engine. Drop-in for `train_on_batch`
    /// (same metric rows out, same `state.step` advance).
    pub fn train(
        &mut self,
        engine: &Engine,
        state: &mut TrainState,
        batch: &TrainBatch,
        lr: f32,
        t_updates: usize,
        version: u64,
    ) -> Result<Vec<Vec<f32>>> {
        let opt_step = state.step;
        let (m, v): (Arc<[f32]>, Arc<[f32]>) = {
            let (_, m, v) = state.host_mirrors(engine)?;
            (Arc::from(m), Arc::from(v))
        };
        let spec = engine.manifest.artifact(batch.artifact)?;

        for rank in 0..self.shards {
            let mut tensors = Vec::with_capacity(batch.tensors.len());
            for (i, slot) in batch.tensors.iter().enumerate() {
                let t = match slot {
                    BatchSlot::Host(t) => t,
                    BatchSlot::Device(_) => bail!(
                        "sharded training needs host batch slots, but input \
                         {i} of `{}` is device-resident; the pipeline drops \
                         round residency when shards are active — this is a \
                         bug",
                        batch.artifact
                    ),
                };
                let d0 =
                    spec.inputs[5 + i].shape.first().copied().unwrap_or(1);
                tensors.push(slice_tile(t, d0, self.shards, rank)?);
            }
            self.jobs[rank]
                .send(ShardJob {
                    artifact: batch.artifact,
                    tensors,
                    m: m.clone(),
                    v: v.clone(),
                    opt_step,
                    params_version: version,
                    lr,
                    t_updates,
                })
                .map_err(|_| {
                    anyhow!(
                        "trainer-shard-{rank} hung up before its job \
                         (see its earlier error reply)"
                    )
                })?;
        }

        // barrier: every rank reports before anything is reduced, and
        // results are indexed by rank so the reduce order is a pure
        // function of the shard layout, never of thread scheduling
        let mut outs: Vec<Option<ShardOut>> =
            (0..self.shards).map(|_| None).collect();
        for _ in 0..self.shards {
            let reply = self.replies.recv().map_err(|_| {
                anyhow!("every trainer shard hung up mid-step — this is a bug")
            })?;
            let out = reply
                .out
                .with_context(|| format!("trainer-shard-{}", reply.rank))?;
            if outs[reply.rank].replace(out).is_some() {
                bail!(
                    "trainer-shard-{} replied twice in one step — this is a \
                     bug",
                    reply.rank
                );
            }
        }

        let mut ps = Vec::with_capacity(self.shards);
        let mut ms = Vec::with_capacity(self.shards);
        let mut vs = Vec::with_capacity(self.shards);
        let mut rows = Vec::with_capacity(self.shards);
        for out in outs {
            let out = out.expect("all ranks replied exactly once");
            ps.push(out.params);
            ms.push(out.m);
            vs.push(out.v);
            rows.push(out.metrics);
        }
        let params = reduce::tree_average(ps)?;
        let m = reduce::tree_average(ms)?;
        let v = reduce::tree_average(vs)?;
        let mut metrics = Vec::with_capacity(t_updates);
        for u in 0..t_updates {
            let update_rows = rows
                .iter()
                .enumerate()
                .map(|(rank, r)| {
                    r.get(u).cloned().ok_or_else(|| {
                        anyhow!(
                            "trainer-shard-{rank} returned {} metric rows \
                             for {t_updates} updates",
                            r.len()
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            metrics.push(reduce::tree_average(update_rows)?);
        }
        *state =
            TrainState::from_host(params, m, v, opt_step + t_updates as u64)?;
        Ok(metrics)
    }

    /// Tear the pool down: close the job channels (shard loops exit on
    /// disconnect) and join every thread. Runs whether or not the train
    /// loop succeeded, mirroring the round-source teardown.
    pub fn finish(mut self) -> Result<()> {
        self.jobs.clear();
        let mut first_err: Option<anyhow::Error> = None;
        for (rank, handle) in self.handles.drain(..).enumerate() {
            if handle.join().is_err() && first_err.is_none() {
                // the catch_unwind inside the thread already converted
                // panics into replies; a join error here means the reply
                // send itself raced teardown
                first_err =
                    Some(anyhow!("trainer-shard-{rank} died during teardown"));
            }
        }
        // surface any error reply the step loop never consumed (e.g. an
        // engine-load failure on a rank the trainer never reached)
        while let Ok(reply) = self.replies.try_recv() {
            if let Err(e) = reply.out {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // finish() drains both vectors, making this a no-op after it; on
        // a panic path it still releases the shard threads
        self.jobs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Body of one shard thread: its own engine, then one reply per job.
/// Per-job errors are replies (the trainer decides to abort), not thread
/// exits, so a rank never disappears silently mid-barrier.
fn shard_seat(
    artifact_dir: &std::path::Path,
    rank: usize,
    seat: usize,
    bus: &ParamBus,
    jobs: &mpsc::Receiver<ShardJob>,
    replies: &mpsc::Sender<ShardReply>,
) {
    let engine = match Engine::load(artifact_dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = replies.send(ShardReply {
                rank,
                out: Err(e.context("loading the shard's engine")),
            });
            return;
        }
    };
    while let Ok(job) = jobs.recv() {
        let out = shard_step(&engine, seat, bus, &job);
        if replies.send(ShardReply { rank, out }).is_err() {
            return; // trainer gone; teardown in progress
        }
    }
}

/// One rank's step: params from the bus seat, T local updates on the
/// tiled slice, host mirrors back out.
fn shard_step(
    engine: &Engine,
    seat: usize,
    bus: &ParamBus,
    job: &ShardJob,
) -> Result<ShardOut> {
    let (version, params) = bus.latest(seat);
    if version != job.params_version {
        bail!(
            "bus seat {seat} holds params version {version} but the job \
             trains at {} — the pre-publish barrier should make these \
             equal; this is a bug",
            job.params_version
        );
    }
    let mut state = TrainState::from_host(
        params.to_vec(),
        job.m.to_vec(),
        job.v.to_vec(),
        job.opt_step,
    )?;
    let mut dev_batch = Vec::with_capacity(job.tensors.len());
    for (i, t) in job.tensors.iter().enumerate() {
        // the loss-specific inputs start after (params, m, v, step, lr)
        dev_batch.push(
            engine
                .upload_inputs(job.artifact, 5 + i, std::slice::from_ref(t))?
                .pop()
                .expect("one buffer per uploaded tensor"),
        );
    }
    let mut metrics = Vec::with_capacity(job.t_updates);
    for _ in 0..job.t_updates {
        metrics.push(state.train_step_uploaded(
            engine,
            job.artifact,
            job.lr,
            &dev_batch,
        )?);
    }
    let (p, m, v) = state.host_mirrors(engine)?;
    Ok(ShardOut {
        params: p.to_vec(),
        m: m.to_vec(),
        v: v.to_vec(),
        metrics,
    })
}

/// Rank `rank`'s slice of a `[d0, ...]` host tensor, tiled `shards`
/// times to refill the executable's fixed batch dim. S = 1 returns the
/// input verbatim.
fn slice_tile(
    t: &HostTensor,
    d0: usize,
    shards: usize,
    rank: usize,
) -> Result<HostTensor> {
    Ok(match t {
        HostTensor::F32(x) => {
            HostTensor::F32(slice_tile_rows(x, d0, shards, rank)?)
        }
        HostTensor::I32(x) => {
            HostTensor::I32(slice_tile_rows(x, d0, shards, rank)?)
        }
    })
}

fn slice_tile_rows<T: Copy>(
    x: &[T],
    d0: usize,
    shards: usize,
    rank: usize,
) -> Result<Vec<T>> {
    if d0 == 0 || x.len() % d0 != 0 {
        bail!(
            "host tensor of {} elements does not factor into {d0} rows",
            x.len()
        );
    }
    if d0 % shards != 0 {
        bail!("batch dim {d0} does not split over {shards} shards");
    }
    let row = x.len() / d0;
    let per = d0 / shards;
    let slice = &x[rank * per * row..(rank + 1) * per * row];
    let mut out = Vec::with_capacity(x.len());
    for _ in 0..shards {
        out.extend_from_slice(slice);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::{slice_tile, slice_tile_rows};
    use crate::runtime::HostTensor;

    #[test]
    fn shard_slices_are_disjoint_and_cover_the_batch() {
        // 6 rows of 2 elements over 3 shards: 2 rows each, in rank order
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mut seen = Vec::new();
        for rank in 0..3 {
            let part = slice_tile_rows(&x, 6, 3, rank).unwrap();
            assert_eq!(part.len(), x.len(), "tiled back to full batch dim");
            let slice = &part[..4];
            assert_eq!(&part[4..8], slice, "tile 1 repeats the slice");
            assert_eq!(&part[8..], slice, "tile 2 repeats the slice");
            seen.extend_from_slice(slice);
        }
        assert_eq!(seen, x, "rank order reassembles the original rows");
    }

    #[test]
    fn shard_slice_at_one_shard_is_the_identity() {
        let x = vec![3, 1, 4, 1, 5, 9];
        assert_eq!(slice_tile_rows(&x, 3, 1, 0).unwrap(), x);
    }

    #[test]
    fn shard_slice_preserves_the_tensor_dtype() {
        let t = slice_tile(&HostTensor::I32(vec![7, 8]), 2, 2, 1).unwrap();
        match t {
            HostTensor::I32(v) => assert_eq!(v, vec![8, 8]),
            HostTensor::F32(_) => panic!("dtype must survive slicing"),
        }
    }

    #[test]
    fn shard_slice_rejects_bad_geometry() {
        let x = vec![0.0f32; 6];
        // 4 rows don't factor 6 elements
        assert!(slice_tile_rows(&x, 4, 2, 0).is_err());
        // 3 rows don't split over 2 shards
        assert!(slice_tile_rows(&x, 3, 2, 0).is_err());
        // 0 rows is degenerate
        assert!(slice_tile_rows(&x, 0, 1, 0).is_err());
    }
}
