//! SFT + proxy-RM pretraining pipeline (paper §3 "Empirical Setup"):
//! 1. supervised finetuning on (prompt, reference) demonstrations,
//! 2. proxy reward-model training on gold-labelled preference pairs,
//! both from the task stream, with checkpoint caching under
//! `<run_dir>/checkpoints/` so experiment sweeps share the same SFT/RM.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::data::{pack_sequence, TaskGen};
use crate::metrics::RunLog;
use crate::runtime::{Engine, HostTensor, TrainState};
use crate::util::npy;

/// Dataset index ranges: disjoint slices of the deterministic task stream.
pub const SFT_RANGE: u64 = 0;
pub const RM_RANGE: u64 = 1_000_000;
pub const RLHF_RANGE: u64 = 2_000_000;
pub const EVAL_RANGE: u64 = 10_000_000;

pub const SFT_LR: f32 = 1e-3;
pub const RM_LR: f32 = 1e-3;

fn ckpt_path(dir: &Path, model: &str, kind: &str) -> PathBuf {
    dir.join("checkpoints").join(format!("{model}_{kind}.npy"))
}

/// Train (or load cached) SFT policy. Returns the flat params.
pub fn sft_checkpoint(
    engine: &Engine,
    taskgen: &TaskGen,
    run_dir: &Path,
    steps: u64,
    log: Option<&mut RunLog>,
) -> Result<Vec<f32>> {
    let model = engine.config_name().to_string();
    let path = ckpt_path(run_dir, &model, "sft");
    if let Ok(arr) = npy::read_f32(&path) {
        if arr.data.len() == engine.manifest.param_count {
            return Ok(arr.data);
        }
    }
    let cfg = &engine.manifest.config;
    let (bg, s) = (cfg.gen_batch, cfg.seq_len);
    let mut state = TrainState::new(engine.init_policy()?);
    let mut log_sink = RunLog::new();
    let logr = log.unwrap_or(&mut log_sink);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let examples = taskgen.batch(SFT_RANGE + step * bg as u64, bg);
        let mut toks = Vec::with_capacity(bg * s);
        let mut mask = Vec::with_capacity(bg * s);
        for ex in &examples {
            let (t, m) = pack_sequence(&ex.prompt, &ex.reference, s, true);
            toks.extend(t);
            mask.extend(m);
        }
        let metrics = state.train_step(
            engine,
            "train_sft",
            SFT_LR,
            vec![HostTensor::I32(toks), HostTensor::F32(mask)],
        )?;
        if step % 20 == 0 || step + 1 == steps {
            logr.push(
                step,
                (step + 1) * bg as u64,
                t0.elapsed().as_secs_f64(),
                &[("sft_loss", metrics[0]), ("sft_ppl", metrics[1])],
            );
        }
    }
    let params = state.into_params(engine)?;
    std::fs::create_dir_all(run_dir.join("checkpoints"))?;
    npy::write_f32(&path, &[params.len()], &params)?;
    Ok(params)
}

/// Train (or load cached) proxy RM from the SFT checkpoint on gold-labelled
/// preference pairs (paper: RM is initialized from the SFT model).
pub fn rm_checkpoint(
    engine: &Engine,
    taskgen: &TaskGen,
    sft_params: &[f32],
    run_dir: &Path,
    steps: u64,
    seed: u64,
    log: Option<&mut RunLog>,
) -> Result<Vec<f32>> {
    let model = engine.config_name().to_string();
    let path = ckpt_path(run_dir, &model, "rm");
    if let Ok(arr) = npy::read_f32(&path) {
        if arr.data.len() == engine.manifest.param_count {
            return Ok(arr.data);
        }
    }
    let cfg = &engine.manifest.config;
    let (bp, s) = (cfg.train_pairs, cfg.seq_len);
    let mut state = TrainState::new(sft_params.to_vec());
    let mut log_sink = RunLog::new();
    let logr = log.unwrap_or(&mut log_sink);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let pairs = crate::reward::build_pref_pairs(
            taskgen,
            s,
            RM_RANGE + step * bp as u64,
            bp,
            seed ^ 0x524d,
        );
        let mut tc = Vec::with_capacity(bp * s);
        let mut mc = Vec::with_capacity(bp * s);
        let mut tr = Vec::with_capacity(bp * s);
        let mut mr = Vec::with_capacity(bp * s);
        for p in &pairs {
            tc.extend_from_slice(&p.chosen.0);
            mc.extend_from_slice(&p.chosen.1);
            tr.extend_from_slice(&p.rejected.0);
            mr.extend_from_slice(&p.rejected.1);
        }
        let metrics = state.train_step(
            engine,
            "train_rm",
            RM_LR,
            vec![
                HostTensor::I32(tc),
                HostTensor::F32(mc),
                HostTensor::I32(tr),
                HostTensor::F32(mr),
            ],
        )?;
        if step % 20 == 0 || step + 1 == steps {
            logr.push(
                step,
                (step + 1) * bp as u64,
                t0.elapsed().as_secs_f64(),
                &[("rm_loss", metrics[0]), ("rm_acc", metrics[1])],
            );
        }
    }
    let params = state.into_params(engine)?;
    std::fs::create_dir_all(run_dir.join("checkpoints"))?;
    npy::write_f32(&path, &[params.len()], &params)?;
    Ok(params)
}
