//! Crash-safe checkpoint/resume for the streaming trainer loop.
//!
//! `--checkpoint-every N` snapshots, every N optimizer steps, everything a
//! restarted process needs to continue the run as if it never died: the
//! full optimizer triple (params / Adam m / Adam v, as `.npy` so Python
//! can inspect them), the optimizer step, the trainer's staleness
//! accumulators, and the round source's resumable position ([`SourceState`]:
//! RNG cursor, per-lane prompt cursors, delivered-index skip lists). A
//! snapshot is written to `<run_dir>/checkpoints/<label>/step_<N>/`
//! **atomically** — staged into a dot-tmp sibling and `rename`d into place
//! — so a crash mid-write can never leave a directory that `--resume`
//! would half-trust; `load_latest` additionally ignores any leftover tmp
//! staging.
//!
//! Sync-mode resume is **bitwise**: the inline source checkpoints only at
//! refill boundaries (its generation RNG cursor + prompt cursor fully
//! determine the future), so kill-and-resume reproduces the uninterrupted
//! run's final parameters exactly (integration-tested). Async resume is
//! exactly-once but not bitwise — worker RNG streams are re-derived under
//! a fresh epoch (live worker threads cannot be snapshotted mid-call) and
//! the trainer's lane accounts make regenerated rounds dedupe instead of
//! double-train. Serve-mode resume rides the same shape: the session
//! boards recompute their whole schedule from `(trace, delivered-turn
//! set)`, so the checkpoint carries just the sorted delivered uids (one
//! skip list, no cursors) and a resumed run re-serves only the
//! undelivered remainder of the trace, exactly once.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::npy;

/// The trainer's running staleness accumulators — checkpointed so the
/// end-of-run `mean_staleness`/`max_staleness` metas stay cumulative
/// across a kill-and-resume instead of restarting at zero.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StalenessAccum {
    pub sum: u64,
    pub max: u64,
    pub tok_sum: f64,
    pub tok_max: u64,
}

/// A round source's resumable position. One shape serves every source:
/// the inline source is a single lane with a bitwise RNG cursor; a worker
/// pool is M lanes with per-lane prompt cursors (the trainer-side
/// *accepted* frontier, not the workers' run-ahead ledger — queued rounds
/// lost in the crash regenerate and dedupe); the serve source is zero
/// cursors and one skip list holding the delivered turn uids.
#[derive(Debug, Clone, PartialEq)]
pub struct SourceState {
    /// `"inline"`, `"pool"`, or `"serve"`; resume refuses a mode
    /// mismatch.
    pub kind: String,
    /// Generation RNG cursor ([`crate::util::rng::Pcg32::state`]) —
    /// inline source only (worker threads own their streams).
    pub rng: Option<(u64, u64)>,
    /// Rounds the source has accounted so far (episode counting stays
    /// cumulative across resume).
    pub generated: u64,
    /// Per-lane next prompt index: block start for round-synchronous
    /// lanes, delivered frontier for continuous lanes.
    pub cursors: Vec<u64>,
    /// Per-lane prompt indices already delivered *above* the frontier
    /// (continuous lanes retire out of admission order; resumed workers
    /// skip these). Empty for round-synchronous lanes.
    pub skip: Vec<Vec<u64>>,
    /// Worker-pool respawn epoch: resumed pools derive worker RNG streams
    /// past every stream this run has already consumed.
    pub epoch: u64,
}

/// One complete snapshot of a run at an optimizer-step boundary.
pub struct Checkpoint {
    /// Trainer steps completed.
    pub step: u64,
    /// Optimizer version (publish counter; `step · updates_per_batch`).
    pub version: u64,
    /// `TrainState::step` (Adam bias-correction counter).
    pub opt_step: u64,
    pub staleness: StalenessAccum,
    pub source: SourceState,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

/// Checkpoint directory of one run: label-scoped under the run dir, so it
/// never collides with the SFT/RM pretrain checkpoints that live directly
/// in `<run_dir>/checkpoints/`.
pub fn dir_for(run_dir: &Path, label: &str) -> PathBuf {
    run_dir.join("checkpoints").join(label)
}

/// u64 → JSON. Decimal *string*, not a number: RNG states use the full
/// u64 range and `Json` keeps numbers as f64, which is exact only to
/// 2^53 — a silently-rounded cursor would resume a different stream.
fn ju64(v: u64) -> Json {
    Json::Str(v.to_string())
}

/// Read back a [`ju64`]-encoded value (tolerating plain numbers for
/// hand-edited manifests, where f64 exactness is the editor's problem).
fn pu64(j: &Json, what: &str) -> Result<u64> {
    match j {
        Json::Str(s) => s
            .parse::<u64>()
            .with_context(|| format!("checkpoint field '{what}': bad u64 '{s}'")),
        Json::Num(n) if *n >= 0.0 => Ok(*n as u64),
        other => bail!("checkpoint field '{what}': expected u64, got {other}"),
    }
}

impl Checkpoint {
    fn manifest(&self) -> Json {
        let s = &self.source;
        Json::obj(vec![
            ("step", ju64(self.step)),
            ("version", ju64(self.version)),
            ("opt_step", ju64(self.opt_step)),
            (
                "staleness",
                Json::obj(vec![
                    ("sum", ju64(self.staleness.sum)),
                    ("max", ju64(self.staleness.max)),
                    ("tok_sum", Json::Num(self.staleness.tok_sum)),
                    ("tok_max", ju64(self.staleness.tok_max)),
                ]),
            ),
            (
                "source",
                Json::obj(vec![
                    ("kind", Json::str(&s.kind)),
                    (
                        "rng",
                        match s.rng {
                            Some((state, inc)) => {
                                Json::Arr(vec![ju64(state), ju64(inc)])
                            }
                            None => Json::Null,
                        },
                    ),
                    ("generated", ju64(s.generated)),
                    (
                        "cursors",
                        Json::Arr(s.cursors.iter().map(|&c| ju64(c)).collect()),
                    ),
                    (
                        "skip",
                        Json::Arr(
                            s.skip
                                .iter()
                                .map(|lane| {
                                    Json::Arr(
                                        lane.iter().map(|&i| ju64(i)).collect(),
                                    )
                                })
                                .collect(),
                        ),
                    ),
                    ("epoch", ju64(s.epoch)),
                ]),
            ),
        ])
    }

    /// Write this snapshot as `<dir>/step_<step>/` atomically: stage into
    /// a `.tmp` sibling, fsync-free rename into place (a crash mid-write
    /// leaves only the tmp staging, which loaders ignore). Returns the
    /// final directory. Re-checkpointing the same step replaces it.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        let final_dir = dir.join(format!("step_{}", self.step));
        let tmp = dir.join(format!(".tmp_step_{}", self.step));
        let _ = fs::remove_dir_all(&tmp);
        fs::create_dir_all(&tmp)
            .with_context(|| format!("checkpoint: create {}", tmp.display()))?;
        npy::write_f32(tmp.join("params.npy"), &[self.params.len()], &self.params)?;
        npy::write_f32(tmp.join("m.npy"), &[self.m.len()], &self.m)?;
        npy::write_f32(tmp.join("v.npy"), &[self.v.len()], &self.v)?;
        fs::write(tmp.join("manifest.json"), self.manifest().to_string())?;
        // the rename is the commit point
        let _ = fs::remove_dir_all(&final_dir);
        fs::rename(&tmp, &final_dir).with_context(|| {
            format!("checkpoint: commit {}", final_dir.display())
        })?;
        Ok(final_dir)
    }

    /// Load one `step_<N>` directory.
    pub fn load(step_dir: &Path) -> Result<Checkpoint> {
        let text = fs::read_to_string(step_dir.join("manifest.json"))
            .with_context(|| {
                format!("checkpoint: read {}", step_dir.display())
            })?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("checkpoint manifest: {e}"))?;
        let read = |name: &str| -> Result<Vec<f32>> {
            Ok(npy::read_f32(step_dir.join(name))
                .with_context(|| format!("checkpoint: read {name}"))?
                .data)
        };
        let (params, m, v) = (read("params.npy")?, read("m.npy")?, read("v.npy")?);
        if m.len() != params.len() || v.len() != params.len() {
            bail!(
                "checkpoint {}: optimizer state sizes disagree \
                 (params {}, m {}, v {})",
                step_dir.display(),
                params.len(),
                m.len(),
                v.len()
            );
        }
        let st = j.req("staleness").map_err(|e| anyhow!("{e}"))?;
        let staleness = StalenessAccum {
            sum: pu64(st.req("sum").map_err(|e| anyhow!("{e}"))?, "staleness.sum")?,
            max: pu64(st.req("max").map_err(|e| anyhow!("{e}"))?, "staleness.max")?,
            tok_sum: st
                .req("tok_sum")
                .map_err(|e| anyhow!("{e}"))?
                .as_f64()
                .ok_or_else(|| anyhow!("checkpoint: staleness.tok_sum"))?,
            tok_max: pu64(
                st.req("tok_max").map_err(|e| anyhow!("{e}"))?,
                "staleness.tok_max",
            )?,
        };
        let sj = j.req("source").map_err(|e| anyhow!("{e}"))?;
        let rng = match sj.req("rng").map_err(|e| anyhow!("{e}"))? {
            Json::Null => None,
            Json::Arr(pair) if pair.len() == 2 => Some((
                pu64(&pair[0], "source.rng[0]")?,
                pu64(&pair[1], "source.rng[1]")?,
            )),
            other => bail!("checkpoint: source.rng malformed ({other})"),
        };
        let cursors = sj
            .req("cursors")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("checkpoint: source.cursors"))?
            .iter()
            .map(|c| pu64(c, "source.cursors[]"))
            .collect::<Result<Vec<_>>>()?;
        let skip = sj
            .req("skip")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("checkpoint: source.skip"))?
            .iter()
            .map(|lane| {
                lane.as_arr()
                    .ok_or_else(|| anyhow!("checkpoint: source.skip[]"))?
                    .iter()
                    .map(|i| pu64(i, "source.skip[][]"))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        let source = SourceState {
            kind: sj
                .req("kind")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("checkpoint: source.kind"))?
                .to_string(),
            rng,
            generated: pu64(
                sj.req("generated").map_err(|e| anyhow!("{e}"))?,
                "source.generated",
            )?,
            cursors,
            skip,
            epoch: pu64(sj.req("epoch").map_err(|e| anyhow!("{e}"))?, "source.epoch")?,
        };
        Ok(Checkpoint {
            step: pu64(j.req("step").map_err(|e| anyhow!("{e}"))?, "step")?,
            version: pu64(j.req("version").map_err(|e| anyhow!("{e}"))?, "version")?,
            opt_step: pu64(j.req("opt_step").map_err(|e| anyhow!("{e}"))?, "opt_step")?,
            staleness,
            source,
            params,
            m,
            v,
        })
    }

    /// Newest committed snapshot under `dir`, or `None` if there are no
    /// checkpoints (a missing directory is simply "none"). Tmp staging
    /// left by a crash mid-save is skipped — only `rename`-committed
    /// `step_<N>` directories count.
    pub fn load_latest(dir: &Path) -> Result<Option<(u64, Checkpoint)>> {
        let entries = match fs::read_dir(dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("checkpoint: scan {}", dir.display()))
            }
        };
        // BTreeMap: deterministic pick of the numerically-largest step
        let mut steps = BTreeMap::new();
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name();
            let Some(n) = name
                .to_str()
                .and_then(|s| s.strip_prefix("step_"))
                .and_then(|s| s.parse::<u64>().ok())
            else {
                continue; // tmp staging, pretrain npy files, strangers
            };
            steps.insert(n, entry.path());
        }
        match steps.into_iter().next_back() {
            Some((n, path)) => Ok(Some((n, Checkpoint::load(&path)?))),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join("async_rlhf_ckpt_test")
            .join(name);
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample(step: u64) -> Checkpoint {
        Checkpoint {
            step,
            version: step * 2,
            opt_step: step * 2,
            staleness: StalenessAccum {
                sum: 7,
                max: 3,
                tok_sum: 6.25,
                tok_max: 4,
            },
            source: SourceState {
                kind: "pool".into(),
                // past 2^53: would corrupt silently through an f64
                rng: Some((u64::MAX - 12345, (0x5c << 1) | 1)),
                generated: step,
                cursors: vec![2_000_000 + step, 2_000_004 + step],
                skip: vec![vec![], vec![2_000_011, 2_000_013]],
                epoch: 1,
            },
            params: vec![0.5, -1.5, 3.0],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.01, 0.02, 0.03],
        }
    }

    #[test]
    fn roundtrip_preserves_everything_including_full_u64_range() {
        let dir = tmp_dir("roundtrip");
        let c = sample(12);
        let where_ = c.save(&dir).unwrap();
        assert!(where_.ends_with("step_12"));
        let back = Checkpoint::load(&where_).unwrap();
        assert_eq!(back.step, 12);
        assert_eq!(back.version, 24);
        assert_eq!(back.opt_step, 24);
        assert_eq!(back.staleness, c.staleness);
        assert_eq!(back.source, c.source, "u64 RNG state must not round");
        assert_eq!(back.params, c.params);
        assert_eq!(back.m, c.m);
        assert_eq!(back.v, c.v);
    }

    #[test]
    fn load_latest_picks_numerically_largest_and_ignores_tmp() {
        let dir = tmp_dir("latest");
        assert!(Checkpoint::load_latest(&dir).unwrap().is_none());
        for step in [2u64, 10, 9] {
            sample(step).save(&dir).unwrap();
        }
        // a crash mid-save leaves tmp staging; it must be invisible
        fs::create_dir_all(dir.join(".tmp_step_99")).unwrap();
        fs::write(dir.join(".tmp_step_99/manifest.json"), "{garbage").unwrap();
        // and the pretrain npy checkpoints share the parent dir's naming
        // style, not ours — unrelated files are skipped too
        fs::write(dir.join("dev_sft.npy"), b"not a checkpoint").unwrap();
        let (n, c) = Checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!(n, 10, "10 > 9 numerically (not lexically)");
        assert_eq!(c.step, 10);
    }

    #[test]
    fn save_replaces_an_existing_step_snapshot() {
        let dir = tmp_dir("replace");
        sample(5).save(&dir).unwrap();
        let mut c = sample(5);
        c.params = vec![9.0, 9.0, 9.0];
        c.save(&dir).unwrap();
        let (_, back) = Checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!(back.params, vec![9.0, 9.0, 9.0]);
    }

    #[test]
    fn missing_directory_is_none_but_corrupt_manifest_is_loud() {
        let dir = tmp_dir("corrupt");
        let step = dir.join("step_3");
        fs::create_dir_all(&step).unwrap();
        fs::write(step.join("manifest.json"), "{]").unwrap();
        assert!(Checkpoint::load_latest(&dir).is_err());
    }
}
