//! Unified streaming RLHF pipeline: ONE trainer loop, N generation
//! workers, a configurable staleness bound K.
//!
//! The paper's central question — "how much off-policyness can we
//! tolerate?" — is a single knob. This module makes it one: a
//! [`RoundSource`] yields generation rounds to [`run`], the only trainer
//! loop in the crate (stage/label → assemble → train → publish → log),
//! and the two sources are the two ends of the design space:
//!
//! - [`InlineSource`] generates on the trainer's own engine/thread —
//!   the synchronous generate-then-train schedule (paper Fig 2 top),
//!   including the §3.2 N-minibatch off-policy ladder. Generation reads
//!   the trainer's live device parameters ([`TrainState::param_view`]),
//!   so the policy never leaves the device.
//! - [`super::pool::WorkerPool`] runs M generation worker threads, each
//!   owning its own `Engine`/PJRT backend, feeding a **bounded** round
//!   queue of depth K. `M = 1, K = 0` is a rendezvous handover — exactly
//!   the Cleanba one-step off-policy coordinator of paper §3.5/Algorithm
//!   1. (The pool, its supervision and the lane ledger live in
//!   `coordinator/pool.rs`; [`SessionSource`] below reuses its seat
//!   plumbing for serve-while-training.)
//!
//! ## Publication: the [`ParamBus`] fan-out
//!
//! After every optimizer step the trainer loop publishes the new policy
//! to a [`ParamBus`]: one latest-wins [`ParamSlot`] per subscriber seat
//! (gen/serve workers first, then trainer shards), so a publish is
//! S + M pointer swaps — the params are downloaded to host once and the
//! `Arc` fans out; no subscriber ever copies them. Subscribers poll
//! their own seat, so a slow reader never contends with the rest.
//!
//! ## Sharded training
//!
//! `--trainer-shards S` (S > 1) runs S trainer engines, each owning its
//! own PJRT client and device-resident param/optimizer cache
//! ([`super::shard::ShardPool`]). Every train batch is split into S
//! disjoint row slices (tiled back to the compiled batch shape — the
//! AOT artifacts are fixed-shape); after the per-shard updates a
//! deterministic tree all-reduce ([`crate::runtime::reduce`]) averages
//! params, Adam moments and metric vectors in fixed rank order, so the
//! result is bitwise-reproducible at any S. The S=1 path does not
//! construct a shard pool at all and is bitwise-identical to the
//! unsharded trainer.
//!
//! ## The staleness invariant
//!
//! With one worker and queue depth K, at most K rounds sit queued and
//! one more is blocked mid-`send`, each generated with parameters
//! fetched *before* the publish of the step that consumed its
//! predecessor. In optimizer-update units with T = `updates_per_batch`,
//! per-step staleness is therefore bounded by
//! [`staleness_bound_updates`]`(K, 1, T) = (K + 2)·T − 1`; for the
//! default T = 1 that is **queue depth K ⇒ staleness ≤ K + 1** policy
//! versions (K = 0 reproduces the one-step bound the seed coordinator
//! enforced). The bound is proven for M = 1 — tight under instantaneous
//! generation, see the discrete model test below. For M > 1 the same
//! formula `(K + M + 1)·T − 1` is the *fair-scheduling* bound (each
//! worker's in-flight round adds one step of age): it holds whenever no
//! worker's single generation call is starved across K + M trainer
//! steps, which the queue back-pressure cannot itself force — so
//! multi-worker staleness is *measured and reported*, not hard-asserted.
//! Per-config measurements land in `BENCH_staleness.json` via
//! `benches/staleness.rs`.
//!
//! Sharded publish re-derives the bound: the S shard seats receive a
//! publish as S separate pointer swaps, so in an adversarial schedule a
//! subscriber can observe a publish up to S − 1 update units after the
//! first seat did — [`staleness_bound_sharded`] adds that `+ (S − 1)`
//! fan-out term. The real trainer barriers all shards *before* each
//! publish (lag 0), so measured staleness stays within the unsharded
//! bound; the fan-out term is proven tight in the discrete-model test
//! (`tests/integration_shard.rs`).
//!
//! ## The failure model
//!
//! Worker pools are **supervised**: each seat's body runs under
//! `catch_unwind` and reports a structured exit; the trainer, while
//! waiting for rounds, reaps exits and heartbeats. A dead seat is
//! respawned on a fresh engine up to `--max-worker-restarts` times — the
//! replacement resumes the dead worker's exact prompt-partition position
//! via the shared **lane ledger** (advanced only *after* a round is
//! handed over, so a crash re-generates at-least-once and the trainer's
//! lane accounts drop the duplicates: exactly-once into the
//! optimizer). When restarts are exhausted, surviving seats inherit the
//! orphaned work in every mode: round-synchronous lanes re-stride onto a
//! live heir mid-flight; continuous lanes force the heir through a clean
//! retire-and-respawn over the merged lane mask (in-flight KV is
//! engine-local and abandoned — `inflight_tokens_abandoned` prices it —
//! and the heir re-admits each lane from the trainer-accepted frontier +
//! skip set, so migration is respawn-on-a-different-seat); serve-mode
//! session residues migrate the same way, with `SessionAccounts` keeping
//! turn uids exactly-once across the move. A pool degrades gracefully
//! down to one seat (`lanes_reassigned` / `sessions_migrated` /
//! `degraded_capacity_steps` in the run metas) before the run fails
//! loudly. Transient engine faults retry with deterministic jittered
//! backoff ([`crate::runtime::RetryPolicy`]); a seat silent past
//! `--stall-timeout-secs` is flagged by the watchdog and surfaced in the
//! run metas. `--inject-fault worker=W,round=R,kind=panic|stall|engine_err`
//! scripts each failure deterministically for the integration tests.

use std::collections::{HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::checkpoint::{self, Checkpoint, SourceState, StalenessAccum};
use super::pool::{
    beat, maybe_inject, panic_message, round_from_groups, supervisor_log,
    Accept, GenMsg, Recovery, SeatShared, SlotCtl, SpawnCtx, Supervision,
    WorkerExit,
};
use super::pretrain::RLHF_RANGE;
use super::shard::ShardPool;
use super::trainer::{
    assemble, batch_data_version, batch_token_versions,
    generate_round_staged, round_metrics, rounds_per_batch, sample_opts,
    stage_and_label, staleness, train_on_batch, LabelScratch, LabelledRound,
    SourcedRound, ROUND_ORIGIN,
};
use super::{Prepared, RunOutput};
use crate::config::{ExpConfig, GenEngine, Mode};
use crate::data::TaskGen;
use crate::gen::continuous::{DeviceBackend, PoolCfg, PoolStats, RoundAssembler};
use crate::gen::{Generator, SampleOpts};
use crate::metrics::{Phase, RunLog, Timeline};
use crate::runtime::{Engine, ParamView, RetryPolicy, TrainState, RETRY_STREAM};
use crate::serve::frontend::ServeMux;
use crate::serve::session::{SessionBoard, TurnRecord};
use crate::serve::traffic::{turn_uid, uid_session_turn, TrafficCfg, TrafficGen};
use crate::util::bench::pct;
use crate::util::bitset::{AtomicBitSet, BitSet};
use crate::util::rng::Pcg32;

/// Prompts consumed by one generation round: the cursor stride. The
/// `.max(1)` guard keeps the cursor strictly monotone even in degenerate
/// geometries (`k_samples > gen_batch`) — the seed async worker lacked it
/// and would replay the same prompts forever.
pub fn cursor_stride(gen_batch: u64, k: usize) -> u64 {
    (gen_batch / k as u64).max(1)
}

/// Worst-case per-step staleness, in optimizer-update units, of a
/// worker-pool run with queue depth `k_bound`, `m` workers and `t`
/// updates per batch: K queued rounds + M blocked sends, each generated
/// one publish behind, gives `(K + M + 1)·T − 1`. Proven (and tight) for
/// `m = 1`; for `m > 1` it additionally assumes fair worker scheduling —
/// a worker stalled mid-generation while its siblings keep feeding the
/// trainer can exceed it (see the module docs). Inline (sync N-ladder)
/// staleness is bounded separately by `(N − 1)·T + T − 1`.
pub fn staleness_bound_updates(k_bound: usize, m: usize, t: usize) -> u64 {
    assert!(m >= 1 && t >= 1, "worker pools have m >= 1 and t >= 1");
    ((k_bound + m + 1) * t) as u64 - 1
}

/// [`staleness_bound_updates`] re-derived for sharded publish. A publish
/// is S pointer swaps across the shard seats of the [`ParamBus`], not one
/// atomic broadcast: in an adversarial schedule a subscriber's seat can
/// be the *last* swapped while other seats already carried the next
/// publications, so the freshest version it has seen trails the freshest
/// published by up to `S − 1` update units — the fan-out term. For S = 1
/// the term vanishes and the bound reduces exactly to the unsharded one.
/// (The real trainer barriers every shard before the loop publishes, so
/// measured staleness also satisfies the tighter unsharded bound; this
/// is the schedule-free guarantee.)
pub fn staleness_bound_sharded(
    k_bound: usize,
    m: usize,
    t: usize,
    s: usize,
) -> u64 {
    assert!(s >= 1, "shard counts are >= 1");
    staleness_bound_updates(k_bound, m, t) + (s as u64 - 1)
}

/// Latest-wins published-policy slot. The trainer overwrites, workers
/// read whatever is freshest; intermediate versions are simply dropped
/// (Algorithm 1 only ever wants θ_i, never the history).
pub struct ParamSlot {
    /// Fast-path hint so a worker can skip the lock when nothing new
    /// was published. Updated after the slot contents.
    hint: AtomicU64,
    latest: Mutex<(u64, Arc<[f32]>)>,
}

impl ParamSlot {
    pub fn new(version: u64, params: Arc<[f32]>) -> ParamSlot {
        ParamSlot {
            hint: AtomicU64::new(version),
            latest: Mutex::new((version, params)),
        }
    }

    /// Poison-free lock. The slot's critical sections are pure pointer
    /// swaps — they cannot leave the pair half-written — so a worker that
    /// panicked *while holding the lock* (supervised and respawned) must
    /// not take the whole pool down with a propagated `PoisonError`.
    fn lock_latest(&self) -> std::sync::MutexGuard<'_, (u64, Arc<[f32]>)> {
        self.latest.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Publish `params` as `version`: one pointer swap under the lock.
    pub fn publish(&self, version: u64, params: Arc<[f32]>) {
        *self.lock_latest() = (version, params);
        self.hint.store(version, Ordering::Release);
    }

    /// The freshest publication newer than `have`, if any.
    pub fn fetch(&self, have: u64) -> Option<(u64, Arc<[f32]>)> {
        if self.hint.load(Ordering::Acquire) <= have {
            return None;
        }
        let guard = self.lock_latest();
        if guard.0 <= have {
            return None;
        }
        Some((guard.0, guard.1.clone()))
    }

    /// The current publication unconditionally — what a freshly (re)spawned
    /// worker initializes from.
    pub fn latest(&self) -> (u64, Arc<[f32]>) {
        let guard = self.lock_latest();
        (guard.0, guard.1.clone())
    }
}

/// Versioned publish fan-out: one latest-wins [`ParamSlot`] per
/// subscriber seat. Seats `[0, M)` belong to the generation / serving
/// workers, seats `[M, M + S)` to the trainer shards; the trainer loop
/// publishes by swapping the same `Arc` into every seat (S + M pointer
/// moves, one host download, zero broadcast copies), and each subscriber
/// polls only its own seat — no reader ever contends with another.
///
/// Each seat individually is torn-read-free and monotone (the
/// [`ParamSlot`] lock covers the version/params pair); across seats a
/// publish is *not* atomic, which is exactly the `+ (S − 1)` fan-out
/// term of [`staleness_bound_sharded`].
pub struct ParamBus {
    seats: Box<[ParamSlot]>,
}

impl ParamBus {
    /// A bus of `seats` subscriber seats, every one seeded with the same
    /// initial publication (SFT params at version 0, or the checkpoint's
    /// policy at its version under `--resume`).
    pub fn new(seats: usize, version: u64, params: Arc<[f32]>) -> ParamBus {
        assert!(seats >= 1, "a param bus needs at least one subscriber");
        ParamBus {
            seats: (0..seats)
                .map(|_| ParamSlot::new(version, params.clone()))
                .collect(),
        }
    }

    pub fn seats(&self) -> usize {
        self.seats.len()
    }

    /// Publish `params` as `version` to every seat: one pointer swap per
    /// seat, sharing a single `Arc`.
    pub fn publish(&self, version: u64, params: Arc<[f32]>) {
        for seat in self.seats.iter() {
            seat.publish(version, params.clone());
        }
    }

    /// The freshest publication on `seat` newer than `have`, if any.
    pub fn fetch(&self, seat: usize, have: u64) -> Option<(u64, Arc<[f32]>)> {
        self.seats[seat].fetch(have)
    }

    /// `seat`'s current publication unconditionally.
    pub fn latest(&self, seat: usize) -> (u64, Arc<[f32]>) {
        self.seats[seat].latest()
    }
}

/// What the trainer loop exposes to its round source on every call: the
/// trainer's engine and optimizer state (inline generation reads the live
/// device parameters, worker pools snapshot them at publish), the current
/// optimizer version, and the shared timeline for span accounting.
pub struct TrainerCx<'a> {
    pub engine: &'a Engine,
    pub state: &'a mut TrainState,
    pub version: u64,
    pub timeline: &'a mut Timeline,
}

/// A stream of generation rounds feeding the one trainer loop ([`run`]).
///
/// Implementations decide *where* rounds come from (inline on the
/// trainer's engine, or a pool of worker threads) and *how stale* they
/// may be; the trainer loop is identical either way.
pub trait RoundSource {
    /// Tag used in verbose step logs ("sync" / "async").
    fn label(&self) -> &'static str;

    /// Produce the next round, generating inline or awaiting a worker.
    /// The source records its own Generate/Idle spans on `cx.timeline`.
    /// Inline sources may attach the fused generate's device-resident
    /// output buffers ([`SourcedRound::staged`]) so the trainer stages
    /// the round with zero token uploads; worker rounds crossed a thread
    /// boundary and are host-only.
    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound>;

    /// Completions accounted so far. Inline sources count at generation
    /// (the §3.2 ladder pays for a whole N-minibatch window up front,
    /// trained or not — the seed sync accounting); worker pools count at
    /// handover (in-flight worker rounds are not yet episodes).
    fn episodes(&self) -> u64;

    /// The source's resumable position for a crash-safe checkpoint, or
    /// `None` when the source is not at a clean boundary (e.g. the sync
    /// N-ladder mid-refill, holding rounds a resumed process could not
    /// reconstruct) — the trainer then retries at the next step.
    fn snapshot(&self) -> Option<SourceState>;

    /// Tear down (join workers), contributing source metadata — e.g.
    /// per-worker generation accounting — to the run log.
    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()>;
}

/// The single RLHF trainer loop, written once against [`RoundSource`]:
/// pull `rounds_per_batch` rounds, stage + label them, assemble the
/// algorithm-specific batch, take `updates_per_batch` optimizer steps,
/// publish the new policy on the [`ParamBus`], log. `make_source`
/// receives the shared timeline origin so worker gen-spans land on the
/// trainer's clock, the restored checkpoint (when `--resume`) so sources
/// re-enter their exact stream position, and the bus (already seeded)
/// for worker seats to subscribe to.
///
/// The loop owns publication: after every optimizer step the new params
/// are downloaded to host once and fanned out to every subscriber seat —
/// worker seats `[0, M)` plus shard seats `[M, M + S)`. Runs with no
/// subscribers (synchronous, unsharded) skip the download entirely,
/// exactly as before.
///
/// With `--checkpoint-every N`, every N-th step atomically snapshots the
/// optimizer triple, staleness accumulators and the source's cursors into
/// `<run_dir>/checkpoints/<label>/step_<n>/`; `--resume` restarts from
/// the newest snapshot mid-stream (bitwise for the sync schedule).
pub fn run<'p>(
    cfg: &ExpConfig,
    prep: &'p Prepared,
    make_source: impl FnOnce(
        Instant,
        Option<&Checkpoint>,
        &Arc<ParamBus>,
    ) -> Result<Box<dyn RoundSource + 'p>>,
    verbose: bool,
) -> Result<RunOutput> {
    let engine: &Engine = &prep.engine;
    let sft_params = prep.sft_params.clone();
    let mut timeline = Timeline::new();
    let ckpt_dir = checkpoint::dir_for(&cfg.run_dir, &cfg.label());
    let restored = if cfg.resume {
        match Checkpoint::load_latest(&ckpt_dir)? {
            Some((n, c)) => {
                if verbose {
                    eprintln!(
                        "[resume] continuing from step {n} ({})",
                        ckpt_dir.display()
                    );
                }
                Some(c)
            }
            None => bail!(
                "--resume: no checkpoints under {} (was the run started \
                 with --checkpoint-every?)",
                ckpt_dir.display()
            ),
        }
    } else {
        None
    };
    // seat layout: worker seats [0, M) — none in sync mode, where
    // generation reads the live device params — then shard seats
    // [M, M + S). The bus always exists (seeded exactly as the worker
    // pool's param slot used to be); whether anything is *published* to
    // it is gated on there being a subscriber.
    let worker_seats = match cfg.mode {
        Mode::Sync => 0,
        _ => cfg.gen_workers.max(1),
    };
    let shard_count = cfg.trainer_shards.max(1);
    let (init_version, init_params): (u64, Arc<[f32]>) = match &restored {
        Some(c) => (c.version, Arc::from(&c.params[..])),
        None => (0, Arc::from(&sft_params[..])),
    };
    let bus = Arc::new(ParamBus::new(
        worker_seats + shard_count,
        init_version,
        init_params,
    ));
    let publish_active = worker_seats > 0 || shard_count > 1;
    let mut source = make_source(timeline.origin(), restored.as_ref(), &bus)?;
    let mut log = RunLog::new();
    log.set_meta("label", cfg.label());

    let (mut state, mut step, mut version, mut accum) = match &restored {
        Some(c) => {
            log.set_meta("resumed_from_step", c.step);
            (
                TrainState::from_host(
                    c.params.clone(),
                    c.m.clone(),
                    c.v.clone(),
                    c.opt_step,
                )?,
                c.step,
                c.version,
                c.staleness.clone(),
            )
        }
        None => (
            TrainState::new(sft_params.clone()),
            0,
            0,
            StalenessAccum::default(),
        ),
    };
    drop(restored); // params/m/v are copied into the train state above
    let mut scratch = LabelScratch::default();
    let rpb = rounds_per_batch(cfg.k_samples);
    // set when a checkpoint came due but the source wasn't at a clean
    // boundary — carries the obligation to the next step
    let mut ckpt_pending = false;
    // S > 1: spin up the data-parallel trainer shards (their own PJRT
    // clients, subscribing to bus seats [M, M + S)); S = 1 keeps the
    // unsharded path bitwise-untouched
    let mut shards = if shard_count > 1 {
        log.set_meta("trainer_shards", shard_count);
        Some(ShardPool::spawn(
            cfg.artifact_dir(),
            engine,
            cfg.algo.artifact(),
            shard_count,
            bus.clone(),
            worker_seats,
        )?)
    } else {
        None
    };

    let result = (|| -> Result<()> {
        while step < cfg.steps {
            let mut rounds = Vec::with_capacity(rpb);
            for _ in 0..rpb {
                let sr = source.next(TrainerCx {
                    engine,
                    state: &mut state,
                    version,
                    timeline: &mut timeline,
                })?;
                // stage the round's tensors on device once (when
                // eligible — chaining the inline source's generate
                // buffers, when attached, for a zero-upload staging),
                // then label off the shared buffers; staging is part of
                // the scoring cost
                let (resident, labels) = timeline.record(Phase::Score, || {
                    stage_and_label(
                        engine,
                        &sr,
                        &sft_params,
                        prep.rm_scorer(),
                        cfg,
                        &mut scratch,
                    )
                })?;
                rounds.push(LabelledRound {
                    round: sr.round,
                    labels,
                    // sharded training consumes host batch slices (each
                    // shard re-uploads its slice to its own device), so
                    // the main engine's staged buffers are dropped to
                    // force the bitwise-identical host assembly path
                    resident: if shards.is_some() { None } else { resident },
                });
            }

            let batch = assemble(engine, cfg.algo, &rounds, cfg.k_samples)?;
            let all_metrics = timeline.record(Phase::Train, || {
                match shards.as_mut() {
                    Some(sp) => sp.train(
                        engine,
                        &mut state,
                        &batch,
                        cfg.lr,
                        cfg.updates_per_batch,
                        version,
                    ),
                    None => train_on_batch(
                        engine,
                        &mut state,
                        &batch,
                        cfg.lr,
                        cfg.updates_per_batch,
                    ),
                }
            })?;
            version += cfg.updates_per_batch as u64;
            step += 1;

            if publish_active {
                // device -> host once, then one latest-wins pointer swap
                // per subscriber seat (S + M swaps, zero copies)
                timeline.record(Phase::Publish, || -> Result<()> {
                    let host = state.params_host(engine)?;
                    bus.publish(version, Arc::from(host));
                    Ok(())
                })?;
            }

            let stale = staleness(version, batch_data_version(&rounds));
            accum.sum += stale;
            accum.max = accum.max.max(stale);
            // per-token staleness: under the continuous engine a
            // sequence's tokens can span policy versions (weights swap
            // between decode steps), so the oldest-token and mean-token
            // ages are reported alongside the per-round bound; for
            // round-synchronous engines all three coincide
            let (tok_min, tok_mean) = batch_token_versions(&rounds);
            let stale_tok_max = staleness(version, tok_min);
            let stale_tok_mean = ((version.saturating_sub(1)) as f64
                - tok_mean)
                .max(0.0);
            accum.tok_sum += stale_tok_mean;
            accum.tok_max = accum.tok_max.max(stale_tok_max);

            let episodes = source.episodes();
            let labels = &rounds[0].labels;
            let mut row = round_metrics(labels);
            let m = all_metrics.last().ok_or_else(|| {
                anyhow!(
                    "train_on_batch returned no metrics at step {step} \
                     (updates_per_batch = {})",
                    cfg.updates_per_batch
                )
            })?;
            row.push(("loss", m[0]));
            row.push(("staleness", stale as f32));
            row.push(("staleness_tok_max", stale_tok_max as f32));
            row.push(("staleness_tok_mean", stale_tok_mean as f32));
            log.push(step, episodes, timeline.wall(), &row);
            if verbose && step % 8 == 0 {
                eprintln!(
                    "[{} {}] step {step}/{} episodes {episodes} \
                     win {:.3} kl-ppl {:.4} loss {:.4} staleness {stale}",
                    source.label(),
                    cfg.algo,
                    cfg.steps,
                    log.recent_mean("win_rate", 8).unwrap_or(0.0),
                    log.recent_mean("kl_ppl", 8).unwrap_or(0.0),
                    m[0],
                );
            }

            if cfg.checkpoint_every > 0 {
                ckpt_pending |= step % cfg.checkpoint_every == 0;
                if ckpt_pending {
                    if let Some(src) = source.snapshot() {
                        timeline.record(Phase::Publish, || -> Result<()> {
                            let opt_step = state.step;
                            let (p, m, v) = state.host_mirrors(engine)?;
                            Checkpoint {
                                step,
                                version,
                                opt_step,
                                staleness: accum.clone(),
                                source: src,
                                params: p.to_vec(),
                                m: m.to_vec(),
                                v: v.to_vec(),
                            }
                            .save(&ckpt_dir)?;
                            Ok(())
                        })?;
                        ckpt_pending = false;
                        if verbose {
                            eprintln!(
                                "[checkpoint] step {step} -> {}",
                                ckpt_dir.join(format!("step_{step}")).display()
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    })();

    // tear the source down whether or not the loop succeeded (a worker
    // blocked in `send` must be released before join); shard threads are
    // torn down the same way — dropping the job senders unblocks them
    let episodes = source.episodes();
    let finish = source.finish(&mut log);
    let shard_finish = match shards.take() {
        Some(sp) => sp.finish(),
        None => Ok(()),
    };
    result?;
    finish?;
    shard_finish?;

    log.set_meta(
        "mean_staleness",
        format!("{:.3}", accum.sum as f64 / cfg.steps.max(1) as f64),
    );
    log.set_meta("max_staleness", accum.max);
    log.set_meta(
        "mean_staleness_tok",
        format!("{:.3}", accum.tok_sum / cfg.steps.max(1) as f64),
    );
    log.set_meta("max_staleness_tok", accum.tok_max);

    Ok(RunOutput {
        final_params: state.into_params(engine)?,
        log,
        timeline,
        episodes,
    })
}

// ---------------------------------------------------------------------------
// InlineSource: generate on the trainer's engine (synchronous schedule)
// ---------------------------------------------------------------------------

/// Generates rounds on the trainer's own engine and thread — the
/// synchronous generate-then-train schedule (paper Fig 2 top). Implements
/// the §3.2 off-policy ladder: each refill generates `n_minibatches`
/// batches of rounds with the then-current (frozen) policy; the trainer
/// drains them over the next N steps, so the last batch is N−1 updates
/// stale by the time it trains.
pub struct InlineSource<'p> {
    generator: Box<dyn Generator>,
    taskgen: &'p TaskGen,
    rng: Pcg32,
    opts: SampleOpts,
    k: usize,
    rounds_per_refill: usize,
    cursor: u64,
    stride: u64,
    gen_bs: u64,
    generated: u64,
    /// Refill window of rounds awaiting training. Sync rounds keep their
    /// fused-generate output buffers attached (same engine, same thread),
    /// so even ladder rounds trained N−1 steps later stage with zero
    /// token uploads.
    buffered: VecDeque<SourcedRound>,
}

impl<'p> InlineSource<'p> {
    /// Build the synchronous source, optionally re-entering the exact
    /// stream position of a restored checkpoint: the generation RNG
    /// cursor and prompt cursor fully determine every future round, so a
    /// resumed sync run is **bitwise** identical to one that never
    /// stopped.
    pub fn new(
        cfg: &ExpConfig,
        prep: &'p Prepared,
        resume: Option<&Checkpoint>,
    ) -> Result<InlineSource<'p>> {
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        let (rng, cursor, generated) = match resume {
            Some(c) => {
                let s = &c.source;
                if s.kind != "inline" {
                    bail!(
                        "--resume: checkpoint was written by a '{}' round \
                         source but this run is synchronous (inline)",
                        s.kind
                    );
                }
                let (st, inc) = s.rng.ok_or_else(|| {
                    anyhow!("--resume: inline checkpoint lacks an RNG cursor")
                })?;
                let cursor = *s.cursors.first().ok_or_else(|| {
                    anyhow!("--resume: inline checkpoint lacks a prompt cursor")
                })?;
                (Pcg32::from_state(st, inc), cursor, s.generated)
            }
            None => (Pcg32::new(cfg.seed, 0x5c), RLHF_RANGE, 0),
        };
        Ok(InlineSource {
            generator: cfg.gen_engine.build(),
            taskgen: &prep.taskgen,
            rng,
            opts: sample_opts(cfg),
            k: cfg.k_samples,
            rounds_per_refill: cfg.n_minibatches * rounds_per_batch(cfg.k_samples),
            cursor,
            stride: cursor_stride(gen_bs, cfg.k_samples),
            gen_bs,
            generated,
            buffered: VecDeque::new(),
        })
    }
}

impl RoundSource for InlineSource<'_> {
    fn label(&self) -> &'static str {
        "sync"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { engine, state, version, timeline } = cx;
        if self.buffered.is_empty() {
            // generation phase: N minibatches of data, frozen policy;
            // the staged variant keeps the fused outputs device-resident
            // for the trainer (same engine) to chain into round staging
            let origin = timeline.origin();
            for _ in 0..self.rounds_per_refill {
                let round = timeline.record(Phase::Generate, || {
                    generate_round_staged(
                        engine,
                        self.generator.as_ref(),
                        state.param_view("policy", version),
                        version,
                        self.taskgen,
                        self.cursor,
                        self.k,
                        self.opts,
                        &mut self.rng,
                        origin,
                    )
                })?;
                self.cursor += self.stride;
                self.generated += 1;
                self.buffered.push_back(round);
            }
        }
        self.buffered.pop_front().ok_or_else(|| {
            anyhow!(
                "inline refill produced no rounds (rounds_per_refill = {})",
                self.rounds_per_refill
            )
        })
    }

    fn episodes(&self) -> u64 {
        // counted at generation: a refill window's episodes are spent
        // the moment the frozen policy generates them (seed accounting)
        self.generated * self.gen_bs
    }

    fn snapshot(&self) -> Option<SourceState> {
        if !self.buffered.is_empty() {
            // mid-ladder: buffered rounds were generated by a policy a
            // resumed process cannot reconstruct — wait for the window
            // boundary (with n_minibatches = 1 every step is one)
            return None;
        }
        Some(SourceState {
            kind: "inline".into(),
            rng: Some(self.rng.state()),
            generated: self.generated,
            cursors: vec![self.cursor],
            skip: vec![],
            epoch: 0,
        })
    }

    fn finish(self: Box<Self>, _log: &mut RunLog) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// SessionSource: served traffic as the prompt stream (serve-while-training)
// ---------------------------------------------------------------------------

/// Serving-side telemetry accumulated across all worker seats: latency
/// samples per retired candidate, served-params staleness lags, and the
/// occupancy numerator/denominator. Folded into the run metas at finish.
#[derive(Default)]
struct ServeTelemetry {
    /// Time-to-first-token per candidate, sweep units.
    ttft: Vec<u64>,
    /// Time-to-retire per candidate, sweep units.
    retire: Vec<u64>,
    /// Served-params staleness per candidate: publish version at
    /// retirement minus the oldest version any of its tokens sampled
    /// under — the "how stale was the reply" distribution.
    lag: Vec<u64>,
    /// Turns completed (user-visible requests).
    requests: u64,
    /// Response tokens emitted across all candidates.
    tokens: u64,
    /// Occupancy denominator: pool slots × sampling sweeps.
    slot_sweeps: u64,
    /// Mux sweeps elapsed (includes idle arrival gaps).
    mux_sweeps: u64,
    /// Every served turn across all seats and incarnations — rendered
    /// into the `serve_transcript` meta at finish. Seats flush records
    /// sweep-by-sweep (not at exit), so a turn a dying seat already
    /// served is never lost with its thread; the union is the whole
    /// trace no matter how residues moved between seats.
    records: Vec<TurnRecord>,
}

/// Seat-side flush of one mux's pool accounting into the shared
/// telemetry — called on every seat exit path.
fn flush_serve_stats(
    telemetry: &Arc<Mutex<ServeTelemetry>>,
    stats: PoolStats,
    slots: usize,
    mux_sweeps: u64,
) {
    let mut t = telemetry.lock().unwrap_or_else(PoisonError::into_inner);
    t.tokens += stats.tokens;
    t.slot_sweeps += stats.sweeps * slots as u64;
    t.mux_sweeps += mux_sweeps;
}

/// The shape of one serve run, shared by the supervisor and its seats.
#[derive(Clone)]
struct ServeCtx {
    base: SpawnCtx,
    sessions: u64,
    turns: u64,
    arrival_rate: f64,
    /// Worker count — the session partition stride.
    workers: u64,
}

/// The shared handles a serving seat runs against: the worker-pool set
/// plus the telemetry sink and the per-seat "partition fully served"
/// flags (a serving seat retires itself when its sessions drain, which
/// the supervisor must distinguish from a mid-run death).
#[derive(Clone)]
struct ServeShared {
    base: SeatShared,
    telemetry: Arc<Mutex<ServeTelemetry>>,
    done: Arc<Vec<AtomicBool>>,
}

/// Exactly-once accounting for served rounds. Where [`LaneAccounts`]
/// tracks lane cursors, this tracks the set of delivered turn uids — and
/// enforces the session-order invariant: within a session, turn `t`
/// cannot deliver before turn `t − 1` (the board gates turn `t` on turn
/// `t − 1`'s completion, so a violation means a turn was dropped).
struct SessionAccounts {
    turns: u64,
    delivered: HashSet<u64>,
    duplicates: u64,
}

impl SessionAccounts {
    fn new(turns: u64) -> SessionAccounts {
        SessionAccounts { turns, delivered: HashSet::new(), duplicates: 0 }
    }

    /// Rebuild the accounts from a checkpoint's delivered-turn set. The
    /// delivered set IS the whole serve-source state: boards recompute
    /// their schedules from it, so resume needs no cursors beyond it.
    fn resume(turns: u64, delivered: HashSet<u64>) -> SessionAccounts {
        SessionAccounts { turns, delivered, duplicates: 0 }
    }

    fn accept(&mut self, msg: &GenMsg) -> Result<Accept> {
        let Some(uids) = &msg.indices else {
            bail!("served round carries no session uids — this is a bug");
        };
        let fresh =
            uids.iter().filter(|&&u| !self.delivered.contains(&u)).count();
        if fresh == 0 {
            self.duplicates += 1;
            return Ok(Accept::Duplicate);
        }
        if fresh < uids.len() {
            let sessions: Vec<u64> = uids
                .iter()
                .map(|&u| uid_session_turn(u, self.turns).0)
                .collect();
            bail!(
                "served round mixes {fresh} fresh and {} replayed turns \
                 (sessions {sessions:?}) — the respawn skip set missed a \
                 delivery",
                uids.len() - fresh
            );
        }
        for &u in uids {
            let (session, turn) = uid_session_turn(u, self.turns);
            // in-message predecessors were inserted just above, so a
            // round carrying consecutive turns of one session is legal
            if turn > 0 && !self.delivered.contains(&(u - 1)) {
                bail!(
                    "serving session {session}: turn {turn} delivered \
                     before turn {} — a turn was dropped",
                    turn - 1
                );
            }
            self.delivered.insert(u);
        }
        Ok(Accept::Fresh)
    }
}

/// Serve-while-training: M serving seats, each multiplexing its slice
/// of the traffic trace (the residues `session % M` it currently owns)
/// onto its own continuous slot pool, with completed turns assembled
/// into training rounds — live traffic IS the prompt stream.
///
/// Structure mirrors [`WorkerPool`] (supervised seats, bounded round
/// queue, a latest-wins [`ParamBus`] seat each, heartbeat watchdog,
/// scripted fault injection) with three deltas:
///
/// - rounds carry **session turn uids** instead of lane cursors;
///   [`SessionAccounts`] extends the trainer's dedup/hole checks to them
///   (a respawned seat rebuilds its schedule from the delivered set, so
///   every post-respawn round is all-fresh);
/// - seats **retire themselves** when their slice is fully served — the
///   run's length is the traffic's, not a step budget;
/// - when a seat exhausts its restarts, its sessions **migrate**: the
///   session board is a pure function of `(trace, delivered-set)`, so a
///   survivor rebuilt over the merged residues resumes every stranded
///   session at its first undelivered turn ([`SessionBoard::for_lanes`]),
///   and [`SessionAccounts`] keeps turn-uid exactly-once across the
///   move. Only when *no* seat survives does the run fail loudly,
///   naming the sessions that cannot complete (silently dropping a turn
///   is the one forbidden outcome).
pub struct SessionSource {
    rx: mpsc::Receiver<GenMsg>,
    tx: Option<mpsc::SyncSender<GenMsg>>,
    exit_rx: mpsc::Receiver<WorkerExit>,
    exit_tx: mpsc::Sender<WorkerExit>,
    bus: Arc<ParamBus>,
    stop: Arc<AtomicBool>,
    /// Unused by serving seats (sessions, not lanes) but part of the
    /// shared seat handle; kept empty.
    ledger: Arc<Vec<AtomicU64>>,
    /// Per-seat control block. The lane mask holds the traffic residues
    /// (`session % workers`) the seat serves; clearing it forces a live
    /// seat to retire so it can respawn over a merged mask (takeover).
    ctl: Arc<Vec<SlotCtl>>,
    fault_fired: Arc<AtomicBool>,
    retry_count: Arc<AtomicU64>,
    telemetry: Arc<Mutex<ServeTelemetry>>,
    done: Arc<Vec<AtomicBool>>,
    ctx: ServeCtx,
    seats: Vec<Option<JoinHandle<()>>>,
    sup: Supervision,
    /// Session migration in flight: the merged residue mask a forcibly
    /// retired heir respawns over once its clean exit is reaped.
    pending_respawn: Vec<Option<BitSet>>,
    accounts: SessionAccounts,
    pending: VecDeque<GenMsg>,
    totals: Vec<(f64, u64)>,
    gen_bs: u64,
    received: u64,
    /// Round-tier counterfactual occupancy accounting: had each
    /// delivered round been generated as a fixed round, it would have
    /// held all B slots for its longest completion's sweeps.
    fixed_tokens: u64,
    fixed_slot_sweeps: u64,
    poll: Duration,
}

impl SessionSource {
    pub fn spawn(
        cfg: &ExpConfig,
        prep: &Prepared,
        origin: Instant,
        resume: Option<&Checkpoint>,
        bus: Arc<ParamBus>,
    ) -> Result<SessionSource> {
        if cfg.gen_engine != GenEngine::Continuous {
            bail!(
                "serve mode needs the continuous engine (got {:?})",
                cfg.gen_engine
            );
        }
        let m = cfg.gen_workers.max(1);
        if cfg.serve_sessions % m as u64 != 0 {
            bail!(
                "--serve-sessions {} must divide evenly over {m} workers \
                 (the residue partition `session % M` must spread the \
                 trace evenly at spawn)",
                cfg.serve_sessions
            );
        }
        // the delivered-turn set is the whole resumable serve state:
        // every board rebuilds its schedule from (trace, delivered), the
        // traffic clock restarts per incarnation, and the epoch shifts
        // worker RNG streams past every stream the prior run consumed
        let (accounts, epoch0, received) = match resume {
            Some(c) => {
                let s = &c.source;
                if s.kind != "serve" {
                    bail!(
                        "--resume: checkpoint was written by a '{}' round \
                         source but this run is serve mode",
                        s.kind
                    );
                }
                let delivered: HashSet<u64> =
                    s.skip.first().cloned().unwrap_or_default().into_iter().collect();
                (
                    SessionAccounts::resume(cfg.serve_turns, delivered),
                    s.epoch + 1,
                    s.generated,
                )
            }
            None => (SessionAccounts::new(cfg.serve_turns), 0, 0),
        };
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        let stride = cursor_stride(gen_bs, cfg.k_samples);
        let ctx = ServeCtx {
            base: SpawnCtx {
                artifact_dir: cfg.artifact_dir(),
                task: prep.taskgen.task,
                prompt_len: prep.taskgen.prompt_len,
                resp_len: prep.taskgen.resp_len,
                seed: cfg.seed,
                opts: sample_opts(cfg),
                k: cfg.k_samples,
                gen_engine: cfg.gen_engine,
                max_cohorts: cfg.max_cohorts,
                admit_min: cfg.admit_min,
                stride,
                hop: stride * m as u64,
                retries: cfg.engine_retries,
                stall_timeout: cfg.stall_timeout_secs,
                fault: cfg.inject_fault,
                origin,
                continuous: true,
            },
            sessions: cfg.serve_sessions,
            turns: cfg.serve_turns,
            arrival_rate: cfg.arrival_rate,
            workers: m as u64,
        };
        let (tx, rx) = mpsc::sync_channel::<GenMsg>(cfg.staleness_bound);
        let (exit_tx, exit_rx) = mpsc::channel::<WorkerExit>();
        let now_ms = origin.elapsed().as_millis() as u64;
        let mut source = SessionSource {
            rx,
            tx: Some(tx),
            exit_rx,
            exit_tx,
            bus,
            stop: Arc::new(AtomicBool::new(false)),
            ledger: Arc::new(Vec::new()),
            ctl: Arc::new(
                (0..m)
                    .map(|w| SlotCtl::new(AtomicBitSet::single(w, m), now_ms))
                    .collect(),
            ),
            fault_fired: Arc::new(AtomicBool::new(false)),
            retry_count: Arc::new(AtomicU64::new(0)),
            telemetry: Arc::new(Mutex::new(ServeTelemetry::default())),
            done: Arc::new((0..m).map(|_| AtomicBool::new(false)).collect()),
            ctx,
            seats: (0..m).map(|_| None).collect(),
            sup: Supervision::new(m, epoch0, cfg.max_worker_restarts),
            pending_respawn: (0..m).map(|_| None).collect(),
            accounts,
            pending: VecDeque::new(),
            totals: vec![(0.0, 0); m],
            gen_bs,
            received,
            fixed_tokens: 0,
            fixed_slot_sweeps: 0,
            poll: Duration::from_secs_f64(
                (cfg.stall_timeout_secs / 4.0).clamp(0.010, 0.050),
            ),
        };
        for w in 0..m {
            source.spawn_seat(w)?;
        }
        Ok(source)
    }

    fn shared(&self) -> Result<ServeShared> {
        let tx = self.tx.clone().ok_or_else(|| {
            anyhow!(
                "serve queue already torn down while (re)spawning a seat — \
                 finish() ran before supervision stopped"
            )
        })?;
        Ok(ServeShared {
            base: SeatShared {
                tx,
                bus: self.bus.clone(),
                stop: self.stop.clone(),
                ledger: self.ledger.clone(),
                ctl: self.ctl.clone(),
                fault_fired: self.fault_fired.clone(),
                retry_count: self.retry_count.clone(),
            },
            telemetry: self.telemetry.clone(),
            done: self.done.clone(),
        })
    }

    /// (Re)spawn serving seat `w` over the residues its control mask
    /// currently holds. A replacement rebuilds its session schedule from
    /// the trainer-accepted delivered set: already-trained turns are
    /// skipped, lost in-flight turns regenerate.
    fn spawn_seat(&mut self, w: usize) -> Result<()> {
        let ctx = self.ctx.clone();
        let sh = self.shared()?;
        let exit_tx = self.exit_tx.clone();
        let incarnation = self.sup.incarnations[w];
        let lanes: Vec<u64> =
            self.ctl[w].lanes.snapshot().ones().map(|l| l as u64).collect();
        let skip = self.accounts.delivered.clone();
        self.done[w].store(false, Ordering::SeqCst);
        beat(&self.ctl[w], self.ctx.base.origin);
        let handle = std::thread::Builder::new()
            .name(format!("gen-worker-{w}"))
            .spawn(move || {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    seat_serve(&ctx, &sh, w, incarnation, &lanes, skip)
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow!("panicked: {}", panic_message(p.as_ref())))
                });
                let _ = exit_tx.send(WorkerExit { slot: w, outcome });
            })
            .map_err(|e| anyhow!("spawn gen-worker-{w}: {e}"))?;
        self.seats[w] = Some(handle);
        Ok(())
    }

    /// Reap exits and heartbeat the watchdog — the [`WorkerPool`] loop
    /// with two legitimate clean-exit reasons: "slice served" (the seat
    /// set its done flag) and "forcibly retired" (the supervisor cleared
    /// its residue mask so it could respawn over a merged one).
    fn supervise(&mut self) -> Result<()> {
        while let Ok(exit) = self.exit_rx.try_recv() {
            let w = exit.slot;
            if let Some(h) = self.seats[w].take() {
                let _ = h.join();
            }
            match exit.outcome {
                Ok((secs, rounds)) => {
                    self.totals[w].0 += secs;
                    self.totals[w].1 += rounds;
                    let served = self.done[w].load(Ordering::SeqCst);
                    let retired = self.ctl[w].lanes.is_empty();
                    if !self.stop.load(Ordering::SeqCst) {
                        if !served && !retired {
                            self.handle_death(
                                w,
                                anyhow!(
                                    "exited cleanly mid-serve (queue closed?)"
                                ),
                            )?;
                        } else if let Some(mask) =
                            self.pending_respawn[w].take()
                        {
                            self.respawn_with_lanes(w, mask)?;
                        }
                    }
                }
                Err(e) => self.handle_death(w, e)?,
            }
        }
        let seats = &self.seats;
        let done = &self.done;
        self.sup.watchdog(
            &self.ctl,
            |w| seats[w].is_some() && !done[w].load(Ordering::SeqCst),
            self.ctx.base.origin,
            self.ctx.base.stall_timeout,
        );
        Ok(())
    }

    /// Absorb queued rounds into the accounts before computing a respawn
    /// skip set — a round in the queue at seat death is not yet
    /// delivered, and a replacement spawned without it would regenerate
    /// it into a duplicate.
    fn drain_queue(&mut self) -> Result<()> {
        while let Ok(msg) = self.rx.try_recv() {
            if let Accept::Fresh = self.accounts.accept(&msg)? {
                self.pending.push_back(msg);
            }
        }
        Ok(())
    }

    /// Sessions whose residue is in `lanes` and which still have
    /// undelivered turns — the migration payload (and, when no seat
    /// survives, the loud-failure payload).
    fn incomplete_sessions(&self, lanes: &BitSet) -> Vec<u64> {
        (0..self.ctx.sessions)
            .filter(|s| lanes.contains((s % self.ctx.workers) as usize))
            .filter(|&s| {
                (0..self.ctx.turns).any(|t| {
                    !self
                        .accounts
                        .delivered
                        .contains(&turn_uid(s, t, self.ctx.turns))
                })
            })
            .collect()
    }

    fn handle_death(&mut self, w: usize, err: anyhow::Error) -> Result<()> {
        self.drain_queue()?;
        // a heir that died while its takeover was queued takes its
        // pending merged mask back so those residues are not lost
        if let Some(mask) = self.pending_respawn[w].take() {
            self.ctl[w].lanes.merge(&mask);
        }
        let lanes = self.ctl[w].lanes.snapshot();
        // its in-flight decode work died with the engine-local KV
        self.sup.inflight_tokens_abandoned +=
            self.ctl[w].inflight_tok.swap(0, Ordering::SeqCst);
        // any non-lost seat can inherit: a live one is forced to retire
        // first, an already-exited one (slice served) respawns directly
        let heir = (0..self.seats.len()).find(|&h| h != w && !self.sup.lost[h]);
        let stranded = format!(
            "; serving sessions {:?} cannot complete their turns",
            self.incomplete_sessions(&lanes)
        );
        match self.sup.on_death(w, &err, heir, &stranded)? {
            Recovery::Respawn => self.spawn_seat(w),
            Recovery::Takeover { heir: h } => {
                self.ctl[w].lanes.clear();
                let moved = self.incomplete_sessions(&lanes);
                self.sup.sessions_migrated += moved.len() as u64;
                supervisor_log(
                    w,
                    "migrate",
                    &format!(
                        "died with no restarts left: {err:#}; residues \
                         {lanes} ({} unfinished sessions) migrating onto \
                         gen-worker-{h}",
                        moved.len()
                    ),
                );
                if let Some(pmask) = &mut self.pending_respawn[h] {
                    // heir already queued for takeover: widen its mask
                    for l in lanes.ones() {
                        pmask.set(l);
                    }
                    Ok(())
                } else {
                    let mut merged = self.ctl[h].lanes.snapshot();
                    for l in lanes.ones() {
                        merged.set(l);
                    }
                    self.ctl[h].lanes.clear();
                    if self.seats[h].is_some()
                        && !self.done[h].load(Ordering::SeqCst)
                    {
                        // live heir: the cleared mask forces it to retire
                        // at its next sweep; supervise() reaps the clean
                        // exit and respawns it over the merged residues
                        self.pending_respawn[h] = Some(merged);
                        Ok(())
                    } else {
                        // heir already exited (slice served): nothing to
                        // retire, respawn it over the merged mask now
                        self.respawn_with_lanes(h, merged)
                    }
                }
            }
        }
    }

    /// Respawn takeover heir `h` over the merged residue mask: its new
    /// board is rebuilt from `(trace, delivered)`, so every migrated
    /// session resumes at its first undelivered turn.
    fn respawn_with_lanes(&mut self, h: usize, mask: BitSet) -> Result<()> {
        self.drain_queue()?;
        // the forced retire abandoned the heir's own in-flight KV too
        self.sup.inflight_tokens_abandoned +=
            self.ctl[h].inflight_tok.swap(0, Ordering::SeqCst);
        // the mask was cleared to force the retire, so merge == assign
        self.ctl[h].lanes.merge(&mask);
        self.sup.on_takeover_respawn(h);
        supervisor_log(
            h,
            "takeover",
            &format!(
                "serving merged residues {mask}; schedule rebuilt from the \
                 delivered-turn set"
            ),
        );
        self.spawn_seat(h)
    }

    fn deliver(
        &mut self,
        msg: GenMsg,
        timeline: &mut Timeline,
        t_wait: f64,
    ) -> SourcedRound {
        let t_got = timeline.origin().elapsed().as_secs_f64();
        timeline.push_span(Phase::Idle, t_wait, t_got);
        timeline.push_span(
            Phase::Generate,
            msg.round.gen_span.0,
            msg.round.gen_span.1,
        );
        self.received += 1;
        // rounds delivered while a seat is permanently lost: the price
        // of running the trace on fewer serving seats
        if self.sup.degraded() {
            self.sup.degraded_capacity_steps += 1;
        }
        // round-tier counterfactual: a fixed round holds every slot for
        // its slowest row's sweeps
        self.fixed_tokens += msg
            .round
            .gen
            .resp_mask
            .iter()
            .map(|row| row.iter().filter(|&&m| m == 1.0).count() as u64)
            .sum::<u64>();
        self.fixed_slot_sweeps += msg.round.gen.steps as u64 * self.gen_bs;
        SourcedRound { round: msg.round, staged: None }
    }
}

impl RoundSource for SessionSource {
    fn label(&self) -> &'static str {
        "serve"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { timeline, .. } = cx;
        let t_wait = timeline.origin().elapsed().as_secs_f64();
        loop {
            if let Some(msg) = self.pending.pop_front() {
                return Ok(self.deliver(msg, timeline, t_wait));
            }
            self.supervise()?;
            match self.rx.recv_timeout(self.poll) {
                Ok(msg) => match self.accounts.accept(&msg)? {
                    Accept::Fresh => {
                        return Ok(self.deliver(msg, timeline, t_wait))
                    }
                    Accept::Duplicate => continue,
                },
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                    "served round queue disconnected while the source holds \
                     a sender — this is a bug"
                ),
            }
        }
    }

    fn episodes(&self) -> u64 {
        self.received * self.gen_bs
    }

    fn snapshot(&self) -> Option<SourceState> {
        // rescued-but-untrained rounds would be lost: they are already in
        // the delivered set, so a resume would skip them without their
        // turns ever reaching the trainer. Skip this boundary; the run
        // loop retries at the next step.
        if !self.pending.is_empty() {
            return None;
        }
        // the delivered-turn set is the whole serve state: every board
        // is a pure function of (trace, delivered), so no cursors beyond
        // it need persisting
        let mut delivered: Vec<u64> =
            self.accounts.delivered.iter().copied().collect();
        delivered.sort_unstable();
        Some(SourceState {
            kind: "serve".to_string(),
            rng: None,
            generated: self.received,
            cursors: Vec::new(),
            skip: vec![delivered],
            epoch: self.sup.incarnations.iter().copied().max().unwrap_or(0),
        })
    }

    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()> {
        let mut src = *self;
        src.stop.store(true, Ordering::SeqCst);
        drop(src.tx.take());
        drop(src.rx);
        for seat in src.seats.iter_mut() {
            if let Some(h) = seat.take() {
                let _ = h.join();
            }
        }
        while let Ok(exit) = src.exit_rx.try_recv() {
            match exit.outcome {
                Ok((secs, rounds)) => {
                    src.totals[exit.slot].0 += secs;
                    src.totals[exit.slot].1 += rounds;
                }
                Err(e) => src
                    .sup
                    .worker_errors
                    .push(format!("gen-worker-{}: {e:#}", exit.slot)),
            }
        }
        let mut gen_total = 0.0f64;
        let mut rounds_total = 0u64;
        for (w, (secs, rounds)) in src.totals.iter().enumerate() {
            log.set_meta(&format!("gen_secs_w{w}"), format!("{secs:.3}"));
            log.set_meta(&format!("gen_rounds_w{w}"), rounds);
            gen_total += secs;
            rounds_total += rounds;
        }
        log.set_meta("gen_total_secs", format!("{gen_total:.3}"));
        log.set_meta("gen_rounds", rounds_total);
        src.sup.meta(log);
        log.set_meta("engine_retries", src.retry_count.load(Ordering::SeqCst));
        log.set_meta("dropped_duplicate_rounds", src.accounts.duplicates);
        // serving telemetry: latency percentiles, staleness lags,
        // occupancy vs the fixed-round counterfactual
        let mut t = std::mem::take(
            &mut *src.telemetry.lock().unwrap_or_else(PoisonError::into_inner),
        );
        log.set_meta("serve_sessions", src.ctx.sessions);
        log.set_meta("serve_turns", src.ctx.turns);
        log.set_meta("serve_requests", t.requests);
        log.set_meta("serve_tokens", t.tokens);
        log.set_meta("serve_mux_sweeps", t.mux_sweeps);
        log.set_meta(
            "serve_ttft_p50",
            format!("{:.3}", pct(&mut t.ttft, 0.50)),
        );
        log.set_meta(
            "serve_ttft_p99",
            format!("{:.3}", pct(&mut t.ttft, 0.99)),
        );
        log.set_meta(
            "serve_retire_p50",
            format!("{:.3}", pct(&mut t.retire, 0.50)),
        );
        log.set_meta(
            "serve_retire_p99",
            format!("{:.3}", pct(&mut t.retire, 0.99)),
        );
        log.set_meta("serve_lag_p50", format!("{:.3}", pct(&mut t.lag, 0.50)));
        log.set_meta("serve_lag_p99", format!("{:.3}", pct(&mut t.lag, 0.99)));
        log.set_meta(
            "serve_lag_max",
            t.lag.iter().copied().max().unwrap_or(0),
        );
        log.set_meta(
            "serve_occupancy",
            format!(
                "{:.4}",
                t.tokens as f64 / t.slot_sweeps.max(1) as f64
            ),
        );
        log.set_meta(
            "serve_occupancy_round_tier",
            format!(
                "{:.4}",
                src.fixed_tokens as f64 / src.fixed_slot_sweeps.max(1) as f64
            ),
        );
        // the union of every seat's served records, rendered in the
        // [`SessionBoard::transcript`] line format and (session, turn)
        // order — deterministic at fixed params regardless of which seat
        // (or incarnation) served each turn, so migration and resume
        // tests compare it byte-for-byte
        t.records.sort_by_key(|r| (r.session, r.turn));
        // a forcibly retired seat may have recorded a completed turn
        // whose round never delivered; its heir re-serves (and re-records)
        // that turn, so the transcript keeps one line per uid
        t.records.dedup_by_key(|r| r.uid);
        let transcript: String = t
            .records
            .iter()
            .map(|r| {
                format!(
                    "session {} turn {} uid {} term {} reply {:?}\n",
                    r.session, r.turn, r.uid, r.terminated, r.reply
                )
            })
            .collect();
        log.set_meta("serve_transcript", transcript);
        Ok(())
    }
}

/// Body of one serving seat: drive the [`ServeMux`] one sweep at a time
/// — traffic clock, admission, decode, retirement routing — re-reading
/// the published policy slot between sweeps (the inflight weight swap,
/// exactly as [`seat_continuous`]), pushing latency/lag samples into the
/// shared telemetry, assembling completed turns into training rounds,
/// and retiring itself once its session slice is fully served. `lanes`
/// holds the traffic residues (`session % workers`) this incarnation
/// serves — one residue at first spawn, several after inheriting a dead
/// seat's sessions; an empty control mask mid-run means the supervisor
/// wants this seat's residues back for a takeover merge, and the seat
/// retires without setting its done flag.
fn seat_serve(
    ctx: &ServeCtx,
    sh: &ServeShared,
    w: usize,
    incarnation: u64,
    lanes: &[u64],
    skip: HashSet<u64>,
) -> Result<(f64, u64)> {
    let base = &ctx.base;
    let sb = &sh.base;
    let engine = Engine::load(&base.artifact_dir)?;
    let taskgen =
        TaskGen::new(base.task, base.prompt_len, base.resp_len, base.seed);
    let stream = w as u64 + (incarnation << 20);
    let mut rng = Pcg32::new(base.seed, 0xa57c + stream);
    let mut retry_rng = Pcg32::new(base.seed, RETRY_STREAM + stream);
    let policy = RetryPolicy::new(base.retries);
    let mcfg = engine.manifest.config.clone();
    let mut backend = DeviceBackend::new(&engine)?;
    let traffic = TrafficGen::new(TrafficCfg {
        sessions: ctx.sessions,
        turns: ctx.turns,
        arrival_rate: ctx.arrival_rate,
        seed: base.seed,
    });
    let board =
        SessionBoard::for_lanes(&traffic, base.k, lanes, ctx.workers, &skip)?;
    let mut mux = ServeMux::new(
        PoolCfg {
            slots: mcfg.gen_batch,
            prompt_len: mcfg.prompt_len,
            seq_len: mcfg.seq_len,
            vocab: mcfg.vocab,
            max_cohorts: base.max_cohorts,
            admit_min: base.admit_min,
        },
        board,
    );
    let mut assembler = RoundAssembler::new(mcfg.gen_batch, base.k);
    let (mut version, mut params) = sb.bus.latest(w);
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut inject_err = false;
    let mut flushed_records = 0usize;
    let mut t_round = base.origin.elapsed().as_secs_f64();
    loop {
        beat(&sb.ctl[w], base.origin);
        if sb.stop.load(Ordering::SeqCst) {
            break;
        }
        if sb.ctl[w].lanes.is_empty() {
            // forcibly retired: the supervisor reclaimed this seat's
            // residues for a takeover merge — abandon in-flight work
            // (the accounts dedup anything re-served) and exit WITHOUT
            // the done flag so supervision respawns over the merged mask
            break;
        }
        if mux.is_done() && assembler.buffered() == 0 {
            // slice fully served and every round handed over
            sh.done[w].store(true, Ordering::SeqCst);
            break;
        }
        if let Some((v, p)) = sb.bus.fetch(w, version) {
            version = v;
            params = p;
        }
        maybe_inject(base, sb, w, rounds_done, &mut inject_err);
        let events = policy.run(
            &mut retry_rng,
            |_| {
                sb.retry_count.fetch_add(1, Ordering::SeqCst);
                engine.note_retry(ROUND_ORIGIN);
            },
            |attempt| {
                if inject_err && attempt == 0 {
                    bail!(
                        "injected fault: scripted engine error in \
                         gen-worker-{w}"
                    );
                }
                mux.step(
                    &mut backend,
                    &taskgen,
                    ParamView::cached("policy", version, &params),
                    version,
                    base.opts,
                    &mut rng,
                )
            },
        )?;
        inject_err = false;
        // what a death right now would abandon with the engine-local KV
        sb.ctl[w]
            .inflight_tok
            .store(mux.inflight_tokens(), Ordering::SeqCst);
        if !events.is_empty() {
            let mut t =
                sh.telemetry.lock().unwrap_or_else(PoisonError::into_inner);
            for (c, ev) in &events {
                t.ttft.push(ev.ttft);
                t.retire.push(ev.retire);
                t.lag.push(version.saturating_sub(c.version_min));
                if ev.turn_done {
                    t.requests += 1;
                }
            }
            // flush served-turn records as they land, not at exit — a
            // seat that dies mid-serve must not take its transcript with
            // it (records only grow when a sweep completes turns)
            let recs = mux.board().records();
            if recs.len() > flushed_records {
                t.records.extend_from_slice(&recs[flushed_records..]);
                flushed_records = recs.len();
            }
        }
        for (c, _) in events {
            assembler.push(c);
        }
        while let Some(groups) = assembler.pop_round() {
            let uids: Vec<u64> = groups.iter().map(|(i, _)| *i).collect();
            let t_now = base.origin.elapsed().as_secs_f64();
            let round = round_from_groups(groups, &taskgen, (t_round, t_now));
            gen_total += t_now - t_round;
            rounds_done += 1;
            beat(&sb.ctl[w], base.origin);
            if sb
                .tx
                .send(GenMsg { round, lane: w, indices: Some(uids) })
                .is_err()
            {
                flush_serve_stats(
                    &sh.telemetry,
                    mux.stats(),
                    mcfg.gen_batch,
                    mux.sweep(),
                );
                return Ok((gen_total, rounds_done));
            }
            t_round = base.origin.elapsed().as_secs_f64();
        }
    }
    flush_serve_stats(
        &sh.telemetry,
        mux.stats(),
        mcfg.gen_batch,
        mux.sweep(),
    );
    Ok((gen_total, rounds_done))
}

#[cfg(test)]
mod tests {
    use std::collections::VecDeque;
    use std::sync::Arc;

    use super::super::pool::{Accept, GenMsg};
    use super::super::trainer::{staleness, Round};
    use super::{
        cursor_stride, staleness_bound_sharded, staleness_bound_updates,
        ParamBus, ParamSlot, SessionAccounts,
    };
    use crate::gen::GenBatch;
    use crate::serve::traffic::turn_uid;

    #[test]
    fn param_slot_is_latest_wins() {
        let slot = ParamSlot::new(0, Arc::from(&[0.0f32][..]));
        assert!(slot.fetch(0).is_none(), "nothing newer than the seed");
        for v in 1..=5u64 {
            slot.publish(v, Arc::from(&[v as f32][..]));
        }
        // a reader at version 0 sees only the freshest publication
        let (v, p) = slot.fetch(0).expect("new version visible");
        assert_eq!(v, 5);
        assert_eq!(&p[..], &[5.0]);
        // and nothing newer than what it now has
        assert!(slot.fetch(5).is_none());
    }

    #[test]
    fn param_slot_survives_a_panicked_lock_holder() {
        // a supervised worker that dies while holding the slot lock
        // poisons the mutex; the slot must keep serving (the critical
        // sections are pure pointer swaps, never half-written)
        let slot = Arc::new(ParamSlot::new(0, Arc::from(&[0.0f32][..])));
        let s2 = slot.clone();
        let _ = std::thread::spawn(move || {
            let _guard = s2.latest.lock().unwrap();
            panic!("die holding the param slot lock");
        })
        .join();
        assert!(slot.latest.is_poisoned(), "test setup must poison the lock");
        slot.publish(3, Arc::from(&[3.0f32][..]));
        let (v, p) = slot.fetch(0).expect("publish visible despite poison");
        assert_eq!((v, &p[..]), (3, &[3.0f32][..]));
        assert_eq!(slot.latest().0, 3);
    }

    #[test]
    fn param_slot_fetch_is_cheap_pointer_clone() {
        let big: Arc<[f32]> = Arc::from(vec![1.0f32; 1024].into_boxed_slice());
        let slot = ParamSlot::new(1, big.clone());
        let (_, p) = slot.fetch(0).unwrap();
        assert!(Arc::ptr_eq(&p, &big), "fetch must share, not copy");
    }

    #[test]
    fn param_bus_publish_fans_out_to_every_seat() {
        let bus = ParamBus::new(3, 0, Arc::from(&[0.0f32][..]));
        assert_eq!(bus.seats(), 3);
        for seat in 0..3 {
            let (v, p) = bus.latest(seat);
            assert_eq!((v, &p[..]), (0, &[0.0f32][..]), "seeded seat {seat}");
        }
        bus.publish(7, Arc::from(&[7.0f32][..]));
        for seat in 0..3 {
            let (v, p) = bus.fetch(seat, 0).expect("publish visible");
            assert_eq!((v, &p[..]), (7, &[7.0f32][..]), "seat {seat}");
        }
    }

    #[test]
    fn param_bus_seats_fetch_independently() {
        // one seat consuming a publish must not mark it consumed for the
        // others — each subscriber tracks its own `have` version
        let bus = ParamBus::new(2, 0, Arc::from(&[0.0f32][..]));
        bus.publish(1, Arc::from(&[1.0f32][..]));
        assert_eq!(bus.fetch(0, 0).expect("seat 0 sees v1").0, 1);
        assert_eq!(bus.fetch(1, 0).expect("seat 1 still sees v1").0, 1);
        assert!(bus.fetch(0, 1).is_none(), "nothing newer than v1");
    }

    #[test]
    fn param_bus_publish_shares_one_allocation_across_seats() {
        // fan-out is S + M pointer swaps, never a broadcast copy: every
        // seat must hand back the SAME Arc allocation
        let big: Arc<[f32]> = Arc::from(vec![2.0f32; 4096].into_boxed_slice());
        let bus = ParamBus::new(4, 0, Arc::from(&[0.0f32][..]));
        bus.publish(1, big.clone());
        for seat in 0..4 {
            let (_, p) = bus.latest(seat);
            assert!(Arc::ptr_eq(&p, &big), "seat {seat} must share, not copy");
        }
    }

    #[test]
    fn sharded_staleness_bound_adds_the_fan_out_term() {
        // S = 1 reduces exactly to the unsharded bound — no penalty for
        // running the sharded code path at one shard
        for (k, m, t) in [(0, 1, 1), (2, 3, 2), (4, 1, 3)] {
            assert_eq!(
                staleness_bound_sharded(k, m, t, 1),
                staleness_bound_updates(k, m, t)
            );
        }
        // every extra shard seat can lag the publish front by one more
        // update unit: bound grows by exactly S - 1
        for s in 1..6usize {
            assert_eq!(
                staleness_bound_sharded(2, 2, 2, s),
                staleness_bound_updates(2, 2, 2) + (s as u64 - 1)
            );
        }
    }

    /// A served round carrying only the fields [`SessionAccounts`] reads.
    fn serve_msg(uids: &[u64]) -> GenMsg {
        GenMsg {
            round: Round {
                gen: GenBatch {
                    tokens: vec![],
                    resp_mask: vec![],
                    blp: vec![],
                    terminated: vec![],
                    steps: 0,
                },
                examples: vec![],
                start_index: 0,
                params_version: 0,
                tok_version_min: 0,
                tok_version_mean: 0.0,
                gen_secs: 0.0,
                gen_span: (0.0, 0.0),
            },
            lane: 0,
            indices: Some(uids.to_vec()),
        }
    }

    #[test]
    fn serving_accounts_dedupe_replayed_rounds() {
        let turns = 2u64;
        let mut a = SessionAccounts::new(turns);
        let r0: Vec<u64> =
            (0..4).map(|s| turn_uid(s, 0, turns)).collect();
        assert!(matches!(a.accept(&serve_msg(&r0)).unwrap(), Accept::Fresh));
        // a respawned seat replaying the same turns: dropped, counted
        assert!(matches!(
            a.accept(&serve_msg(&r0)).unwrap(),
            Accept::Duplicate
        ));
        assert_eq!(a.duplicates, 1);
        // the next turn of each session is fresh again
        let r1: Vec<u64> =
            (0..4).map(|s| turn_uid(s, 1, turns)).collect();
        assert!(matches!(a.accept(&serve_msg(&r1)).unwrap(), Accept::Fresh));
    }

    #[test]
    fn serving_accounts_reject_mixed_and_missing_uids() {
        let turns = 2u64;
        let mut a = SessionAccounts::new(turns);
        let r0: Vec<u64> =
            (0..4).map(|s| turn_uid(s, 0, turns)).collect();
        a.accept(&serve_msg(&r0)).unwrap();
        // half replayed, half fresh: the respawn skip set missed a
        // delivery — loud failure naming the sessions
        let mixed =
            vec![turn_uid(0, 0, turns), turn_uid(4, 0, turns)];
        let err = a.accept(&serve_msg(&mixed)).unwrap_err().to_string();
        assert!(err.contains("mixes"), "{err}");
        assert!(err.contains("skip set"), "{err}");
        // a served round must carry session uids at all
        let mut no_uids = serve_msg(&[]);
        no_uids.indices = None;
        assert!(a.accept(&no_uids).is_err());
    }

    #[test]
    fn serving_accounts_fail_loudly_on_a_dropped_turn() {
        let turns = 3u64;
        let mut a = SessionAccounts::new(turns);
        // turn 1 of session 2 arriving before its turn 0 means the board
        // dropped a turn: the session-order invariant is violated
        let err = a
            .accept(&serve_msg(&[turn_uid(2, 1, turns)]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("session 2"), "{err}");
        assert!(err.contains("turn 1"), "{err}");
        // consecutive turns of one session inside one round are legal
        // (in-message predecessors count as delivered)
        let chain =
            vec![turn_uid(0, 0, turns), turn_uid(0, 1, turns)];
        assert!(matches!(
            a.accept(&serve_msg(&chain)).unwrap(),
            Accept::Fresh
        ));
    }

    #[test]
    fn cursor_never_freezes_when_k_exceeds_gen_batch() {
        // normal geometries: one round consumes gen_batch/k prompts
        assert_eq!(cursor_stride(8, 2), 4);
        assert_eq!(cursor_stride(4, 4), 1);
        // regression: the seed async worker advanced by gen_bs / k
        // WITHOUT the guard, so k > gen_batch froze the cursor and
        // replayed the same prompts forever
        assert_eq!(cursor_stride(2, 4), 1);
        let mut cursor = 0u64;
        for _ in 0..10 {
            cursor += cursor_stride(2, 4);
        }
        assert_eq!(cursor, 10, "cursor must be strictly monotone");
    }

    /// Discrete worst-case model of the K-bounded queue with one worker
    /// and *instantaneous* generation: the worker fills the queue (K
    /// rounds) plus one blocked `send`, fetching the freshest publish
    /// before each round. Per-step staleness must never exceed
    /// `staleness_bound_updates(K, 1, T) = (K + 2)·T − 1`, and the bound
    /// is tight (instant generation reaches it).
    #[test]
    fn bounded_queue_model_staleness_is_tight_at_bound() {
        for k_bound in 0..5usize {
            for t in 1..4u64 {
                let mut queue: VecDeque<u64> = VecDeque::new();
                let mut blocked: Option<u64> = None;
                let mut published = 0u64;
                let mut version = 0u64;
                let mut max_seen = 0u64;
                let refill = |queue: &mut VecDeque<u64>,
                              blocked: &mut Option<u64>,
                              published: u64| {
                    while queue.len() < k_bound {
                        queue.push_back(published);
                    }
                    if blocked.is_none() {
                        *blocked = Some(published);
                    }
                };
                refill(&mut queue, &mut blocked, published);
                for _ in 0..50 {
                    // trainer pops one round; a blocked send slides in
                    let data = match queue.pop_front() {
                        Some(front) => {
                            if let Some(b) = blocked.take() {
                                queue.push_back(b);
                            }
                            front
                        }
                        None => blocked.take().expect("rendezvous handover"),
                    };
                    // worker runs ahead again before this step publishes
                    refill(&mut queue, &mut blocked, published);
                    version += t;
                    published = version;
                    let st = staleness(version, data);
                    let bound = staleness_bound_updates(k_bound, 1, t as usize);
                    assert!(
                        st <= bound,
                        "K={k_bound} T={t}: staleness {st} > bound {bound}"
                    );
                    max_seen = max_seen.max(st);
                }
                assert_eq!(
                    max_seen,
                    staleness_bound_updates(k_bound, 1, t as usize),
                    "K={k_bound} T={t}: bound should be tight under \
                     instantaneous generation"
                );
            }
        }
    }

    #[test]
    fn staleness_bound_reduces_to_the_documented_invariants() {
        // queue depth K, one worker, T=1: staleness <= K + 1 policy
        // versions — K=0 is the seed coordinator's one-step bound
        assert_eq!(staleness_bound_updates(0, 1, 1), 1);
        assert_eq!(staleness_bound_updates(1, 1, 1), 2);
        assert_eq!(staleness_bound_updates(4, 1, 1), 5);
        // M workers add one in-flight round each
        assert_eq!(staleness_bound_updates(0, 2, 1), 2);
        assert_eq!(staleness_bound_updates(2, 2, 1), 4);
        // T updates per batch scale every version distance
        assert_eq!(staleness_bound_updates(0, 1, 3), 5);
    }
}
