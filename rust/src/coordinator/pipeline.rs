//! Unified streaming RLHF pipeline: ONE trainer loop, N generation
//! workers, a configurable staleness bound K.
//!
//! The paper's central question — "how much off-policyness can we
//! tolerate?" — is a single knob. This module makes it one: a
//! [`RoundSource`] yields generation rounds to [`run`], the only trainer
//! loop in the crate (stage/label → assemble → train → publish → log),
//! and the two sources are the two ends of the design space:
//!
//! - [`InlineSource`] generates on the trainer's own engine/thread —
//!   the synchronous generate-then-train schedule (paper Fig 2 top),
//!   including the §3.2 N-minibatch off-policy ladder. Generation reads
//!   the trainer's live device parameters ([`TrainState::param_view`]),
//!   so the policy never leaves the device.
//! - [`WorkerPool`] runs M generation worker threads, each owning its
//!   own `Engine`/PJRT backend, feeding a **bounded** round queue of
//!   depth K. `M = 1, K = 0` is a rendezvous handover — exactly the
//!   Cleanba one-step off-policy coordinator of paper §3.5/Algorithm 1.
//!
//! ## The staleness invariant
//!
//! With one worker and queue depth K, at most K rounds sit queued and
//! one more is blocked mid-`send`, each generated with parameters
//! fetched *before* the publish of the step that consumed its
//! predecessor. In optimizer-update units with T = `updates_per_batch`,
//! per-step staleness is therefore bounded by
//! [`staleness_bound_updates`]`(K, 1, T) = (K + 2)·T − 1`; for the
//! default T = 1 that is **queue depth K ⇒ staleness ≤ K + 1** policy
//! versions (K = 0 reproduces the one-step bound the seed coordinator
//! enforced). The bound is proven for M = 1 — tight under instantaneous
//! generation, see the discrete model test below. For M > 1 the same
//! formula `(K + M + 1)·T − 1` is the *fair-scheduling* bound (each
//! worker's in-flight round adds one step of age): it holds whenever no
//! worker's single generation call is starved across K + M trainer
//! steps, which the queue back-pressure cannot itself force — so
//! multi-worker staleness is *measured and reported*, not hard-asserted.
//! Per-config measurements land in `BENCH_staleness.json` via
//! `benches/staleness.rs`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::pretrain::RLHF_RANGE;
use super::trainer::{
    assemble, batch_data_version, batch_token_versions, generate_round,
    generate_round_staged, round_metrics, rounds_per_batch, sample_opts,
    stage_and_label, staleness, train_on_batch, LabelScratch, LabelledRound,
    Round, SourcedRound,
};
use super::{Prepared, RunOutput};
use crate::config::{ExpConfig, GenEngine};
use crate::data::TaskGen;
use crate::gen::continuous::{
    AdmitSeq, Completed, DeviceBackend, Pool, PoolCfg, RoundAssembler,
};
use crate::gen::{GenBatch, Generator, SampleOpts};
use crate::metrics::{Phase, RunLog, Timeline};
use crate::runtime::{Engine, ParamView, TrainState};
use crate::util::rng::Pcg32;

/// Prompts consumed by one generation round: the cursor stride. The
/// `.max(1)` guard keeps the cursor strictly monotone even in degenerate
/// geometries (`k_samples > gen_batch`) — the seed async worker lacked it
/// and would replay the same prompts forever.
pub fn cursor_stride(gen_batch: u64, k: usize) -> u64 {
    (gen_batch / k as u64).max(1)
}

/// Worst-case per-step staleness, in optimizer-update units, of a
/// worker-pool run with queue depth `k_bound`, `m` workers and `t`
/// updates per batch: K queued rounds + M blocked sends, each generated
/// one publish behind, gives `(K + M + 1)·T − 1`. Proven (and tight) for
/// `m = 1`; for `m > 1` it additionally assumes fair worker scheduling —
/// a worker stalled mid-generation while its siblings keep feeding the
/// trainer can exceed it (see the module docs). Inline (sync N-ladder)
/// staleness is bounded separately by `(N − 1)·T + T − 1`.
pub fn staleness_bound_updates(k_bound: usize, m: usize, t: usize) -> u64 {
    assert!(m >= 1 && t >= 1, "worker pools have m >= 1 and t >= 1");
    ((k_bound + m + 1) * t) as u64 - 1
}

/// Latest-wins published-policy slot. The trainer overwrites, workers
/// read whatever is freshest; intermediate versions are simply dropped
/// (Algorithm 1 only ever wants θ_i, never the history).
pub struct ParamSlot {
    /// Fast-path hint so a worker can skip the lock when nothing new
    /// was published. Updated after the slot contents.
    hint: AtomicU64,
    latest: Mutex<(u64, Arc<[f32]>)>,
}

impl ParamSlot {
    pub fn new(version: u64, params: Arc<[f32]>) -> ParamSlot {
        ParamSlot {
            hint: AtomicU64::new(version),
            latest: Mutex::new((version, params)),
        }
    }

    /// Publish `params` as `version`: one pointer swap under the lock.
    pub fn publish(&self, version: u64, params: Arc<[f32]>) {
        *self.latest.lock().unwrap() = (version, params);
        self.hint.store(version, Ordering::Release);
    }

    /// The freshest publication newer than `have`, if any.
    pub fn fetch(&self, have: u64) -> Option<(u64, Arc<[f32]>)> {
        if self.hint.load(Ordering::Acquire) <= have {
            return None;
        }
        let guard = self.latest.lock().unwrap();
        if guard.0 <= have {
            return None;
        }
        Some((guard.0, guard.1.clone()))
    }
}

/// What the trainer loop exposes to its round source on every call: the
/// trainer's engine and optimizer state (inline generation reads the live
/// device parameters, worker pools snapshot them at publish), the current
/// optimizer version, and the shared timeline for span accounting.
pub struct TrainerCx<'a> {
    pub engine: &'a Engine,
    pub state: &'a mut TrainState,
    pub version: u64,
    pub timeline: &'a mut Timeline,
}

/// A stream of generation rounds feeding the one trainer loop ([`run`]).
///
/// Implementations decide *where* rounds come from (inline on the
/// trainer's engine, or a pool of worker threads) and *how stale* they
/// may be; the trainer loop is identical either way.
pub trait RoundSource {
    /// Tag used in verbose step logs ("sync" / "async").
    fn label(&self) -> &'static str;

    /// Produce the next round, generating inline or awaiting a worker.
    /// The source records its own Generate/Idle spans on `cx.timeline`.
    /// Inline sources may attach the fused generate's device-resident
    /// output buffers ([`SourcedRound::staged`]) so the trainer stages
    /// the round with zero token uploads; worker rounds crossed a thread
    /// boundary and are host-only.
    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound>;

    /// Completions accounted so far. Inline sources count at generation
    /// (the §3.2 ladder pays for a whole N-minibatch window up front,
    /// trained or not — the seed sync accounting); worker pools count at
    /// handover (in-flight worker rounds are not yet episodes).
    fn episodes(&self) -> u64;

    /// Called once after every optimizer step, with `cx.version` already
    /// bumped. Worker pools snapshot and publish the new policy here;
    /// inline sources read the live device buffer and need not.
    fn publish(&mut self, cx: TrainerCx<'_>) -> Result<()>;

    /// Tear down (join workers), contributing source metadata — e.g.
    /// per-worker generation accounting — to the run log.
    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()>;
}

/// The single RLHF trainer loop, written once against [`RoundSource`]:
/// pull `rounds_per_batch` rounds, stage + label them, assemble the
/// algorithm-specific batch, take `updates_per_batch` optimizer steps,
/// publish, log. `make_source` receives the shared timeline origin so
/// worker gen-spans land on the trainer's clock.
pub fn run<'p>(
    cfg: &ExpConfig,
    prep: &'p Prepared,
    make_source: impl FnOnce(Instant) -> Result<Box<dyn RoundSource + 'p>>,
    verbose: bool,
) -> Result<RunOutput> {
    let engine: &Engine = &prep.engine;
    let sft_params = prep.sft_params.clone();
    let mut timeline = Timeline::new();
    let mut source = make_source(timeline.origin())?;
    let mut log = RunLog::new();
    log.set_meta("label", cfg.label());

    let mut state = TrainState::new(sft_params.clone());
    let mut scratch = LabelScratch::default();
    let rpb = rounds_per_batch(cfg.k_samples);
    let mut step = 0u64;
    let mut version = 0u64;
    let mut staleness_sum = 0u64;
    let mut staleness_max = 0u64;
    let mut staleness_tok_sum = 0.0f64;
    let mut staleness_tok_max = 0u64;

    let result = (|| -> Result<()> {
        while step < cfg.steps {
            let mut rounds = Vec::with_capacity(rpb);
            for _ in 0..rpb {
                let sr = source.next(TrainerCx {
                    engine,
                    state: &mut state,
                    version,
                    timeline: &mut timeline,
                })?;
                // stage the round's tensors on device once (when
                // eligible — chaining the inline source's generate
                // buffers, when attached, for a zero-upload staging),
                // then label off the shared buffers; staging is part of
                // the scoring cost
                let (resident, labels) = timeline.record(Phase::Score, || {
                    stage_and_label(
                        engine,
                        &sr,
                        &sft_params,
                        prep.rm_scorer(),
                        cfg,
                        &mut scratch,
                    )
                })?;
                rounds.push(LabelledRound { round: sr.round, labels, resident });
            }

            let batch = assemble(engine, cfg.algo, &rounds, cfg.k_samples)?;
            let all_metrics = timeline.record(Phase::Train, || {
                train_on_batch(
                    engine,
                    &mut state,
                    &batch,
                    cfg.lr,
                    cfg.updates_per_batch,
                )
            })?;
            version += cfg.updates_per_batch as u64;
            step += 1;

            source.publish(TrainerCx {
                engine,
                state: &mut state,
                version,
                timeline: &mut timeline,
            })?;

            let stale = staleness(version, batch_data_version(&rounds));
            staleness_sum += stale;
            staleness_max = staleness_max.max(stale);
            // per-token staleness: under the continuous engine a
            // sequence's tokens can span policy versions (weights swap
            // between decode steps), so the oldest-token and mean-token
            // ages are reported alongside the per-round bound; for
            // round-synchronous engines all three coincide
            let (tok_min, tok_mean) = batch_token_versions(&rounds);
            let stale_tok_max = staleness(version, tok_min);
            let stale_tok_mean = ((version.saturating_sub(1)) as f64
                - tok_mean)
                .max(0.0);
            staleness_tok_sum += stale_tok_mean;
            staleness_tok_max = staleness_tok_max.max(stale_tok_max);

            let episodes = source.episodes();
            let labels = &rounds[0].labels;
            let mut row = round_metrics(labels);
            let m = all_metrics.last().unwrap();
            row.push(("loss", m[0]));
            row.push(("staleness", stale as f32));
            row.push(("staleness_tok_max", stale_tok_max as f32));
            row.push(("staleness_tok_mean", stale_tok_mean as f32));
            log.push(step, episodes, timeline.wall(), &row);
            if verbose && step % 8 == 0 {
                eprintln!(
                    "[{} {}] step {step}/{} episodes {episodes} \
                     win {:.3} kl-ppl {:.4} loss {:.4} staleness {stale}",
                    source.label(),
                    cfg.algo,
                    cfg.steps,
                    log.recent_mean("win_rate", 8).unwrap_or(0.0),
                    log.recent_mean("kl_ppl", 8).unwrap_or(0.0),
                    m[0],
                );
            }
        }
        Ok(())
    })();

    // tear the source down whether or not the loop succeeded (a worker
    // blocked in `send` must be released before join)
    let episodes = source.episodes();
    let finish = source.finish(&mut log);
    result?;
    finish?;

    log.set_meta(
        "mean_staleness",
        format!("{:.3}", staleness_sum as f64 / cfg.steps.max(1) as f64),
    );
    log.set_meta("max_staleness", staleness_max);
    log.set_meta(
        "mean_staleness_tok",
        format!("{:.3}", staleness_tok_sum / cfg.steps.max(1) as f64),
    );
    log.set_meta("max_staleness_tok", staleness_tok_max);

    Ok(RunOutput {
        final_params: state.into_params(engine)?,
        log,
        timeline,
        episodes,
    })
}

// ---------------------------------------------------------------------------
// InlineSource: generate on the trainer's engine (synchronous schedule)
// ---------------------------------------------------------------------------

/// Generates rounds on the trainer's own engine and thread — the
/// synchronous generate-then-train schedule (paper Fig 2 top). Implements
/// the §3.2 off-policy ladder: each refill generates `n_minibatches`
/// batches of rounds with the then-current (frozen) policy; the trainer
/// drains them over the next N steps, so the last batch is N−1 updates
/// stale by the time it trains.
pub struct InlineSource<'p> {
    generator: Box<dyn Generator>,
    taskgen: &'p TaskGen,
    rng: Pcg32,
    opts: SampleOpts,
    k: usize,
    rounds_per_refill: usize,
    cursor: u64,
    stride: u64,
    gen_bs: u64,
    generated: u64,
    /// Refill window of rounds awaiting training. Sync rounds keep their
    /// fused-generate output buffers attached (same engine, same thread),
    /// so even ladder rounds trained N−1 steps later stage with zero
    /// token uploads.
    buffered: VecDeque<SourcedRound>,
}

impl<'p> InlineSource<'p> {
    pub fn new(cfg: &ExpConfig, prep: &'p Prepared) -> InlineSource<'p> {
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        InlineSource {
            generator: cfg.gen_engine.build(),
            taskgen: &prep.taskgen,
            rng: Pcg32::new(cfg.seed, 0x5c),
            opts: sample_opts(cfg),
            k: cfg.k_samples,
            rounds_per_refill: cfg.n_minibatches * rounds_per_batch(cfg.k_samples),
            cursor: RLHF_RANGE,
            stride: cursor_stride(gen_bs, cfg.k_samples),
            gen_bs,
            generated: 0,
            buffered: VecDeque::new(),
        }
    }
}

impl RoundSource for InlineSource<'_> {
    fn label(&self) -> &'static str {
        "sync"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { engine, state, version, timeline } = cx;
        if self.buffered.is_empty() {
            // generation phase: N minibatches of data, frozen policy;
            // the staged variant keeps the fused outputs device-resident
            // for the trainer (same engine) to chain into round staging
            let origin = timeline.origin();
            for _ in 0..self.rounds_per_refill {
                let round = timeline.record(Phase::Generate, || {
                    generate_round_staged(
                        engine,
                        self.generator.as_ref(),
                        state.param_view("policy", version),
                        version,
                        self.taskgen,
                        self.cursor,
                        self.k,
                        self.opts,
                        &mut self.rng,
                        origin,
                    )
                })?;
                self.cursor += self.stride;
                self.generated += 1;
                self.buffered.push_back(round);
            }
        }
        Ok(self.buffered.pop_front().expect("refill yields >= 1 round"))
    }

    fn episodes(&self) -> u64 {
        // counted at generation: a refill window's episodes are spent
        // the moment the frozen policy generates them (seed accounting)
        self.generated * self.gen_bs
    }

    fn publish(&mut self, _cx: TrainerCx<'_>) -> Result<()> {
        // generation reads the trainer's live device parameters directly;
        // there is nothing to move
        Ok(())
    }

    fn finish(self: Box<Self>, _log: &mut RunLog) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// WorkerPool: M generation workers, bounded round queue of depth K
// ---------------------------------------------------------------------------

/// One round crossing the worker → trainer queue.
struct GenMsg {
    round: Round,
}

/// Per-worker generation accounting returned at join.
type WorkerOut = Result<(f64, u64)>;

/// M generation worker threads, each owning its own PJRT backend (the
/// `xla` crate's client is not `Send`, which conveniently mirrors the
/// paper's separate generation/training processes), feeding the trainer
/// over a bounded queue of depth K:
///
/// - each **worker** pulls the freshest published policy, generates one
///   round, and hands it over `send`, which blocks while the queue is
///   full — that back-pressure is the staleness guarantee;
/// - the **trainer** pops rounds; with K = 0 the queue is a rendezvous
///   and `M = 1, K = 0` reproduces the seed Cleanba coordinator exactly
///   (θ_{t+1} updated with data from θ_t, paper §3.5).
///
/// Workers partition the prompt stream by striding: worker `w` starts at
/// `RLHF_RANGE + w·stride` and hops `M·stride` per round, so pools of any
/// width consume disjoint, contiguously-tiling prompt ranges.
///
/// Parameter publication is a latest-wins [`ParamSlot`]: the trainer
/// downloads its device-resident params once per publish, snapshots them
/// into an `Arc`, and the swap itself is a pointer move — workers clone
/// the `Arc`, not the parameters, and re-upload to their device only when
/// the version actually changed (the A.2 "passing policy parameters" cost
/// is paid per publish, never per call).
pub struct WorkerPool {
    rx: mpsc::Receiver<GenMsg>,
    slot: Arc<ParamSlot>,
    stop: Arc<AtomicBool>,
    workers: Vec<JoinHandle<WorkerOut>>,
    gen_bs: u64,
    received: u64,
}

impl WorkerPool {
    /// Spawn `cfg.gen_workers` workers over a queue of depth
    /// `cfg.staleness_bound`. `origin` is the trainer timeline's clock so
    /// worker gen-spans are directly comparable.
    pub fn spawn(
        cfg: &ExpConfig,
        prep: &Prepared,
        origin: Instant,
    ) -> Result<WorkerPool> {
        let m = cfg.gen_workers.max(1);
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        let stride = cursor_stride(gen_bs, cfg.k_samples);
        let (round_tx, round_rx) =
            mpsc::sync_channel::<GenMsg>(cfg.staleness_bound);
        // seeded with the SFT checkpoint at version 0
        let slot =
            Arc::new(ParamSlot::new(0, Arc::from(&prep.sft_params[..])));
        let stop = Arc::new(AtomicBool::new(false));

        let mut workers = Vec::with_capacity(m);
        for w in 0..m {
            let tx = round_tx.clone();
            let stop = stop.clone();
            let slot = slot.clone();
            let artifact_dir = cfg.artifact_dir();
            let init_params: Arc<[f32]> = Arc::from(&prep.sft_params[..]);
            let taskgen = TaskGen::new(
                prep.taskgen.task,
                prep.taskgen.prompt_len,
                prep.taskgen.resp_len,
                cfg.seed,
            );
            let opts = sample_opts(cfg);
            let k = cfg.k_samples;
            let seed = cfg.seed;
            let gen_engine = cfg.gen_engine;
            let (max_cohorts, admit_min) = (cfg.max_cohorts, cfg.admit_min);
            let start = RLHF_RANGE + w as u64 * stride;
            let hop = stride * m as u64;
            let handle = std::thread::Builder::new()
                .name(format!("gen-worker-{w}"))
                .spawn(move || -> Result<(f64, u64)> {
                    // own engine, own PJRT client (separate "GPU");
                    // worker 0 keeps the seed coordinator's RNG stream so
                    // M=1 pools replay it bitwise
                    let engine = Engine::load(&artifact_dir)?;
                    let mut rng = Pcg32::new(seed, 0xa57c + w as u64);
                    if gen_engine == GenEngine::Continuous {
                        // slot-pool streaming: rounds are assembled from
                        // retired sequences, not generated round-at-a-time
                        return continuous_worker(
                            &engine, &taskgen, &slot, &stop, &tx, init_params,
                            k, opts, start, stride, hop, max_cohorts,
                            admit_min, &mut rng, origin,
                        );
                    }
                    let generator = gen_engine.build();
                    let mut params = init_params;
                    let mut version = 0u64;
                    let mut cursor = start;
                    let mut gen_total = 0.0f64;
                    let mut rounds_done = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        // pick up the freshest published policy
                        // (Algorithm 1: "update generation model
                        // θ <- θ_i"); the cached view below re-uploads to
                        // device only on a version change
                        if let Some((v, p)) = slot.fetch(version) {
                            version = v;
                            params = p;
                        }
                        let round = generate_round(
                            &engine,
                            generator.as_ref(),
                            ParamView::cached("policy", version, &params),
                            version,
                            &taskgen,
                            cursor,
                            k,
                            opts,
                            &mut rng,
                            origin,
                        )?;
                        cursor += hop;
                        gen_total += round.gen_secs;
                        rounds_done += 1;
                        // blocks while K rounds are queued — the
                        // staleness bound's back-pressure
                        if tx.send(GenMsg { round }).is_err() {
                            break;
                        }
                    }
                    Ok((gen_total, rounds_done))
                })
                .map_err(|e| anyhow!("spawn gen-worker-{w}: {e}"))?;
            workers.push(handle);
        }
        // trainer holds no sender: when every worker exits, recv errors
        drop(round_tx);
        Ok(WorkerPool {
            rx: round_rx,
            slot,
            stop,
            workers,
            gen_bs,
            received: 0,
        })
    }
}

impl RoundSource for WorkerPool {
    fn label(&self) -> &'static str {
        "async"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { timeline, .. } = cx;
        let t_wait = timeline.origin().elapsed().as_secs_f64();
        let msg = self
            .rx
            .recv()
            .map_err(|_| anyhow!("generation workers died"))?;
        let t_got = timeline.origin().elapsed().as_secs_f64();
        timeline.push_span(Phase::Idle, t_wait, t_got);
        timeline.push_span(
            Phase::Generate,
            msg.round.gen_span.0,
            msg.round.gen_span.1,
        );
        self.received += 1;
        // worker rounds crossed the thread boundary as host data: the
        // trainer re-stages them (the async mode's one upload per round)
        Ok(SourcedRound { round: msg.round, staged: None })
    }

    fn episodes(&self) -> u64 {
        // counted at handover: rounds still in flight inside a worker
        // (or queued) are not episodes yet
        self.received * self.gen_bs
    }

    fn publish(&mut self, cx: TrainerCx<'_>) -> Result<()> {
        let TrainerCx { engine, state, version, timeline } = cx;
        // device -> host once per publish, then a latest-wins pointer swap
        timeline.record(Phase::Publish, || -> Result<()> {
            let host = state.params_host(engine)?;
            self.slot.publish(version, Arc::from(host));
            Ok(())
        })
    }

    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()> {
        let pool = *self;
        pool.stop.store(true, Ordering::Relaxed);
        // release workers blocked in `send` so join cannot deadlock
        drop(pool.rx);
        let mut gen_total = 0.0f64;
        let mut rounds_total = 0u64;
        let mut first_err = None;
        for (w, handle) in pool.workers.into_iter().enumerate() {
            let joined = handle
                .join()
                .map_err(|_| anyhow!("gen-worker-{w} panicked"))?;
            match joined {
                Ok((secs, rounds)) => {
                    log.set_meta(&format!("gen_secs_w{w}"), format!("{secs:.3}"));
                    log.set_meta(&format!("gen_rounds_w{w}"), rounds);
                    gen_total += secs;
                    rounds_total += rounds;
                }
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        log.set_meta("gen_total_secs", format!("{gen_total:.3}"));
        log.set_meta("gen_rounds", rounds_total);
        Ok(())
    }
}

/// Streaming body of a continuous-engine generation worker: drive the
/// slot pool one sweep at a time, re-reading the published policy slot
/// *between decode steps* (PipelineRL's inflight weight swap — in-flight
/// sequences keep their KV cache and finish under the new weights,
/// stamping their remaining tokens with the new version), feeding retired
/// sequences through a [`RoundAssembler`] and handing assembled rounds
/// over the same bounded queue as the round-synchronous workers — the
/// staleness back-pressure simply pauses the pool mid-flight while `send`
/// blocks.
#[allow(clippy::too_many_arguments)]
fn continuous_worker(
    engine: &Engine,
    taskgen: &TaskGen,
    slot: &ParamSlot,
    stop: &AtomicBool,
    tx: &mpsc::SyncSender<GenMsg>,
    init_params: Arc<[f32]>,
    k: usize,
    opts: SampleOpts,
    start: u64,
    stride: u64,
    hop: u64,
    max_cohorts: usize,
    admit_min: usize,
    rng: &mut Pcg32,
    origin: Instant,
) -> Result<(f64, u64)> {
    let mcfg = engine.manifest.config.clone();
    let mut backend = DeviceBackend::new(engine)?;
    let mut pool = Pool::new(PoolCfg {
        slots: mcfg.gen_batch,
        prompt_len: mcfg.prompt_len,
        seq_len: mcfg.seq_len,
        vocab: mcfg.vocab,
        max_cohorts,
        admit_min,
    });
    // the same strided prompt partition the round-based workers walk
    // (worker w: blocks of `stride` indices, hopping M·stride, each
    // index k times), consumed one prompt per freed slot
    let mut admission = taskgen
        .admission(start, stride, hop, k)
        .map(|a| AdmitSeq { index: a.index, dup: a.dup, prompt: a.prompt });
    let mut assembler = RoundAssembler::new(mcfg.gen_batch, k);
    let mut params = init_params;
    let mut version = 0u64;
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut t_round = origin.elapsed().as_secs_f64();
    while !stop.load(Ordering::Relaxed) {
        if let Some((v, p)) = slot.fetch(version) {
            version = v;
            params = p;
        }
        pool.step(
            &mut backend,
            ParamView::cached("policy", version, &params),
            version,
            &mut admission,
            opts,
            rng,
        )?;
        for c in pool.drain_completed() {
            assembler.push(c);
        }
        while let Some(groups) = assembler.pop_round() {
            let t_now = origin.elapsed().as_secs_f64();
            let round = round_from_groups(groups, taskgen, (t_round, t_now));
            gen_total += t_now - t_round;
            rounds_done += 1;
            // blocks while K rounds are queued — the staleness bound's
            // back-pressure; in-flight sequences wait between sweeps
            if tx.send(GenMsg { round }).is_err() {
                return Ok((gen_total, rounds_done));
            }
            // blocked-send time belongs to the queue, not generation
            t_round = origin.elapsed().as_secs_f64();
        }
    }
    Ok((gen_total, rounds_done))
}

/// Assemble a trainer [`Round`] from `gen_batch / k` retired prompt
/// groups (each `k` completions, in dup order) — the continuous engine's
/// counterpart of `generate_round`'s fixed-round output. Examples are
/// regenerated from the pure task stream by index; per-token version
/// provenance aggregates into the round's staleness fields.
fn round_from_groups(
    groups: Vec<(u64, Vec<Completed>)>,
    taskgen: &TaskGen,
    span: (f64, f64),
) -> Round {
    let n: usize = groups.iter().map(|(_, g)| g.len()).sum();
    let mut tokens = Vec::with_capacity(n);
    let mut resp_mask = Vec::with_capacity(n);
    let mut blp = Vec::with_capacity(n);
    let mut terminated = Vec::with_capacity(n);
    let mut examples = Vec::with_capacity(groups.len());
    let start_index = groups.first().map(|(i, _)| *i).unwrap_or(0);
    let mut steps_max = 0usize;
    let mut ver_min = u64::MAX;
    let mut ver_max = 0u64;
    let mut ver_sum = 0.0f64;
    let mut tok_count = 0u64;
    for (index, group) in groups {
        examples.push(taskgen.example(index));
        for c in group {
            steps_max = steps_max.max(c.steps);
            ver_min = ver_min.min(c.version_min);
            ver_max = ver_max.max(c.version_max);
            ver_sum += c.version_sum;
            tok_count += c.steps as u64;
            tokens.push(c.tokens);
            resp_mask.push(c.resp_mask);
            blp.push(c.blp);
            terminated.push(c.terminated);
        }
    }
    Round {
        gen: GenBatch { tokens, resp_mask, blp, terminated, steps: steps_max },
        examples,
        start_index,
        // newest token version: keeps the per-round staleness bound's
        // "freshest data age" meaning under version mixing
        params_version: ver_max,
        tok_version_min: ver_min.min(ver_max),
        tok_version_mean: if tok_count > 0 {
            ver_sum / tok_count as f64
        } else {
            ver_max as f64
        },
        gen_secs: span.1 - span.0,
        gen_span: span,
    }
}

#[cfg(test)]
mod tests {
    use std::collections::VecDeque;
    use std::sync::Arc;

    use super::super::trainer::staleness;
    use super::{
        cursor_stride, round_from_groups, staleness_bound_updates, Completed,
        ParamSlot,
    };
    use crate::data::{Task, TaskGen};

    #[test]
    fn continuous_round_aggregates_token_version_provenance() {
        let tg = TaskGen::new(Task::Tldr, 8, 4, 1);
        let mk = |index: u64, dup: usize, vmin: u64, vmax: u64, sum: f64| {
            Completed {
                index,
                dup,
                tokens: vec![0; 12],
                resp_mask: vec![0.0; 12],
                blp: vec![0.0; 12],
                terminated: true,
                steps: 2,
                version_min: vmin,
                version_max: vmax,
                version_sum: sum,
            }
        };
        // two prompt groups of k=2, tokens spanning versions 0..=4
        let groups = vec![
            (5u64, vec![mk(5, 0, 0, 2, 2.0), mk(5, 1, 1, 3, 4.0)]),
            (9u64, vec![mk(9, 0, 2, 4, 6.0), mk(9, 1, 2, 2, 4.0)]),
        ];
        let round = round_from_groups(groups, &tg, (1.0, 3.5));
        // per-round anchor = NEWEST token version (freshest data age);
        // per-token fields carry the oldest and the mean
        assert_eq!(round.params_version, 4);
        assert_eq!(round.tok_version_min, 0);
        let expect_mean = (2.0 + 4.0 + 6.0 + 4.0) / 8.0;
        assert!((round.tok_version_mean - expect_mean).abs() < 1e-12);
        assert_eq!(round.start_index, 5);
        assert_eq!(round.gen.tokens.len(), 4, "k rows per prompt group");
        assert_eq!(round.examples.len(), 2, "one example per prompt");
        assert_eq!(round.examples[1].prompt, tg.example(9).prompt);
        assert_eq!(round.gen.steps, 2);
        assert!((round.gen_secs - 2.5).abs() < 1e-12);
    }

    #[test]
    fn param_slot_is_latest_wins() {
        let slot = ParamSlot::new(0, Arc::from(&[0.0f32][..]));
        assert!(slot.fetch(0).is_none(), "nothing newer than the seed");
        for v in 1..=5u64 {
            slot.publish(v, Arc::from(&[v as f32][..]));
        }
        // a reader at version 0 sees only the freshest publication
        let (v, p) = slot.fetch(0).expect("new version visible");
        assert_eq!(v, 5);
        assert_eq!(&p[..], &[5.0]);
        // and nothing newer than what it now has
        assert!(slot.fetch(5).is_none());
    }

    #[test]
    fn param_slot_fetch_is_cheap_pointer_clone() {
        let big: Arc<[f32]> = Arc::from(vec![1.0f32; 1024].into_boxed_slice());
        let slot = ParamSlot::new(1, big.clone());
        let (_, p) = slot.fetch(0).unwrap();
        assert!(Arc::ptr_eq(&p, &big), "fetch must share, not copy");
    }

    #[test]
    fn cursor_never_freezes_when_k_exceeds_gen_batch() {
        // normal geometries: one round consumes gen_batch/k prompts
        assert_eq!(cursor_stride(8, 2), 4);
        assert_eq!(cursor_stride(4, 4), 1);
        // regression: the seed async worker advanced by gen_bs / k
        // WITHOUT the guard, so k > gen_batch froze the cursor and
        // replayed the same prompts forever
        assert_eq!(cursor_stride(2, 4), 1);
        let mut cursor = 0u64;
        for _ in 0..10 {
            cursor += cursor_stride(2, 4);
        }
        assert_eq!(cursor, 10, "cursor must be strictly monotone");
    }

    /// Discrete worst-case model of the K-bounded queue with one worker
    /// and *instantaneous* generation: the worker fills the queue (K
    /// rounds) plus one blocked `send`, fetching the freshest publish
    /// before each round. Per-step staleness must never exceed
    /// `staleness_bound_updates(K, 1, T) = (K + 2)·T − 1`, and the bound
    /// is tight (instant generation reaches it).
    #[test]
    fn bounded_queue_model_staleness_is_tight_at_bound() {
        for k_bound in 0..5usize {
            for t in 1..4u64 {
                let mut queue: VecDeque<u64> = VecDeque::new();
                let mut blocked: Option<u64> = None;
                let mut published = 0u64;
                let mut version = 0u64;
                let mut max_seen = 0u64;
                let refill = |queue: &mut VecDeque<u64>,
                              blocked: &mut Option<u64>,
                              published: u64| {
                    while queue.len() < k_bound {
                        queue.push_back(published);
                    }
                    if blocked.is_none() {
                        *blocked = Some(published);
                    }
                };
                refill(&mut queue, &mut blocked, published);
                for _ in 0..50 {
                    // trainer pops one round; a blocked send slides in
                    let data = match queue.pop_front() {
                        Some(front) => {
                            if let Some(b) = blocked.take() {
                                queue.push_back(b);
                            }
                            front
                        }
                        None => blocked.take().expect("rendezvous handover"),
                    };
                    // worker runs ahead again before this step publishes
                    refill(&mut queue, &mut blocked, published);
                    version += t;
                    published = version;
                    let st = staleness(version, data);
                    let bound = staleness_bound_updates(k_bound, 1, t as usize);
                    assert!(
                        st <= bound,
                        "K={k_bound} T={t}: staleness {st} > bound {bound}"
                    );
                    max_seen = max_seen.max(st);
                }
                assert_eq!(
                    max_seen,
                    staleness_bound_updates(k_bound, 1, t as usize),
                    "K={k_bound} T={t}: bound should be tight under \
                     instantaneous generation"
                );
            }
        }
    }

    #[test]
    fn staleness_bound_reduces_to_the_documented_invariants() {
        // queue depth K, one worker, T=1: staleness <= K + 1 policy
        // versions — K=0 is the seed coordinator's one-step bound
        assert_eq!(staleness_bound_updates(0, 1, 1), 1);
        assert_eq!(staleness_bound_updates(1, 1, 1), 2);
        assert_eq!(staleness_bound_updates(4, 1, 1), 5);
        // M workers add one in-flight round each
        assert_eq!(staleness_bound_updates(0, 2, 1), 2);
        assert_eq!(staleness_bound_updates(2, 2, 1), 4);
        // T updates per batch scale every version distance
        assert_eq!(staleness_bound_updates(0, 1, 3), 5);
    }
}
