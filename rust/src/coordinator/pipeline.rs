//! Unified streaming RLHF pipeline: ONE trainer loop, N generation
//! workers, a configurable staleness bound K.
//!
//! The paper's central question — "how much off-policyness can we
//! tolerate?" — is a single knob. This module makes it one: a
//! [`RoundSource`] yields generation rounds to [`run`], the only trainer
//! loop in the crate (stage/label → assemble → train → publish → log),
//! and the two sources are the two ends of the design space:
//!
//! - [`InlineSource`] generates on the trainer's own engine/thread —
//!   the synchronous generate-then-train schedule (paper Fig 2 top),
//!   including the §3.2 N-minibatch off-policy ladder. Generation reads
//!   the trainer's live device parameters ([`TrainState::param_view`]),
//!   so the policy never leaves the device.
//! - [`WorkerPool`] runs M generation worker threads, each owning its
//!   own `Engine`/PJRT backend, feeding a **bounded** round queue of
//!   depth K. `M = 1, K = 0` is a rendezvous handover — exactly the
//!   Cleanba one-step off-policy coordinator of paper §3.5/Algorithm 1.
//!
//! ## The staleness invariant
//!
//! With one worker and queue depth K, at most K rounds sit queued and
//! one more is blocked mid-`send`, each generated with parameters
//! fetched *before* the publish of the step that consumed its
//! predecessor. In optimizer-update units with T = `updates_per_batch`,
//! per-step staleness is therefore bounded by
//! [`staleness_bound_updates`]`(K, 1, T) = (K + 2)·T − 1`; for the
//! default T = 1 that is **queue depth K ⇒ staleness ≤ K + 1** policy
//! versions (K = 0 reproduces the one-step bound the seed coordinator
//! enforced). The bound is proven for M = 1 — tight under instantaneous
//! generation, see the discrete model test below. For M > 1 the same
//! formula `(K + M + 1)·T − 1` is the *fair-scheduling* bound (each
//! worker's in-flight round adds one step of age): it holds whenever no
//! worker's single generation call is starved across K + M trainer
//! steps, which the queue back-pressure cannot itself force — so
//! multi-worker staleness is *measured and reported*, not hard-asserted.
//! Per-config measurements land in `BENCH_staleness.json` via
//! `benches/staleness.rs`.
//!
//! ## The failure model
//!
//! Worker pools are **supervised**: each seat's body runs under
//! `catch_unwind` and reports a structured [`WorkerExit`]; the trainer,
//! while waiting for rounds, reaps exits and heartbeats. A dead seat is
//! respawned on a fresh engine up to `--max-worker-restarts` times — the
//! replacement resumes the dead worker's exact prompt-partition position
//! via the shared **lane ledger** (advanced only *after* a round is
//! handed over, so a crash re-generates at-least-once and the trainer's
//! [`LaneAccounts`] drop the duplicates: exactly-once into the
//! optimizer). When restarts are exhausted, surviving workers inherit the
//! orphaned lanes (cursor re-striding) — a pool degrades gracefully down
//! to one worker before the run fails loudly. Transient engine faults
//! retry with deterministic jittered backoff
//! ([`crate::runtime::RetryPolicy`]); a seat silent past
//! `--stall-timeout-secs` is flagged by the watchdog and surfaced in the
//! run metas. `--inject-fault worker=W,round=R,kind=panic|stall|engine_err`
//! scripts each failure deterministically for the integration tests.

use std::collections::{HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::checkpoint::{self, Checkpoint, SourceState, StalenessAccum};
use super::pretrain::RLHF_RANGE;
use super::trainer::{
    assemble, batch_data_version, batch_token_versions, generate_round,
    generate_round_staged, round_metrics, rounds_per_batch, sample_opts,
    stage_and_label, staleness, train_on_batch, LabelScratch, LabelledRound,
    Round, SourcedRound, ROUND_ORIGIN,
};
use super::{Prepared, RunOutput};
use crate::config::{ExpConfig, FaultKind, FaultPlan, GenEngine};
use crate::data::{Task, TaskGen};
use crate::gen::continuous::{
    AdmitSeq, Completed, DeviceBackend, Pool, PoolCfg, PoolStats,
    RoundAssembler,
};
use crate::gen::{GenBatch, Generator, SampleOpts};
use crate::metrics::{Phase, RunLog, Timeline};
use crate::runtime::{Engine, ParamView, RetryPolicy, TrainState, RETRY_STREAM};
use crate::serve::frontend::ServeMux;
use crate::serve::session::SessionBoard;
use crate::serve::traffic::{turn_uid, uid_session_turn, TrafficCfg, TrafficGen};
use crate::util::bench::pct;
use crate::util::rng::Pcg32;

/// Prompts consumed by one generation round: the cursor stride. The
/// `.max(1)` guard keeps the cursor strictly monotone even in degenerate
/// geometries (`k_samples > gen_batch`) — the seed async worker lacked it
/// and would replay the same prompts forever.
pub fn cursor_stride(gen_batch: u64, k: usize) -> u64 {
    (gen_batch / k as u64).max(1)
}

/// Worst-case per-step staleness, in optimizer-update units, of a
/// worker-pool run with queue depth `k_bound`, `m` workers and `t`
/// updates per batch: K queued rounds + M blocked sends, each generated
/// one publish behind, gives `(K + M + 1)·T − 1`. Proven (and tight) for
/// `m = 1`; for `m > 1` it additionally assumes fair worker scheduling —
/// a worker stalled mid-generation while its siblings keep feeding the
/// trainer can exceed it (see the module docs). Inline (sync N-ladder)
/// staleness is bounded separately by `(N − 1)·T + T − 1`.
pub fn staleness_bound_updates(k_bound: usize, m: usize, t: usize) -> u64 {
    assert!(m >= 1 && t >= 1, "worker pools have m >= 1 and t >= 1");
    ((k_bound + m + 1) * t) as u64 - 1
}

/// Latest-wins published-policy slot. The trainer overwrites, workers
/// read whatever is freshest; intermediate versions are simply dropped
/// (Algorithm 1 only ever wants θ_i, never the history).
pub struct ParamSlot {
    /// Fast-path hint so a worker can skip the lock when nothing new
    /// was published. Updated after the slot contents.
    hint: AtomicU64,
    latest: Mutex<(u64, Arc<[f32]>)>,
}

impl ParamSlot {
    pub fn new(version: u64, params: Arc<[f32]>) -> ParamSlot {
        ParamSlot {
            hint: AtomicU64::new(version),
            latest: Mutex::new((version, params)),
        }
    }

    /// Poison-free lock. The slot's critical sections are pure pointer
    /// swaps — they cannot leave the pair half-written — so a worker that
    /// panicked *while holding the lock* (supervised and respawned) must
    /// not take the whole pool down with a propagated `PoisonError`.
    fn lock_latest(&self) -> std::sync::MutexGuard<'_, (u64, Arc<[f32]>)> {
        self.latest.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Publish `params` as `version`: one pointer swap under the lock.
    pub fn publish(&self, version: u64, params: Arc<[f32]>) {
        *self.lock_latest() = (version, params);
        self.hint.store(version, Ordering::Release);
    }

    /// The freshest publication newer than `have`, if any.
    pub fn fetch(&self, have: u64) -> Option<(u64, Arc<[f32]>)> {
        if self.hint.load(Ordering::Acquire) <= have {
            return None;
        }
        let guard = self.lock_latest();
        if guard.0 <= have {
            return None;
        }
        Some((guard.0, guard.1.clone()))
    }

    /// The current publication unconditionally — what a freshly (re)spawned
    /// worker initializes from.
    pub fn latest(&self) -> (u64, Arc<[f32]>) {
        let guard = self.lock_latest();
        (guard.0, guard.1.clone())
    }
}

/// What the trainer loop exposes to its round source on every call: the
/// trainer's engine and optimizer state (inline generation reads the live
/// device parameters, worker pools snapshot them at publish), the current
/// optimizer version, and the shared timeline for span accounting.
pub struct TrainerCx<'a> {
    pub engine: &'a Engine,
    pub state: &'a mut TrainState,
    pub version: u64,
    pub timeline: &'a mut Timeline,
}

/// A stream of generation rounds feeding the one trainer loop ([`run`]).
///
/// Implementations decide *where* rounds come from (inline on the
/// trainer's engine, or a pool of worker threads) and *how stale* they
/// may be; the trainer loop is identical either way.
pub trait RoundSource {
    /// Tag used in verbose step logs ("sync" / "async").
    fn label(&self) -> &'static str;

    /// Produce the next round, generating inline or awaiting a worker.
    /// The source records its own Generate/Idle spans on `cx.timeline`.
    /// Inline sources may attach the fused generate's device-resident
    /// output buffers ([`SourcedRound::staged`]) so the trainer stages
    /// the round with zero token uploads; worker rounds crossed a thread
    /// boundary and are host-only.
    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound>;

    /// Completions accounted so far. Inline sources count at generation
    /// (the §3.2 ladder pays for a whole N-minibatch window up front,
    /// trained or not — the seed sync accounting); worker pools count at
    /// handover (in-flight worker rounds are not yet episodes).
    fn episodes(&self) -> u64;

    /// Called once after every optimizer step, with `cx.version` already
    /// bumped. Worker pools snapshot and publish the new policy here;
    /// inline sources read the live device buffer and need not.
    fn publish(&mut self, cx: TrainerCx<'_>) -> Result<()>;

    /// The source's resumable position for a crash-safe checkpoint, or
    /// `None` when the source is not at a clean boundary (e.g. the sync
    /// N-ladder mid-refill, holding rounds a resumed process could not
    /// reconstruct) — the trainer then retries at the next step.
    fn snapshot(&self) -> Option<SourceState>;

    /// Tear down (join workers), contributing source metadata — e.g.
    /// per-worker generation accounting — to the run log.
    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()>;
}

/// The single RLHF trainer loop, written once against [`RoundSource`]:
/// pull `rounds_per_batch` rounds, stage + label them, assemble the
/// algorithm-specific batch, take `updates_per_batch` optimizer steps,
/// publish, log. `make_source` receives the shared timeline origin so
/// worker gen-spans land on the trainer's clock, plus the restored
/// checkpoint (when `--resume`) so sources re-enter their exact stream
/// position.
///
/// With `--checkpoint-every N`, every N-th step atomically snapshots the
/// optimizer triple, staleness accumulators and the source's cursors into
/// `<run_dir>/checkpoints/<label>/step_<n>/`; `--resume` restarts from
/// the newest snapshot mid-stream (bitwise for the sync schedule).
pub fn run<'p>(
    cfg: &ExpConfig,
    prep: &'p Prepared,
    make_source: impl FnOnce(
        Instant,
        Option<&Checkpoint>,
    ) -> Result<Box<dyn RoundSource + 'p>>,
    verbose: bool,
) -> Result<RunOutput> {
    let engine: &Engine = &prep.engine;
    let sft_params = prep.sft_params.clone();
    let mut timeline = Timeline::new();
    let ckpt_dir = checkpoint::dir_for(&cfg.run_dir, &cfg.label());
    let restored = if cfg.resume {
        match Checkpoint::load_latest(&ckpt_dir)? {
            Some((n, c)) => {
                if verbose {
                    eprintln!(
                        "[resume] continuing from step {n} ({})",
                        ckpt_dir.display()
                    );
                }
                Some(c)
            }
            None => bail!(
                "--resume: no checkpoints under {} (was the run started \
                 with --checkpoint-every?)",
                ckpt_dir.display()
            ),
        }
    } else {
        None
    };
    let mut source = make_source(timeline.origin(), restored.as_ref())?;
    let mut log = RunLog::new();
    log.set_meta("label", cfg.label());

    let (mut state, mut step, mut version, mut accum) = match &restored {
        Some(c) => {
            log.set_meta("resumed_from_step", c.step);
            (
                TrainState::from_host(
                    c.params.clone(),
                    c.m.clone(),
                    c.v.clone(),
                    c.opt_step,
                )?,
                c.step,
                c.version,
                c.staleness.clone(),
            )
        }
        None => (
            TrainState::new(sft_params.clone()),
            0,
            0,
            StalenessAccum::default(),
        ),
    };
    drop(restored); // params/m/v are copied into the train state above
    let mut scratch = LabelScratch::default();
    let rpb = rounds_per_batch(cfg.k_samples);
    // set when a checkpoint came due but the source wasn't at a clean
    // boundary — carries the obligation to the next step
    let mut ckpt_pending = false;

    let result = (|| -> Result<()> {
        while step < cfg.steps {
            let mut rounds = Vec::with_capacity(rpb);
            for _ in 0..rpb {
                let sr = source.next(TrainerCx {
                    engine,
                    state: &mut state,
                    version,
                    timeline: &mut timeline,
                })?;
                // stage the round's tensors on device once (when
                // eligible — chaining the inline source's generate
                // buffers, when attached, for a zero-upload staging),
                // then label off the shared buffers; staging is part of
                // the scoring cost
                let (resident, labels) = timeline.record(Phase::Score, || {
                    stage_and_label(
                        engine,
                        &sr,
                        &sft_params,
                        prep.rm_scorer(),
                        cfg,
                        &mut scratch,
                    )
                })?;
                rounds.push(LabelledRound { round: sr.round, labels, resident });
            }

            let batch = assemble(engine, cfg.algo, &rounds, cfg.k_samples)?;
            let all_metrics = timeline.record(Phase::Train, || {
                train_on_batch(
                    engine,
                    &mut state,
                    &batch,
                    cfg.lr,
                    cfg.updates_per_batch,
                )
            })?;
            version += cfg.updates_per_batch as u64;
            step += 1;

            source.publish(TrainerCx {
                engine,
                state: &mut state,
                version,
                timeline: &mut timeline,
            })?;

            let stale = staleness(version, batch_data_version(&rounds));
            accum.sum += stale;
            accum.max = accum.max.max(stale);
            // per-token staleness: under the continuous engine a
            // sequence's tokens can span policy versions (weights swap
            // between decode steps), so the oldest-token and mean-token
            // ages are reported alongside the per-round bound; for
            // round-synchronous engines all three coincide
            let (tok_min, tok_mean) = batch_token_versions(&rounds);
            let stale_tok_max = staleness(version, tok_min);
            let stale_tok_mean = ((version.saturating_sub(1)) as f64
                - tok_mean)
                .max(0.0);
            accum.tok_sum += stale_tok_mean;
            accum.tok_max = accum.tok_max.max(stale_tok_max);

            let episodes = source.episodes();
            let labels = &rounds[0].labels;
            let mut row = round_metrics(labels);
            let m = all_metrics.last().ok_or_else(|| {
                anyhow!(
                    "train_on_batch returned no metrics at step {step} \
                     (updates_per_batch = {})",
                    cfg.updates_per_batch
                )
            })?;
            row.push(("loss", m[0]));
            row.push(("staleness", stale as f32));
            row.push(("staleness_tok_max", stale_tok_max as f32));
            row.push(("staleness_tok_mean", stale_tok_mean as f32));
            log.push(step, episodes, timeline.wall(), &row);
            if verbose && step % 8 == 0 {
                eprintln!(
                    "[{} {}] step {step}/{} episodes {episodes} \
                     win {:.3} kl-ppl {:.4} loss {:.4} staleness {stale}",
                    source.label(),
                    cfg.algo,
                    cfg.steps,
                    log.recent_mean("win_rate", 8).unwrap_or(0.0),
                    log.recent_mean("kl_ppl", 8).unwrap_or(0.0),
                    m[0],
                );
            }

            if cfg.checkpoint_every > 0 {
                ckpt_pending |= step % cfg.checkpoint_every == 0;
                if ckpt_pending {
                    if let Some(src) = source.snapshot() {
                        timeline.record(Phase::Publish, || -> Result<()> {
                            let opt_step = state.step;
                            let (p, m, v) = state.host_mirrors(engine)?;
                            Checkpoint {
                                step,
                                version,
                                opt_step,
                                staleness: accum.clone(),
                                source: src,
                                params: p.to_vec(),
                                m: m.to_vec(),
                                v: v.to_vec(),
                            }
                            .save(&ckpt_dir)?;
                            Ok(())
                        })?;
                        ckpt_pending = false;
                        if verbose {
                            eprintln!(
                                "[checkpoint] step {step} -> {}",
                                ckpt_dir.join(format!("step_{step}")).display()
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    })();

    // tear the source down whether or not the loop succeeded (a worker
    // blocked in `send` must be released before join)
    let episodes = source.episodes();
    let finish = source.finish(&mut log);
    result?;
    finish?;

    log.set_meta(
        "mean_staleness",
        format!("{:.3}", accum.sum as f64 / cfg.steps.max(1) as f64),
    );
    log.set_meta("max_staleness", accum.max);
    log.set_meta(
        "mean_staleness_tok",
        format!("{:.3}", accum.tok_sum / cfg.steps.max(1) as f64),
    );
    log.set_meta("max_staleness_tok", accum.tok_max);

    Ok(RunOutput {
        final_params: state.into_params(engine)?,
        log,
        timeline,
        episodes,
    })
}

// ---------------------------------------------------------------------------
// InlineSource: generate on the trainer's engine (synchronous schedule)
// ---------------------------------------------------------------------------

/// Generates rounds on the trainer's own engine and thread — the
/// synchronous generate-then-train schedule (paper Fig 2 top). Implements
/// the §3.2 off-policy ladder: each refill generates `n_minibatches`
/// batches of rounds with the then-current (frozen) policy; the trainer
/// drains them over the next N steps, so the last batch is N−1 updates
/// stale by the time it trains.
pub struct InlineSource<'p> {
    generator: Box<dyn Generator>,
    taskgen: &'p TaskGen,
    rng: Pcg32,
    opts: SampleOpts,
    k: usize,
    rounds_per_refill: usize,
    cursor: u64,
    stride: u64,
    gen_bs: u64,
    generated: u64,
    /// Refill window of rounds awaiting training. Sync rounds keep their
    /// fused-generate output buffers attached (same engine, same thread),
    /// so even ladder rounds trained N−1 steps later stage with zero
    /// token uploads.
    buffered: VecDeque<SourcedRound>,
}

impl<'p> InlineSource<'p> {
    /// Build the synchronous source, optionally re-entering the exact
    /// stream position of a restored checkpoint: the generation RNG
    /// cursor and prompt cursor fully determine every future round, so a
    /// resumed sync run is **bitwise** identical to one that never
    /// stopped.
    pub fn new(
        cfg: &ExpConfig,
        prep: &'p Prepared,
        resume: Option<&Checkpoint>,
    ) -> Result<InlineSource<'p>> {
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        let (rng, cursor, generated) = match resume {
            Some(c) => {
                let s = &c.source;
                if s.kind != "inline" {
                    bail!(
                        "--resume: checkpoint was written by a '{}' round \
                         source but this run is synchronous (inline)",
                        s.kind
                    );
                }
                let (st, inc) = s.rng.ok_or_else(|| {
                    anyhow!("--resume: inline checkpoint lacks an RNG cursor")
                })?;
                let cursor = *s.cursors.first().ok_or_else(|| {
                    anyhow!("--resume: inline checkpoint lacks a prompt cursor")
                })?;
                (Pcg32::from_state(st, inc), cursor, s.generated)
            }
            None => (Pcg32::new(cfg.seed, 0x5c), RLHF_RANGE, 0),
        };
        Ok(InlineSource {
            generator: cfg.gen_engine.build(),
            taskgen: &prep.taskgen,
            rng,
            opts: sample_opts(cfg),
            k: cfg.k_samples,
            rounds_per_refill: cfg.n_minibatches * rounds_per_batch(cfg.k_samples),
            cursor,
            stride: cursor_stride(gen_bs, cfg.k_samples),
            gen_bs,
            generated,
            buffered: VecDeque::new(),
        })
    }
}

impl RoundSource for InlineSource<'_> {
    fn label(&self) -> &'static str {
        "sync"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { engine, state, version, timeline } = cx;
        if self.buffered.is_empty() {
            // generation phase: N minibatches of data, frozen policy;
            // the staged variant keeps the fused outputs device-resident
            // for the trainer (same engine) to chain into round staging
            let origin = timeline.origin();
            for _ in 0..self.rounds_per_refill {
                let round = timeline.record(Phase::Generate, || {
                    generate_round_staged(
                        engine,
                        self.generator.as_ref(),
                        state.param_view("policy", version),
                        version,
                        self.taskgen,
                        self.cursor,
                        self.k,
                        self.opts,
                        &mut self.rng,
                        origin,
                    )
                })?;
                self.cursor += self.stride;
                self.generated += 1;
                self.buffered.push_back(round);
            }
        }
        self.buffered.pop_front().ok_or_else(|| {
            anyhow!(
                "inline refill produced no rounds (rounds_per_refill = {})",
                self.rounds_per_refill
            )
        })
    }

    fn episodes(&self) -> u64 {
        // counted at generation: a refill window's episodes are spent
        // the moment the frozen policy generates them (seed accounting)
        self.generated * self.gen_bs
    }

    fn publish(&mut self, _cx: TrainerCx<'_>) -> Result<()> {
        // generation reads the trainer's live device parameters directly;
        // there is nothing to move
        Ok(())
    }

    fn snapshot(&self) -> Option<SourceState> {
        if !self.buffered.is_empty() {
            // mid-ladder: buffered rounds were generated by a policy a
            // resumed process cannot reconstruct — wait for the window
            // boundary (with n_minibatches = 1 every step is one)
            return None;
        }
        Some(SourceState {
            kind: "inline".into(),
            rng: Some(self.rng.state()),
            generated: self.generated,
            cursors: vec![self.cursor],
            skip: vec![],
            epoch: 0,
        })
    }

    fn finish(self: Box<Self>, _log: &mut RunLog) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// WorkerPool: M generation workers, bounded round queue of depth K
// ---------------------------------------------------------------------------

/// One round crossing the worker → trainer queue, tagged with the lane
/// (prompt-partition stripe) it came from so the trainer's
/// [`LaneAccounts`] can enforce exactly-once delivery across respawns.
struct GenMsg {
    round: Round,
    lane: usize,
    /// Continuous engine only: the prompt indices retired into this round
    /// (continuous lanes retire out of admission order, so block-cursor
    /// accounting does not apply).
    indices: Option<Vec<u64>>,
}

/// Structured exit report of one worker seat: sent on every exit path —
/// clean retirement, engine error, or caught panic.
struct WorkerExit {
    slot: usize,
    outcome: Result<(f64, u64)>,
}

/// Supervisor-side control block of one worker seat: the lanes it owns
/// (a bitmask — hence the 64-worker cap in config validation) and its
/// last heartbeat, in milliseconds since the trainer timeline origin.
struct SlotCtl {
    lanes: AtomicU64,
    beat_ms: AtomicU64,
}

fn beat(ctl: &SlotCtl, origin: Instant) {
    ctl.beat_ms
        .store(origin.elapsed().as_millis() as u64, Ordering::SeqCst);
}

/// Lane indices set in `mask`, ascending.
fn lanes_of(mask: u64) -> impl Iterator<Item = usize> {
    (0..64usize).filter(move |l| mask & (1u64 << l) != 0)
}

/// The lane a worker should generate for next: the one whose cursor is
/// furthest behind (ties to the lowest lane), so an heir that inherited
/// orphaned lanes round-robins them instead of starving one.
fn pick_lane(mask: u64, ledger: &[AtomicU64]) -> Result<usize> {
    lanes_of(mask)
        .min_by_key(|&l| (ledger[l].load(Ordering::SeqCst), l))
        .ok_or_else(|| {
            anyhow!(
                "worker scheduled with an empty lane mask ({mask:#b}) — \
                 supervision should have retired this seat"
            )
        })
}

/// Successor of `idx` in one lane's admission sequence (blocks of
/// `stride` consecutive indices starting at `start`, hopping `hop`
/// between blocks).
fn lane_next(idx: u64, start: u64, stride: u64, hop: u64) -> u64 {
    let rel = idx - start;
    let (block, off) = (rel / hop, rel % hop);
    debug_assert!(off < stride, "index off the lane's admission sequence");
    if off + 1 < stride {
        idx + 1
    } else {
        start + (block + 1) * hop
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

enum Accept {
    Fresh,
    Duplicate,
}

/// Trainer-side delivery accounting, per lane. The worker-side ledger
/// advances only *after* a successful handover (at-least-once); these
/// accounts turn that into exactly-once by dropping replays — and by
/// failing loudly on a *hole*, which no recovery path can legally
/// produce.
struct LaneAccounts {
    stride: u64,
    hop: u64,
    starts: Vec<u64>,
    /// Next index the trainer is owed per lane: block start for
    /// round-synchronous engines, delivered frontier for continuous.
    expected: Vec<u64>,
    /// Continuous engines: indices delivered above the frontier.
    delivered: Vec<HashSet<u64>>,
    duplicates: u64,
}

impl LaneAccounts {
    fn new(starts: Vec<u64>, stride: u64, hop: u64) -> LaneAccounts {
        let n = starts.len();
        LaneAccounts {
            stride,
            hop,
            expected: starts.clone(),
            starts,
            delivered: vec![HashSet::new(); n],
            duplicates: 0,
        }
    }

    fn resume(
        starts: Vec<u64>,
        stride: u64,
        hop: u64,
        cursors: &[u64],
        skip: &[Vec<u64>],
    ) -> LaneAccounts {
        let mut a = LaneAccounts::new(starts, stride, hop);
        a.expected = cursors.to_vec();
        for (lane, s) in skip.iter().enumerate() {
            a.delivered[lane] = s.iter().copied().collect();
        }
        a
    }

    fn accept(&mut self, msg: &GenMsg) -> Result<Accept> {
        match &msg.indices {
            Some(indices) => self.accept_indices(msg.lane, indices),
            None => self.accept_block(msg.lane, msg.round.start_index),
        }
    }

    /// Round-synchronous engines: a round is one whole block; the lane
    /// cursor either matches (fresh), trails (replay after a respawn —
    /// dropped), or was skipped (a lost round: loud failure).
    fn accept_block(&mut self, lane: usize, start: u64) -> Result<Accept> {
        let exp = self.expected[lane];
        if start == exp {
            self.expected[lane] = exp + self.hop;
            Ok(Accept::Fresh)
        } else if start < exp {
            self.duplicates += 1;
            Ok(Accept::Duplicate)
        } else {
            bail!(
                "prompt partition violated: lane {lane} jumped from index \
                 {exp} to {start} — a round was lost without recovery"
            )
        }
    }

    /// Continuous engines: a round is a set of retired prompt indices. A
    /// respawned worker's skip set must make every round all-fresh or
    /// all-replay; a mixed round means the skip set missed a delivery.
    fn accept_indices(&mut self, lane: usize, indices: &[u64]) -> Result<Accept> {
        let fresh = indices
            .iter()
            .filter(|&&i| {
                i >= self.expected[lane] && !self.delivered[lane].contains(&i)
            })
            .count();
        if fresh == 0 {
            self.duplicates += 1;
            return Ok(Accept::Duplicate);
        }
        if fresh < indices.len() {
            bail!(
                "continuous round on lane {lane} mixes {fresh} fresh and {} \
                 replayed prompt indices — the respawn skip set missed a \
                 delivery",
                indices.len() - fresh
            );
        }
        self.delivered[lane].extend(indices.iter().copied());
        // advance the frontier across everything now contiguous
        while self.delivered[lane].remove(&self.expected[lane]) {
            self.expected[lane] = lane_next(
                self.expected[lane],
                self.starts[lane],
                self.stride,
                self.hop,
            );
        }
        Ok(Accept::Fresh)
    }
}

/// Everything needed to (re)spawn a worker seat, owned so replacement
/// threads can be built mid-run without borrowing the config.
#[derive(Clone)]
struct SpawnCtx {
    artifact_dir: PathBuf,
    task: Task,
    prompt_len: usize,
    resp_len: usize,
    seed: u64,
    opts: SampleOpts,
    k: usize,
    gen_engine: GenEngine,
    max_cohorts: usize,
    admit_min: usize,
    stride: u64,
    hop: u64,
    retries: u32,
    stall_timeout: f64,
    fault: Option<FaultPlan>,
    origin: Instant,
    max_restarts: usize,
    continuous: bool,
}

/// The shared handles a worker seat runs against.
#[derive(Clone)]
struct SeatShared {
    tx: mpsc::SyncSender<GenMsg>,
    pslot: Arc<ParamSlot>,
    stop: Arc<AtomicBool>,
    ledger: Arc<Vec<AtomicU64>>,
    ctl: Arc<Vec<SlotCtl>>,
    fault_fired: Arc<AtomicBool>,
    retry_count: Arc<AtomicU64>,
}

/// M generation worker threads, each owning its own PJRT backend (the
/// `xla` crate's client is not `Send`, which conveniently mirrors the
/// paper's separate generation/training processes), feeding the trainer
/// over a bounded queue of depth K:
///
/// - each **worker** pulls the freshest published policy, generates one
///   round, and hands it over `send`, which blocks while the queue is
///   full — that back-pressure is the staleness guarantee;
/// - the **trainer** pops rounds; with K = 0 the queue is a rendezvous
///   and `M = 1, K = 0` reproduces the seed Cleanba coordinator exactly
///   (θ_{t+1} updated with data from θ_t, paper §3.5).
///
/// Workers partition the prompt stream by striding: worker `w` starts at
/// `RLHF_RANGE + w·stride` and hops `M·stride` per round, so pools of any
/// width consume disjoint, contiguously-tiling prompt ranges.
///
/// Parameter publication is a latest-wins [`ParamSlot`]: the trainer
/// downloads its device-resident params once per publish, snapshots them
/// into an `Arc`, and the swap itself is a pointer move — workers clone
/// the `Arc`, not the parameters, and re-upload to their device only when
/// the version actually changed (the A.2 "passing policy parameters" cost
/// is paid per publish, never per call).
pub struct WorkerPool {
    rx: mpsc::Receiver<GenMsg>,
    /// The pool's own sender clone: keeps the queue open for respawned
    /// workers, and makes trainer-side `Disconnected` impossible mid-run.
    tx: Option<mpsc::SyncSender<GenMsg>>,
    exit_rx: mpsc::Receiver<WorkerExit>,
    exit_tx: mpsc::Sender<WorkerExit>,
    slot: Arc<ParamSlot>,
    stop: Arc<AtomicBool>,
    /// Per-lane next-cursor, advanced by workers *after* handover.
    ledger: Arc<Vec<AtomicU64>>,
    ctl: Arc<Vec<SlotCtl>>,
    fault_fired: Arc<AtomicBool>,
    retry_count: Arc<AtomicU64>,
    ctx: SpawnCtx,
    /// One seat per worker slot; `None` = dead (reaped or re-strided).
    seats: Vec<Option<JoinHandle<()>>>,
    /// Per-slot incarnation: respawns (and resume epochs) shift the
    /// replacement's RNG streams so a replayed prompt block still samples
    /// fresh tokens instead of re-walking the dead worker's stream.
    incarnations: Vec<u64>,
    restarts_used: Vec<usize>,
    accounts: LaneAccounts,
    /// Rounds accepted while draining a dead worker's queue, served
    /// before new receives.
    pending: VecDeque<GenMsg>,
    /// Per-slot accumulated (gen_secs, rounds) across incarnations.
    totals: Vec<(f64, u64)>,
    worker_errors: Vec<String>,
    worker_restarts: u64,
    stalled_now: Vec<bool>,
    ever_stalled: Vec<bool>,
    gen_bs: u64,
    received: u64,
    /// Receive slice between supervision passes.
    poll: Duration,
}

impl WorkerPool {
    /// Spawn `cfg.gen_workers` supervised workers over a queue of depth
    /// `cfg.staleness_bound`. `origin` is the trainer timeline's clock so
    /// worker gen-spans are directly comparable. With `resume`, lanes
    /// re-enter the checkpoint's cursors, the param slot seeds from the
    /// checkpoint's policy at its version, and worker RNG streams shift
    /// to a fresh epoch (async resume is exactly-once, not bitwise —
    /// live worker threads cannot be snapshotted mid-call).
    pub fn spawn(
        cfg: &ExpConfig,
        prep: &Prepared,
        origin: Instant,
        resume: Option<&Checkpoint>,
    ) -> Result<WorkerPool> {
        let m = cfg.gen_workers.max(1);
        assert!(m <= 64, "lane ownership is a u64 bitmask");
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        let stride = cursor_stride(gen_bs, cfg.k_samples);
        let hop = stride * m as u64;
        let continuous = cfg.gen_engine == GenEngine::Continuous;
        let starts: Vec<u64> =
            (0..m).map(|w| RLHF_RANGE + w as u64 * stride).collect();

        let (accounts, epoch0, received, init_version, init_params) =
            match resume {
                Some(c) => {
                    let s = &c.source;
                    if s.kind != "pool" {
                        bail!(
                            "--resume: checkpoint was written by a '{}' \
                             round source but this run is async (worker \
                             pool)",
                            s.kind
                        );
                    }
                    if s.cursors.len() != m {
                        bail!(
                            "--resume: checkpoint has {} worker lanes but \
                             --gen-workers is {m}",
                            s.cursors.len()
                        );
                    }
                    let skip: Vec<Vec<u64>> = if s.skip.len() == m {
                        s.skip.clone()
                    } else if s.skip.is_empty() {
                        vec![Vec::new(); m]
                    } else {
                        bail!(
                            "--resume: checkpoint has {} skip lists for {m} \
                             lanes",
                            s.skip.len()
                        );
                    };
                    (
                        LaneAccounts::resume(
                            starts.clone(),
                            stride,
                            hop,
                            &s.cursors,
                            &skip,
                        ),
                        // past every RNG stream this run already consumed
                        s.epoch + 1,
                        s.generated,
                        c.version,
                        Arc::from(&c.params[..]),
                    )
                }
                None => (
                    LaneAccounts::new(starts, stride, hop),
                    0,
                    0,
                    0,
                    Arc::from(&prep.sft_params[..]),
                ),
            };

        let (tx, rx) = mpsc::sync_channel::<GenMsg>(cfg.staleness_bound);
        let (exit_tx, exit_rx) = mpsc::channel::<WorkerExit>();
        let slot = Arc::new(ParamSlot::new(init_version, init_params));
        let stop = Arc::new(AtomicBool::new(false));
        let ledger: Arc<Vec<AtomicU64>> = Arc::new(
            accounts.expected.iter().map(|&c| AtomicU64::new(c)).collect(),
        );
        let now_ms = origin.elapsed().as_millis() as u64;
        let ctl: Arc<Vec<SlotCtl>> = Arc::new(
            (0..m)
                .map(|w| SlotCtl {
                    lanes: AtomicU64::new(1u64 << w),
                    beat_ms: AtomicU64::new(now_ms),
                })
                .collect(),
        );
        let ctx = SpawnCtx {
            artifact_dir: cfg.artifact_dir(),
            task: prep.taskgen.task,
            prompt_len: prep.taskgen.prompt_len,
            resp_len: prep.taskgen.resp_len,
            seed: cfg.seed,
            opts: sample_opts(cfg),
            k: cfg.k_samples,
            gen_engine: cfg.gen_engine,
            max_cohorts: cfg.max_cohorts,
            admit_min: cfg.admit_min,
            stride,
            hop,
            retries: cfg.engine_retries,
            stall_timeout: cfg.stall_timeout_secs,
            fault: cfg.inject_fault,
            origin,
            max_restarts: cfg.max_worker_restarts,
            continuous,
        };
        let poll = Duration::from_secs_f64(
            (cfg.stall_timeout_secs / 4.0).clamp(0.010, 0.050),
        );
        let mut pool = WorkerPool {
            rx,
            tx: Some(tx),
            exit_rx,
            exit_tx,
            slot,
            stop,
            ledger,
            ctl,
            fault_fired: Arc::new(AtomicBool::new(false)),
            retry_count: Arc::new(AtomicU64::new(0)),
            ctx,
            seats: (0..m).map(|_| None).collect(),
            incarnations: vec![epoch0; m],
            restarts_used: vec![0; m],
            accounts,
            pending: VecDeque::new(),
            totals: vec![(0.0, 0); m],
            worker_errors: Vec::new(),
            worker_restarts: 0,
            stalled_now: vec![false; m],
            ever_stalled: vec![false; m],
            gen_bs,
            received,
            poll,
        };
        for w in 0..m {
            pool.spawn_seat(w)?;
        }
        Ok(pool)
    }

    /// The shared handles a seat thread runs against.
    fn shared(&self) -> Result<SeatShared> {
        let tx = self.tx.clone().ok_or_else(|| {
            anyhow!(
                "worker pool queue already torn down while (re)spawning a \
                 seat — finish() ran before supervision stopped"
            )
        })?;
        Ok(SeatShared {
            tx,
            pslot: self.slot.clone(),
            stop: self.stop.clone(),
            ledger: self.ledger.clone(),
            ctl: self.ctl.clone(),
            fault_fired: self.fault_fired.clone(),
            retry_count: self.retry_count.clone(),
        })
    }

    /// (Re)spawn seat `w` at its current incarnation. The body runs under
    /// `catch_unwind`; every exit path reports a [`WorkerExit`].
    fn spawn_seat(&mut self, w: usize) -> Result<()> {
        let ctx = self.ctx.clone();
        let sh = self.shared()?;
        let exit_tx = self.exit_tx.clone();
        let incarnation = self.incarnations[w];
        // continuous lanes resume from the trainer-accepted frontier,
        // skipping out-of-order deliveries above it
        let resume = (
            self.accounts.expected[w],
            self.accounts.delivered[w].clone(),
        );
        beat(&self.ctl[w], self.ctx.origin);
        let handle = std::thread::Builder::new()
            .name(format!("gen-worker-{w}"))
            .spawn(move || {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if ctx.continuous {
                        let (frontier, skip) = resume;
                        seat_continuous(&ctx, &sh, w, incarnation, frontier, skip)
                    } else {
                        seat_rounds(&ctx, &sh, w, incarnation)
                    }
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow!("panicked: {}", panic_message(p.as_ref())))
                });
                // best-effort: at teardown the receiver may already be gone
                let _ = exit_tx.send(WorkerExit { slot: w, outcome });
            })
            .map_err(|e| anyhow!("spawn gen-worker-{w}: {e}"))?;
        self.seats[w] = Some(handle);
        Ok(())
    }

    /// Reap dead seats (respawn / re-stride / fail) and run the heartbeat
    /// watchdog. Called from `next` between receive slices.
    fn supervise(&mut self) -> Result<()> {
        while let Ok(exit) = self.exit_rx.try_recv() {
            let w = exit.slot;
            if let Some(h) = self.seats[w].take() {
                let _ = h.join();
            }
            match exit.outcome {
                Ok((secs, rounds)) => {
                    self.totals[w].0 += secs;
                    self.totals[w].1 += rounds;
                    // a clean exit is only legitimate at teardown or after
                    // its lanes were re-strided away
                    let retired = self.ctl[w].lanes.load(Ordering::SeqCst) == 0;
                    if !self.stop.load(Ordering::SeqCst) && !retired {
                        self.handle_death(
                            w,
                            anyhow!("exited cleanly mid-run (queue closed?)"),
                        )?;
                    }
                }
                Err(e) => self.handle_death(w, e)?,
            }
        }
        let now_ms = self.ctx.origin.elapsed().as_millis() as u64;
        for w in 0..self.seats.len() {
            if self.seats[w].is_none() {
                self.stalled_now[w] = false;
                continue;
            }
            let age =
                now_ms.saturating_sub(self.ctl[w].beat_ms.load(Ordering::SeqCst));
            let stalled = age as f64 / 1000.0 > self.ctx.stall_timeout;
            if stalled && !self.stalled_now[w] {
                self.stalled_now[w] = true;
                self.ever_stalled[w] = true;
                eprintln!(
                    "[supervisor] gen-worker-{w} silent for {:.1}s \
                     (--stall-timeout-secs {:.1}) — flagged as stalled",
                    age as f64 / 1000.0,
                    self.ctx.stall_timeout
                );
            } else if !stalled && self.stalled_now[w] {
                self.stalled_now[w] = false;
                eprintln!("[supervisor] gen-worker-{w} resumed heartbeats");
            }
        }
        Ok(())
    }

    /// Absorb every queued round into the accounts (fresh ones buffer in
    /// `pending`). Must run before computing a respawn position: a round
    /// sitting in the queue at worker death is not yet accounted, and a
    /// replacement spawned without it would replay it as a partial
    /// duplicate.
    fn drain_queue(&mut self) -> Result<()> {
        while let Ok(msg) = self.rx.try_recv() {
            if let Accept::Fresh = self.accounts.accept(&msg)? {
                self.pending.push_back(msg);
            }
        }
        Ok(())
    }

    fn handle_death(&mut self, w: usize, err: anyhow::Error) -> Result<()> {
        self.drain_queue()?;
        self.worker_errors.push(format!("gen-worker-{w}: {err:#}"));
        let lanes = self.ctl[w].lanes.load(Ordering::SeqCst);
        // the dead worker may have generated without completing the
        // handover: rewind-proof the ledger to the accepted frontier
        for l in lanes_of(lanes) {
            self.ledger[l].fetch_max(self.accounts.expected[l], Ordering::SeqCst);
        }
        if self.restarts_used[w] < self.ctx.max_restarts {
            self.restarts_used[w] += 1;
            self.worker_restarts += 1;
            self.incarnations[w] += 1;
            eprintln!(
                "[supervisor] gen-worker-{w} died: {err:#}; respawning on a \
                 fresh engine (restart {}/{})",
                self.restarts_used[w], self.ctx.max_restarts
            );
            return self.spawn_seat(w);
        }
        if self.ctx.continuous {
            bail!(
                "gen-worker-{w} is unrecoverable after {} restarts: {err:#}; \
                 a continuous lane's in-flight sequences cannot be \
                 re-strided onto a survivor",
                self.ctx.max_restarts
            );
        }
        let heir =
            (0..self.seats.len()).find(|&h| h != w && self.seats[h].is_some());
        match heir {
            Some(h) => {
                self.ctl[w].lanes.store(0, Ordering::SeqCst);
                self.ctl[h].lanes.fetch_or(lanes, Ordering::SeqCst);
                eprintln!(
                    "[supervisor] gen-worker-{w} died with no restarts left: \
                     {err:#}; re-striding its lanes ({lanes:#b}) onto \
                     gen-worker-{h}"
                );
                Ok(())
            }
            None => bail!(
                "gen-worker-{w} died with no restarts left and no surviving \
                 workers: {err:#}"
            ),
        }
    }

    fn deliver(
        &mut self,
        msg: GenMsg,
        timeline: &mut Timeline,
        t_wait: f64,
    ) -> SourcedRound {
        let t_got = timeline.origin().elapsed().as_secs_f64();
        timeline.push_span(Phase::Idle, t_wait, t_got);
        timeline.push_span(
            Phase::Generate,
            msg.round.gen_span.0,
            msg.round.gen_span.1,
        );
        self.received += 1;
        // worker rounds crossed the thread boundary as host data: the
        // trainer re-stages them (the async mode's one upload per round)
        SourcedRound { round: msg.round, staged: None }
    }
}

impl RoundSource for WorkerPool {
    fn label(&self) -> &'static str {
        "async"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { timeline, .. } = cx;
        let t_wait = timeline.origin().elapsed().as_secs_f64();
        loop {
            // rounds rescued from a dead worker's queue go first
            if let Some(msg) = self.pending.pop_front() {
                return Ok(self.deliver(msg, timeline, t_wait));
            }
            self.supervise()?;
            match self.rx.recv_timeout(self.poll) {
                Ok(msg) => match self.accounts.accept(&msg)? {
                    Accept::Fresh => {
                        return Ok(self.deliver(msg, timeline, t_wait))
                    }
                    // a respawned worker replaying its at-least-once
                    // window: drop, it is already trained on
                    Accept::Duplicate => continue,
                },
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                    "round queue disconnected while the pool holds a \
                     sender — this is a bug"
                ),
            }
        }
    }

    fn episodes(&self) -> u64 {
        // counted at handover: rounds still in flight inside a worker
        // (or queued) are not episodes yet
        self.received * self.gen_bs
    }

    fn publish(&mut self, cx: TrainerCx<'_>) -> Result<()> {
        let TrainerCx { engine, state, version, timeline } = cx;
        // device -> host once per publish, then a latest-wins pointer swap
        timeline.record(Phase::Publish, || -> Result<()> {
            let host = state.params_host(engine)?;
            self.slot.publish(version, Arc::from(host));
            Ok(())
        })
    }

    fn snapshot(&self) -> Option<SourceState> {
        // always at a clean boundary: cursors are the trainer-accepted
        // frontier, and rounds in flight (or queued) simply regenerate
        // after resume, where the accounts would dedupe them
        let skip = if self.ctx.continuous {
            self.accounts
                .delivered
                .iter()
                .map(|s| {
                    let mut v: Vec<u64> = s.iter().copied().collect();
                    v.sort_unstable();
                    v
                })
                .collect()
        } else {
            vec![Vec::new(); self.accounts.expected.len()]
        };
        Some(SourceState {
            kind: "pool".into(),
            rng: None,
            generated: self.received,
            cursors: self.accounts.expected.clone(),
            skip,
            epoch: self.incarnations.iter().copied().max().unwrap_or(0),
        })
    }

    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()> {
        let mut pool = *self;
        pool.stop.store(true, Ordering::SeqCst);
        // dropping the trainer's channel ends release workers blocked in
        // `send`, so join cannot deadlock
        drop(pool.tx.take());
        drop(pool.rx);
        for seat in pool.seats.iter_mut() {
            if let Some(h) = seat.take() {
                // seat bodies run under catch_unwind: join only fails if
                // the exit-report send itself panicked
                let _ = h.join();
            }
        }
        // mid-run failures were already surfaced (and recovered or
        // escalated) by `supervise`; teardown absorbs what remains into
        // the run metas instead of failing a finished run
        while let Ok(exit) = pool.exit_rx.try_recv() {
            match exit.outcome {
                Ok((secs, rounds)) => {
                    pool.totals[exit.slot].0 += secs;
                    pool.totals[exit.slot].1 += rounds;
                }
                Err(e) => pool
                    .worker_errors
                    .push(format!("gen-worker-{}: {e:#}", exit.slot)),
            }
        }
        let mut gen_total = 0.0f64;
        let mut rounds_total = 0u64;
        for (w, (secs, rounds)) in pool.totals.iter().enumerate() {
            log.set_meta(&format!("gen_secs_w{w}"), format!("{secs:.3}"));
            log.set_meta(&format!("gen_rounds_w{w}"), rounds);
            gen_total += secs;
            rounds_total += rounds;
        }
        log.set_meta("gen_total_secs", format!("{gen_total:.3}"));
        log.set_meta("gen_rounds", rounds_total);
        log.set_meta("worker_restarts", pool.worker_restarts);
        log.set_meta(
            "stalled_workers",
            pool.ever_stalled.iter().filter(|&&b| b).count(),
        );
        log.set_meta("engine_retries", pool.retry_count.load(Ordering::SeqCst));
        log.set_meta("dropped_duplicate_rounds", pool.accounts.duplicates);
        if !pool.worker_errors.is_empty() {
            log.set_meta("worker_errors", pool.worker_errors.join(" | "));
        }
        Ok(())
    }
}

/// Scripted-fault check at the top of a worker round: fires exactly once
/// per run (`fault_fired`), so a respawned replacement does not re-fault.
/// `Panic` and `Stall` act immediately; `EngineErr` arms the caller's
/// next attempt-0 engine call to fail.
fn maybe_inject(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    rounds_done: u64,
    inject_err: &mut bool,
) {
    let Some(f) = &ctx.fault else { return };
    if f.worker != w
        || rounds_done != f.round
        || sh.fault_fired.swap(true, Ordering::SeqCst)
    {
        return;
    }
    match f.kind {
        FaultKind::Panic => panic!(
            "injected fault: scripted panic in gen-worker-{w} at round {}",
            f.round
        ),
        FaultKind::Stall => std::thread::sleep(Duration::from_secs_f64(
            ctx.stall_timeout * 2.0,
        )),
        FaultKind::EngineErr => *inject_err = true,
    }
}

/// Body of a round-synchronous worker seat (cached / device / naive
/// generators): fetch the freshest policy, generate one round on the
/// lane furthest behind, hand it over, advance the lane ledger.
///
/// Worker `w` at incarnation 0 keeps the seed coordinator's RNG stream
/// (`0xa57c + w`) so M=1 pools replay the seed bitwise; respawns and
/// resume epochs shift the stream so replayed prompts resample fresh.
fn seat_rounds(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    incarnation: u64,
) -> Result<(f64, u64)> {
    // own engine, own PJRT client (separate "GPU")
    let engine = Engine::load(&ctx.artifact_dir)?;
    let taskgen = TaskGen::new(ctx.task, ctx.prompt_len, ctx.resp_len, ctx.seed);
    let stream = w as u64 + (incarnation << 20);
    let mut rng = Pcg32::new(ctx.seed, 0xa57c + stream);
    let mut retry_rng = Pcg32::new(ctx.seed, RETRY_STREAM + stream);
    let policy = RetryPolicy::new(ctx.retries);
    let generator = ctx.gen_engine.build();
    let (mut version, mut params) = sh.pslot.latest();
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut inject_err = false;
    loop {
        beat(&sh.ctl[w], ctx.origin);
        if sh.stop.load(Ordering::SeqCst) {
            break;
        }
        let mask = sh.ctl[w].lanes.load(Ordering::SeqCst);
        if mask == 0 {
            break; // lanes re-strided away: retire cleanly
        }
        // pick up the freshest published policy (Algorithm 1: "update
        // generation model θ <- θ_i"); the cached view below re-uploads
        // to device only on a version change
        if let Some((v, p)) = sh.pslot.fetch(version) {
            version = v;
            params = p;
        }
        let lane = pick_lane(mask, &sh.ledger)?;
        let cursor = sh.ledger[lane].load(Ordering::SeqCst);
        maybe_inject(ctx, sh, w, rounds_done, &mut inject_err);
        let round = policy.run(
            &mut retry_rng,
            |_| {
                sh.retry_count.fetch_add(1, Ordering::SeqCst);
                engine.note_retry(ROUND_ORIGIN);
            },
            |attempt| {
                if inject_err && attempt == 0 {
                    bail!(
                        "injected fault: scripted engine error in \
                         gen-worker-{w}"
                    );
                }
                generate_round(
                    &engine,
                    generator.as_ref(),
                    ParamView::cached("policy", version, &params),
                    version,
                    &taskgen,
                    cursor,
                    ctx.k,
                    ctx.opts,
                    &mut rng,
                    ctx.origin,
                )
            },
        )?;
        inject_err = false;
        gen_total += round.gen_secs;
        beat(&sh.ctl[w], ctx.origin);
        // blocks while K rounds are queued — the staleness bound's
        // back-pressure
        if sh.tx.send(GenMsg { round, lane, indices: None }).is_err() {
            break;
        }
        rounds_done += 1;
        // advance ONLY after the handover (at-least-once): a crash before
        // this store regenerates the round; a crash after the send leaves
        // a duplicate the trainer's accounts drop
        sh.ledger[lane].store(cursor + ctx.hop, Ordering::SeqCst);
    }
    Ok((gen_total, rounds_done))
}

/// Streaming body of a continuous-engine worker seat: drive the slot
/// pool one sweep at a time, re-reading the published policy slot
/// *between decode steps* (PipelineRL's inflight weight swap — in-flight
/// sequences keep their KV cache and finish under the new weights,
/// stamping their remaining tokens with the new version), feeding retired
/// sequences through a [`RoundAssembler`] and handing assembled rounds
/// over the same bounded queue as the round-synchronous workers — the
/// staleness back-pressure simply pauses the pool mid-flight while `send`
/// blocks.
///
/// A respawned incarnation re-enters the lane at the trainer-accepted
/// `frontier`, skipping the out-of-order indices already delivered above
/// it — the admission filter makes every post-respawn round all-fresh.
fn seat_continuous(
    ctx: &SpawnCtx,
    sh: &SeatShared,
    w: usize,
    incarnation: u64,
    frontier: u64,
    skip: HashSet<u64>,
) -> Result<(f64, u64)> {
    let engine = Engine::load(&ctx.artifact_dir)?;
    let taskgen = TaskGen::new(ctx.task, ctx.prompt_len, ctx.resp_len, ctx.seed);
    let stream = w as u64 + (incarnation << 20);
    let mut rng = Pcg32::new(ctx.seed, 0xa57c + stream);
    let mut retry_rng = Pcg32::new(ctx.seed, RETRY_STREAM + stream);
    let policy = RetryPolicy::new(ctx.retries);
    let mcfg = engine.manifest.config.clone();
    let mut backend = DeviceBackend::new(&engine)?;
    let mut pool = Pool::new(PoolCfg {
        slots: mcfg.gen_batch,
        prompt_len: mcfg.prompt_len,
        seq_len: mcfg.seq_len,
        vocab: mcfg.vocab,
        max_cohorts: ctx.max_cohorts,
        admit_min: ctx.admit_min,
    });
    // the same strided prompt partition the round-based workers walk
    // (worker w: blocks of `stride` indices, hopping M·stride, each
    // index k times), consumed one prompt per freed slot — re-entered at
    // the block holding the frontier, minus what was already delivered
    let start = RLHF_RANGE + w as u64 * ctx.stride;
    let base = start + ((frontier - start) / ctx.hop) * ctx.hop;
    let mut admission = taskgen
        .admission(base, ctx.stride, ctx.hop, ctx.k)
        .filter(move |a| a.index >= frontier && !skip.contains(&a.index))
        .map(|a| AdmitSeq { index: a.index, dup: a.dup, prompt: a.prompt });
    let mut assembler = RoundAssembler::new(mcfg.gen_batch, ctx.k);
    let (mut version, mut params) = sh.pslot.latest();
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut inject_err = false;
    let mut t_round = ctx.origin.elapsed().as_secs_f64();
    loop {
        beat(&sh.ctl[w], ctx.origin);
        if sh.stop.load(Ordering::SeqCst)
            || sh.ctl[w].lanes.load(Ordering::SeqCst) == 0
        {
            break;
        }
        if let Some((v, p)) = sh.pslot.fetch(version) {
            version = v;
            params = p;
        }
        maybe_inject(ctx, sh, w, rounds_done, &mut inject_err);
        policy.run(
            &mut retry_rng,
            |_| {
                sh.retry_count.fetch_add(1, Ordering::SeqCst);
                engine.note_retry(ROUND_ORIGIN);
            },
            |attempt| {
                if inject_err && attempt == 0 {
                    bail!(
                        "injected fault: scripted engine error in \
                         gen-worker-{w}"
                    );
                }
                pool.step(
                    &mut backend,
                    ParamView::cached("policy", version, &params),
                    version,
                    &mut admission,
                    ctx.opts,
                    &mut rng,
                )
            },
        )?;
        inject_err = false;
        for c in pool.drain_completed() {
            assembler.push(c);
        }
        while let Some(groups) = assembler.pop_round() {
            let indices: Vec<u64> = groups.iter().map(|(i, _)| *i).collect();
            let t_now = ctx.origin.elapsed().as_secs_f64();
            let round = round_from_groups(groups, &taskgen, (t_round, t_now));
            gen_total += t_now - t_round;
            rounds_done += 1;
            beat(&sh.ctl[w], ctx.origin);
            // blocks while K rounds are queued — the staleness bound's
            // back-pressure; in-flight sequences wait between sweeps
            if sh
                .tx
                .send(GenMsg { round, lane: w, indices: Some(indices) })
                .is_err()
            {
                return Ok((gen_total, rounds_done));
            }
            // blocked-send time belongs to the queue, not generation
            t_round = ctx.origin.elapsed().as_secs_f64();
        }
    }
    Ok((gen_total, rounds_done))
}

/// Assemble a trainer [`Round`] from `gen_batch / k` retired prompt
/// groups (each `k` completions, in dup order) — the continuous engine's
/// counterpart of `generate_round`'s fixed-round output. Examples are
/// regenerated from the pure task stream by index; per-token version
/// provenance aggregates into the round's staleness fields.
fn round_from_groups(
    groups: Vec<(u64, Vec<Completed>)>,
    taskgen: &TaskGen,
    span: (f64, f64),
) -> Round {
    let n: usize = groups.iter().map(|(_, g)| g.len()).sum();
    let mut tokens = Vec::with_capacity(n);
    let mut resp_mask = Vec::with_capacity(n);
    let mut blp = Vec::with_capacity(n);
    let mut terminated = Vec::with_capacity(n);
    let mut examples = Vec::with_capacity(groups.len());
    let start_index = groups.first().map(|(i, _)| *i).unwrap_or(0);
    let mut steps_max = 0usize;
    let mut ver_min = u64::MAX;
    let mut ver_max = 0u64;
    let mut ver_sum = 0.0f64;
    let mut tok_count = 0u64;
    for (index, group) in groups {
        examples.push(taskgen.example(index));
        for c in group {
            steps_max = steps_max.max(c.steps);
            ver_min = ver_min.min(c.version_min);
            ver_max = ver_max.max(c.version_max);
            ver_sum += c.version_sum;
            tok_count += c.steps as u64;
            tokens.push(c.tokens);
            resp_mask.push(c.resp_mask);
            blp.push(c.blp);
            terminated.push(c.terminated);
        }
    }
    Round {
        gen: GenBatch { tokens, resp_mask, blp, terminated, steps: steps_max },
        examples,
        start_index,
        // newest token version: keeps the per-round staleness bound's
        // "freshest data age" meaning under version mixing
        params_version: ver_max,
        tok_version_min: ver_min.min(ver_max),
        tok_version_mean: if tok_count > 0 {
            ver_sum / tok_count as f64
        } else {
            ver_max as f64
        },
        gen_secs: span.1 - span.0,
        gen_span: span,
    }
}

// ---------------------------------------------------------------------------
// SessionSource: served traffic as the prompt stream (serve-while-training)
// ---------------------------------------------------------------------------

/// Serving-side telemetry accumulated across all worker seats: latency
/// samples per retired candidate, served-params staleness lags, and the
/// occupancy numerator/denominator. Folded into the run metas at finish.
#[derive(Default)]
struct ServeTelemetry {
    /// Time-to-first-token per candidate, sweep units.
    ttft: Vec<u64>,
    /// Time-to-retire per candidate, sweep units.
    retire: Vec<u64>,
    /// Served-params staleness per candidate: publish version at
    /// retirement minus the oldest version any of its tokens sampled
    /// under — the "how stale was the reply" distribution.
    lag: Vec<u64>,
    /// Turns completed (user-visible requests).
    requests: u64,
    /// Response tokens emitted across all candidates.
    tokens: u64,
    /// Occupancy denominator: pool slots × sampling sweeps.
    slot_sweeps: u64,
    /// Mux sweeps elapsed (includes idle arrival gaps).
    mux_sweeps: u64,
}

/// Seat-side flush of one mux's pool accounting into the shared
/// telemetry — called on every seat exit path.
fn flush_serve_stats(
    telemetry: &Arc<Mutex<ServeTelemetry>>,
    stats: PoolStats,
    slots: usize,
    mux_sweeps: u64,
) {
    let mut t = telemetry.lock().unwrap_or_else(PoisonError::into_inner);
    t.tokens += stats.tokens;
    t.slot_sweeps += stats.sweeps * slots as u64;
    t.mux_sweeps += mux_sweeps;
}

/// The shape of one serve run, shared by the supervisor and its seats.
#[derive(Clone)]
struct ServeCtx {
    base: SpawnCtx,
    sessions: u64,
    turns: u64,
    arrival_rate: f64,
    /// Worker count — the session partition stride.
    workers: u64,
}

/// The shared handles a serving seat runs against: the worker-pool set
/// plus the telemetry sink and the per-seat "partition fully served"
/// flags (a serving seat retires itself when its sessions drain, which
/// the supervisor must distinguish from a mid-run death).
#[derive(Clone)]
struct ServeShared {
    base: SeatShared,
    telemetry: Arc<Mutex<ServeTelemetry>>,
    done: Arc<Vec<AtomicBool>>,
}

/// Exactly-once accounting for served rounds. Where [`LaneAccounts`]
/// tracks lane cursors, this tracks the set of delivered turn uids — and
/// enforces the session-order invariant: within a session, turn `t`
/// cannot deliver before turn `t − 1` (the board gates turn `t` on turn
/// `t − 1`'s completion, so a violation means a turn was dropped).
struct SessionAccounts {
    turns: u64,
    delivered: HashSet<u64>,
    duplicates: u64,
}

impl SessionAccounts {
    fn new(turns: u64) -> SessionAccounts {
        SessionAccounts { turns, delivered: HashSet::new(), duplicates: 0 }
    }

    fn accept(&mut self, msg: &GenMsg) -> Result<Accept> {
        let Some(uids) = &msg.indices else {
            bail!("served round carries no session uids — this is a bug");
        };
        let fresh =
            uids.iter().filter(|&&u| !self.delivered.contains(&u)).count();
        if fresh == 0 {
            self.duplicates += 1;
            return Ok(Accept::Duplicate);
        }
        if fresh < uids.len() {
            let sessions: Vec<u64> = uids
                .iter()
                .map(|&u| uid_session_turn(u, self.turns).0)
                .collect();
            bail!(
                "served round mixes {fresh} fresh and {} replayed turns \
                 (sessions {sessions:?}) — the respawn skip set missed a \
                 delivery",
                uids.len() - fresh
            );
        }
        for &u in uids {
            let (session, turn) = uid_session_turn(u, self.turns);
            // in-message predecessors were inserted just above, so a
            // round carrying consecutive turns of one session is legal
            if turn > 0 && !self.delivered.contains(&(u - 1)) {
                bail!(
                    "serving session {session}: turn {turn} delivered \
                     before turn {} — a turn was dropped",
                    turn - 1
                );
            }
            self.delivered.insert(u);
        }
        Ok(Accept::Fresh)
    }
}

/// Serve-while-training: M serving seats, each multiplexing its static
/// partition of the traffic trace (`session % M == w`) onto its own
/// continuous slot pool, with completed turns assembled into training
/// rounds — live traffic IS the prompt stream.
///
/// Structure mirrors [`WorkerPool`] (supervised seats, bounded round
/// queue, latest-wins [`ParamSlot`], heartbeat watchdog, scripted fault
/// injection) with three deltas:
///
/// - rounds carry **session turn uids** instead of lane cursors;
///   [`SessionAccounts`] extends the trainer's dedup/hole checks to them
///   (a respawned seat rebuilds its schedule from the delivered set, so
///   every post-respawn round is all-fresh);
/// - seats **retire themselves** when their partition is fully served —
///   the run's length is the traffic's, not a step budget;
/// - sessions never migrate between seats: when a seat exhausts its
///   restarts the run fails loudly **naming the sessions** that can no
///   longer complete (silently dropping a turn is the one forbidden
///   outcome).
pub struct SessionSource {
    rx: mpsc::Receiver<GenMsg>,
    tx: Option<mpsc::SyncSender<GenMsg>>,
    exit_rx: mpsc::Receiver<WorkerExit>,
    exit_tx: mpsc::Sender<WorkerExit>,
    slot: Arc<ParamSlot>,
    stop: Arc<AtomicBool>,
    /// Unused by serving seats (sessions, not lanes) but part of the
    /// shared seat handle; kept empty.
    ledger: Arc<Vec<AtomicU64>>,
    ctl: Arc<Vec<SlotCtl>>,
    fault_fired: Arc<AtomicBool>,
    retry_count: Arc<AtomicU64>,
    telemetry: Arc<Mutex<ServeTelemetry>>,
    done: Arc<Vec<AtomicBool>>,
    ctx: ServeCtx,
    seats: Vec<Option<JoinHandle<()>>>,
    incarnations: Vec<u64>,
    restarts_used: Vec<usize>,
    accounts: SessionAccounts,
    pending: VecDeque<GenMsg>,
    totals: Vec<(f64, u64)>,
    worker_errors: Vec<String>,
    worker_restarts: u64,
    stalled_now: Vec<bool>,
    ever_stalled: Vec<bool>,
    gen_bs: u64,
    received: u64,
    /// Round-tier counterfactual occupancy accounting: had each
    /// delivered round been generated as a fixed round, it would have
    /// held all B slots for its longest completion's sweeps.
    fixed_tokens: u64,
    fixed_slot_sweeps: u64,
    poll: Duration,
}

impl SessionSource {
    pub fn spawn(
        cfg: &ExpConfig,
        prep: &Prepared,
        origin: Instant,
        resume: Option<&Checkpoint>,
    ) -> Result<SessionSource> {
        if resume.is_some() {
            bail!(
                "serve mode is not checkpointable (sessions in flight \
                 cannot be snapshotted); run without --resume"
            );
        }
        if cfg.gen_engine != GenEngine::Continuous {
            bail!(
                "serve mode needs the continuous engine (got {:?})",
                cfg.gen_engine
            );
        }
        let m = cfg.gen_workers.max(1);
        assert!(m <= 64, "config validation caps gen_workers at 64");
        if cfg.serve_sessions % m as u64 != 0 {
            bail!(
                "--serve-sessions {} must divide evenly over {m} workers \
                 (sessions partition statically; they never migrate)",
                cfg.serve_sessions
            );
        }
        let gen_bs = prep.engine.manifest.config.gen_batch as u64;
        let stride = cursor_stride(gen_bs, cfg.k_samples);
        let ctx = ServeCtx {
            base: SpawnCtx {
                artifact_dir: cfg.artifact_dir(),
                task: prep.taskgen.task,
                prompt_len: prep.taskgen.prompt_len,
                resp_len: prep.taskgen.resp_len,
                seed: cfg.seed,
                opts: sample_opts(cfg),
                k: cfg.k_samples,
                gen_engine: cfg.gen_engine,
                max_cohorts: cfg.max_cohorts,
                admit_min: cfg.admit_min,
                stride,
                hop: stride * m as u64,
                retries: cfg.engine_retries,
                stall_timeout: cfg.stall_timeout_secs,
                fault: cfg.inject_fault,
                origin,
                max_restarts: cfg.max_worker_restarts,
                continuous: true,
            },
            sessions: cfg.serve_sessions,
            turns: cfg.serve_turns,
            arrival_rate: cfg.arrival_rate,
            workers: m as u64,
        };
        let (tx, rx) = mpsc::sync_channel::<GenMsg>(cfg.staleness_bound);
        let (exit_tx, exit_rx) = mpsc::channel::<WorkerExit>();
        let now_ms = origin.elapsed().as_millis() as u64;
        let mut source = SessionSource {
            rx,
            tx: Some(tx),
            exit_rx,
            exit_tx,
            slot: Arc::new(ParamSlot::new(0, Arc::from(&prep.sft_params[..]))),
            stop: Arc::new(AtomicBool::new(false)),
            ledger: Arc::new(Vec::new()),
            ctl: Arc::new(
                (0..m)
                    .map(|w| SlotCtl {
                        lanes: AtomicU64::new(1u64 << w),
                        beat_ms: AtomicU64::new(now_ms),
                    })
                    .collect(),
            ),
            fault_fired: Arc::new(AtomicBool::new(false)),
            retry_count: Arc::new(AtomicU64::new(0)),
            telemetry: Arc::new(Mutex::new(ServeTelemetry::default())),
            done: Arc::new((0..m).map(|_| AtomicBool::new(false)).collect()),
            ctx,
            seats: (0..m).map(|_| None).collect(),
            incarnations: vec![0; m],
            restarts_used: vec![0; m],
            accounts: SessionAccounts::new(cfg.serve_turns),
            pending: VecDeque::new(),
            totals: vec![(0.0, 0); m],
            worker_errors: Vec::new(),
            worker_restarts: 0,
            stalled_now: vec![false; m],
            ever_stalled: vec![false; m],
            gen_bs,
            received: 0,
            fixed_tokens: 0,
            fixed_slot_sweeps: 0,
            poll: Duration::from_secs_f64(
                (cfg.stall_timeout_secs / 4.0).clamp(0.010, 0.050),
            ),
        };
        for w in 0..m {
            source.spawn_seat(w)?;
        }
        Ok(source)
    }

    fn shared(&self) -> Result<ServeShared> {
        let tx = self.tx.clone().ok_or_else(|| {
            anyhow!(
                "serve queue already torn down while (re)spawning a seat — \
                 finish() ran before supervision stopped"
            )
        })?;
        Ok(ServeShared {
            base: SeatShared {
                tx,
                pslot: self.slot.clone(),
                stop: self.stop.clone(),
                ledger: self.ledger.clone(),
                ctl: self.ctl.clone(),
                fault_fired: self.fault_fired.clone(),
                retry_count: self.retry_count.clone(),
            },
            telemetry: self.telemetry.clone(),
            done: self.done.clone(),
        })
    }

    /// (Re)spawn serving seat `w`. A replacement rebuilds its session
    /// schedule from the trainer-accepted delivered set: already-trained
    /// turns are skipped, lost in-flight turns regenerate.
    fn spawn_seat(&mut self, w: usize) -> Result<()> {
        let ctx = self.ctx.clone();
        let sh = self.shared()?;
        let exit_tx = self.exit_tx.clone();
        let incarnation = self.incarnations[w];
        let skip = self.accounts.delivered.clone();
        beat(&self.ctl[w], self.ctx.base.origin);
        let handle = std::thread::Builder::new()
            .name(format!("gen-worker-{w}"))
            .spawn(move || {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    seat_serve(&ctx, &sh, w, incarnation, skip)
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow!("panicked: {}", panic_message(p.as_ref())))
                });
                let _ = exit_tx.send(WorkerExit { slot: w, outcome });
            })
            .map_err(|e| anyhow!("spawn gen-worker-{w}: {e}"))?;
        self.seats[w] = Some(handle);
        Ok(())
    }

    /// Reap exits and heartbeat the watchdog — the [`WorkerPool`] loop
    /// with "partition served" as the legitimate clean-exit reason.
    fn supervise(&mut self) -> Result<()> {
        while let Ok(exit) = self.exit_rx.try_recv() {
            let w = exit.slot;
            if let Some(h) = self.seats[w].take() {
                let _ = h.join();
            }
            match exit.outcome {
                Ok((secs, rounds)) => {
                    self.totals[w].0 += secs;
                    self.totals[w].1 += rounds;
                    let served = self.done[w].load(Ordering::SeqCst);
                    if !self.stop.load(Ordering::SeqCst) && !served {
                        self.handle_death(
                            w,
                            anyhow!("exited cleanly mid-serve (queue closed?)"),
                        )?;
                    }
                }
                Err(e) => self.handle_death(w, e)?,
            }
        }
        let now_ms = self.ctx.base.origin.elapsed().as_millis() as u64;
        for w in 0..self.seats.len() {
            if self.seats[w].is_none() || self.done[w].load(Ordering::SeqCst) {
                self.stalled_now[w] = false;
                continue;
            }
            let age = now_ms
                .saturating_sub(self.ctl[w].beat_ms.load(Ordering::SeqCst));
            let stalled = age as f64 / 1000.0 > self.ctx.base.stall_timeout;
            if stalled && !self.stalled_now[w] {
                self.stalled_now[w] = true;
                self.ever_stalled[w] = true;
                eprintln!(
                    "[supervisor] gen-worker-{w} silent for {:.1}s \
                     (--stall-timeout-secs {:.1}) — flagged as stalled",
                    age as f64 / 1000.0,
                    self.ctx.base.stall_timeout
                );
            } else if !stalled && self.stalled_now[w] {
                self.stalled_now[w] = false;
                eprintln!("[supervisor] gen-worker-{w} resumed heartbeats");
            }
        }
        Ok(())
    }

    /// Absorb queued rounds into the accounts before computing a respawn
    /// skip set — a round in the queue at seat death is not yet
    /// delivered, and a replacement spawned without it would regenerate
    /// it into a duplicate.
    fn drain_queue(&mut self) -> Result<()> {
        while let Ok(msg) = self.rx.try_recv() {
            if let Accept::Fresh = self.accounts.accept(&msg)? {
                self.pending.push_back(msg);
            }
        }
        Ok(())
    }

    /// Sessions in `w`'s partition with undelivered turns — the loud
    /// failure payload.
    fn incomplete_sessions(&self, w: usize) -> Vec<u64> {
        (w as u64..self.ctx.sessions)
            .step_by(self.ctx.workers as usize)
            .filter(|&s| {
                (0..self.ctx.turns).any(|t| {
                    !self
                        .accounts
                        .delivered
                        .contains(&turn_uid(s, t, self.ctx.turns))
                })
            })
            .collect()
    }

    fn handle_death(&mut self, w: usize, err: anyhow::Error) -> Result<()> {
        self.drain_queue()?;
        self.worker_errors.push(format!("gen-worker-{w}: {err:#}"));
        if self.restarts_used[w] < self.ctx.base.max_restarts {
            self.restarts_used[w] += 1;
            self.worker_restarts += 1;
            self.incarnations[w] += 1;
            eprintln!(
                "[supervisor] gen-worker-{w} died: {err:#}; respawning on a \
                 fresh engine (restart {}/{}) — resuming its sessions past \
                 the delivered turns",
                self.restarts_used[w], self.ctx.base.max_restarts
            );
            return self.spawn_seat(w);
        }
        // sessions never migrate: their turn chains live in the dead
        // seat's traffic partition, so the run fails naming them rather
        // than silently dropping their remaining turns
        bail!(
            "gen-worker-{w} is unrecoverable after {} restarts: {err:#}; \
             serving sessions {:?} cannot complete their turns",
            self.ctx.base.max_restarts,
            self.incomplete_sessions(w)
        );
    }

    fn deliver(
        &mut self,
        msg: GenMsg,
        timeline: &mut Timeline,
        t_wait: f64,
    ) -> SourcedRound {
        let t_got = timeline.origin().elapsed().as_secs_f64();
        timeline.push_span(Phase::Idle, t_wait, t_got);
        timeline.push_span(
            Phase::Generate,
            msg.round.gen_span.0,
            msg.round.gen_span.1,
        );
        self.received += 1;
        // round-tier counterfactual: a fixed round holds every slot for
        // its slowest row's sweeps
        self.fixed_tokens += msg
            .round
            .gen
            .resp_mask
            .iter()
            .map(|row| row.iter().filter(|&&m| m == 1.0).count() as u64)
            .sum::<u64>();
        self.fixed_slot_sweeps += msg.round.gen.steps as u64 * self.gen_bs;
        SourcedRound { round: msg.round, staged: None }
    }
}

impl RoundSource for SessionSource {
    fn label(&self) -> &'static str {
        "serve"
    }

    fn next(&mut self, cx: TrainerCx<'_>) -> Result<SourcedRound> {
        let TrainerCx { timeline, .. } = cx;
        let t_wait = timeline.origin().elapsed().as_secs_f64();
        loop {
            if let Some(msg) = self.pending.pop_front() {
                return Ok(self.deliver(msg, timeline, t_wait));
            }
            self.supervise()?;
            match self.rx.recv_timeout(self.poll) {
                Ok(msg) => match self.accounts.accept(&msg)? {
                    Accept::Fresh => {
                        return Ok(self.deliver(msg, timeline, t_wait))
                    }
                    Accept::Duplicate => continue,
                },
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                    "served round queue disconnected while the source holds \
                     a sender — this is a bug"
                ),
            }
        }
    }

    fn episodes(&self) -> u64 {
        self.received * self.gen_bs
    }

    fn publish(&mut self, cx: TrainerCx<'_>) -> Result<()> {
        let TrainerCx { engine, state, version, timeline } = cx;
        timeline.record(Phase::Publish, || -> Result<()> {
            let host = state.params_host(engine)?;
            self.slot.publish(version, Arc::from(host));
            Ok(())
        })
    }

    fn snapshot(&self) -> Option<SourceState> {
        // serve runs are bounded by their traffic trace, not resumable
        // from a mid-trace cursor; config validation rejects
        // --checkpoint-every in serve mode
        None
    }

    fn finish(self: Box<Self>, log: &mut RunLog) -> Result<()> {
        let mut src = *self;
        src.stop.store(true, Ordering::SeqCst);
        drop(src.tx.take());
        drop(src.rx);
        for seat in src.seats.iter_mut() {
            if let Some(h) = seat.take() {
                let _ = h.join();
            }
        }
        while let Ok(exit) = src.exit_rx.try_recv() {
            match exit.outcome {
                Ok((secs, rounds)) => {
                    src.totals[exit.slot].0 += secs;
                    src.totals[exit.slot].1 += rounds;
                }
                Err(e) => src
                    .worker_errors
                    .push(format!("gen-worker-{}: {e:#}", exit.slot)),
            }
        }
        let mut gen_total = 0.0f64;
        let mut rounds_total = 0u64;
        for (w, (secs, rounds)) in src.totals.iter().enumerate() {
            log.set_meta(&format!("gen_secs_w{w}"), format!("{secs:.3}"));
            log.set_meta(&format!("gen_rounds_w{w}"), rounds);
            gen_total += secs;
            rounds_total += rounds;
        }
        log.set_meta("gen_total_secs", format!("{gen_total:.3}"));
        log.set_meta("gen_rounds", rounds_total);
        log.set_meta("worker_restarts", src.worker_restarts);
        log.set_meta(
            "stalled_workers",
            src.ever_stalled.iter().filter(|&&b| b).count(),
        );
        log.set_meta("engine_retries", src.retry_count.load(Ordering::SeqCst));
        log.set_meta("dropped_duplicate_rounds", src.accounts.duplicates);
        if !src.worker_errors.is_empty() {
            log.set_meta("worker_errors", src.worker_errors.join(" | "));
        }
        // serving telemetry: latency percentiles, staleness lags,
        // occupancy vs the fixed-round counterfactual
        let mut t = std::mem::take(
            &mut *src.telemetry.lock().unwrap_or_else(PoisonError::into_inner),
        );
        log.set_meta("serve_sessions", src.ctx.sessions);
        log.set_meta("serve_turns", src.ctx.turns);
        log.set_meta("serve_requests", t.requests);
        log.set_meta("serve_tokens", t.tokens);
        log.set_meta("serve_mux_sweeps", t.mux_sweeps);
        log.set_meta(
            "serve_ttft_p50",
            format!("{:.3}", pct(&mut t.ttft, 0.50)),
        );
        log.set_meta(
            "serve_ttft_p99",
            format!("{:.3}", pct(&mut t.ttft, 0.99)),
        );
        log.set_meta(
            "serve_retire_p50",
            format!("{:.3}", pct(&mut t.retire, 0.50)),
        );
        log.set_meta(
            "serve_retire_p99",
            format!("{:.3}", pct(&mut t.retire, 0.99)),
        );
        log.set_meta("serve_lag_p50", format!("{:.3}", pct(&mut t.lag, 0.50)));
        log.set_meta("serve_lag_p99", format!("{:.3}", pct(&mut t.lag, 0.99)));
        log.set_meta(
            "serve_lag_max",
            t.lag.iter().copied().max().unwrap_or(0),
        );
        log.set_meta(
            "serve_occupancy",
            format!(
                "{:.4}",
                t.tokens as f64 / t.slot_sweeps.max(1) as f64
            ),
        );
        log.set_meta(
            "serve_occupancy_round_tier",
            format!(
                "{:.4}",
                src.fixed_tokens as f64 / src.fixed_slot_sweeps.max(1) as f64
            ),
        );
        Ok(())
    }
}

/// Body of one serving seat: drive the [`ServeMux`] one sweep at a time
/// — traffic clock, admission, decode, retirement routing — re-reading
/// the published policy slot between sweeps (the inflight weight swap,
/// exactly as [`seat_continuous`]), pushing latency/lag samples into the
/// shared telemetry, assembling completed turns into training rounds,
/// and retiring itself once its session partition is fully served.
fn seat_serve(
    ctx: &ServeCtx,
    sh: &ServeShared,
    w: usize,
    incarnation: u64,
    skip: HashSet<u64>,
) -> Result<(f64, u64)> {
    let base = &ctx.base;
    let sb = &sh.base;
    let engine = Engine::load(&base.artifact_dir)?;
    let taskgen =
        TaskGen::new(base.task, base.prompt_len, base.resp_len, base.seed);
    let stream = w as u64 + (incarnation << 20);
    let mut rng = Pcg32::new(base.seed, 0xa57c + stream);
    let mut retry_rng = Pcg32::new(base.seed, RETRY_STREAM + stream);
    let policy = RetryPolicy::new(base.retries);
    let mcfg = engine.manifest.config.clone();
    let mut backend = DeviceBackend::new(&engine)?;
    let traffic = TrafficGen::new(TrafficCfg {
        sessions: ctx.sessions,
        turns: ctx.turns,
        arrival_rate: ctx.arrival_rate,
        seed: base.seed,
    });
    let board =
        SessionBoard::new(&traffic, base.k, w as u64, ctx.workers, &skip)?;
    let mut mux = ServeMux::new(
        PoolCfg {
            slots: mcfg.gen_batch,
            prompt_len: mcfg.prompt_len,
            seq_len: mcfg.seq_len,
            vocab: mcfg.vocab,
            max_cohorts: base.max_cohorts,
            admit_min: base.admit_min,
        },
        board,
    );
    let mut assembler = RoundAssembler::new(mcfg.gen_batch, base.k);
    let (mut version, mut params) = sb.pslot.latest();
    let mut gen_total = 0.0f64;
    let mut rounds_done = 0u64;
    let mut inject_err = false;
    let mut t_round = base.origin.elapsed().as_secs_f64();
    loop {
        beat(&sb.ctl[w], base.origin);
        if sb.stop.load(Ordering::SeqCst) {
            break;
        }
        if mux.is_done() && assembler.buffered() == 0 {
            // partition fully served and every round handed over
            sh.done[w].store(true, Ordering::SeqCst);
            break;
        }
        if let Some((v, p)) = sb.pslot.fetch(version) {
            version = v;
            params = p;
        }
        maybe_inject(base, sb, w, rounds_done, &mut inject_err);
        let events = policy.run(
            &mut retry_rng,
            |_| {
                sb.retry_count.fetch_add(1, Ordering::SeqCst);
                engine.note_retry(ROUND_ORIGIN);
            },
            |attempt| {
                if inject_err && attempt == 0 {
                    bail!(
                        "injected fault: scripted engine error in \
                         gen-worker-{w}"
                    );
                }
                mux.step(
                    &mut backend,
                    &taskgen,
                    ParamView::cached("policy", version, &params),
                    version,
                    base.opts,
                    &mut rng,
                )
            },
        )?;
        inject_err = false;
        if !events.is_empty() {
            let mut t =
                sh.telemetry.lock().unwrap_or_else(PoisonError::into_inner);
            for (c, ev) in &events {
                t.ttft.push(ev.ttft);
                t.retire.push(ev.retire);
                t.lag.push(version.saturating_sub(c.version_min));
                if ev.turn_done {
                    t.requests += 1;
                }
            }
        }
        for (c, _) in events {
            assembler.push(c);
        }
        while let Some(groups) = assembler.pop_round() {
            let uids: Vec<u64> = groups.iter().map(|(i, _)| *i).collect();
            let t_now = base.origin.elapsed().as_secs_f64();
            let round = round_from_groups(groups, &taskgen, (t_round, t_now));
            gen_total += t_now - t_round;
            rounds_done += 1;
            beat(&sb.ctl[w], base.origin);
            if sb
                .tx
                .send(GenMsg { round, lane: w, indices: Some(uids) })
                .is_err()
            {
                flush_serve_stats(
                    &sh.telemetry,
                    mux.stats(),
                    mcfg.gen_batch,
                    mux.sweep(),
                );
                return Ok((gen_total, rounds_done));
            }
            t_round = base.origin.elapsed().as_secs_f64();
        }
    }
    flush_serve_stats(&sh.telemetry, mux.stats(), mcfg.gen_batch, mux.sweep());
    Ok((gen_total, rounds_done))
}

#[cfg(test)]
mod tests {
    use std::collections::VecDeque;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    use super::super::trainer::{staleness, Round};
    use super::{
        cursor_stride, lane_next, pick_lane, round_from_groups,
        staleness_bound_updates, Accept, Completed, GenMsg, LaneAccounts,
        ParamSlot, SessionAccounts,
    };
    use crate::data::{Task, TaskGen};
    use crate::gen::GenBatch;
    use crate::serve::traffic::turn_uid;

    #[test]
    fn continuous_round_aggregates_token_version_provenance() {
        let tg = TaskGen::new(Task::Tldr, 8, 4, 1);
        let mk = |index: u64, dup: usize, vmin: u64, vmax: u64, sum: f64| {
            Completed {
                index,
                dup,
                tokens: vec![0; 12],
                resp_mask: vec![0.0; 12],
                blp: vec![0.0; 12],
                terminated: true,
                steps: 2,
                version_min: vmin,
                version_max: vmax,
                version_sum: sum,
            }
        };
        // two prompt groups of k=2, tokens spanning versions 0..=4
        let groups = vec![
            (5u64, vec![mk(5, 0, 0, 2, 2.0), mk(5, 1, 1, 3, 4.0)]),
            (9u64, vec![mk(9, 0, 2, 4, 6.0), mk(9, 1, 2, 2, 4.0)]),
        ];
        let round = round_from_groups(groups, &tg, (1.0, 3.5));
        // per-round anchor = NEWEST token version (freshest data age);
        // per-token fields carry the oldest and the mean
        assert_eq!(round.params_version, 4);
        assert_eq!(round.tok_version_min, 0);
        let expect_mean = (2.0 + 4.0 + 6.0 + 4.0) / 8.0;
        assert!((round.tok_version_mean - expect_mean).abs() < 1e-12);
        assert_eq!(round.start_index, 5);
        assert_eq!(round.gen.tokens.len(), 4, "k rows per prompt group");
        assert_eq!(round.examples.len(), 2, "one example per prompt");
        assert_eq!(round.examples[1].prompt, tg.example(9).prompt);
        assert_eq!(round.gen.steps, 2);
        assert!((round.gen_secs - 2.5).abs() < 1e-12);
    }

    #[test]
    fn param_slot_is_latest_wins() {
        let slot = ParamSlot::new(0, Arc::from(&[0.0f32][..]));
        assert!(slot.fetch(0).is_none(), "nothing newer than the seed");
        for v in 1..=5u64 {
            slot.publish(v, Arc::from(&[v as f32][..]));
        }
        // a reader at version 0 sees only the freshest publication
        let (v, p) = slot.fetch(0).expect("new version visible");
        assert_eq!(v, 5);
        assert_eq!(&p[..], &[5.0]);
        // and nothing newer than what it now has
        assert!(slot.fetch(5).is_none());
    }

    #[test]
    fn param_slot_survives_a_panicked_lock_holder() {
        // a supervised worker that dies while holding the slot lock
        // poisons the mutex; the slot must keep serving (the critical
        // sections are pure pointer swaps, never half-written)
        let slot = Arc::new(ParamSlot::new(0, Arc::from(&[0.0f32][..])));
        let s2 = slot.clone();
        let _ = std::thread::spawn(move || {
            let _guard = s2.latest.lock().unwrap();
            panic!("die holding the param slot lock");
        })
        .join();
        assert!(slot.latest.is_poisoned(), "test setup must poison the lock");
        slot.publish(3, Arc::from(&[3.0f32][..]));
        let (v, p) = slot.fetch(0).expect("publish visible despite poison");
        assert_eq!((v, &p[..]), (3, &[3.0f32][..]));
        assert_eq!(slot.latest().0, 3);
    }

    #[test]
    fn pick_lane_prefers_the_lane_furthest_behind() {
        let ledger: Vec<AtomicU64> =
            [30u64, 10, 20].into_iter().map(AtomicU64::new).collect();
        // owning all three lanes: the lowest cursor wins
        assert_eq!(pick_lane(0b111, &ledger).unwrap(), 1);
        // ownership masks restrict the choice
        assert_eq!(pick_lane(0b101, &ledger).unwrap(), 2);
        assert_eq!(pick_lane(0b001, &ledger).unwrap(), 0);
        // ties go to the lowest lane
        ledger[2].store(10, std::sync::atomic::Ordering::SeqCst);
        assert_eq!(pick_lane(0b110, &ledger).unwrap(), 1);
        // an empty mask is a supervision bug, surfaced as an error rather
        // than a panic on the worker seat
        assert!(pick_lane(0, &ledger).is_err());
    }

    #[test]
    fn lane_next_walks_blocks_and_hops() {
        // lane at start 100, blocks of 3, hop 12:
        // 100 101 102 | 112 113 114 | 124 ...
        assert_eq!(lane_next(100, 100, 3, 12), 101);
        assert_eq!(lane_next(101, 100, 3, 12), 102);
        assert_eq!(lane_next(102, 100, 3, 12), 112);
        assert_eq!(lane_next(114, 100, 3, 12), 124);
        // stride 1 (degenerate geometry): every step is a hop
        assert_eq!(lane_next(100, 100, 1, 2), 102);
    }

    #[test]
    fn lane_accounts_block_mode_dedupes_and_detects_holes() {
        // two lanes, stride 4, hop 8: lane 0 blocks 0,8,16…, lane 1
        // blocks 4,12,20…
        let mut a = LaneAccounts::new(vec![0, 4], 4, 8);
        assert!(matches!(a.accept_block(0, 0).unwrap(), Accept::Fresh));
        assert!(matches!(a.accept_block(1, 4).unwrap(), Accept::Fresh));
        // a respawned worker replaying its last handed-over block
        assert!(matches!(a.accept_block(0, 0).unwrap(), Accept::Duplicate));
        assert_eq!(a.duplicates, 1);
        assert!(matches!(a.accept_block(0, 8).unwrap(), Accept::Fresh));
        // a skipped block can only mean a lost round: loud failure
        let err = a.accept_block(1, 20).unwrap_err().to_string();
        assert!(err.contains("lane 1"), "{err}");
        assert!(err.contains("12"), "names the expected index: {err}");
    }

    #[test]
    fn lane_accounts_continuous_mode_advances_frontier_out_of_order() {
        // one lane at start 0, stride 4, hop 4 (M=1): indices 0,1,2,3,4…
        let mut a = LaneAccounts::new(vec![0], 4, 4);
        // a round retires {1, 3} first (continuous retirement is
        // completion-ordered): frontier stays at 0
        assert!(matches!(a.accept_indices(0, &[1, 3]).unwrap(), Accept::Fresh));
        assert_eq!(a.expected[0], 0);
        assert_eq!(a.delivered[0].len(), 2);
        // {0, 2} closes the gap: frontier sweeps to 4, sets drain
        assert!(matches!(a.accept_indices(0, &[0, 2]).unwrap(), Accept::Fresh));
        assert_eq!(a.expected[0], 4);
        assert!(a.delivered[0].is_empty(), "frontier absorbed the set");
        // full replay is dropped …
        assert!(matches!(
            a.accept_indices(0, &[1, 3]).unwrap(),
            Accept::Duplicate
        ));
        // … but a mixed round means the respawn skip set was wrong
        assert!(a.accept_indices(0, &[3, 4]).is_err());
    }

    #[test]
    fn param_slot_fetch_is_cheap_pointer_clone() {
        let big: Arc<[f32]> = Arc::from(vec![1.0f32; 1024].into_boxed_slice());
        let slot = ParamSlot::new(1, big.clone());
        let (_, p) = slot.fetch(0).unwrap();
        assert!(Arc::ptr_eq(&p, &big), "fetch must share, not copy");
    }

    /// A served round carrying only the fields [`SessionAccounts`] reads.
    fn serve_msg(uids: &[u64]) -> GenMsg {
        GenMsg {
            round: Round {
                gen: GenBatch {
                    tokens: vec![],
                    resp_mask: vec![],
                    blp: vec![],
                    terminated: vec![],
                    steps: 0,
                },
                examples: vec![],
                start_index: 0,
                params_version: 0,
                tok_version_min: 0,
                tok_version_mean: 0.0,
                gen_secs: 0.0,
                gen_span: (0.0, 0.0),
            },
            lane: 0,
            indices: Some(uids.to_vec()),
        }
    }

    #[test]
    fn serving_accounts_dedupe_replayed_rounds() {
        let turns = 2u64;
        let mut a = SessionAccounts::new(turns);
        let r0: Vec<u64> =
            (0..4).map(|s| turn_uid(s, 0, turns)).collect();
        assert!(matches!(a.accept(&serve_msg(&r0)).unwrap(), Accept::Fresh));
        // a respawned seat replaying the same turns: dropped, counted
        assert!(matches!(
            a.accept(&serve_msg(&r0)).unwrap(),
            Accept::Duplicate
        ));
        assert_eq!(a.duplicates, 1);
        // the next turn of each session is fresh again
        let r1: Vec<u64> =
            (0..4).map(|s| turn_uid(s, 1, turns)).collect();
        assert!(matches!(a.accept(&serve_msg(&r1)).unwrap(), Accept::Fresh));
    }

    #[test]
    fn serving_accounts_reject_mixed_and_missing_uids() {
        let turns = 2u64;
        let mut a = SessionAccounts::new(turns);
        let r0: Vec<u64> =
            (0..4).map(|s| turn_uid(s, 0, turns)).collect();
        a.accept(&serve_msg(&r0)).unwrap();
        // half replayed, half fresh: the respawn skip set missed a
        // delivery — loud failure naming the sessions
        let mixed =
            vec![turn_uid(0, 0, turns), turn_uid(4, 0, turns)];
        let err = a.accept(&serve_msg(&mixed)).unwrap_err().to_string();
        assert!(err.contains("mixes"), "{err}");
        assert!(err.contains("skip set"), "{err}");
        // a served round must carry session uids at all
        let mut no_uids = serve_msg(&[]);
        no_uids.indices = None;
        assert!(a.accept(&no_uids).is_err());
    }

    #[test]
    fn serving_accounts_fail_loudly_on_a_dropped_turn() {
        let turns = 3u64;
        let mut a = SessionAccounts::new(turns);
        // turn 1 of session 2 arriving before its turn 0 means the board
        // dropped a turn: the session-order invariant is violated
        let err = a
            .accept(&serve_msg(&[turn_uid(2, 1, turns)]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("session 2"), "{err}");
        assert!(err.contains("turn 1"), "{err}");
        // consecutive turns of one session inside one round are legal
        // (in-message predecessors count as delivered)
        let chain =
            vec![turn_uid(0, 0, turns), turn_uid(0, 1, turns)];
        assert!(matches!(
            a.accept(&serve_msg(&chain)).unwrap(),
            Accept::Fresh
        ));
    }

    #[test]
    fn cursor_never_freezes_when_k_exceeds_gen_batch() {
        // normal geometries: one round consumes gen_batch/k prompts
        assert_eq!(cursor_stride(8, 2), 4);
        assert_eq!(cursor_stride(4, 4), 1);
        // regression: the seed async worker advanced by gen_bs / k
        // WITHOUT the guard, so k > gen_batch froze the cursor and
        // replayed the same prompts forever
        assert_eq!(cursor_stride(2, 4), 1);
        let mut cursor = 0u64;
        for _ in 0..10 {
            cursor += cursor_stride(2, 4);
        }
        assert_eq!(cursor, 10, "cursor must be strictly monotone");
    }

    /// Discrete worst-case model of the K-bounded queue with one worker
    /// and *instantaneous* generation: the worker fills the queue (K
    /// rounds) plus one blocked `send`, fetching the freshest publish
    /// before each round. Per-step staleness must never exceed
    /// `staleness_bound_updates(K, 1, T) = (K + 2)·T − 1`, and the bound
    /// is tight (instant generation reaches it).
    #[test]
    fn bounded_queue_model_staleness_is_tight_at_bound() {
        for k_bound in 0..5usize {
            for t in 1..4u64 {
                let mut queue: VecDeque<u64> = VecDeque::new();
                let mut blocked: Option<u64> = None;
                let mut published = 0u64;
                let mut version = 0u64;
                let mut max_seen = 0u64;
                let refill = |queue: &mut VecDeque<u64>,
                              blocked: &mut Option<u64>,
                              published: u64| {
                    while queue.len() < k_bound {
                        queue.push_back(published);
                    }
                    if blocked.is_none() {
                        *blocked = Some(published);
                    }
                };
                refill(&mut queue, &mut blocked, published);
                for _ in 0..50 {
                    // trainer pops one round; a blocked send slides in
                    let data = match queue.pop_front() {
                        Some(front) => {
                            if let Some(b) = blocked.take() {
                                queue.push_back(b);
                            }
                            front
                        }
                        None => blocked.take().expect("rendezvous handover"),
                    };
                    // worker runs ahead again before this step publishes
                    refill(&mut queue, &mut blocked, published);
                    version += t;
                    published = version;
                    let st = staleness(version, data);
                    let bound = staleness_bound_updates(k_bound, 1, t as usize);
                    assert!(
                        st <= bound,
                        "K={k_bound} T={t}: staleness {st} > bound {bound}"
                    );
                    max_seen = max_seen.max(st);
                }
                assert_eq!(
                    max_seen,
                    staleness_bound_updates(k_bound, 1, t as usize),
                    "K={k_bound} T={t}: bound should be tight under \
                     instantaneous generation"
                );
            }
        }
    }

    #[test]
    fn staleness_bound_reduces_to_the_documented_invariants() {
        // queue depth K, one worker, T=1: staleness <= K + 1 policy
        // versions — K=0 is the seed coordinator's one-step bound
        assert_eq!(staleness_bound_updates(0, 1, 1), 1);
        assert_eq!(staleness_bound_updates(1, 1, 1), 2);
        assert_eq!(staleness_bound_updates(4, 1, 1), 5);
        // M workers add one in-flight round each
        assert_eq!(staleness_bound_updates(0, 2, 1), 2);
        assert_eq!(staleness_bound_updates(2, 2, 1), 4);
        // T updates per batch scale every version distance
        assert_eq!(staleness_bound_updates(0, 1, 3), 5);
    }
}
