//! Shared RLHF round machinery used by both the synchronous and the
//! asynchronous coordinators: prompt scheduling, reward labelling (proxy RM
//! or rule-based), reference-policy logprobs, and algorithm-specific train
//! batch assembly against the fused train-step executables.

use anyhow::{bail, Result};

use crate::config::{Algo, ExpConfig};
use crate::data::{Example, Task, TaskGen};
use crate::gen::{GenBatch, GenBuffers, Generator, SampleOpts};
use crate::reward::{gold, valid_mask};
use crate::runtime::{
    CallArg, DeviceBuffer, Engine, HostTensor, ParamView, TrainState,
};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

/// Stats origin for the once-per-round token/mask uploads of the resident
/// labelling path — one bucket so `CallStats` shows exactly how many bytes
/// a round costs to stage (the acceptance counter for "upload once").
pub const ROUND_ORIGIN: &str = "round";

/// One generation round: `gen_batch` completions plus provenance.
pub struct Round {
    pub gen: GenBatch,
    pub examples: Vec<Example>,
    /// Index of the first prompt of this round in the task stream.
    pub start_index: u64,
    /// Policy version that generated this round (staleness accounting).
    /// For the continuous engine's streamed rounds — whose sequences may
    /// mix tokens from several versions as weights swap mid-flight —
    /// this is the NEWEST version any token sampled under, keeping the
    /// per-round [`staleness`] bound's "freshest data age" meaning.
    pub params_version: u64,
    /// Oldest policy version any token of this round sampled under.
    /// Equals `params_version` for round-synchronous engines (one
    /// version generates the whole round); older under the continuous
    /// engine's between-step policy swaps.
    pub tok_version_min: u64,
    /// Response-token-weighted mean of per-token behaviour versions
    /// (== `params_version` for round-synchronous engines).
    pub tok_version_mean: f64,
    /// Wall-clock seconds spent generating (gen thread's measurement).
    pub gen_secs: f64,
    /// Span of generation relative to the shared timeline origin.
    pub gen_span: (f64, f64),
}

/// A round's tensors staged on the device ONCE and shared (as
/// `CallArg::Device` inputs) across reference logprobs (`logprob_dev`),
/// proxy-RM scoring (`score_rm`) and train-batch assembly — PPO reads the
/// buffers in round layout, the pairwise family permutes them through the
/// `gather_pairs` executable. The seed path uploaded the same `[B*S]`
/// token tensor three separate times per round (label, score, train);
/// this uploads it exactly once, under the [`ROUND_ORIGIN`] stats bucket
/// — or **zero** times when the fused generate's output buffers are
/// chained in (sync mode, see [`GenBuffers`]).
///
/// Device buffers belong to the engine that created them: a
/// `ResidentRound` is built by the labelling/training engine (the trainer
/// thread's own) and must only be used with it. A cross-scale RM engine
/// (Fig 5) cannot read these buffers — scoring falls back to the host
/// path in that case.
pub struct ResidentRound {
    /// Flattened `[B*S]` round tokens (i32).
    pub tokens: DeviceBuffer,
    /// Flattened `[B*S]` response mask — the logprob / PPO-train mask.
    pub resp_mask: DeviceBuffer,
    /// Whole-sequence validity mask for RM scoring (prompt + response,
    /// see [`crate::reward::valid_mask`]); `None` when the round's reward
    /// does not come from a same-engine RM.
    pub rm_mask: Option<DeviceBuffer>,
    /// Flattened `[B*S]` behaviour logprobs. Staged (one upload) only
    /// when the algorithm's train batch consumes them (PPO / RLOO
    /// family, [`algo_stages_blp`]); chained for free from the fused
    /// generate's buffers in sync mode regardless.
    pub blp: Option<DeviceBuffer>,
    /// Reference token logprobs `[B*S]` — `logprob_dev`'s second output,
    /// captured during labelling (zero upload; `None` until the round is
    /// labelled or when labelling took the host-literal path).
    pub rlp_tok: Option<DeviceBuffer>,
    /// Reference sequence logprobs `[B]` — `logprob_dev`'s first output,
    /// captured during labelling (DPO's reference margins).
    pub rlp_seq: Option<DeviceBuffer>,
}

impl ResidentRound {
    /// Stage a round's tensors: chain the fused generate's still-resident
    /// buffers when `staged` is given (zero uploads — sync mode),
    /// otherwise flatten and upload. `with_rm_mask` additionally stages
    /// the RM validity mask (derived from `resp_mask` on the host — it is
    /// a different tensor, so it is always its own upload); `with_blp`
    /// stages the behaviour logprobs on the upload path (see the field
    /// doc — the chained path carries them for free).
    pub fn upload(
        engine: &Engine,
        gen: &GenBatch,
        staged: Option<&GenBuffers>,
        prompt_len: usize,
        with_rm_mask: bool,
        with_blp: bool,
        scratch: &mut LabelScratch,
    ) -> Result<ResidentRound> {
        let (tokens, resp_mask, blp) = match staged {
            Some(gb) => (
                gb.tokens.clone(),
                gb.resp_mask.clone(),
                Some(gb.blp.clone()),
            ),
            None => {
                gen.flatten_into(&mut scratch.toks, &mut scratch.mask);
                // logprob's input specs 1/2 carry the [B, S] shapes shared
                // by every consumer (score_rm, gather_pairs, train_ppo) of
                // these buffers
                let tokens = engine.upload_arg_as(
                    ROUND_ORIGIN,
                    "logprob",
                    1,
                    &CallArg::I32(&scratch.toks),
                )?;
                let resp_mask = engine.upload_arg_as(
                    ROUND_ORIGIN,
                    "logprob",
                    2,
                    &CallArg::F32(&scratch.mask),
                )?;
                let blp = if with_blp {
                    scratch.mask.clear();
                    for row in &gen.blp {
                        scratch.mask.extend_from_slice(row);
                    }
                    Some(engine.upload_arg_as(
                        ROUND_ORIGIN,
                        "logprob",
                        2,
                        &CallArg::F32(&scratch.mask),
                    )?)
                } else {
                    None
                };
                (tokens, resp_mask, blp)
            }
        };
        let rm_mask = if with_rm_mask {
            scratch.mask.clear();
            for m in &gen.resp_mask {
                scratch.mask.extend(valid_mask(prompt_len, m));
            }
            Some(engine.upload_arg_as(
                ROUND_ORIGIN,
                "score_rm",
                2,
                &CallArg::F32(&scratch.mask),
            )?)
        } else {
            None
        };
        Ok(ResidentRound {
            tokens,
            resp_mask,
            rm_mask,
            blp,
            rlp_tok: None,
            rlp_seq: None,
        })
    }
}

/// Whether `algo`'s train batch consumes per-token behaviour logprobs —
/// the only algorithms worth paying a `[B*S]` blp staging upload for on
/// the async path (sync rounds chain the buffer for free; DPO and
/// Best-of-N never read blp).
pub fn algo_stages_blp(algo: Algo) -> bool {
    matches!(algo, Algo::Ppo | Algo::Rloo | Algo::Prloo | Algo::Copg)
}

/// Stage a round for the resident labelling path when the bundle supports
/// it (`logprob_dev` present) AND the PJRT client has been observed to
/// untuple (under the root-tuple fallback, `execute_buffers` would move
/// MORE bytes than the seed literal path — so fall back to it). `None`
/// means host-literal labelling; with the default fused generator or any
/// train step already run, the capability is known by the first label.
/// The RM mask is staged only when the reward actually comes from a
/// same-engine RM (rule-reward tasks and cross-engine RMs score on their
/// own path).
pub fn make_resident(
    engine: &Engine,
    gen: &GenBatch,
    staged: Option<&GenBuffers>,
    rm: Option<(&Engine, &[f32])>,
    gold_reward: bool,
    with_blp: bool,
    scratch: &mut LabelScratch,
) -> Result<Option<ResidentRound>> {
    if !engine.buffer_path_ready("logprob_dev") {
        return Ok(None);
    }
    let cfg = &engine.manifest.config;
    let rule_reward = Task::from_name(&cfg.task)
        .is_some_and(|t| uses_rule_reward(t, gold_reward));
    let with_rm_mask = !rule_reward
        && rm.is_some_and(|(rm_engine, _)| {
            std::ptr::eq(rm_engine as *const Engine, engine as *const Engine)
        });
    ResidentRound::upload(
        engine,
        gen,
        staged,
        cfg.prompt_len,
        with_rm_mask,
        with_blp,
        scratch,
    )
    .map(Some)
}

/// Rule-reward rounds (the math task, or the gold-reward ablation) never
/// touch the proxy RM; everything else scores with it. The single
/// predicate shared by [`make_resident`]'s staging decision and
/// [`label_round`]'s reward dispatch, so the two cannot drift.
fn uses_rule_reward(task: Task, gold_reward: bool) -> bool {
    task == Task::Math || gold_reward
}

/// A labelled round plus its (optional) device-staged tensors, as consumed
/// by [`assemble`].
pub struct LabelledRound {
    pub round: Round,
    pub labels: Labels,
    pub resident: Option<ResidentRound>,
}

/// A generated round plus (sync mode) the fused generate's still-resident
/// output buffers, as handed from a [`crate::coordinator::pipeline::RoundSource`]
/// to the trainer loop. Async rounds cross the worker→trainer thread
/// boundary as plain host data, so `staged` is `None` there — that one
/// re-upload per round is the price of the thread hop.
pub struct SourcedRound {
    pub round: Round,
    pub staged: Option<GenBuffers>,
}

/// Stage (when eligible) and label one round — the coordinators' Score
/// phase. One definition so the sync and async paths cannot drift in
/// staging policy or labelling traffic.
pub fn stage_and_label(
    engine: &Engine,
    sr: &SourcedRound,
    ref_params: &[f32],
    rm: Option<(&Engine, &[f32])>,
    cfg: &ExpConfig,
    scratch: &mut LabelScratch,
) -> Result<(Option<ResidentRound>, Labels)> {
    let mut resident = make_resident(
        engine,
        &sr.round.gen,
        sr.staged.as_ref(),
        rm,
        cfg.gold_reward,
        algo_stages_blp(cfg.algo),
        scratch,
    )?;
    let labels = label_round(
        engine,
        &sr.round,
        ref_params,
        rm,
        cfg.k_samples,
        cfg.eos_penalty,
        cfg.gold_reward,
        scratch,
        resident.as_mut(),
    )?;
    Ok((resident, labels))
}

/// Prompts for round starting at `start`: each distinct prompt is repeated
/// `k` times consecutively (k completions per prompt, paper §4.2).
pub fn round_prompts(
    taskgen: &TaskGen,
    start: u64,
    gen_batch: usize,
    k: usize,
) -> (Vec<Example>, Vec<Vec<i32>>) {
    assert!(gen_batch % k == 0, "gen_batch must be divisible by k");
    let n_prompts = gen_batch / k;
    let examples = taskgen.batch(start, n_prompts);
    let mut prompts = Vec::with_capacity(gen_batch);
    for ex in &examples {
        for _ in 0..k {
            prompts.push(ex.prompt.clone());
        }
    }
    (examples, prompts)
}

/// Generate one round (runs on whichever thread owns the generation
/// engine). `params` is a [`ParamView`]: cached/device views avoid
/// re-uploading the policy unless its version changed.
#[allow(clippy::too_many_arguments)]
pub fn generate_round(
    engine: &Engine,
    generator: &dyn Generator,
    params: ParamView<'_>,
    params_version: u64,
    taskgen: &TaskGen,
    start_index: u64,
    k: usize,
    opts: SampleOpts,
    rng: &mut Pcg32,
    origin: std::time::Instant,
) -> Result<Round> {
    let cfg = &engine.manifest.config;
    let (examples, prompts) = round_prompts(taskgen, start_index, cfg.gen_batch, k);
    let t0 = origin.elapsed().as_secs_f64();
    let gen = generator.generate(engine, params, &prompts, opts, rng)?;
    let t1 = origin.elapsed().as_secs_f64();
    Ok(Round {
        gen,
        examples,
        start_index,
        params_version,
        tok_version_min: params_version,
        tok_version_mean: params_version as f64,
        gen_secs: t1 - t0,
        gen_span: (t0, t1),
    })
}

/// Sync-mode variant of [`generate_round`]: also chains the fused
/// generate's device-resident outputs into the returned [`SourcedRound`]
/// when the engine produced them ([`Generator::generate_staged`]). The
/// buffers belong to `engine`, so only same-thread/same-engine callers
/// (the inline source — `engine` IS the trainer's) may use this; worker
/// threads use [`generate_round`] and ship host data.
#[allow(clippy::too_many_arguments)]
pub fn generate_round_staged(
    engine: &Engine,
    generator: &dyn Generator,
    params: ParamView<'_>,
    params_version: u64,
    taskgen: &TaskGen,
    start_index: u64,
    k: usize,
    opts: SampleOpts,
    rng: &mut Pcg32,
    origin: std::time::Instant,
) -> Result<SourcedRound> {
    let cfg = &engine.manifest.config;
    let (examples, prompts) = round_prompts(taskgen, start_index, cfg.gen_batch, k);
    let t0 = origin.elapsed().as_secs_f64();
    let (gen, staged) =
        generator.generate_staged(engine, params, &prompts, opts, rng)?;
    let t1 = origin.elapsed().as_secs_f64();
    Ok(SourcedRound {
        round: Round {
            gen,
            examples,
            start_index,
            params_version,
            tok_version_min: params_version,
            tok_version_mean: params_version as f64,
            gen_secs: t1 - t0,
            gen_span: (t0, t1),
        },
        staged,
    })
}

/// Labels for one round: rewards (what the optimizer sees), gold scores and
/// wins (what evaluation sees), reference logprobs (KL anchor).
pub struct Labels {
    /// Reward per slot: proxy-RM score (+ EOS penalty) for RM tasks, gold
    /// rule reward for math.
    pub rewards: Vec<f32>,
    /// Gold score per slot (ground truth, for metrics only).
    pub gold_scores: Vec<f32>,
    /// Gold-judged win value vs the dataset reference (1/0.5/0), per slot.
    pub wins: Vec<f32>,
    /// Reference-policy token logprobs, flattened [B*S].
    pub rlp_tok: Vec<f32>,
    /// Reference-policy sequence logprobs [B].
    pub rlp_seq: Vec<f32>,
    /// exp(-mean ref token logprob) over response tokens: the paper's
    /// KL-as-perplexity measurement.
    pub ref_ppl: f32,
    /// Mean behaviour entropy proxy: -mean blp.
    pub mean_blp: f32,
    /// Mean response length (tokens incl. EOS).
    pub mean_len: f32,
}

/// Reusable flattening scratch for per-round labelling: one allocation
/// per run instead of two per round.
#[derive(Default)]
pub struct LabelScratch {
    toks: Vec<i32>,
    mask: Vec<f32>,
}

/// Label a round: score with the proxy RM (or the rule reward for math),
/// judge with gold, compute reference logprobs. Runs on the trainer thread
/// (paper Algorithm 1 places reward + loss on the learner). `rm` is the
/// (engine, params) scorer — possibly a different-scale bundle (Fig 5).
///
/// `ref_params` is frozen for the run, so it lives in the engine's device
/// cache under the `"ref"` key: uploaded on the first round, reused
/// thereafter (the engine's reference params must not change under the
/// same key — every coordinator uses the one SFT checkpoint per run).
///
/// When `resident` is staged (see [`make_resident`]) the round's tensors
/// are NOT re-uploaded here: reference logprobs run through the untupled
/// `logprob_dev` twin and RM scoring through `score_rm`, both reading the
/// shared device buffers — and `logprob_dev`'s output buffers are
/// captured back into the resident round (`rlp_tok`/`rlp_seq`) so the
/// pairwise gather can consume them with zero re-upload. The host-literal
/// path (resident = `None`) remains byte-for-byte the seed behaviour and
/// is the equivalence baseline in the integration tests.
#[allow(clippy::too_many_arguments)]
pub fn label_round(
    engine: &Engine,
    round: &Round,
    ref_params: &[f32],
    rm: Option<(&Engine, &[f32])>,
    k: usize,
    eos_penalty: f32,
    gold_reward: bool,
    scratch: &mut LabelScratch,
    resident: Option<&mut ResidentRound>,
) -> Result<Labels> {
    let cfg = &engine.manifest.config;
    let (b, p) = (cfg.gen_batch, cfg.prompt_len);
    let gen = &round.gen;
    let task = Task::from_name(&cfg.task)
        .ok_or_else(|| anyhow::anyhow!("bad task {}", cfg.task))?;

    // --- gold scoring + win judging (metrics) ---
    let mut gold_scores = Vec::with_capacity(b);
    let mut wins = Vec::with_capacity(b);
    let mut total_len = 0usize;
    for i in 0..b {
        let ex = &round.examples[i / k];
        let resp = gen.response(i, p);
        total_len += resp.len();
        let score = gold::score(&ex.meta, resp);
        gold_scores.push(score);
        let mut ref_resp = ex.reference.clone();
        ref_resp.push(tk::EOS);
        wins.push(gold::win_value(&ex.meta, resp, &ref_resp));
    }

    // --- optimizer rewards ---
    // math: rule reward, no RM (paper §5.2); gold_reward: ablation in
    // the well-trained-RM limit
    let rewards = if uses_rule_reward(task, gold_reward) {
        gold_scores.clone()
    } else {
        let (rm_engine, rm_params) = rm
            .ok_or_else(|| anyhow::anyhow!("task {task:?} needs an RM"))?;
        // staged rounds carry an rm_mask ONLY when make_resident saw
        // a same-engine RM (the one place that eligibility is
        // decided), so its presence is the whole dispatch here;
        // cross-engine RMs and unstaged rounds score via the host
        let staged = resident.as_deref().and_then(|rr| {
            rr.rm_mask.as_ref().map(|m| (&rr.tokens, m))
        });
        let mut scores = match staged {
            Some((toks, rm_mask)) => crate::reward::score_batch_resident(
                rm_engine, rm_params, toks, rm_mask,
            )?,
            None => {
                let masks: Vec<Vec<f32>> = gen
                    .resp_mask
                    .iter()
                    .map(|m| valid_mask(p, m))
                    .collect();
                crate::reward::score_batch(
                    rm_engine, rm_params, &gen.tokens, &masks,
                )?
            }
        };
        for (i, sc) in scores.iter_mut().enumerate() {
            if !gen.terminated[i] {
                *sc += eos_penalty; // paper Table 4: penalty without EOS
            }
        }
        scores
    };

    // --- reference logprobs (KL anchor + DPO reference) ---
    let (rlp_seq, rlp_tok) = if let Some(rr) = resident {
        // shared device buffers in, both outputs read: download them from
        // the untupled twin (each its own accounted transfer)
        let out = engine.execute_buffers(
            "logprob_dev",
            &[
                CallArg::Param(ParamView::cached("ref", 0, ref_params)),
                CallArg::Device(&rr.tokens),
                CallArg::Device(&rr.resp_mask),
            ],
        )?;
        let host = (
            engine.download(&out[0])?.into_f32()?,
            engine.download(&out[1])?.into_f32()?,
        );
        // capture the device outputs too: the pairwise gather reads
        // rlp_seq (DPO margins) and rlp_tok (RLOO anchors) straight off
        // these buffers — zero re-upload
        let mut it = out.into_iter();
        rr.rlp_seq = it.next();
        rr.rlp_tok = it.next();
        host
    } else {
        gen.flatten_into(&mut scratch.toks, &mut scratch.mask);
        let out = engine.call_with(
            "logprob",
            &[
                CallArg::Param(ParamView::cached("ref", 0, ref_params)),
                CallArg::I32(&scratch.toks),
                CallArg::F32(&scratch.mask),
            ],
        )?;
        let mut it = out.into_iter();
        let rlp_seq = it.next().unwrap().into_f32()?;
        let rlp_tok = it.next().unwrap().into_f32()?;
        (rlp_seq, rlp_tok)
    };

    // masked sums read straight off the round (not the flattening
    // scratch, which the resident path never fills)
    let flat_mask = || gen.resp_mask.iter().flatten();
    let mask_total: f32 = flat_mask().sum();
    let rlp_masked: f32 =
        rlp_tok.iter().zip(flat_mask()).map(|(l, m)| l * m).sum();
    let ref_ppl = (-rlp_masked / mask_total.max(1.0)).exp();
    let blp_masked: f32 = gen
        .blp
        .iter()
        .flatten()
        .zip(flat_mask())
        .map(|(l, m)| l * m)
        .sum();

    Ok(Labels {
        rewards,
        gold_scores,
        wins,
        rlp_tok,
        rlp_seq,
        ref_ppl,
        mean_blp: blp_masked / mask_total.max(1.0),
        mean_len: total_len as f32 / b as f32,
    })
}

/// One train-batch tensor slot: host memory still to be uploaded, or a
/// device buffer shared from the round's one-time staging (moves nothing).
pub enum BatchSlot {
    Host(HostTensor),
    Device(DeviceBuffer),
}

/// A fully-assembled train batch: tensors in the executable's input order
/// (after params/m/v/step/lr).
pub struct TrainBatch {
    pub artifact: &'static str,
    pub tensors: Vec<BatchSlot>,
    /// Completions consumed by this batch (episode accounting).
    pub episodes: u64,
}

/// Best/worst completion (by reward) among one prompt's `slots` range.
///
/// NaN-safe by construction: `f32::total_cmp` is a total order (NaN sorts
/// above +inf), so a NaN reward — an exploding RM score, a poisoned
/// logprob — selects deterministically instead of panicking the trainer
/// loop mid-run (the seed used `partial_cmp(..).unwrap()`).
pub fn best_worst(
    rewards: &[f32],
    slots: std::ops::Range<usize>,
) -> (usize, usize) {
    debug_assert!(!slots.is_empty());
    let best = slots
        .clone()
        .max_by(|&a, &b| rewards[a].total_cmp(&rewards[b]))
        .unwrap();
    let worst = slots
        .min_by(|&a, &b| rewards[a].total_cmp(&rewards[b]))
        .unwrap();
    (best, worst)
}

/// One best/worst selection: row indices into `rounds[round]`'s gen batch.
struct Pair {
    round: usize,
    best: usize,
    worst: usize,
}

/// Assemble the algorithm-specific train batch from a labelled round pair.
///
/// - K=2: `rounds` is one round -> one batch (train_pairs pairs, or
///   gen_batch singles for PPO/SFT-style losses).
/// - K=4: `rounds` is two rounds -> one batch of best/worst pairs
///   (paper §4.2: generation takes K/2 times longer, training unchanged).
///
/// PPO's batch layout is the round layout, so its token/mask/blp/rlp
/// slots reuse the round's resident device buffers when staged. Pairwise
/// losses (DPO/RLOO family/Best-of-N) permute rows into best/worst pairs:
/// with staged rounds on an untupling client the permutation runs on
/// device through the `gather_pairs` executable — only the `[2*Bp]`
/// pair-index vector is uploaded, every `[B,S]` tensor stays resident —
/// and otherwise falls back to the host assembly (permanently so for
/// root-tuple clients, where staging never engages). Both paths produce
/// bitwise-identical train batches (integration-tested).
pub fn assemble(
    engine: &Engine,
    algo: Algo,
    rounds: &[LabelledRound],
    k: usize,
) -> Result<TrainBatch> {
    let cfg = &engine.manifest.config;
    let (bg, bp, s) = (cfg.gen_batch, cfg.train_pairs, cfg.seq_len);
    let rounds_needed = rounds_per_batch(k);
    if rounds.len() != rounds_needed {
        bail!("algo {algo} with k={k} needs {rounds_needed} rounds");
    }
    let episodes = (bg * rounds.len()) as u64;

    if algo == Algo::Ppo {
        // PPO consumes all slots as singles (k must be 1 slot per prompt
        // conceptually; duplicated prompts are still valid episodes).
        let lr = &rounds[0];
        let (round, labels) = (&lr.round, &lr.labels);
        let (tok_slot, mask_slot, blp_dev, rlp_dev) = match &lr.resident {
            Some(rr) => (
                BatchSlot::Device(rr.tokens.clone()),
                BatchSlot::Device(rr.resp_mask.clone()),
                rr.blp.clone().map(BatchSlot::Device),
                rr.rlp_tok.clone().map(BatchSlot::Device),
            ),
            None => {
                let mut toks = Vec::new();
                let mut mask = Vec::new();
                round.gen.flatten_into(&mut toks, &mut mask);
                (
                    BatchSlot::Host(HostTensor::I32(toks)),
                    BatchSlot::Host(HostTensor::F32(mask)),
                    None,
                    None,
                )
            }
        };
        let blp_slot = blp_dev.unwrap_or_else(|| {
            let mut blp = Vec::with_capacity(bg * s);
            for i in 0..bg {
                blp.extend_from_slice(&round.gen.blp[i]);
            }
            BatchSlot::Host(HostTensor::F32(blp))
        });
        let rlp_slot = rlp_dev.unwrap_or_else(|| {
            BatchSlot::Host(HostTensor::F32(labels.rlp_tok.clone()))
        });
        return Ok(TrainBatch {
            artifact: algo.artifact(),
            tensors: vec![
                tok_slot,
                mask_slot,
                blp_slot,
                rlp_slot,
                BatchSlot::Host(HostTensor::F32(labels.rewards.clone())),
            ],
            episodes,
        });
    }

    // Pairwise: pick best/worst of each prompt's k completions by reward
    // (on host — the rewards live here; only the resulting index vector
    // matters to the device path).
    let n_prompts = bg / k;
    let mut pairs: Vec<Pair> = Vec::with_capacity(bp);
    for (ri, lr) in rounds.iter().enumerate() {
        for pi in 0..n_prompts {
            let (best, worst) =
                best_worst(&lr.labels.rewards, pi * k..(pi + 1) * k);
            pairs.push(Pair { round: ri, best, worst });
        }
    }
    if pairs.len() != bp {
        bail!(
            "assembled {} pairs but train_pairs is {bp} (k={k})",
            pairs.len()
        );
    }

    let row = |p: &Pair, side: usize| -> (&LabelledRound, usize) {
        (&rounds[p.round], if side == 0 { p.best } else { p.worst })
    };
    let reward = |side: usize| -> Vec<f32> {
        pairs
            .iter()
            .map(|p| {
                let (lr, i) = row(p, side);
                lr.labels.rewards[i]
            })
            .collect()
    };

    // --- device path: gather_pairs over the rounds' resident buffers ---
    if let Some(gathered) = gather_pairs_device(engine, algo, rounds, &pairs)? {
        let mut tensors = gathered;
        if matches!(algo, Algo::Rloo | Algo::Prloo | Algo::Copg) {
            // rewards are host-made ([Bp] each — the EOS penalty and the
            // gold/RM dispatch happen on host) and tiny; they ride along
            tensors.push(BatchSlot::Host(HostTensor::F32(reward(0))));
            tensors.push(BatchSlot::Host(HostTensor::F32(reward(1))));
        }
        return Ok(TrainBatch { artifact: algo.artifact(), tensors, episodes });
    }

    // --- host fallback: permute on the host, extending from slices (this
    // path is permanent for root-tuple clients, so it stays
    // allocation-light: one Vec per tensor, no per-row clones) ---
    let flat_toks = |side: usize| -> Vec<i32> {
        let mut out = Vec::with_capacity(bp * s);
        for p in &pairs {
            let (lr, i) = row(p, side);
            out.extend_from_slice(&lr.round.gen.tokens[i]);
        }
        out
    };
    let flat_mask = |side: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(bp * s);
        for p in &pairs {
            let (lr, i) = row(p, side);
            out.extend_from_slice(&lr.round.gen.resp_mask[i]);
        }
        out
    };
    let flat_blp = |side: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(bp * s);
        for p in &pairs {
            let (lr, i) = row(p, side);
            out.extend_from_slice(&lr.round.gen.blp[i]);
        }
        out
    };
    let flat_rlp = |side: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(bp * s);
        for p in &pairs {
            let (lr, i) = row(p, side);
            out.extend_from_slice(&lr.labels.rlp_tok[i * s..(i + 1) * s]);
        }
        out
    };

    let tensors = match algo {
        Algo::Dpo => {
            let rlp_seq = |side: usize| -> Vec<f32> {
                pairs
                    .iter()
                    .map(|p| {
                        let (lr, i) = row(p, side);
                        lr.labels.rlp_seq[i]
                    })
                    .collect()
            };
            vec![
                HostTensor::I32(flat_toks(0)),
                HostTensor::F32(flat_mask(0)),
                HostTensor::I32(flat_toks(1)),
                HostTensor::F32(flat_mask(1)),
                HostTensor::F32(rlp_seq(0)),
                HostTensor::F32(rlp_seq(1)),
            ]
        }
        Algo::Rloo | Algo::Prloo | Algo::Copg => vec![
            HostTensor::I32(flat_toks(0)),
            HostTensor::F32(flat_mask(0)),
            HostTensor::I32(flat_toks(1)),
            HostTensor::F32(flat_mask(1)),
            HostTensor::F32(flat_blp(0)),
            HostTensor::F32(flat_blp(1)),
            HostTensor::F32(flat_rlp(0)),
            HostTensor::F32(flat_rlp(1)),
            HostTensor::F32(reward(0)),
            HostTensor::F32(reward(1)),
        ],
        Algo::BestOfN => {
            // SFT on the best completion; duplicate to fill the singles
            // batch (effective batch = train_pairs distinct rows).
            let mut toks_out = Vec::with_capacity(bg * s);
            let mut mask_out = Vec::with_capacity(bg * s);
            for p in &pairs {
                let (lr, i) = row(p, 0);
                for _ in 0..2 {
                    toks_out.extend_from_slice(&lr.round.gen.tokens[i]);
                    mask_out.extend_from_slice(&lr.round.gen.resp_mask[i]);
                }
            }
            vec![HostTensor::I32(toks_out), HostTensor::F32(mask_out)]
        }
        Algo::Ppo => unreachable!(),
    };
    let tensors = tensors.into_iter().map(BatchSlot::Host).collect();

    Ok(TrainBatch { artifact: algo.artifact(), tensors, episodes })
}

/// Run the `gather_pairs` executable over the rounds' resident buffers,
/// returning the algorithm's train-batch device slots, or `None` to fall
/// back to the host assembly: when the bundle lacks the artifact or the
/// client returns root tuples ([`Engine::buffer_path_ready`]), when any
/// round is unstaged or missing its chained rlp buffers (host-literal
/// labelling fills neither), or when a RLOO-family batch lacks staged
/// blp.
///
/// The `[2*Bp]` index vector — the ONLY per-batch upload — addresses the
/// concatenated two-round row space (round r row i ↦ r·Bg + i); K=2
/// batches bind the same round to both gather inputs, so indices stay
/// below Bg and the concat's second half is simply never addressed.
fn gather_pairs_device(
    engine: &Engine,
    algo: Algo,
    rounds: &[LabelledRound],
    pairs: &[Pair],
) -> Result<Option<Vec<BatchSlot>>> {
    let cfg = &engine.manifest.config;
    let (bg, bp) = (cfg.gen_batch, cfg.train_pairs);
    if !engine.buffer_path_ready("gather_pairs") {
        return Ok(None);
    }
    if algo == Algo::BestOfN && 2 * bp != bg {
        // tok_all/mask_all are [2*Bp, S]; train_bon consumes [Bg, S]
        return Ok(None);
    }
    struct Side<'a> {
        tok: &'a DeviceBuffer,
        mask: &'a DeviceBuffer,
        blp: &'a DeviceBuffer,
        rlp: &'a DeviceBuffer,
        rseq: &'a DeviceBuffer,
    }
    fn side_of(lr: &LabelledRound, needs_blp: bool) -> Option<Side<'_>> {
        let rr = lr.resident.as_ref()?;
        let rlp = rr.rlp_tok.as_ref()?;
        let rseq = rr.rlp_seq.as_ref()?;
        let blp = match rr.blp.as_ref() {
            Some(b) => b,
            // DPO / Best-of-N never read the gathered blp outputs: feed
            // the (shape/dtype-identical) rlp buffer as a stand-in
            // rather than paying a [B,S] upload for ignored data
            None if !needs_blp => rlp,
            None => return None,
        };
        Some(Side { tok: &rr.tokens, mask: &rr.resp_mask, blp, rlp, rseq })
    }
    let needs_blp = algo_stages_blp(algo);
    let Some(a) = side_of(&rounds[0], needs_blp) else {
        return Ok(None);
    };
    let Some(b) = side_of(&rounds[rounds.len() - 1], needs_blp) else {
        return Ok(None);
    };

    let mut idx = Vec::with_capacity(2 * bp);
    if algo == Algo::BestOfN {
        // duplicated best rows in pair order: tok_all/mask_all then ARE
        // the train_bon singles batch (each best twice, the host layout)
        for p in pairs {
            let g = (p.round * bg + p.best) as i32;
            idx.push(g);
            idx.push(g);
        }
    } else {
        idx.extend(pairs.iter().map(|p| (p.round * bg + p.best) as i32));
        idx.extend(pairs.iter().map(|p| (p.round * bg + p.worst) as i32));
    }

    let out = engine.execute_buffers(
        "gather_pairs",
        &[
            CallArg::Device(a.tok),
            CallArg::Device(a.mask),
            CallArg::Device(a.blp),
            CallArg::Device(a.rlp),
            CallArg::Device(a.rseq),
            CallArg::Device(b.tok),
            CallArg::Device(b.mask),
            CallArg::Device(b.blp),
            CallArg::Device(b.rlp),
            CallArg::Device(b.rseq),
            CallArg::I32(&idx),
        ],
    )?;
    // outputs (python/compile/losses.py::gather_pairs): 0..3 tok/mask per
    // side, 4..7 blp/rlp per side, 8..9 rseq per side, 10..11 stacked
    let mut out: Vec<Option<DeviceBuffer>> = out.into_iter().map(Some).collect();
    let mut take = |i: usize| BatchSlot::Device(out[i].take().unwrap());
    Ok(Some(match algo {
        Algo::Dpo => vec![
            take(0),
            take(1),
            take(2),
            take(3),
            take(8),
            take(9),
        ],
        Algo::Rloo | Algo::Prloo | Algo::Copg => vec![
            take(0),
            take(1),
            take(2),
            take(3),
            take(4),
            take(5),
            take(6),
            take(7),
        ],
        Algo::BestOfN => vec![take(10), take(11)],
        Algo::Ppo => unreachable!("PPO consumes the round layout directly"),
    }))
}

/// How many generation rounds one train batch consumes.
pub fn rounds_per_batch(k: usize) -> usize {
    match k {
        2 => 1,
        4 => 2,
        _ => panic!("k must be 2 or 4"),
    }
}

/// Run `t` optimizer updates on one assembled batch ("ppo epochs",
/// paper §4.1). Returns the metrics of each update.
///
/// Host slots upload to the device once and are reused across the whole
/// inner loop; device slots (round-resident tokens/masks) move nothing at
/// all. On untupled train artifacts the optimizer triple also stays
/// device-resident, so repeat updates move only the metrics vector.
pub fn train_on_batch(
    engine: &Engine,
    state: &mut TrainState,
    batch: &TrainBatch,
    lr: f32,
    t_updates: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut dev_batch = Vec::with_capacity(batch.tensors.len());
    for (i, slot) in batch.tensors.iter().enumerate() {
        dev_batch.push(match slot {
            // the loss-specific inputs start after (params, m, v, step, lr)
            BatchSlot::Host(t) => engine
                .upload_inputs(batch.artifact, 5 + i, std::slice::from_ref(t))?
                .pop()
                .unwrap(),
            BatchSlot::Device(b) => b.clone(),
        });
    }
    let mut all = Vec::with_capacity(t_updates);
    for _ in 0..t_updates {
        let metrics =
            state.train_step_uploaded(engine, batch.artifact, lr, &dev_batch)?;
        all.push(metrics);
    }
    Ok(all)
}

/// Staleness of a just-applied update: how many optimizer versions behind
/// the freshest pre-update version (`version - 1`) the training data's
/// behaviour policy was. 0 means fully on-policy.
pub fn staleness(version_after_update: u64, data_version: u64) -> u64 {
    version_after_update
        .saturating_sub(1)
        .saturating_sub(data_version)
}

/// Behaviour-policy version of a train batch: the freshest
/// `params_version` among its rounds (k=4 batches pair two rounds, which
/// the sync N-ladder may have generated at different versions; taking the
/// max keeps [`staleness`] conservative). The one definition shared by
/// every [`staleness`] measurement in the pipeline.
pub fn batch_data_version(rounds: &[LabelledRound]) -> u64 {
    rounds
        .iter()
        .map(|r| r.round.params_version)
        .max()
        .unwrap_or(0)
}

/// Token-level behaviour-version summary of a train batch: the oldest
/// per-token version and the (round-averaged) mean per-token version
/// across its rounds — the per-token counterpart of
/// [`batch_data_version`], meaningful when the continuous engine mixes
/// versions within a sequence. Round-synchronous engines collapse both
/// to `params_version`.
pub fn batch_token_versions(rounds: &[LabelledRound]) -> (u64, f64) {
    let min = rounds
        .iter()
        .map(|r| r.round.tok_version_min)
        .min()
        .unwrap_or(0);
    let mean = rounds
        .iter()
        .map(|r| r.round.tok_version_mean)
        .sum::<f64>()
        / rounds.len().max(1) as f64;
    (min, mean)
}

/// Per-round training-curve metrics derived from labels (gold win-rate and
/// KL-as-ppl measured on the training stream itself, costing nothing —
/// final eval uses held-out prompts).
pub fn round_metrics(labels: &Labels) -> Vec<(&'static str, f32)> {
    vec![
        ("win_rate", crate::util::mean(&labels.wins)),
        ("gold_score", crate::util::mean(&labels.gold_scores)),
        ("rm_reward", crate::util::mean(&labels.rewards)),
        ("kl_ppl", labels.ref_ppl),
        ("resp_len", labels.mean_len),
        ("behaviour_lp", labels.mean_blp),
    ]
}

/// ExpConfig-driven sampling options.
pub fn sample_opts(cfg: &ExpConfig) -> SampleOpts {
    SampleOpts { temperature: cfg.temperature, greedy: false }
}

#[cfg(test)]
mod tests {
    use super::staleness;

    #[test]
    fn staleness_is_plain_saturating_sub() {
        // on-policy: data generated at the pre-update version
        assert_eq!(staleness(1, 0), 0);
        assert_eq!(staleness(5, 4), 0);
        // one version behind
        assert_eq!(staleness(5, 3), 1);
        // data "from the future" (defensive) saturates to 0
        assert_eq!(staleness(1, 7), 0);
        assert_eq!(staleness(0, 0), 0);
    }

    #[test]
    fn one_step_queue_bounds_staleness() {
        // Discrete model of the bound-0 rendezvous queue: the worker picks
        // up the freshest published params right after handing round t
        // over (i.e. before step t's update publishes), so round t+1 is
        // generated with the version published after step t-1. Per-step
        // staleness is then bounded by 2*T - 1 (T = updates_per_batch) and
        // for the paper's T=1 the mean is <= updates_per_batch = 1.
        for t_updates in [1u64, 2, 3] {
            let steps = 50u64;
            let mut published = 0u64; // latest version the worker saw
            let mut version = 0u64; // trainer's optimizer version
            let mut next_round_version = 0u64; // round in flight
            let mut sum = 0u64;
            for _ in 0..steps {
                let data_version = next_round_version;
                // handover: worker immediately starts the next round with
                // the freshest published params (step's publish not yet out)
                next_round_version = published;
                version += t_updates;
                published = version; // end-of-step publish
                let st = staleness(version, data_version);
                assert!(st <= 2 * t_updates - 1, "st {st} T {t_updates}");
                sum += st;
            }
            let mean = sum as f64 / steps as f64;
            if t_updates == 1 {
                assert!(mean <= 1.0, "mean staleness {mean} > updates_per_batch");
            }
        }
    }
}
