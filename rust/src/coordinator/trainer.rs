//! Shared RLHF round machinery used by both the synchronous and the
//! asynchronous coordinators: prompt scheduling, reward labelling (proxy RM
//! or rule-based), reference-policy logprobs, and algorithm-specific train
//! batch assembly against the fused train-step executables.

use anyhow::{bail, Result};

use crate::config::{Algo, ExpConfig};
use crate::data::{Example, Task, TaskGen};
use crate::gen::{GenBatch, Generator, SampleOpts};
use crate::reward::{gold, valid_mask};
use crate::runtime::{
    CallArg, DeviceBuffer, Engine, HostTensor, ParamView, TrainState,
};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

/// Stats origin for the once-per-round token/mask uploads of the resident
/// labelling path — one bucket so `CallStats` shows exactly how many bytes
/// a round costs to stage (the acceptance counter for "upload once").
pub const ROUND_ORIGIN: &str = "round";

/// One generation round: `gen_batch` completions plus provenance.
pub struct Round {
    pub gen: GenBatch,
    pub examples: Vec<Example>,
    /// Index of the first prompt of this round in the task stream.
    pub start_index: u64,
    /// Policy version that generated this round (staleness accounting).
    pub params_version: u64,
    /// Wall-clock seconds spent generating (gen thread's measurement).
    pub gen_secs: f64,
    /// Span of generation relative to the shared timeline origin.
    pub gen_span: (f64, f64),
}

/// A round's token/mask tensors staged on the device ONCE and shared (as
/// `CallArg::Device` inputs) across reference logprobs (`logprob_dev`),
/// proxy-RM scoring (`score_rm`) and PPO-style train-batch assembly. The
/// seed path uploaded the same `[B*S]` token tensor three separate times
/// per round (label, score, train); this uploads it exactly once, under
/// the [`ROUND_ORIGIN`] stats bucket.
///
/// Device buffers belong to the engine that created them: a
/// `ResidentRound` is built by the labelling/training engine (the trainer
/// thread's own) and must only be used with it. A cross-scale RM engine
/// (Fig 5) cannot read these buffers — scoring falls back to the host
/// path in that case.
pub struct ResidentRound {
    /// Flattened `[B*S]` round tokens (i32).
    pub tokens: DeviceBuffer,
    /// Flattened `[B*S]` response mask — the logprob / PPO-train mask.
    pub resp_mask: DeviceBuffer,
    /// Whole-sequence validity mask for RM scoring (prompt + response,
    /// see [`crate::reward::valid_mask`]); `None` when the round's reward
    /// does not come from a same-engine RM.
    pub rm_mask: Option<DeviceBuffer>,
}

impl ResidentRound {
    /// Flatten and upload a round's tensors. `with_rm_mask` additionally
    /// stages the RM validity mask (derived from `resp_mask` on the
    /// host — it is a different tensor, so it is its own upload).
    pub fn upload(
        engine: &Engine,
        gen: &GenBatch,
        prompt_len: usize,
        with_rm_mask: bool,
        scratch: &mut LabelScratch,
    ) -> Result<ResidentRound> {
        gen.flatten_into(&mut scratch.toks, &mut scratch.mask);
        // logprob's input specs 1/2 carry the [B, S] shapes shared by
        // every consumer (score_rm, train_ppo) of these buffers
        let tokens = engine.upload_arg_as(
            ROUND_ORIGIN,
            "logprob",
            1,
            &CallArg::I32(&scratch.toks),
        )?;
        let resp_mask = engine.upload_arg_as(
            ROUND_ORIGIN,
            "logprob",
            2,
            &CallArg::F32(&scratch.mask),
        )?;
        let rm_mask = if with_rm_mask {
            scratch.mask.clear();
            for m in &gen.resp_mask {
                scratch.mask.extend(valid_mask(prompt_len, m));
            }
            Some(engine.upload_arg_as(
                ROUND_ORIGIN,
                "score_rm",
                2,
                &CallArg::F32(&scratch.mask),
            )?)
        } else {
            None
        };
        Ok(ResidentRound { tokens, resp_mask, rm_mask })
    }
}

/// Stage a round for the resident labelling path when the bundle supports
/// it (`logprob_dev` present) AND the PJRT client has been observed to
/// untuple (under the root-tuple fallback, `execute_buffers` would move
/// MORE bytes than the seed literal path — so fall back to it). `None`
/// means host-literal labelling; with the default fused generator or any
/// train step already run, the capability is known by the first label.
/// The RM mask is staged only when the reward actually comes from a
/// same-engine RM (rule-reward tasks and cross-engine RMs score on their
/// own path).
pub fn make_resident(
    engine: &Engine,
    gen: &GenBatch,
    rm: Option<(&Engine, &[f32])>,
    gold_reward: bool,
    scratch: &mut LabelScratch,
) -> Result<Option<ResidentRound>> {
    if !engine.buffer_path_ready("logprob_dev") {
        return Ok(None);
    }
    let cfg = &engine.manifest.config;
    let rule_reward = Task::from_name(&cfg.task)
        .is_some_and(|t| uses_rule_reward(t, gold_reward));
    let with_rm_mask = !rule_reward
        && rm.is_some_and(|(rm_engine, _)| {
            std::ptr::eq(rm_engine as *const Engine, engine as *const Engine)
        });
    ResidentRound::upload(engine, gen, cfg.prompt_len, with_rm_mask, scratch)
        .map(Some)
}

/// Rule-reward rounds (the math task, or the gold-reward ablation) never
/// touch the proxy RM; everything else scores with it. The single
/// predicate shared by [`make_resident`]'s staging decision and
/// [`label_round`]'s reward dispatch, so the two cannot drift.
fn uses_rule_reward(task: Task, gold_reward: bool) -> bool {
    task == Task::Math || gold_reward
}

/// A labelled round plus its (optional) device-staged tensors, as consumed
/// by [`assemble`].
pub struct LabelledRound {
    pub round: Round,
    pub labels: Labels,
    pub resident: Option<ResidentRound>,
}

/// Stage (when eligible) and label one round — the coordinators' Score
/// phase. One definition so the sync and async paths cannot drift in
/// staging policy or labelling traffic.
pub fn stage_and_label(
    engine: &Engine,
    round: &Round,
    ref_params: &[f32],
    rm: Option<(&Engine, &[f32])>,
    cfg: &ExpConfig,
    scratch: &mut LabelScratch,
) -> Result<(Option<ResidentRound>, Labels)> {
    let resident =
        make_resident(engine, &round.gen, rm, cfg.gold_reward, scratch)?;
    let labels = label_round(
        engine,
        round,
        ref_params,
        rm,
        cfg.k_samples,
        cfg.eos_penalty,
        cfg.gold_reward,
        scratch,
        resident.as_ref(),
    )?;
    Ok((resident, labels))
}

/// Prompts for round starting at `start`: each distinct prompt is repeated
/// `k` times consecutively (k completions per prompt, paper §4.2).
pub fn round_prompts(
    taskgen: &TaskGen,
    start: u64,
    gen_batch: usize,
    k: usize,
) -> (Vec<Example>, Vec<Vec<i32>>) {
    assert!(gen_batch % k == 0, "gen_batch must be divisible by k");
    let n_prompts = gen_batch / k;
    let examples = taskgen.batch(start, n_prompts);
    let mut prompts = Vec::with_capacity(gen_batch);
    for ex in &examples {
        for _ in 0..k {
            prompts.push(ex.prompt.clone());
        }
    }
    (examples, prompts)
}

/// Generate one round (runs on whichever thread owns the generation
/// engine). `params` is a [`ParamView`]: cached/device views avoid
/// re-uploading the policy unless its version changed.
#[allow(clippy::too_many_arguments)]
pub fn generate_round(
    engine: &Engine,
    generator: &dyn Generator,
    params: ParamView<'_>,
    params_version: u64,
    taskgen: &TaskGen,
    start_index: u64,
    k: usize,
    opts: SampleOpts,
    rng: &mut Pcg32,
    origin: std::time::Instant,
) -> Result<Round> {
    let cfg = &engine.manifest.config;
    let (examples, prompts) = round_prompts(taskgen, start_index, cfg.gen_batch, k);
    let t0 = origin.elapsed().as_secs_f64();
    let gen = generator.generate(engine, params, &prompts, opts, rng)?;
    let t1 = origin.elapsed().as_secs_f64();
    Ok(Round {
        gen,
        examples,
        start_index,
        params_version,
        gen_secs: t1 - t0,
        gen_span: (t0, t1),
    })
}

/// Labels for one round: rewards (what the optimizer sees), gold scores and
/// wins (what evaluation sees), reference logprobs (KL anchor).
pub struct Labels {
    /// Reward per slot: proxy-RM score (+ EOS penalty) for RM tasks, gold
    /// rule reward for math.
    pub rewards: Vec<f32>,
    /// Gold score per slot (ground truth, for metrics only).
    pub gold_scores: Vec<f32>,
    /// Gold-judged win value vs the dataset reference (1/0.5/0), per slot.
    pub wins: Vec<f32>,
    /// Reference-policy token logprobs, flattened [B*S].
    pub rlp_tok: Vec<f32>,
    /// Reference-policy sequence logprobs [B].
    pub rlp_seq: Vec<f32>,
    /// exp(-mean ref token logprob) over response tokens: the paper's
    /// KL-as-perplexity measurement.
    pub ref_ppl: f32,
    /// Mean behaviour entropy proxy: -mean blp.
    pub mean_blp: f32,
    /// Mean response length (tokens incl. EOS).
    pub mean_len: f32,
}

/// Reusable flattening scratch for per-round labelling: one allocation
/// per run instead of two per round.
#[derive(Default)]
pub struct LabelScratch {
    toks: Vec<i32>,
    mask: Vec<f32>,
}

/// Label a round: score with the proxy RM (or the rule reward for math),
/// judge with gold, compute reference logprobs. Runs on the trainer thread
/// (paper Algorithm 1 places reward + loss on the learner). `rm` is the
/// (engine, params) scorer — possibly a different-scale bundle (Fig 5).
///
/// `ref_params` is frozen for the run, so it lives in the engine's device
/// cache under the `"ref"` key: uploaded on the first round, reused
/// thereafter (the engine's reference params must not change under the
/// same key — every coordinator uses the one SFT checkpoint per run).
///
/// When `resident` is staged (see [`make_resident`]) the round's tensors
/// are NOT re-uploaded here: reference logprobs run through the untupled
/// `logprob_dev` twin and RM scoring through `score_rm`, both reading the
/// shared device buffers. The host-literal path (resident = `None`)
/// remains byte-for-byte the seed behaviour and is the equivalence
/// baseline in the integration tests.
#[allow(clippy::too_many_arguments)]
pub fn label_round(
    engine: &Engine,
    round: &Round,
    ref_params: &[f32],
    rm: Option<(&Engine, &[f32])>,
    k: usize,
    eos_penalty: f32,
    gold_reward: bool,
    scratch: &mut LabelScratch,
    resident: Option<&ResidentRound>,
) -> Result<Labels> {
    let cfg = &engine.manifest.config;
    let (b, p) = (cfg.gen_batch, cfg.prompt_len);
    let gen = &round.gen;
    let task = Task::from_name(&cfg.task)
        .ok_or_else(|| anyhow::anyhow!("bad task {}", cfg.task))?;

    // --- gold scoring + win judging (metrics) ---
    let mut gold_scores = Vec::with_capacity(b);
    let mut wins = Vec::with_capacity(b);
    let mut total_len = 0usize;
    for i in 0..b {
        let ex = &round.examples[i / k];
        let resp = gen.response(i, p);
        total_len += resp.len();
        let score = gold::score(&ex.meta, resp);
        gold_scores.push(score);
        let mut ref_resp = ex.reference.clone();
        ref_resp.push(tk::EOS);
        wins.push(gold::win_value(&ex.meta, resp, &ref_resp));
    }

    // --- optimizer rewards ---
    // math: rule reward, no RM (paper §5.2); gold_reward: ablation in
    // the well-trained-RM limit
    let rewards = if uses_rule_reward(task, gold_reward) {
        gold_scores.clone()
    } else {
        let (rm_engine, rm_params) = rm
            .ok_or_else(|| anyhow::anyhow!("task {task:?} needs an RM"))?;
        // staged rounds carry an rm_mask ONLY when make_resident saw
        // a same-engine RM (the one place that eligibility is
        // decided), so its presence is the whole dispatch here;
        // cross-engine RMs and unstaged rounds score via the host
        let staged = resident.and_then(|rr| {
            rr.rm_mask.as_ref().map(|m| (&rr.tokens, m))
        });
        let mut scores = match staged {
            Some((toks, rm_mask)) => crate::reward::score_batch_resident(
                rm_engine, rm_params, toks, rm_mask,
            )?,
            None => {
                let masks: Vec<Vec<f32>> = gen
                    .resp_mask
                    .iter()
                    .map(|m| valid_mask(p, m))
                    .collect();
                crate::reward::score_batch(
                    rm_engine, rm_params, &gen.tokens, &masks,
                )?
            }
        };
        for (i, sc) in scores.iter_mut().enumerate() {
            if !gen.terminated[i] {
                *sc += eos_penalty; // paper Table 4: penalty without EOS
            }
        }
        scores
    };

    // --- reference logprobs (KL anchor + DPO reference) ---
    let (rlp_seq, rlp_tok) = if let Some(rr) = resident {
        // shared device buffers in, both outputs read: download them from
        // the untupled twin (each its own accounted transfer)
        let out = engine.execute_buffers(
            "logprob_dev",
            &[
                CallArg::Param(ParamView::cached("ref", 0, ref_params)),
                CallArg::Device(&rr.tokens),
                CallArg::Device(&rr.resp_mask),
            ],
        )?;
        (
            engine.download(&out[0])?.into_f32()?,
            engine.download(&out[1])?.into_f32()?,
        )
    } else {
        gen.flatten_into(&mut scratch.toks, &mut scratch.mask);
        let out = engine.call_with(
            "logprob",
            &[
                CallArg::Param(ParamView::cached("ref", 0, ref_params)),
                CallArg::I32(&scratch.toks),
                CallArg::F32(&scratch.mask),
            ],
        )?;
        let mut it = out.into_iter();
        let rlp_seq = it.next().unwrap().into_f32()?;
        let rlp_tok = it.next().unwrap().into_f32()?;
        (rlp_seq, rlp_tok)
    };

    // masked sums read straight off the round (not the flattening
    // scratch, which the resident path never fills)
    let flat_mask = || gen.resp_mask.iter().flatten();
    let mask_total: f32 = flat_mask().sum();
    let rlp_masked: f32 =
        rlp_tok.iter().zip(flat_mask()).map(|(l, m)| l * m).sum();
    let ref_ppl = (-rlp_masked / mask_total.max(1.0)).exp();
    let blp_masked: f32 = gen
        .blp
        .iter()
        .flatten()
        .zip(flat_mask())
        .map(|(l, m)| l * m)
        .sum();

    Ok(Labels {
        rewards,
        gold_scores,
        wins,
        rlp_tok,
        rlp_seq,
        ref_ppl,
        mean_blp: blp_masked / mask_total.max(1.0),
        mean_len: total_len as f32 / b as f32,
    })
}

/// One train-batch tensor slot: host memory still to be uploaded, or a
/// device buffer shared from the round's one-time staging (moves nothing).
pub enum BatchSlot {
    Host(HostTensor),
    Device(DeviceBuffer),
}

/// A fully-assembled train batch: tensors in the executable's input order
/// (after params/m/v/step/lr).
pub struct TrainBatch {
    pub artifact: &'static str,
    pub tensors: Vec<BatchSlot>,
    /// Completions consumed by this batch (episode accounting).
    pub episodes: u64,
}

/// Assemble the algorithm-specific train batch from a labelled round pair.
///
/// - K=2: `rounds` is one round -> one batch (train_pairs pairs, or
///   gen_batch singles for PPO/SFT-style losses).
/// - K=4: `rounds` is two rounds -> one batch of best/worst pairs
///   (paper §4.2: generation takes K/2 times longer, training unchanged).
///
/// PPO's batch layout is the round layout, so its token/mask slots reuse
/// the round's resident device buffers when staged — the third of the
/// seed path's three per-round token uploads gone. Pairwise losses
/// permute slots into best/worst pairs on the host (a device-side gather
/// is an open ROADMAP item), so their slots stay host tensors.
pub fn assemble(
    engine: &Engine,
    algo: Algo,
    rounds: &[LabelledRound],
    k: usize,
) -> Result<TrainBatch> {
    let cfg = &engine.manifest.config;
    let (bg, bp, s) = (cfg.gen_batch, cfg.train_pairs, cfg.seq_len);
    let rounds_needed = rounds_per_batch(k);
    if rounds.len() != rounds_needed {
        bail!("algo {algo} with k={k} needs {rounds_needed} rounds");
    }
    let episodes = (bg * rounds.len()) as u64;

    if algo == Algo::Ppo {
        // PPO consumes all slots as singles (k must be 1 slot per prompt
        // conceptually; duplicated prompts are still valid episodes).
        let lr = &rounds[0];
        let (round, labels) = (&lr.round, &lr.labels);
        let (tok_slot, mask_slot) = match &lr.resident {
            Some(rr) => (
                BatchSlot::Device(rr.tokens.clone()),
                BatchSlot::Device(rr.resp_mask.clone()),
            ),
            None => {
                let mut toks = Vec::new();
                let mut mask = Vec::new();
                round.gen.flatten_into(&mut toks, &mut mask);
                (
                    BatchSlot::Host(HostTensor::I32(toks)),
                    BatchSlot::Host(HostTensor::F32(mask)),
                )
            }
        };
        let mut blp = Vec::with_capacity(bg * s);
        for i in 0..bg {
            blp.extend_from_slice(&round.gen.blp[i]);
        }
        return Ok(TrainBatch {
            artifact: algo.artifact(),
            tensors: vec![
                tok_slot,
                mask_slot,
                BatchSlot::Host(HostTensor::F32(blp)),
                BatchSlot::Host(HostTensor::F32(labels.rlp_tok.clone())),
                BatchSlot::Host(HostTensor::F32(labels.rewards.clone())),
            ],
            episodes,
        });
    }

    // Pairwise: pick best/worst of each prompt's k completions by reward.
    struct Slot<'a> {
        round: &'a Round,
        labels: &'a Labels,
        idx: usize,
    }
    let mut pairs: Vec<(Slot, Slot)> = Vec::with_capacity(bp);
    for lr in rounds {
        let (round, labels) = (&lr.round, &lr.labels);
        let n_prompts = bg / k;
        for pi in 0..n_prompts {
            let slots = pi * k..(pi + 1) * k;
            let best = slots
                .clone()
                .max_by(|&a, &b| {
                    labels.rewards[a]
                        .partial_cmp(&labels.rewards[b])
                        .unwrap()
                })
                .unwrap();
            let worst = slots
                .clone()
                .min_by(|&a, &b| {
                    labels.rewards[a]
                        .partial_cmp(&labels.rewards[b])
                        .unwrap()
                })
                .unwrap();
            pairs.push((
                Slot { round, labels, idx: best },
                Slot { round, labels, idx: worst },
            ));
        }
    }
    if pairs.len() != bp {
        bail!(
            "assembled {} pairs but train_pairs is {bp} (k={k})",
            pairs.len()
        );
    }

    let flat_i32 = |f: fn(&Slot) -> Vec<i32>, side: usize| -> Vec<i32> {
        let mut out = Vec::with_capacity(bp * s);
        for p in &pairs {
            out.extend(f(if side == 0 { &p.0 } else { &p.1 }));
        }
        out
    };
    let flat_f32 = |f: fn(&Slot) -> Vec<f32>, side: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(bp * s);
        for p in &pairs {
            out.extend(f(if side == 0 { &p.0 } else { &p.1 }));
        }
        out
    };
    fn toks(sl: &Slot) -> Vec<i32> {
        sl.round.gen.tokens[sl.idx].clone()
    }
    fn mask(sl: &Slot) -> Vec<f32> {
        sl.round.gen.resp_mask[sl.idx].clone()
    }
    fn blp(sl: &Slot) -> Vec<f32> {
        sl.round.gen.blp[sl.idx].clone()
    }
    fn rlp(sl: &Slot) -> Vec<f32> {
        let s = sl.round.gen.tokens[sl.idx].len();
        sl.labels.rlp_tok[sl.idx * s..(sl.idx + 1) * s].to_vec()
    }
    let reward = |side: usize| -> Vec<f32> {
        pairs
            .iter()
            .map(|p| {
                let sl = if side == 0 { &p.0 } else { &p.1 };
                sl.labels.rewards[sl.idx]
            })
            .collect()
    };

    let tensors = match algo {
        Algo::Dpo => {
            let rlp_seq = |side: usize| -> Vec<f32> {
                pairs
                    .iter()
                    .map(|p| {
                        let sl = if side == 0 { &p.0 } else { &p.1 };
                        sl.labels.rlp_seq[sl.idx]
                    })
                    .collect()
            };
            vec![
                HostTensor::I32(flat_i32(toks, 0)),
                HostTensor::F32(flat_f32(mask, 0)),
                HostTensor::I32(flat_i32(toks, 1)),
                HostTensor::F32(flat_f32(mask, 1)),
                HostTensor::F32(rlp_seq(0)),
                HostTensor::F32(rlp_seq(1)),
            ]
        }
        Algo::Rloo | Algo::Prloo | Algo::Copg => vec![
            HostTensor::I32(flat_i32(toks, 0)),
            HostTensor::F32(flat_f32(mask, 0)),
            HostTensor::I32(flat_i32(toks, 1)),
            HostTensor::F32(flat_f32(mask, 1)),
            HostTensor::F32(flat_f32(blp, 0)),
            HostTensor::F32(flat_f32(blp, 1)),
            HostTensor::F32(flat_f32(rlp, 0)),
            HostTensor::F32(flat_f32(rlp, 1)),
            HostTensor::F32(reward(0)),
            HostTensor::F32(reward(1)),
        ],
        Algo::BestOfN => {
            // SFT on the best completion; duplicate to fill the singles
            // batch (effective batch = train_pairs distinct rows).
            let mut toks_out = Vec::with_capacity(bg * s);
            let mut mask_out = Vec::with_capacity(bg * s);
            for p in &pairs {
                for _ in 0..2 {
                    toks_out.extend(toks(&p.0));
                    mask_out.extend(mask(&p.0));
                }
            }
            vec![HostTensor::I32(toks_out), HostTensor::F32(mask_out)]
        }
        Algo::Ppo => unreachable!(),
    };
    let tensors = tensors.into_iter().map(BatchSlot::Host).collect();

    Ok(TrainBatch { artifact: algo.artifact(), tensors, episodes })
}

/// How many generation rounds one train batch consumes.
pub fn rounds_per_batch(k: usize) -> usize {
    match k {
        2 => 1,
        4 => 2,
        _ => panic!("k must be 2 or 4"),
    }
}

/// Run `t` optimizer updates on one assembled batch ("ppo epochs",
/// paper §4.1). Returns the metrics of each update.
///
/// Host slots upload to the device once and are reused across the whole
/// inner loop; device slots (round-resident tokens/masks) move nothing at
/// all. On untupled train artifacts the optimizer triple also stays
/// device-resident, so repeat updates move only the metrics vector.
pub fn train_on_batch(
    engine: &Engine,
    state: &mut TrainState,
    batch: &TrainBatch,
    lr: f32,
    t_updates: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut dev_batch = Vec::with_capacity(batch.tensors.len());
    for (i, slot) in batch.tensors.iter().enumerate() {
        dev_batch.push(match slot {
            // the loss-specific inputs start after (params, m, v, step, lr)
            BatchSlot::Host(t) => engine
                .upload_inputs(batch.artifact, 5 + i, std::slice::from_ref(t))?
                .pop()
                .unwrap(),
            BatchSlot::Device(b) => b.clone(),
        });
    }
    let mut all = Vec::with_capacity(t_updates);
    for _ in 0..t_updates {
        let metrics =
            state.train_step_uploaded(engine, batch.artifact, lr, &dev_batch)?;
        all.push(metrics);
    }
    Ok(all)
}

/// Staleness of a just-applied update: how many optimizer versions behind
/// the freshest pre-update version (`version - 1`) the training data's
/// behaviour policy was. 0 means fully on-policy.
pub fn staleness(version_after_update: u64, data_version: u64) -> u64 {
    version_after_update
        .saturating_sub(1)
        .saturating_sub(data_version)
}

/// Behaviour-policy version of a train batch: the freshest
/// `params_version` among its rounds (k=4 batches pair two rounds, which
/// the sync N-ladder may have generated at different versions; taking the
/// max keeps [`staleness`] conservative). The one definition shared by
/// every [`staleness`] measurement in the pipeline.
pub fn batch_data_version(rounds: &[LabelledRound]) -> u64 {
    rounds
        .iter()
        .map(|r| r.round.params_version)
        .max()
        .unwrap_or(0)
}

/// Per-round training-curve metrics derived from labels (gold win-rate and
/// KL-as-ppl measured on the training stream itself, costing nothing —
/// final eval uses held-out prompts).
pub fn round_metrics(labels: &Labels) -> Vec<(&'static str, f32)> {
    vec![
        ("win_rate", crate::util::mean(&labels.wins)),
        ("gold_score", crate::util::mean(&labels.gold_scores)),
        ("rm_reward", crate::util::mean(&labels.rewards)),
        ("kl_ppl", labels.ref_ppl),
        ("resp_len", labels.mean_len),
        ("behaviour_lp", labels.mean_blp),
    ]
}

/// ExpConfig-driven sampling options.
pub fn sample_opts(cfg: &ExpConfig) -> SampleOpts {
    SampleOpts { temperature: cfg.temperature, greedy: false }
}

#[cfg(test)]
mod tests {
    use super::staleness;

    #[test]
    fn staleness_is_plain_saturating_sub() {
        // on-policy: data generated at the pre-update version
        assert_eq!(staleness(1, 0), 0);
        assert_eq!(staleness(5, 4), 0);
        // one version behind
        assert_eq!(staleness(5, 3), 1);
        // data "from the future" (defensive) saturates to 0
        assert_eq!(staleness(1, 7), 0);
        assert_eq!(staleness(0, 0), 0);
    }

    #[test]
    fn one_step_queue_bounds_staleness() {
        // Discrete model of the bound-0 rendezvous queue: the worker picks
        // up the freshest published params right after handing round t
        // over (i.e. before step t's update publishes), so round t+1 is
        // generated with the version published after step t-1. Per-step
        // staleness is then bounded by 2*T - 1 (T = updates_per_batch) and
        // for the paper's T=1 the mean is <= updates_per_batch = 1.
        for t_updates in [1u64, 2, 3] {
            let steps = 50u64;
            let mut published = 0u64; // latest version the worker saw
            let mut version = 0u64; // trainer's optimizer version
            let mut next_round_version = 0u64; // round in flight
            let mut sum = 0u64;
            for _ in 0..steps {
                let data_version = next_round_version;
                // handover: worker immediately starts the next round with
                // the freshest published params (step's publish not yet out)
                next_round_version = published;
                version += t_updates;
                published = version; // end-of-step publish
                let st = staleness(version, data_version);
                assert!(st <= 2 * t_updates - 1, "st {st} T {t_updates}");
                sum += st;
            }
            let mean = sum as f64 / steps as f64;
            if t_updates == 1 {
                assert!(mean <= 1.0, "mean staleness {mean} > updates_per_batch");
            }
        }
    }
}
