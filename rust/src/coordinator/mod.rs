//! L3 coordinator — the paper's system contribution.
//!
//! - [`pipeline`]: the unified streaming trainer loop. A
//!   [`pipeline::RoundSource`] yields generation rounds; the one trainer
//!   loop ([`pipeline::run`]) stages/labels, assembles, trains, publishes
//!   and logs — identically for every schedule. Sources:
//!   [`pipeline::InlineSource`] (generate on the trainer's engine — the
//!   synchronous schedule, with the §3.2 N-minibatch ladder) and
//!   [`pool::WorkerPool`] (M generation worker threads behind a
//!   **bounded** round queue of depth K — with one worker, queue depth
//!   K ⇒ training data is at most K+1 policy versions stale at the
//!   default one update per batch; K=0 is a rendezvous handover, the
//!   paper's Cleanba one-step coordinator of §3.5/Algorithm 1).
//!   [`run`] dispatches `--mode sync|async|serve` straight onto these
//!   sources — the schedules differ only in who feeds the loop.
//! - [`pool`]: the supervised generation worker pool (seat supervision,
//!   lane ledger, heartbeat watchdog, fault injection) behind the async
//!   schedule and reused by serve's session seats.
//! - [`shard`]: data-parallel trainer shards (`--trainer-shards S`) —
//!   each rank trains its slice of every batch on its own PJRT client,
//!   combined by a deterministic tree all-reduce.
//! - [`trainer`]: shared round machinery (labelling, batch assembly,
//!   fused train-step invocation, staleness accounting).
//! - [`checkpoint`]: crash-safe snapshot/resume of the trainer loop
//!   (`--checkpoint-every` / `--resume`): optimizer triple + RNG and
//!   prompt cursors, written atomically at step boundaries.
//! - [`pretrain`]: the SFT + proxy-RM pipeline that precedes RLHF.

pub mod checkpoint;
pub mod pipeline;
pub mod pool;
pub mod pretrain;
pub mod shard;
pub mod trainer;

use anyhow::Result;

use crate::config::{ExpConfig, Mode};
use crate::data::{Task, TaskGen};
use crate::metrics::{RunLog, Timeline};
use crate::runtime::Engine;

/// Result of one RLHF run.
pub struct RunOutput {
    pub final_params: Vec<f32>,
    pub log: RunLog,
    pub timeline: Timeline,
    pub episodes: u64,
}

/// A reward model hosted by a *different* artifact bundle (Fig 5 right:
/// scaling the RM independently of the policy). Sequences are
/// token-compatible across tldr_{s,m,l} (same vocab + geometry), so a
/// larger RM can score a smaller policy's completions.
pub struct CrossRm {
    pub engine: Engine,
    pub params: Vec<f32>,
}

/// Everything an RLHF run needs besides the config: engine, task stream,
/// SFT checkpoint (policy init + KL reference) and proxy RM.
pub struct Prepared {
    pub engine: Engine,
    pub taskgen: TaskGen,
    pub sft_params: Vec<f32>,
    pub rm_params: Option<Vec<f32>>,
    /// When set, overrides `rm_params` as the reward scorer.
    pub cross_rm: Option<CrossRm>,
}

impl Prepared {
    /// The (engine, params) pair used for reward scoring.
    pub fn rm_scorer(&self) -> Option<(&Engine, &[f32])> {
        if let Some(cr) = &self.cross_rm {
            Some((&cr.engine, &cr.params))
        } else {
            self.rm_params
                .as_deref()
                .map(|p| (&self.engine, p))
        }
    }
}

/// Load artifacts and run (or restore) the SFT/RM pipeline.
pub fn prepare(cfg: &ExpConfig, verbose: bool) -> Result<Prepared> {
    let engine = Engine::load(&cfg.artifact_dir())?;
    let mcfg = engine.manifest.config.clone();
    let task = Task::from_name(&mcfg.task)
        .ok_or_else(|| anyhow::anyhow!("unknown task {}", mcfg.task))?;
    let taskgen = TaskGen::new(task, mcfg.prompt_len, mcfg.resp_len, cfg.seed);

    if verbose {
        eprintln!(
            "[prepare] {} ({} params, task {})",
            mcfg.name, engine.manifest.param_count, mcfg.task
        );
    }
    let sft_params = pretrain::sft_checkpoint(
        &engine, &taskgen, &cfg.run_dir, cfg.sft_steps, None,
    )?;
    let rm_params = if task == Task::Math {
        None // rule reward, no RM (paper §5.2)
    } else {
        Some(pretrain::rm_checkpoint(
            &engine,
            &taskgen,
            &sft_params,
            &cfg.run_dir,
            cfg.rm_steps,
            cfg.seed,
            None,
        )?)
    };
    Ok(Prepared { engine, taskgen, sft_params, rm_params, cross_rm: None })
}

/// Dispatch an RLHF run by mode: every schedule is the one
/// [`pipeline::run`] trainer loop fed by a mode-specific
/// [`pipeline::RoundSource`] (PR 3's thin per-mode constructor modules
/// collapsed into this match once the sources converged).
pub fn run(cfg: &ExpConfig, prep: &Prepared, verbose: bool) -> Result<RunOutput> {
    match cfg.mode {
        // synchronous (paper Fig 2 top): generate on the trainer's own
        // engine via the §3.2 N-minibatch ladder; a `--resume` restart
        // re-enters the inline RNG and prompt cursors exactly, so sync
        // kill-and-resume is bitwise identical to an uninterrupted run
        Mode::Sync => pipeline::run(
            cfg,
            prep,
            |_origin, resume, _bus| {
                let src: Box<dyn pipeline::RoundSource> =
                    Box::new(pipeline::InlineSource::new(cfg, prep, resume)?);
                Ok(src)
            },
            verbose,
        ),
        // asynchronous (paper Fig 2 bottom, Algorithm 1): a supervised
        // worker pool behind a bounded round queue; a `--resume` restart
        // re-enters each lane's cursor under a fresh RNG epoch —
        // exactly-once delivery, not bitwise replay
        Mode::Async => pipeline::run(
            cfg,
            prep,
            |origin, resume, bus| {
                let src: Box<dyn pipeline::RoundSource> = Box::new(
                    pool::WorkerPool::spawn(cfg, prep, origin, resume, bus.clone())?,
                );
                Ok(src)
            },
            verbose,
        ),
        Mode::Serve => crate::serve::run(cfg, prep, verbose),
    }
}
