//! Fig 1 + Fig 2 + A.2 overhead: the headline sync-vs-async comparison.
//!
//! Shapes to reproduce (DESIGN.md §6):
//! - async matches sync final win-rate at every scale,
//! - async wall-clock < sync wall-clock, gap growing with scale,
//! - async step time ≈ max(gen, train) + small overhead (A.2).

use anyhow::Result;

use super::runner::{base_cfg, print_table, run_variant, save_csv};
use super::{out_dir, require_model};
use crate::config::Mode;
use crate::coordinator;
use crate::metrics::Phase;
use crate::sim::{analyze, StepCosts};
use crate::util::args::Args;

pub fn fig1(args: &Args) -> Result<()> {
    let models: Vec<String> = args
        .get("models")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["tldr_s".into(), "tldr_m".into(), "tldr_l".into()]);
    let mut rows = Vec::new();
    for model in &models {
        require_model(args, model)?;
        let base = base_cfg(args, model)?;
        let verbose = !args.has_flag("quiet");
        let prep = coordinator::prepare(&base, verbose)?;
        for mode in [Mode::Sync, Mode::Async] {
            let mut cfg = base.clone();
            cfg.mode = mode;
            eprintln!("[fig1] {model} {}", mode.name());
            let r = run_variant(&cfg, &prep, verbose)?;
            rows.push(vec![
                model.clone(),
                mode.name().to_string(),
                format!("{:.3}", r.eval.win_rate),
                format!("{:.4}", r.eval.kl_ppl),
                format!("{:.1}", r.out.timeline.wall()),
                r.out.episodes.to_string(),
            ]);
        }
        // speedup row
        if let [.., s, a] = &rows[..] {
            let sw: f32 = s[4].parse().unwrap_or(1.0);
            let aw: f32 = a[4].parse().unwrap_or(1.0);
            eprintln!(
                "[fig1] {model}: async {:.1}% faster",
                (sw / aw - 1.0) * 100.0
            );
        }
    }
    print_table(
        "Fig 1: final win-rate and wall-clock, sync vs async (Online DPO)",
        &["model", "mode", "win_rate", "kl_ppl", "wall_s", "episodes"],
        &rows,
    );
    let dir = out_dir(args).join("fig1");
    save_csv(&dir, "final",
             &["model", "mode", "win_rate", "kl_ppl", "wall_s", "episodes"],
             &rows)?;
    println!("saved: {}", dir.display());
    Ok(())
}

pub fn fig2(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tldr_s").to_string();
    require_model(args, &model)?;
    let base = base_cfg(args, &model)?;
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&base, verbose)?;
    for mode in [Mode::Sync, Mode::Async] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        cfg.steps = cfg.steps.min(12); // a short window renders legibly
        let r = run_variant(&cfg, &prep, verbose)?;
        println!("\n== Fig 2 ({}) measured schedule ==", mode.name());
        println!("{}", r.out.timeline.render_ascii(96));
        let totals = r.out.timeline.totals();
        for (phase, secs) in &totals {
            println!("  {:<9} {secs:>8.2}s", phase.name());
        }
    }
    Ok(())
}

/// A.2: overhead decomposition. Measures real per-phase times from a short
/// async run, then compares the measured wall against the ideal schedule
/// (max of gen/train) and against sync — in this testbed's ratios and in
/// the paper's (21 s / 33 s).
pub fn overhead(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tldr_s").to_string();
    require_model(args, &model)?;
    let base = base_cfg(args, &model)?;
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&base, verbose)?;

    let mut rows = Vec::new();
    let mut measured: Vec<(Mode, f64, std::collections::BTreeMap<Phase, f64>)> =
        Vec::new();
    for mode in [Mode::Sync, Mode::Async] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        let r = run_variant(&cfg, &prep, verbose)?;
        let totals = r.out.timeline.totals();
        measured.push((mode, r.out.timeline.wall(), totals.clone()));
        let steps = cfg.steps as f64;
        rows.push(vec![
            mode.name().to_string(),
            format!("{:.2}", totals.get(&Phase::Generate).unwrap_or(&0.0) / steps),
            format!("{:.2}", totals.get(&Phase::Score).unwrap_or(&0.0) / steps),
            format!("{:.2}", totals.get(&Phase::Train).unwrap_or(&0.0) / steps),
            format!("{:.2}", totals.get(&Phase::Publish).unwrap_or(&0.0) / steps),
            format!("{:.2}", r.out.timeline.wall() / steps),
        ]);
    }
    print_table(
        "A.2: measured per-step phase seconds",
        &["mode", "gen", "score", "train", "publish", "step"],
        &rows,
    );

    // ideal vs actual (paper A.2 arithmetic) on measured costs
    if let [(_, _sync_wall, st), (_, async_wall, _)] = &measured[..] {
        let steps = base.steps;
        let per = |p: Phase| st.get(&p).copied().unwrap_or(0.0) / steps as f64;
        let costs = StepCosts::new(per(Phase::Generate), per(Phase::Score), per(Phase::Train));
        let a = analyze(&costs, steps);
        println!("\nideal-schedule analysis on measured costs:");
        println!("  sync  (model) : {:.1}s", a.sync_wall);
        println!("  ideal async   : {:.1}s ({:+.1}%)", a.ideal_wall, a.ideal_speedup_pct);
        println!(
            "  actual async  : {:.1}s (overhead {:.2}s/step)",
            async_wall,
            (async_wall - a.ideal_wall).max(0.0) / steps as f64
        );
    }

    // the paper's own numbers through the same analyzer
    let paper = analyze(&StepCosts::new(21.0, 0.0, 33.0), 233);
    println!("\npaper №Robots costs (21 s gen / 33 s train, 233 steps):");
    println!(
        "  sync {:.0} min, ideal async {:.0} min ({:+.0}%)",
        paper.sync_wall / 60.0,
        paper.ideal_wall / 60.0,
        paper.ideal_speedup_pct
    );
    Ok(())
}
