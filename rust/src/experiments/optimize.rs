//! §4 compute-balance optimizations.
//!
//! - Fig 6: idle-time analysis for mismatched gen/train speeds (simulated
//!   over a ratio sweep + measured on this testbed).
//! - Fig 7 (generation-bound): T ∈ {1,2,3} updates per mini-batch raises
//!   sample efficiency but drifts KL.
//! - Fig 8 (training-bound): K=4 best/worst-of-K with lr/2 and steps/2
//!   reaches the same win-rate in roughly half the compute, at higher KL.

use anyhow::Result;

use super::runner::{base_cfg, print_table, run_variant, save_csv};
use super::{out_dir, require_model};
use crate::config::{Algo, Mode};
use crate::coordinator;
use crate::sim::{classify, simulate_async, Bound, StepCosts};
use crate::util::args::Args;

pub fn fig6(args: &Args) -> Result<()> {
    // simulated idle-time sweep over gen:train ratios
    let mut rows = Vec::new();
    for ratio in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let costs = StepCosts::new(ratio, 0.0, 1.0);
        let steps = 200;
        let r = simulate_async(&costs, steps);
        let bound = match classify(&costs) {
            Bound::GenerationBound => "generation-bound",
            Bound::TrainingBound => "training-bound",
            Bound::Balanced => "balanced",
        };
        rows.push(vec![
            format!("{ratio:.2}"),
            bound.to_string(),
            format!("{:.1}%", 100.0 * r.gen_idle / r.wall),
            format!("{:.1}%", 100.0 * r.train_idle / r.wall),
        ]);
    }
    print_table(
        "Fig 6: idle fraction vs gen:train ratio (bound-1 async queue)",
        &["gen/train", "regime", "gen idle", "train idle"],
        &rows,
    );
    save_csv(&out_dir(args).join("fig6"), "sim",
             &["ratio", "regime", "gen_idle", "train_idle"], &rows)?;
    Ok(())
}

pub fn fig7(args: &Args) -> Result<()> {
    let models: Vec<String> = args
        .get("models")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["tldr_s".into(), "tldr_m".into()]);
    let ts: Vec<usize> = args.get_list("t-sweep", &[1usize, 2, 3])?;
    let mut rows = Vec::new();
    for model in &models {
        require_model(args, model)?;
        let mut base = base_cfg(args, model)?;
        base.mode = Mode::Async;
        base.algo = Algo::Dpo;
        let verbose = !args.has_flag("quiet");
        let prep = coordinator::prepare(&base, verbose)?;
        for &t in &ts {
            let mut cfg = base.clone();
            cfg.updates_per_batch = t;
            eprintln!("[fig7] {model} T={t}");
            let r = run_variant(&cfg, &prep, verbose)?;
            rows.push(vec![
                model.clone(),
                t.to_string(),
                format!("{:.3}", r.eval.win_rate),
                format!("{:.4}", r.eval.kl_ppl),
                r.out.episodes.to_string(),
            ]);
        }
    }
    print_table(
        "Fig 7: updates-per-batch T (generation-bound optimization)",
        &["model", "T", "win_rate", "kl_ppl", "episodes"],
        &rows,
    );
    save_csv(&out_dir(args).join("fig7"), "final",
             &["model", "T", "win_rate", "kl_ppl", "episodes"], &rows)?;
    Ok(())
}

pub fn fig8(args: &Args) -> Result<()> {
    let models: Vec<String> = args
        .get("models")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["tldr_s".into(), "tldr_m".into()]);
    let mut rows = Vec::new();
    for model in &models {
        require_model(args, model)?;
        let mut base = base_cfg(args, model)?;
        base.mode = Mode::Async;
        base.algo = Algo::Dpo;
        let verbose = !args.has_flag("quiet");
        let prep = coordinator::prepare(&base, verbose)?;

        // K=2 baseline at full steps; K=4 with lr/2 and steps/2 (paper §4.2)
        for (k, lr_mult, step_mult) in [(2usize, 1.0f32, 1.0f64), (4, 0.5, 0.5)] {
            let mut cfg = base.clone();
            cfg.k_samples = k;
            cfg.lr = base.lr * lr_mult;
            cfg.steps = ((base.steps as f64) * step_mult).max(1.0) as u64;
            eprintln!("[fig8] {model} K={k} lr={} steps={}", cfg.lr, cfg.steps);
            let r = run_variant(&cfg, &prep, verbose)?;
            rows.push(vec![
                model.clone(),
                format!("K={k}"),
                format!("{:.3}", r.eval.win_rate),
                format!("{:.4}", r.eval.kl_ppl),
                format!("{:.1}", r.out.timeline.wall()),
                r.out.episodes.to_string(),
            ]);
        }
    }
    print_table(
        "Fig 8: best/worst-of-K sampling (training-bound optimization)",
        &["model", "variant", "win_rate", "kl_ppl", "wall_s", "episodes"],
        &rows,
    );
    save_csv(&out_dir(args).join("fig8"), "final",
             &["model", "variant", "win_rate", "kl_ppl", "wall_s", "episodes"],
             &rows)?;
    Ok(())
}
