//! Fig 4 + Fig 13: robustness of RLHF losses to off-policyness.
//!
//! Paper shapes to reproduce:
//! - Fig 4: Online DPO retains performance across N ∈ {1,2,4,8,16}; PPO
//!   and RLOO degrade sharply; Best-of-2 SFT also fails to retain.
//! - Fig 13: CoPG-style RLOO collapses at N=16 while Proximal RLOO
//!   (clipped IS) survives.

use anyhow::Result;

use super::runner::{base_cfg, print_table, run_variant, save_csv};
use super::{out_dir, require_model};
use crate::config::Algo;
use crate::coordinator;
use crate::util::args::Args;

fn loss_sweep(
    args: &Args,
    algos: &[Algo],
    ns: &[usize],
    title: &str,
    out_name: &str,
) -> Result<()> {
    let model = args.get_or("model", "tldr_s").to_string();
    require_model(args, &model)?;
    let base = base_cfg(args, &model)?;
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&base, verbose)?;

    let mut rows = Vec::new();
    for &algo in algos {
        for &n in ns {
            let mut cfg = base.clone();
            cfg.algo = algo;
            cfg.n_minibatches = n;
            eprintln!("[{out_name}] {algo} N={n}");
            let r = run_variant(&cfg, &prep, verbose)?;
            rows.push(vec![
                algo.name().to_string(),
                n.to_string(),
                format!("{:.3}", r.eval.win_rate),
                format!("{:.4}", r.eval.kl_ppl),
                format!("{:.3}", r.eval.mean_gold),
            ]);
        }
    }
    print_table(
        title,
        &["algo", "N", "win_rate", "kl_ppl", "gold"],
        &rows,
    );
    let dir = out_dir(args).join(out_name);
    save_csv(&dir, "final", &["algo", "N", "win_rate", "kl_ppl", "gold"], &rows)?;
    println!("saved: {}", dir.display());
    Ok(())
}

pub fn fig4(args: &Args) -> Result<()> {
    let ns: Vec<usize> = args.get_list("n-sweep", &[1usize, 2, 4, 8, 16])?;
    loss_sweep(
        args,
        &[Algo::Dpo, Algo::Ppo, Algo::Rloo, Algo::BestOfN],
        &ns,
        "Fig 4: loss robustness across off-policyness N",
        "fig4",
    )
}

pub fn fig13(args: &Args) -> Result<()> {
    let ns: Vec<usize> = args.get_list("n-sweep", &[1usize, 4, 16])?;
    loss_sweep(
        args,
        &[Algo::Prloo, Algo::Copg],
        &ns,
        "Fig 13: Proximal RLOO vs CoPG under off-policyness",
        "fig13",
    )
}
