//! Fig 3: PPO under increasing off-policyness (N mini-batches per
//! generation round). Paper findings to reproduce in shape:
//! - win-rate degrades monotonically (log-ish) with N; N=1 ≈ N=2,
//! - all N lie on roughly the same win-rate-vs-KL pareto curve — staleness
//!   slows progress along the frontier rather than moving it.

use anyhow::Result;

use super::runner::{base_cfg, print_table, run_variant, save_csv};
use super::{out_dir, require_model};
use crate::config::Algo;
use crate::coordinator;
use crate::util::args::Args;

pub fn fig3(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tldr_s").to_string();
    require_model(args, &model)?;
    let ns: Vec<usize> = args.get_list("n-sweep", &[1usize, 2, 4, 8, 16, 32, 64])?;
    let base = {
        let mut c = base_cfg(args, &model)?;
        c.algo = Algo::Ppo;
        c
    };
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&base, verbose)?;

    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for &n in &ns {
        let mut cfg = base.clone();
        cfg.n_minibatches = n;
        // keep total updates fixed: steps is already the number of
        // minibatch updates, so nothing else changes — larger N only
        // changes how stale the data is.
        eprintln!("[fig3] PPO N={n}");
        let r = run_variant(&cfg, &prep, verbose)?;
        // training curves (win-rate + KL over steps) for the left/middle
        // panels
        for (step, win) in r.out.log.series("win_rate") {
            let kl = r
                .out
                .log
                .rows
                .iter()
                .find(|row| row.step == step)
                .and_then(|row| row.values.get("kl_ppl").copied())
                .unwrap_or(f32::NAN);
            curves.push(vec![
                n.to_string(),
                step.to_string(),
                format!("{win:.4}"),
                format!("{kl:.5}"),
            ]);
        }
        rows.push(vec![
            format!("N={n}"),
            format!("{:.3}", r.eval.win_rate),
            format!("{:.4}", r.eval.kl_ppl),
            format!("{:.3}", r.eval.mean_gold),
            format!("{:.1}", r.out.timeline.wall()),
        ]);
    }

    print_table(
        "Fig 3 (right): final win-rate vs KL across off-policyness N (PPO)",
        &["variant", "win_rate", "kl_ppl", "gold", "wall_s"],
        &rows,
    );
    let dir = out_dir(args).join("fig3");
    save_csv(&dir, "final", &["variant", "win_rate", "kl_ppl", "gold", "wall_s"], &rows)?;
    save_csv(&dir, "curves", &["n", "step", "win_rate", "kl_ppl"], &curves)?;
    println!("saved: {}", dir.display());
    Ok(())
}
