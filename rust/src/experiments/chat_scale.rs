//! Table 1/8 + Fig 9 (chatbot, Online DPO) and Table 9 + Fig 10 (PPO):
//! the paper's at-scale verification on the instruction-following task.
//!
//! Shapes to reproduce: async matches sync win-rate while being ~40%
//! faster; the SFT row sits far below both; PPO also works async but
//! scores below Online DPO.

use anyhow::Result;

use super::runner::{base_cfg, print_table, run_variant, save_csv};
use super::{out_dir, require_model};
use crate::config::{Algo, Mode};
use crate::coordinator;
use crate::eval::evaluate;
use crate::util::args::Args;

fn chat_table(args: &Args, algo: Algo, title: &str, out_name: &str) -> Result<()> {
    let model = args.get_or("model", "chat_m").to_string();
    require_model(args, &model)?;
    let mut base = base_cfg(args, &model)?;
    base.algo = algo;
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&base, verbose)?;

    // SFT baseline row
    let sft_eval = evaluate(
        &prep.engine,
        &prep.sft_params,
        &prep.sft_params,
        &prep.taskgen,
        base.eval_prompts,
        base.temperature,
        base.seed,
    )?;
    let mut rows = vec![vec![
        "SFT".to_string(),
        format!("{:.2}%", sft_eval.win_rate * 100.0),
        "-".to_string(),
        format!("{:.1}", sft_eval.mean_len),
        format!("{:.4}", sft_eval.kl_ppl),
    ]];

    for mode in [Mode::Sync, Mode::Async] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        eprintln!("[{out_name}] {} {}", algo.name(), mode.name());
        let r = run_variant(&cfg, &prep, verbose)?;
        rows.push(vec![
            format!("{} {}", mode.name(), algo.name()),
            format!("{:.2}%", r.eval.win_rate * 100.0),
            format!("{:.1}", r.out.timeline.wall()),
            format!("{:.1}", r.eval.mean_len),
            format!("{:.4}", r.eval.kl_ppl),
        ]);
    }
    print_table(
        title,
        &["model", "win_rate", "compute_s", "resp_len", "kl_ppl"],
        &rows,
    );
    save_csv(&out_dir(args).join(out_name), "final",
             &["model", "win_rate", "compute_s", "resp_len", "kl_ppl"],
             &rows)?;
    Ok(())
}

pub fn table1(args: &Args) -> Result<()> {
    chat_table(
        args,
        Algo::Dpo,
        "Table 1/8: chatbot at scale — sync vs async Online DPO",
        "table1",
    )
}

pub fn table9(args: &Args) -> Result<()> {
    chat_table(
        args,
        Algo::Ppo,
        "Table 9: chatbot at scale — sync vs async PPO",
        "table9",
    )
}
