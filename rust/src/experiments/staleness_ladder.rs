//! Staleness ladder: the pipeline's K × M sweep.
//!
//! The related work treats the staleness bound as the object of study
//! (*Staleness–Learning Rate Scaling Laws for Asynchronous RLHF* sweeps
//! it directly); with the unified pipeline it is a config knob, so this
//! runner sweeps queue depth K × worker count M on one artifact and
//! reports, per config: final win-rate and KL, mean/max measured
//! staleness against the proven bound, trainer idle time and wall clock.
//!
//! `async-rlhf exp staleness` prints the table and saves the CSV;
//! `benches/staleness.rs` drives [`sweep`] on the small artifact and
//! dumps [`bench_json`] to `BENCH_staleness.json` for the perf/quality
//! trajectory.

use anyhow::Result;

use super::runner::{base_cfg, print_table, run_variant, save_csv};
use super::{out_dir, require_model};
use crate::config::{ExpConfig, Mode};
use crate::coordinator::pipeline::staleness_bound_updates;
use crate::coordinator::{self, Prepared};
use crate::metrics::Phase;
use crate::util::args::Args;
use crate::util::json::Json;

/// One (K, M) configuration's measurements.
pub struct LadderPoint {
    pub k_bound: usize,
    pub workers: usize,
    pub win_rate: f32,
    pub kl_ppl: f32,
    pub mean_staleness: f64,
    pub max_staleness: u64,
    /// Worst case for this config ([`staleness_bound_updates`]): proven
    /// for M=1, fair-scheduling for M>1 — `max_staleness` is checked
    /// against it where proven, reported against it otherwise.
    pub bound: u64,
    /// Trainer idle seconds (waiting on the round queue).
    pub idle_secs: f64,
    pub wall_secs: f64,
    /// Worker deaths recovered by the supervisor (run meta).
    pub worker_restarts: u64,
    /// Workers the heartbeat watchdog ever flagged (run meta) — the
    /// observable behind the M>1 fair-scheduling caveat.
    pub stalled_workers: u64,
    /// Lanes re-strided onto a survivor after a restart-exhausted
    /// continuous seat died (run meta) — nonzero only under faults.
    pub lanes_reassigned: u64,
    /// Optimizer steps delivered while at least one seat was lost for
    /// good (run meta) — how much of the measured wall clock ran at
    /// degraded generation capacity.
    pub degraded_capacity_steps: u64,
}

/// Parse a numeric run meta, defaulting to 0 when absent (e.g. logs
/// written before the supervision layer).
fn meta_u64(r: &super::runner::VariantResult, key: &str) -> u64 {
    r.out
        .log
        .meta
        .get(key)
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0)
}

/// Run the ladder: every (K, M) in `ks` × `ms`, async mode, on a shared
/// `prep`. Errors if a single-worker config's measured staleness escapes
/// its proven bound — the sweep doubles as an invariant check on real
/// executables; multi-worker configs only warn (their bound assumes fair
/// worker scheduling) and the JSON records `within_bound` either way.
pub fn sweep(
    base: &ExpConfig,
    prep: &Prepared,
    ks: &[usize],
    ms: &[usize],
    verbose: bool,
) -> Result<Vec<LadderPoint>> {
    let mut points = Vec::with_capacity(ks.len() * ms.len());
    for &m in ms {
        for &k in ks {
            let mut cfg = base.clone();
            cfg.mode = Mode::Async;
            cfg.gen_workers = m;
            cfg.staleness_bound = k;
            eprintln!("[staleness] K={k} M={m}");
            let r = run_variant(&cfg, prep, verbose)?;
            let st: Vec<u64> = r
                .out
                .log
                .rows
                .iter()
                .filter_map(|row| row.values.get("staleness"))
                .map(|&s| s as u64)
                .collect();
            let max_staleness = st.iter().copied().max().unwrap_or(0);
            let mean_staleness =
                st.iter().sum::<u64>() as f64 / st.len().max(1) as f64;
            let bound = staleness_bound_updates(k, m, cfg.updates_per_batch);
            let stalled_workers = meta_u64(&r, "stalled_workers");
            if max_staleness > bound {
                if m == 1 {
                    anyhow::bail!(
                        "K={k}: measured staleness {max_staleness} exceeds \
                         the proven bound {bound}"
                    );
                }
                eprintln!(
                    "[staleness] WARN K={k} M={m}: {max_staleness} > \
                     fair-scheduling bound {bound} ({stalled_workers} \
                     worker(s) flagged stalled)"
                );
            }
            points.push(LadderPoint {
                k_bound: k,
                workers: m,
                win_rate: r.eval.win_rate,
                kl_ppl: r.eval.kl_ppl,
                mean_staleness,
                max_staleness,
                bound,
                idle_secs: r.out.timeline.total(Phase::Idle),
                wall_secs: r.out.timeline.wall(),
                worker_restarts: meta_u64(&r, "worker_restarts"),
                stalled_workers,
                lanes_reassigned: meta_u64(&r, "lanes_reassigned"),
                degraded_capacity_steps: meta_u64(
                    &r,
                    "degraded_capacity_steps",
                ),
            });
        }
    }
    Ok(points)
}

/// Table rows for printing/CSV.
fn rows(points: &[LadderPoint]) -> Vec<Vec<String>> {
    points
        .iter()
        .map(|p| {
            vec![
                format!("K={} M={}", p.k_bound, p.workers),
                format!("{:.3}", p.win_rate),
                format!("{:.4}", p.kl_ppl),
                format!("{:.2}", p.mean_staleness),
                format!("{}", p.max_staleness),
                format!("{}", p.bound),
                format!("{:.2}", p.idle_secs),
                format!("{:.1}", p.wall_secs),
                format!("{}", p.worker_restarts),
                format!("{}", p.stalled_workers),
                format!("{}", p.lanes_reassigned),
                format!("{}", p.degraded_capacity_steps),
            ]
        })
        .collect()
}

const HEADERS: &[&str] = &[
    "config",
    "win_rate",
    "kl_ppl",
    "mean_stale",
    "max_stale",
    "bound",
    "idle_s",
    "wall_s",
    "restarts",
    "stalled",
    "reassigned",
    "degraded",
];

/// Machine-readable dump for `BENCH_staleness.json`.
pub fn bench_json(model: &str, steps: u64, points: &[LadderPoint]) -> Json {
    let configs = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("k_bound", Json::num(p.k_bound as f64)),
                ("gen_workers", Json::num(p.workers as f64)),
                ("win_rate", Json::num(p.win_rate as f64)),
                ("kl_ppl", Json::num(p.kl_ppl as f64)),
                ("mean_staleness", Json::num(p.mean_staleness)),
                ("max_staleness", Json::num(p.max_staleness as f64)),
                ("bound", Json::num(p.bound as f64)),
                (
                    "within_bound",
                    Json::Bool(p.max_staleness <= p.bound),
                ),
                ("idle_secs", Json::num(p.idle_secs)),
                ("wall_secs", Json::num(p.wall_secs)),
                ("worker_restarts", Json::num(p.worker_restarts as f64)),
                ("stalled_workers", Json::num(p.stalled_workers as f64)),
                ("lanes_reassigned", Json::num(p.lanes_reassigned as f64)),
                (
                    "degraded_capacity_steps",
                    Json::num(p.degraded_capacity_steps as f64),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("model", Json::str(model)),
        ("steps", Json::num(steps as f64)),
        ("configs", Json::Arr(configs)),
    ])
}

/// `exp staleness`: K ∈ {0,1,2,4} × M ∈ {1,2} by default
/// (`--k-sweep` / `--m-sweep` override), small artifact.
pub fn ladder(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tldr_s").to_string();
    require_model(args, &model)?;
    let ks: Vec<usize> = args.get_list("k-sweep", &[0usize, 1, 2, 4])?;
    let ms: Vec<usize> = args.get_list("m-sweep", &[1usize, 2])?;
    let base = base_cfg(args, &model)?;
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&base, verbose)?;

    let points = sweep(&base, &prep, &ks, &ms, verbose)?;
    let table = rows(&points);
    print_table(
        "Staleness ladder: queue depth K x workers M (async pipeline)",
        HEADERS,
        &table,
    );
    let dir = out_dir(args).join("staleness");
    save_csv(&dir, "ladder", HEADERS, &table)?;
    println!("saved: {}", dir.display());
    Ok(())
}
