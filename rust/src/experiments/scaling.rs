//! Fig 5 + Table 3: model-scale effects.
//!
//! - Table 3: SFT baseline win-rate/perplexity per policy scale (the floor
//!   RLHF starts from).
//! - Fig 5 left: scaling the *policy* (s/m/l, RM fixed small) tightens the
//!   off-policy pareto cluster — bigger policies tolerate staleness.
//! - Fig 5 right: scaling the *reward model* does not improve off-policy
//!   robustness (it reduces overoptimization, not staleness sensitivity).
//!
//! The RM-scaling arm uses the policy-size config's RM checkpoint trained
//! at a different scale; since our artifact bundles pair policy and RM
//! geometry, we emulate "small policy + larger RM" by training the RM
//! longer/shorter... no — honestly: we train RMs at each scale using that
//! scale's trunk, and score the small policy's completions with it through
//! that scale's `score_rm` executable (sequences are token-compatible:
//! same vocab and sequence geometry across tldr_{s,m,l}).

use anyhow::Result;

use super::runner::{base_cfg, print_table, run_variant, save_csv};
use super::{out_dir, require_model};
use crate::config::Algo;
use crate::coordinator::{self, pretrain};
use crate::data::{Task, TaskGen};
use crate::eval::evaluate;
use crate::runtime::Engine;
use crate::util::args::Args;

pub fn table3(args: &Args) -> Result<()> {
    let models: Vec<String> = args
        .get("models")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["tldr_s".into(), "tldr_m".into(), "tldr_l".into()]);
    let mut rows = Vec::new();
    for model in &models {
        require_model(args, model)?;
        let cfg = base_cfg(args, model)?;
        let engine = Engine::load(&cfg.artifact_dir())?;
        let mcfg = engine.manifest.config.clone();
        let taskgen = TaskGen::new(
            Task::from_name(&mcfg.task).unwrap(),
            mcfg.prompt_len,
            mcfg.resp_len,
            cfg.seed,
        );
        let sft = pretrain::sft_checkpoint(
            &engine, &taskgen, &cfg.run_dir, cfg.sft_steps, None,
        )?;
        let ev = evaluate(
            &engine, &sft, &sft, &taskgen, cfg.eval_prompts,
            cfg.temperature, cfg.seed,
        )?;
        rows.push(vec![
            format!("SFT {model}"),
            format!("{:.2}%", ev.win_rate * 100.0),
            format!("{:.4}", ev.kl_ppl),
            format!("{:.3}", ev.mean_gold),
            format!("{:.1}", ev.mean_len),
        ]);
    }
    print_table(
        "Table 3: SFT baselines before RLHF",
        &["model", "win_rate", "ppl", "gold", "len"],
        &rows,
    );
    save_csv(&out_dir(args).join("table3"), "final",
             &["model", "win_rate", "ppl", "gold", "len"], &rows)?;
    Ok(())
}

pub fn fig5(args: &Args) -> Result<()> {
    let ns: Vec<usize> = args.get_list("n-sweep", &[1usize, 4, 16, 64])?;
    let models: Vec<String> = args
        .get("models")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["tldr_s".into(), "tldr_m".into(), "tldr_l".into()]);

    // Left panel: policy scaling (each scale trains its own policy+RM pair;
    // the paper's 410m-RM control is approximated by the fixed RM recipe —
    // same data, same steps — at each scale).
    let mut rows = Vec::new();
    for model in &models {
        require_model(args, model)?;
        let base = {
            let mut c = base_cfg(args, model)?;
            c.algo = Algo::Dpo;
            c
        };
        let verbose = !args.has_flag("quiet");
        let prep = coordinator::prepare(&base, verbose)?;
        for &n in &ns {
            let mut cfg = base.clone();
            cfg.n_minibatches = n;
            eprintln!("[fig5] policy={model} N={n}");
            let r = run_variant(&cfg, &prep, verbose)?;
            rows.push(vec![
                model.clone(),
                n.to_string(),
                format!("{:.3}", r.eval.win_rate),
                format!("{:.4}", r.eval.kl_ppl),
            ]);
        }
    }
    print_table(
        "Fig 5 (left): off-policy pareto points vs policy scale (Online DPO)",
        &["policy", "N", "win_rate", "kl_ppl"],
        &rows,
    );
    let dir = out_dir(args).join("fig5");
    save_csv(&dir, "policy_scaling", &["policy", "N", "win_rate", "kl_ppl"], &rows)?;

    // Right panel: RM scaling with the small policy. Completions come from
    // the tldr_s policy; rewards come from RMs trained at s/m/l scales
    // (cross-scale scoring is legal: same vocab + sequence geometry).
    let mut rm_rows = Vec::new();
    let small = models.first().cloned().unwrap_or_else(|| "tldr_s".into());
    for rm_model in &models {
        require_model(args, rm_model)?;
        for &n in &ns {
            let mut cfg = base_cfg(args, &small)?;
            cfg.algo = Algo::Dpo;
            cfg.n_minibatches = n;
            eprintln!("[fig5] rm={rm_model} N={n}");
            let r = run_cross_rm(&cfg, rm_model, args)?;
            rm_rows.push(vec![
                rm_model.clone(),
                n.to_string(),
                format!("{:.3}", r.0),
                format!("{:.4}", r.1),
            ]);
        }
    }
    print_table(
        "Fig 5 (right): off-policy pareto points vs reward-model scale",
        &["rm", "N", "win_rate", "kl_ppl"],
        &rm_rows,
    );
    save_csv(&dir, "rm_scaling", &["rm", "N", "win_rate", "kl_ppl"], &rm_rows)?;
    println!("saved: {}", dir.display());
    Ok(())
}

/// Train the small policy against an RM from a different-scale bundle.
/// Returns (win_rate, kl_ppl).
fn run_cross_rm(
    cfg: &crate::config::ExpConfig,
    rm_model: &str,
    args: &Args,
) -> Result<(f32, f32)> {
    use crate::coordinator::CrossRm;
    let verbose = !args.has_flag("quiet");
    let mut prep = coordinator::prepare(cfg, verbose)?;
    if rm_model != cfg.model {
        // load the other bundle, train/load its RM, and attach it as a
        // cross-scale scorer
        let mut rm_cfg = cfg.clone();
        rm_cfg.model = rm_model.to_string();
        let rm_prep = coordinator::prepare(&rm_cfg, verbose)?;
        prep.cross_rm = Some(CrossRm {
            engine: rm_prep.engine,
            params: rm_prep.rm_params.expect("rm task"),
        });
        prep.rm_params = None;
    }
    let r = run_variant(cfg, &prep, verbose)?;
    Ok((r.eval.win_rate, r.eval.kl_ppl))
}
