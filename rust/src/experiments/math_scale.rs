//! Table 2/11 + Fig 11: math & reasoning (GSM8k analogue).
//!
//! Rule-based exact-match reward, no reward model. Shapes to reproduce:
//! sync Online DPO >= RLOO >= (PPO baseline); async Online DPO matches
//! sync pass@1 while being substantially faster; KL (base-model ppl on
//! completions) stays comparable.

use anyhow::Result;

use super::runner::{base_cfg, print_table, run_variant, save_csv};
use super::{out_dir, require_model};
use crate::config::{Algo, Mode};
use crate::coordinator;
use crate::eval::evaluate;
use crate::util::args::Args;

pub fn table2(args: &Args) -> Result<()> {
    let model = args.get_or("model", "math_s").to_string();
    require_model(args, &model)?;
    let base = base_cfg(args, &model)?;
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&base, verbose)?;

    // SFT row (pass@1 of the warm-started model)
    let sft_eval = evaluate(
        &prep.engine,
        &prep.sft_params,
        &prep.sft_params,
        &prep.taskgen,
        base.eval_prompts,
        base.temperature,
        base.seed,
    )?;
    let mut rows = vec![vec![
        "SFT".to_string(),
        format!("{:.1}%", sft_eval.pass1 * 100.0),
        "-".to_string(),
        "-".to_string(),
    ]];

    let variants: Vec<(String, Algo, Mode)> = vec![
        ("Sync PPO".into(), Algo::Ppo, Mode::Sync),
        ("Sync RLOO".into(), Algo::Rloo, Mode::Sync),
        ("Sync Online DPO".into(), Algo::Dpo, Mode::Sync),
        ("Async Online DPO".into(), Algo::Dpo, Mode::Async),
    ];
    for (label, algo, mode) in &variants {
        let mut cfg = base.clone();
        cfg.algo = *algo;
        cfg.mode = *mode;
        eprintln!("[table2] {label}");
        let r = run_variant(&cfg, &prep, verbose)?;
        rows.push(vec![
            label.clone(),
            format!("{:.1}%", r.eval.pass1 * 100.0),
            format!("{:.4}", r.eval.kl_ppl),
            format!("{:.1}", r.out.timeline.wall()),
        ]);
    }
    print_table(
        "Table 2/11: math exact-match (pass@1), KL (ppl), compute time",
        &["model", "pass@1", "ppl", "compute_s"],
        &rows,
    );
    save_csv(&out_dir(args).join("table2"), "final",
             &["model", "pass@1", "ppl", "compute_s"], &rows)?;

    // speedup callout (paper: async 68% faster than sync on GSM8k)
    if rows.len() >= 2 {
        let sync_dpo: f32 = rows[rows.len() - 2][3].parse().unwrap_or(0.0);
        let async_dpo: f32 = rows[rows.len() - 1][3].parse().unwrap_or(1.0);
        if async_dpo > 0.0 {
            println!(
                "async speedup vs sync DPO: {:+.1}%",
                (sync_dpo / async_dpo - 1.0) * 100.0
            );
        }
    }
    Ok(())
}
