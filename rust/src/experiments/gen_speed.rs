//! Fig 14 / Appendix C.1: generation-engine speed, cached (vLLM analogue)
//! vs naive full-recompute (HF-transformers analogue), across model scales
//! — plus the device-KV tier (step-wise decode with the cache chained
//! device-to-device) sitting between them.
//!
//! Shape to reproduce: cached >> naive at every scale, with the gap
//! growing superlinearly in model size (the paper measures 12-20x for
//! 7-8B models; asymptotically the naive engine pays O(S) forwards of
//! O(S) tokens per response vs the cached engine's O(S) single-token
//! steps). The device tier runs the same arithmetic as cached but strips
//! the per-token KV literal round-trip, so its gap to cached isolates
//! pure data movement — the paper's "asynchronous speedups are bounded by
//! the slowest stage's data movement" observation in microcosm.

use std::time::Instant;

use anyhow::Result;

use super::runner::{print_table, save_csv};
use super::{out_dir, require_model};
use crate::data::{Task, TaskGen};
use crate::gen::{
    cached::CachedEngine, device::DeviceCachedEngine, naive::NaiveEngine,
    Generator, SampleOpts,
};
use crate::runtime::{Engine, ParamView};
use crate::util::args::Args;

pub fn fig14(args: &Args) -> Result<()> {
    let models: Vec<String> = args
        .get("models")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["tldr_s".into(), "tldr_m".into(), "tldr_l".into()]);
    let reps: usize = args.get_parse("reps", 3)?;
    let seed: u64 = args.get_parse("seed", 42)?;

    let mut rows = Vec::new();
    for model in &models {
        let dir = require_model(args, model)?;
        let engine = Engine::load(&dir)?;
        let mcfg = engine.manifest.config.clone();
        let taskgen = TaskGen::new(
            Task::from_name(&mcfg.task).unwrap(),
            mcfg.prompt_len,
            mcfg.resp_len,
            seed,
        );
        let params = engine.init_policy()?;
        let examples = taskgen.batch(0, mcfg.gen_batch);
        let prompts: Vec<Vec<i32>> =
            examples.iter().map(|e| e.prompt.clone()).collect();
        let opts = SampleOpts { temperature: 0.7, greedy: false };

        // same device-cached param set for every engine, so the measured
        // gap is forward-pass + KV transfer cost, not param upload traffic
        let pv = ParamView::cached("bench_policy", 0, &params);
        let cached_engine = CachedEngine::default();
        let device_engine = DeviceCachedEngine::default();
        let mut engines: Vec<(&str, &dyn Generator)> =
            vec![("cached", &cached_engine)];
        if DeviceCachedEngine::supported(&engine) {
            engines.push(("device", &device_engine));
        }
        engines.push(("naive", &NaiveEngine));

        // (name, mean_secs, tok/s, bytes/token)
        let mut times: Vec<(&str, f64, f64, f64)> = Vec::new();
        for (name, gen) in engines {
            // warmup compiles the executables + fills the param cache
            let mut rng = crate::util::rng::Pcg32::new(seed, 1);
            gen.generate(&engine, pv, &prompts, opts, &mut rng)?;
            if name == "device" && engine.client_untuples() != Some(true) {
                // warmup settled the capability: a root-tuple client runs
                // this tier through host splits — skip rather than report
                // degraded numbers as "device"
                println!("  {model}/device: SKIP (client returns root tuples)");
                continue;
            }
            engine.reset_stats();
            let t0 = Instant::now();
            let mut tokens = 0usize;
            for rep in 0..reps {
                let mut rng = crate::util::rng::Pcg32::new(seed, 2 + rep as u64);
                let out = gen.generate(&engine, pv, &prompts, opts, &mut rng)?;
                tokens += out
                    .resp_mask
                    .iter()
                    .map(|m| m.iter().filter(|&&x| x == 1.0).count())
                    .sum::<usize>();
            }
            let secs = t0.elapsed().as_secs_f64();
            let (up, down) = engine.transfer_totals();
            times.push((
                name,
                secs / reps as f64,
                tokens as f64 / secs,
                (up + down) as f64 / tokens.max(1) as f64,
            ));
        }
        let by = |n: &str| times.iter().find(|t| t.0 == n);
        let cached = by("cached").unwrap();
        let naive = by("naive").unwrap();
        let (dev_s, dev_bpt) = by("device")
            .map(|d| (format!("{:.3}", d.1), format!("{:.0}", d.3)))
            .unwrap_or_else(|| ("-".into(), "-".into()));
        rows.push(vec![
            model.clone(),
            format!("{}", engine.manifest.param_count),
            format!("{:.3}", cached.1),
            dev_s,
            format!("{:.3}", naive.1),
            format!("{:.1}x", naive.1 / cached.1),
            format!("{:.0}", cached.2),
            format!("{:.0}", cached.3),
            dev_bpt,
        ]);
    }
    print_table(
        "Fig 14: batch generation, cached (vLLM-like) vs device-KV vs naive (HF-like)",
        &["model", "params", "cached_s", "device_s", "naive_s", "speedup",
          "tok/s cached", "B/tok cached", "B/tok device"],
        &rows,
    );
    save_csv(&out_dir(args).join("fig14"), "final",
             &["model", "params", "cached_s", "device_s", "naive_s", "speedup",
               "cached_tok_per_s", "cached_bytes_per_tok",
               "device_bytes_per_tok"],
             &rows)?;
    println!(
        "\npaper shape check: speedup should grow with model scale \
         (vLLM vs transformers grows superlinearly, Fig 14); the device \
         column should undercut cached_s purely by moving fewer bytes"
    );
    Ok(())
}
