//! Fig 14 / Appendix C.1: generation-engine speed, cached (vLLM analogue)
//! vs naive full-recompute (HF-transformers analogue), across model scales.
//!
//! Shape to reproduce: cached >> naive at every scale, with the gap
//! growing superlinearly in model size (the paper measures 12-20x for
//! 7-8B models; asymptotically the naive engine pays O(S) forwards of
//! O(S) tokens per response vs the cached engine's O(S) single-token
//! steps).

use std::time::Instant;

use anyhow::Result;

use super::runner::{print_table, save_csv};
use super::{out_dir, require_model};
use crate::data::{Task, TaskGen};
use crate::gen::{cached::CachedEngine, naive::NaiveEngine, Generator, SampleOpts};
use crate::runtime::{Engine, ParamView};
use crate::util::args::Args;

pub fn fig14(args: &Args) -> Result<()> {
    let models: Vec<String> = args
        .get("models")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["tldr_s".into(), "tldr_m".into(), "tldr_l".into()]);
    let reps: usize = args.get_parse("reps", 3)?;
    let seed: u64 = args.get_parse("seed", 42)?;

    let mut rows = Vec::new();
    for model in &models {
        let dir = require_model(args, model)?;
        let engine = Engine::load(&dir)?;
        let mcfg = engine.manifest.config.clone();
        let taskgen = TaskGen::new(
            Task::from_name(&mcfg.task).unwrap(),
            mcfg.prompt_len,
            mcfg.resp_len,
            seed,
        );
        let params = engine.init_policy()?;
        let examples = taskgen.batch(0, mcfg.gen_batch);
        let prompts: Vec<Vec<i32>> =
            examples.iter().map(|e| e.prompt.clone()).collect();
        let opts = SampleOpts { temperature: 0.7, greedy: false };

        // same device-cached param set for both engines, so the measured
        // gap is forward-pass cost, not param upload traffic
        let pv = ParamView::cached("bench_policy", 0, &params);
        let mut times = Vec::new();
        for gen in [&CachedEngine as &dyn Generator, &NaiveEngine] {
            // warmup compiles the executables
            let mut rng = crate::util::rng::Pcg32::new(seed, 1);
            gen.generate(&engine, pv, &prompts, opts, &mut rng)?;
            let t0 = Instant::now();
            let mut tokens = 0usize;
            for rep in 0..reps {
                let mut rng = crate::util::rng::Pcg32::new(seed, 2 + rep as u64);
                let out = gen.generate(&engine, pv, &prompts, opts, &mut rng)?;
                tokens += out
                    .resp_mask
                    .iter()
                    .map(|m| m.iter().filter(|&&x| x == 1.0).count())
                    .sum::<usize>();
            }
            let secs = t0.elapsed().as_secs_f64();
            times.push((gen.name(), secs / reps as f64, tokens as f64 / secs));
        }
        let speedup = times[1].1 / times[0].1;
        rows.push(vec![
            model.clone(),
            format!("{}", engine.manifest.param_count),
            format!("{:.3}", times[0].1),
            format!("{:.3}", times[1].1),
            format!("{speedup:.1}x"),
            format!("{:.0}", times[0].2),
        ]);
    }
    print_table(
        "Fig 14: batch generation time, cached (vLLM-like) vs naive (HF-like)",
        &["model", "params", "cached_s", "naive_s", "speedup", "tok/s cached"],
        &rows,
    );
    save_csv(&out_dir(args).join("fig14"), "final",
             &["model", "params", "cached_s", "naive_s", "speedup", "cached_tok_per_s"],
             &rows)?;
    println!(
        "\npaper shape check: speedup should grow with model scale \
         (vLLM vs transformers grows superlinearly, Fig 14)"
    );
    Ok(())
}
