//! Shared experiment plumbing: config construction, run+eval, table output.

use anyhow::Result;

use crate::config::ExpConfig;
use crate::coordinator::{self, Prepared, RunOutput};
use crate::eval::{evaluate, EvalResult};
use crate::util::args::Args;

/// Base config for an experiment variant; CLI flags override defaults so
/// every experiment can be scaled down (`--steps 16`) for smoke runs.
pub fn base_cfg(args: &Args, model: &str) -> Result<ExpConfig> {
    let mut cfg = ExpConfig::from_args(args)?;
    cfg.model = model.to_string();
    Ok(cfg)
}

pub struct VariantResult {
    pub out: RunOutput,
    pub eval: EvalResult,
}

/// Run one fully-specified variant and evaluate the final policy.
pub fn run_variant(
    cfg: &ExpConfig,
    prep: &Prepared,
    verbose: bool,
) -> Result<VariantResult> {
    cfg.validate()?;
    let out = coordinator::run(cfg, prep, verbose)?;
    let eval = evaluate(
        &prep.engine,
        &out.final_params,
        &prep.sft_params,
        &prep.taskgen,
        cfg.eval_prompts,
        cfg.temperature,
        cfg.seed,
    )?;
    Ok(VariantResult { out, eval })
}

/// Render a results table; also returns the rows for saving.
pub fn print_table(
    title: &str,
    headers: &[&str],
    rows: &[Vec<String>],
) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Save rows as CSV under the experiment output dir.
pub fn save_csv(
    dir: &std::path::Path,
    name: &str,
    headers: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut text = headers.join(",");
    text.push('\n');
    for row in rows {
        text.push_str(&row.join(","));
        text.push('\n');
    }
    std::fs::write(dir.join(format!("{name}.csv")), text)?;
    Ok(())
}
