//! Experiment runners: one per paper figure/table (DESIGN.md §6).
//!
//! `async-rlhf exp <id>` regenerates the rows/series the paper reports.
//! Absolute numbers come from this testbed; the acceptance criteria are
//! the paper-shape checks listed in DESIGN.md §6 and recorded in
//! EXPERIMENTS.md.

mod chat_scale;
mod runner;
mod gen_speed;
mod losses;
mod math_scale;
mod offpolicy;
mod optimize;
mod scaling;
mod speed;
pub mod staleness_ladder;

use anyhow::{anyhow, bail, Result};

use crate::util::args::Args;

pub struct Exp {
    pub id: &'static str,
    pub paper: &'static str,
    pub run: fn(&Args) -> Result<()>,
}

pub fn catalog() -> Vec<Exp> {
    vec![
        Exp { id: "fig1", paper: "Fig 1: win-rate vs wall-clock, sync vs async, 3 scales", run: speed::fig1 },
        Exp { id: "fig2", paper: "Fig 2: sync vs async schedule timelines", run: speed::fig2 },
        Exp { id: "fig3", paper: "Fig 3: PPO off-policyness (N sweep): win-rate, KL, pareto", run: offpolicy::fig3 },
        Exp { id: "fig4", paper: "Fig 4: loss robustness to off-policyness (DPO/PPO/RLOO/BoN)", run: losses::fig4 },
        Exp { id: "fig5", paper: "Fig 5: scaling policy vs reward model under off-policyness", run: scaling::fig5 },
        Exp { id: "fig6", paper: "Fig 6: training- vs generation-bound idle time", run: optimize::fig6 },
        Exp { id: "fig7", paper: "Fig 7: generation-bound: T updates per batch", run: optimize::fig7 },
        Exp { id: "fig8", paper: "Fig 8: training-bound: best/worst-of-K sampling", run: optimize::fig8 },
        Exp { id: "table1", paper: "Table 1/8 + Fig 9: chatbot at scale, sync vs async DPO", run: chat_scale::table1 },
        Exp { id: "table9", paper: "Table 9 + Fig 10: async PPO at scale", run: chat_scale::table9 },
        Exp { id: "table2", paper: "Table 2/11 + Fig 11: GSM8k math, sync vs async", run: math_scale::table2 },
        Exp { id: "table3", paper: "Table 3: SFT baselines (win-rate, ppl) per scale", run: scaling::table3 },
        Exp { id: "fig13", paper: "Fig 13: Proximal RLOO vs CoPG off-policy", run: losses::fig13 },
        Exp { id: "fig14", paper: "Fig 14/C.1: cached vs naive generation speed by scale", run: gen_speed::fig14 },
        Exp { id: "overhead", paper: "A.2: async overhead decomposition (ideal vs actual)", run: speed::overhead },
        Exp { id: "staleness", paper: "Staleness ladder: queue depth K x workers M (pipeline API)", run: staleness_ladder::ladder },
    ]
}

pub fn run(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("list");
    if id == "list" {
        println!("{:<9} {}", "id", "paper artifact");
        for e in catalog() {
            println!("{:<9} {}", e.id, e.paper);
        }
        return Ok(());
    }
    let exp = catalog()
        .into_iter()
        .find(|e| e.id == id)
        .ok_or_else(|| anyhow!("unknown experiment '{id}' (try `exp list`)"))?;
    eprintln!("[exp {}] {}", exp.id, exp.paper);
    (exp.run)(args)
}

/// Shared option: where experiment outputs are written.
pub(crate) fn out_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.get_or("out", "results"))
}

/// Fail fast if an artifact config is missing.
pub(crate) fn require_model(args: &Args, model: &str) -> Result<std::path::PathBuf> {
    let dir = crate::runtime::artifacts_root(args.get("artifacts")).join(model);
    if !dir.join("manifest.json").exists() {
        bail!(
            "artifacts for '{model}' not found under {} — run `make artifacts`",
            dir.display()
        );
    }
    Ok(dir)
}
