//! Synthetic task suite (DESIGN.md §3 substitutions).
//!
//! Three tasks mirror the paper's three workloads:
//! - `tldr`: controlled summarization — prompts embed *salient* tokens the
//!   gold reward wants covered concisely (TLDR, paper §3).
//! - `math`: multi-digit arithmetic with exact-match binary reward
//!   (GSM8k, paper §5.2).
//! - `chat`: instruction-following over token spans with noisy "human"
//!   references (No Robots, paper §5.1).
//!
//! Every prompt is exactly `prompt_len` tokens (the model geometry has no
//! left-padding; filler is drawn from content noise). References are
//! *intentionally imperfect* — like human-written summaries/responses —
//! so RLHF can beat the SFT/reference win-rate floor (paper Table 3).

pub mod chat;
pub mod math;
pub mod tldr;

use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

/// Task-specific ground-truth payload consumed by the gold reward.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskMeta {
    /// Distinct salient tokens, in order of first appearance.
    Tldr { salient: Vec<i32> },
    /// Digit tokens of the correct answer.
    Math { answer: Vec<i32> },
    /// Exact target transformation of the span.
    Chat { target: Vec<i32> },
}

/// One example: fixed-length prompt, imperfect reference response, and the
/// hidden ground truth for gold scoring.
#[derive(Debug, Clone)]
pub struct Example {
    pub prompt: Vec<i32>,
    /// Reference response *without* EOS (appended by consumers as needed).
    pub reference: Vec<i32>,
    pub meta: TaskMeta,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Tldr,
    Math,
    Chat,
}

impl Task {
    pub fn from_name(name: &str) -> Option<Task> {
        match name {
            "tldr" => Some(Task::Tldr),
            "math" => Some(Task::Math),
            "chat" => Some(Task::Chat),
            _ => None,
        }
    }
}

/// Deterministic example stream: `gen(seed, index)` is pure, so train/eval
/// splits are disjoint index ranges and every run is reproducible.
pub struct TaskGen {
    pub task: Task,
    pub prompt_len: usize,
    pub resp_len: usize,
    seed: u64,
}

impl TaskGen {
    pub fn new(task: Task, prompt_len: usize, resp_len: usize, seed: u64) -> TaskGen {
        TaskGen { task, prompt_len, resp_len, seed }
    }

    /// The i-th example of the stream (pure in (seed, i)).
    pub fn example(&self, i: u64) -> Example {
        let mut rng = Pcg32::new(self.seed ^ 0x5eed, i);
        let ex = match self.task {
            Task::Tldr => tldr::generate(&mut rng, self.prompt_len, self.resp_len),
            Task::Math => math::generate(&mut rng, self.prompt_len, self.resp_len),
            Task::Chat => chat::generate(&mut rng, self.prompt_len, self.resp_len),
        };
        debug_assert_eq!(ex.prompt.len(), self.prompt_len);
        debug_assert!(ex.reference.len() < self.resp_len); // room for EOS
        ex
    }

    pub fn batch(&self, start: u64, n: usize) -> Vec<Example> {
        (0..n as u64).map(|j| self.example(start + j)).collect()
    }

    /// Infinite strided admission stream over the prompt indices: blocks
    /// of `block` consecutive indices separated by `hop`, each index
    /// yielded `k` times consecutively (duplicates 0..k) — exactly the
    /// order the round-based workers consume via `round_prompts` +
    /// cursor hops, exposed one prompt at a time so the continuous
    /// engine can admit into single freed slots mid-flight.
    pub fn admission(
        &self,
        start: u64,
        block: u64,
        hop: u64,
        k: usize,
    ) -> Admission<'_> {
        assert!(block >= 1, "admission block must be at least 1");
        assert!(hop >= block, "hop must not revisit the block");
        assert!(k >= 1);
        Admission { gen: self, k, block, hop, base: start, off: 0, dup: 0 }
    }
}

/// One admitted prompt: duplicate `dup` (of k) of stream index `index`.
/// The full [`Example`] (reference, gold meta) is regenerated on demand
/// from `index` by the consumer — `TaskGen::example` is pure — so only the
/// prompt travels with the admission.
#[derive(Debug, Clone)]
pub struct AdmitPrompt {
    pub index: u64,
    pub dup: usize,
    pub prompt: Vec<i32>,
}

/// Iterator behind [`TaskGen::admission`]. Infinite: `next()` never
/// returns `None`.
pub struct Admission<'a> {
    gen: &'a TaskGen,
    k: usize,
    block: u64,
    hop: u64,
    base: u64,
    off: u64,
    dup: usize,
}

impl Iterator for Admission<'_> {
    type Item = AdmitPrompt;

    fn next(&mut self) -> Option<AdmitPrompt> {
        let index = self.base + self.off;
        let item = AdmitPrompt {
            index,
            dup: self.dup,
            prompt: self.gen.example(index).prompt,
        };
        self.dup += 1;
        if self.dup == self.k {
            self.dup = 0;
            self.off += 1;
            if self.off == self.block {
                self.off = 0;
                self.base += self.hop;
            }
        }
        Some(item)
    }
}

/// Fill `len - used` remaining slots with content noise (helper shared by
/// task generators to reach the fixed prompt length).
pub(crate) fn noise_fill(rng: &mut Pcg32, out: &mut Vec<i32>, len: usize) {
    while out.len() < len {
        out.push(tk::content(rng.gen_range(tk::CONTENT_COUNT as u32) as i32));
    }
}

/// Build a full training sequence: prompt ++ response ++ EOS ++ PAD, plus
/// the response mask (1.0 on response tokens incl. EOS). `resp` must not
/// contain EOS already.
pub fn pack_sequence(
    prompt: &[i32],
    resp: &[i32],
    seq_len: usize,
    with_eos: bool,
) -> (Vec<i32>, Vec<f32>) {
    let mut toks = Vec::with_capacity(seq_len);
    toks.extend_from_slice(prompt);
    let resp_start = toks.len();
    toks.extend_from_slice(resp);
    if with_eos {
        toks.push(tk::EOS);
    }
    let resp_end = toks.len().min(seq_len);
    toks.truncate(seq_len);
    toks.resize(seq_len, tk::PAD);
    let mut mask = vec![0.0f32; seq_len];
    for m in mask.iter_mut().take(resp_end).skip(resp_start) {
        *m = 1.0;
    }
    (toks, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        for task in [Task::Tldr, Task::Math, Task::Chat] {
            let g1 = TaskGen::new(task, 24, 12, 7);
            let g2 = TaskGen::new(task, 24, 12, 7);
            for i in 0..20 {
                let a = g1.example(i);
                let b = g2.example(i);
                assert_eq!(a.prompt, b.prompt);
                assert_eq!(a.reference, b.reference);
                assert_eq!(a.meta, b.meta);
            }
        }
    }

    #[test]
    fn seeds_differ() {
        let g1 = TaskGen::new(Task::Tldr, 24, 12, 1);
        let g2 = TaskGen::new(Task::Tldr, 24, 12, 2);
        let diff = (0..20)
            .filter(|&i| g1.example(i).prompt != g2.example(i).prompt)
            .count();
        assert!(diff > 15);
    }

    #[test]
    fn prompts_have_exact_length() {
        for task in [Task::Tldr, Task::Math, Task::Chat] {
            let g = TaskGen::new(task, 28, 14, 3);
            for i in 0..50 {
                let ex = g.example(i);
                assert_eq!(ex.prompt.len(), 28, "{task:?} example {i}");
                assert!(ex.reference.len() < 14);
                assert!(!ex.reference.contains(&tk::EOS));
            }
        }
    }

    #[test]
    fn admission_strides_blocks_with_k_duplicates() {
        let g = TaskGen::new(Task::Tldr, 24, 12, 7);
        // start 100, blocks of 2, hop 6, k 2:
        // 100 100 101 101, 106 106 107 107, 112 ...
        let got: Vec<(u64, usize)> = g
            .admission(100, 2, 6, 2)
            .take(9)
            .map(|a| (a.index, a.dup))
            .collect();
        assert_eq!(
            got,
            vec![
                (100, 0),
                (100, 1),
                (101, 0),
                (101, 1),
                (106, 0),
                (106, 1),
                (107, 0),
                (107, 1),
                (112, 0),
            ]
        );
        // prompts match the pure example stream
        let a = g.admission(100, 2, 6, 2).next().unwrap();
        assert_eq!(a.prompt, g.example(100).prompt);
    }

    #[test]
    fn pack_sequence_shapes() {
        let prompt = vec![tk::BOS, 30, 31];
        let resp = vec![40, 41];
        let (toks, mask) = pack_sequence(&prompt, &resp, 8, true);
        assert_eq!(toks, vec![tk::BOS, 30, 31, 40, 41, tk::EOS, 0, 0]);
        assert_eq!(mask, vec![0., 0., 0., 1., 1., 1., 0., 0.]);
    }

    #[test]
    fn pack_sequence_truncates() {
        let prompt = vec![1; 4];
        let resp = vec![40; 10];
        let (toks, mask) = pack_sequence(&prompt, &resp, 8, true);
        assert_eq!(toks.len(), 8);
        assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), 4);
    }
}
