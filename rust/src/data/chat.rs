//! No-Robots chatbot analogue (paper §5.1): instruction-following over
//! token spans. The instruction verb determines the correct transformation
//! of the span; references carry "human-written" noise so trained policies
//! can exceed the reference win-rate (paper Tables 1/8: SFT 31.8% ->
//! RLHF 57.2%).

use super::{Example, TaskMeta};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

const INSTRUCTIONS: [i32; 5] = [
    tk::INSTR_COPY,
    tk::INSTR_REVERSE,
    tk::INSTR_SORT,
    tk::INSTR_FIRST,
    tk::INSTR_LAST,
];

/// Reference noise rate (the "human variability" floor).
const REF_NOISE: f64 = 0.15;

/// Apply an instruction to a span.
pub fn apply(instr: i32, span: &[i32]) -> Vec<i32> {
    match instr {
        tk::INSTR_COPY => span.to_vec(),
        tk::INSTR_REVERSE => span.iter().rev().copied().collect(),
        tk::INSTR_SORT => {
            let mut v = span.to_vec();
            v.sort();
            v
        }
        tk::INSTR_FIRST => span[..3.min(span.len())].to_vec(),
        tk::INSTR_LAST => span[span.len().saturating_sub(3)..].to_vec(),
        _ => panic!("not an instruction token: {instr}"),
    }
}

pub fn generate(rng: &mut Pcg32, prompt_len: usize, resp_len: usize) -> Example {
    let instr = INSTRUCTIONS[rng.gen_usize(INSTRUCTIONS.len())];
    // span fits the prompt (BOS instr SEP span SEP) and the response (+EOS)
    // spans are kept short (4-8): COPY/REVERSE over long spans is a hard
    // induction task for from-scratch models, and span length is
    // orthogonal to the paper's sync-vs-async question
    let max_span = (prompt_len - 4).min(resp_len - 2).min(8);
    let min_span = 4.min(max_span);
    let span_len = min_span + rng.gen_usize(max_span - min_span + 1);
    let span: Vec<i32> = (0..span_len)
        .map(|_| tk::content(rng.gen_range(tk::CONTENT_COUNT as u32) as i32))
        .collect();

    let mut prompt = vec![tk::BOS, instr, tk::SEP];
    prompt.extend_from_slice(&span);
    prompt.push(tk::SEP);
    assert!(prompt.len() <= prompt_len);
    prompt.resize(prompt_len, tk::PAD);

    let target = apply(instr, &span);

    // noisy human reference
    let mut reference = Vec::new();
    for &t in &target {
        if rng.gen_bool(REF_NOISE) {
            match rng.gen_usize(2) {
                0 => {} // drop
                _ => reference.push(tk::content(
                    rng.gen_range(tk::CONTENT_COUNT as u32) as i32,
                )),
            }
        } else {
            reference.push(t);
        }
    }
    if reference.is_empty() {
        reference.push(target[0]);
    }
    reference.truncate(resp_len - 1);

    Example {
        prompt,
        reference,
        meta: TaskMeta::Chat { target },
    }
}

/// Extract (instruction, span) from a prompt.
pub fn parse_prompt(prompt: &[i32]) -> Option<(i32, Vec<i32>)> {
    if prompt.first() != Some(&tk::BOS) || prompt.get(2) != Some(&tk::SEP) {
        return None;
    }
    let instr = *prompt.get(1)?;
    if !INSTRUCTIONS.contains(&instr) {
        return None;
    }
    let rest = &prompt[3..];
    let end = rest.iter().position(|&t| t == tk::SEP)?;
    Some((instr, rest[..end].to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_transformations() {
        let span = [30, 28, 31, 29];
        assert_eq!(apply(tk::INSTR_COPY, &span), vec![30, 28, 31, 29]);
        assert_eq!(apply(tk::INSTR_REVERSE, &span), vec![29, 31, 28, 30]);
        assert_eq!(apply(tk::INSTR_SORT, &span), vec![28, 29, 30, 31]);
        assert_eq!(apply(tk::INSTR_FIRST, &span), vec![30, 28, 31]);
        assert_eq!(apply(tk::INSTR_LAST, &span), vec![28, 31, 29]);
    }

    #[test]
    fn target_matches_instruction() {
        let mut rng = Pcg32::new(21, 0);
        for _ in 0..50 {
            let ex = generate(&mut rng, 24, 20);
            let (instr, span) = parse_prompt(&ex.prompt).expect("parseable");
            if let TaskMeta::Chat { target } = &ex.meta {
                assert_eq!(target, &apply(instr, &span));
            } else {
                panic!("wrong meta");
            }
        }
    }

    #[test]
    fn reference_is_noisy_but_related() {
        let mut rng = Pcg32::new(22, 0);
        let mut exact = 0;
        let n = 100;
        for _ in 0..n {
            let ex = generate(&mut rng, 24, 20);
            if let TaskMeta::Chat { target } = &ex.meta {
                if &ex.reference == target {
                    exact += 1;
                }
            }
        }
        // most references are imperfect, but not all
        assert!(exact > 0, "no exact references at all");
        assert!(exact < n, "references are never noisy");
    }
}
