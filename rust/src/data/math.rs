//! GSM8k analogue (paper §5.2): arithmetic word problems with binary
//! exact-match reward — no reward model on the path, exactly the paper's
//! "efficiency is purely about optimizing LLM generation and training"
//! regime.
//!
//! Problems: `a + b`, `a - b` (a >= b) with a, b < 50, and `a * b` with
//! a, b <= 9 — a problem family a from-scratch ~100k-param model can
//! partially master (the paper's SFT floor is 40.3% pass@1; RL then
//! improves exact-match). The answer is the decimal digit string;
//! reward 1.0 iff the response is exactly the answer digits + EOS.

use super::{Example, TaskMeta};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

pub fn generate(rng: &mut Pcg32, prompt_len: usize, resp_len: usize) -> Example {
    let (a, b, op_tok, result) = match rng.gen_usize(3) {
        0 => {
            let a = rng.gen_range(50);
            let b = rng.gen_range(50);
            (a, b, tk::OP_PLUS, a + b)
        }
        1 => {
            let a = rng.gen_range(50);
            let b = rng.gen_range(a + 1);
            (a, b, tk::OP_MINUS, a - b)
        }
        _ => {
            let a = rng.gen_range(10);
            let b = rng.gen_range(10);
            (a, b, tk::OP_TIMES, a * b)
        }
    };

    let mut prompt = vec![tk::BOS];
    prompt.extend(tk::encode_number(a));
    prompt.push(op_tok);
    prompt.extend(tk::encode_number(b));
    prompt.push(tk::OP_EQ);
    prompt.push(tk::SEP);
    // fixed-length prompt: right-pad with PAD after SEP
    assert!(prompt.len() <= prompt_len, "prompt_len too small for math");
    prompt.resize(prompt_len, tk::PAD);

    let answer = tk::encode_number(result);
    assert!(answer.len() < resp_len);

    Example {
        reference: answer.clone(),
        prompt,
        meta: TaskMeta::Math { answer },
    }
}

/// Parse the (a, op, b) problem back out of a prompt (used by tests and by
/// the data inspector example).
pub fn parse_prompt(prompt: &[i32]) -> Option<(u32, i32, u32)> {
    let mut it = prompt.iter().copied().peekable();
    if it.next()? != tk::BOS {
        return None;
    }
    let mut a_toks = Vec::new();
    while it.peek().is_some_and(|&t| tk::is_digit(t)) {
        a_toks.push(it.next().unwrap());
    }
    let op = it.next()?;
    let mut b_toks = Vec::new();
    while it.peek().is_some_and(|&t| tk::is_digit(t)) {
        b_toks.push(it.next().unwrap());
    }
    if it.next()? != tk::OP_EQ {
        return None;
    }
    Some((tk::decode_number(&a_toks)?, op, tk::decode_number(&b_toks)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answers_are_correct() {
        let mut rng = Pcg32::new(9, 0);
        for _ in 0..100 {
            let ex = generate(&mut rng, 16, 12);
            let (a, op, b) = parse_prompt(&ex.prompt).expect("parseable");
            let expect = match op {
                tk::OP_PLUS => a + b,
                tk::OP_MINUS => a - b,
                tk::OP_TIMES => a * b,
                _ => panic!("bad op"),
            };
            if let TaskMeta::Math { answer } = &ex.meta {
                assert_eq!(tk::decode_number(answer), Some(expect));
                assert_eq!(answer, &ex.reference);
            } else {
                panic!("wrong meta");
            }
        }
    }

    #[test]
    fn subtraction_never_negative() {
        let mut rng = Pcg32::new(10, 0);
        for _ in 0..200 {
            let ex = generate(&mut rng, 16, 12);
            let (a, op, b) = parse_prompt(&ex.prompt).unwrap();
            if op == tk::OP_MINUS {
                assert!(a >= b);
            }
        }
    }

    #[test]
    fn prompt_is_padded_to_length() {
        let mut rng = Pcg32::new(11, 0);
        let ex = generate(&mut rng, 16, 12);
        assert_eq!(ex.prompt.len(), 16);
    }
}
