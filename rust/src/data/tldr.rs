//! Controlled TLDR-summarization analogue (paper §3, Gao et al. 2022 setup).
//!
//! A "post" is a fixed-length stream of content tokens in which a few
//! *salient* tokens recur; a good "summary" lists exactly the distinct
//! salient tokens, tersely, and terminates. The gold reward (reward::gold)
//! scores coverage, brevity, non-repetition and termination — enough
//! structure for reward hacking to exist (padding with extras, repetition),
//! which is what makes proxy-RM overoptimization measurable.

use super::{noise_fill, Example, TaskMeta};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

pub const MIN_SALIENT: usize = 3;
pub const MAX_SALIENT: usize = 6;
/// Probability per reference-token of an imperfection (drop/extra/dup),
/// giving the "human-written reference" quality floor of paper Table 3.
const REF_NOISE: f64 = 0.12;
/// Salient tokens recur this many times (3-4): frequent enough that a
/// from-scratch 2-layer model can learn "list the repeated tokens".
const MIN_REPEATS: usize = 3;

pub fn generate(rng: &mut Pcg32, prompt_len: usize, resp_len: usize) -> Example {
    let max_salient = MAX_SALIENT.min(resp_len.saturating_sub(2)).max(MIN_SALIENT);
    let n_salient =
        MIN_SALIENT + rng.gen_usize(max_salient - MIN_SALIENT + 1);

    // distinct salient content tokens
    let mut pool: Vec<i32> = (0..tk::CONTENT_COUNT).map(tk::content).collect();
    rng.shuffle(&mut pool);
    let salient: Vec<i32> = pool[..n_salient].to_vec();

    // body: each salient token appears 3-4 times, noise elsewhere
    let mut body = Vec::new();
    for &s in &salient {
        for _ in 0..(MIN_REPEATS + rng.gen_usize(2)) {
            body.push(s);
        }
    }
    let body_budget = prompt_len - 2; // BOS ... SEP
    while body.len() < body_budget {
        // noise tokens, avoiding accidental salient repeats
        let t = pool[n_salient + rng.gen_usize(pool.len() - n_salient)];
        body.push(t);
    }
    body.truncate(body_budget);
    rng.shuffle(&mut body);

    let mut prompt = Vec::with_capacity(prompt_len);
    prompt.push(tk::BOS);
    prompt.extend_from_slice(&body);
    prompt.push(tk::SEP);
    debug_assert_eq!(prompt.len(), prompt_len);

    // canonical summary order: ascending token id (a deterministic,
    // position-free target a small model can learn; the paper's task
    // difficulty is irrelevant to the async-vs-sync question)
    let mut ordered = salient.clone();
    ordered.sort();

    // imperfect human reference
    let mut reference = Vec::new();
    for &t in &ordered {
        if rng.gen_bool(REF_NOISE) {
            match rng.gen_usize(3) {
                0 => {}                        // drop
                1 => {                          // replace with noise
                    let nz = pool[n_salient + rng.gen_usize(pool.len() - n_salient)];
                    reference.push(nz);
                }
                _ => {                          // duplicate
                    reference.push(t);
                    reference.push(t);
                }
            }
        } else {
            reference.push(t);
        }
    }
    if reference.is_empty() {
        reference.push(ordered[0]);
    }
    reference.truncate(resp_len - 1); // leave room for EOS

    Example {
        prompt,
        reference,
        meta: TaskMeta::Tldr { salient: ordered },
    }
}

/// Perturb a response for preference-pair construction (reward::proxy):
/// higher `noise` -> worse expected gold score.
pub fn perturb(rng: &mut Pcg32, resp: &[i32], noise: f64, resp_len: usize) -> Vec<i32> {
    let mut out = Vec::new();
    for &t in resp {
        if rng.gen_bool(noise) {
            match rng.gen_usize(3) {
                0 => {}
                1 => out.push(tk::content(
                    rng.gen_range(tk::CONTENT_COUNT as u32) as i32,
                )),
                _ => {
                    out.push(t);
                    out.push(t);
                }
            }
        } else {
            out.push(t);
        }
    }
    if out.is_empty() {
        noise_fill(rng, &mut out, 1);
    }
    out.truncate(resp_len - 1);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn salient_tokens_appear_in_prompt() {
        let mut rng = Pcg32::new(1, 0);
        for _ in 0..30 {
            let ex = generate(&mut rng, 32, 16);
            if let TaskMeta::Tldr { salient } = &ex.meta {
                assert!((MIN_SALIENT..=MAX_SALIENT).contains(&salient.len()));
                for s in salient {
                    let count =
                        ex.prompt.iter().filter(|&&t| t == *s).count();
                    assert!(count >= MIN_REPEATS, "salient token appears {count} times");
                }
            } else {
                panic!("wrong meta");
            }
        }
    }

    #[test]
    fn prompt_structure() {
        let mut rng = Pcg32::new(2, 0);
        let ex = generate(&mut rng, 32, 16);
        assert_eq!(ex.prompt[0], tk::BOS);
        assert_eq!(*ex.prompt.last().unwrap(), tk::SEP);
    }

    #[test]
    fn perturb_zero_noise_is_identity() {
        let mut rng = Pcg32::new(3, 0);
        let resp = vec![30, 31, 32];
        assert_eq!(perturb(&mut rng, &resp, 0.0, 16), resp);
    }

    #[test]
    fn perturb_full_noise_changes() {
        let mut rng = Pcg32::new(4, 0);
        let resp = vec![30, 31, 32, 33, 34];
        let out = perturb(&mut rng, &resp, 1.0, 16);
        assert_ne!(out, resp);
    }
}
