//! Metrics: per-step run logs, win-rate/KL accounting, and wall-clock
//! timelines (the paper's evaluation axes: gold win-rate, KL-as-perplexity,
//! episodes, compute time).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// One logged training step.
#[derive(Debug, Clone)]
pub struct StepRow {
    pub step: u64,
    pub episodes: u64,
    pub wall_secs: f64,
    pub values: BTreeMap<String, f32>,
}

/// Append-only run log with CSV/JSON export.
#[derive(Debug, Default)]
pub struct RunLog {
    pub rows: Vec<StepRow>,
    pub meta: BTreeMap<String, String>,
}

impl RunLog {
    pub fn new() -> RunLog {
        RunLog::default()
    }

    pub fn set_meta(&mut self, key: &str, value: impl ToString) {
        self.meta.insert(key.to_string(), value.to_string());
    }

    pub fn push(
        &mut self,
        step: u64,
        episodes: u64,
        wall_secs: f64,
        values: &[(&str, f32)],
    ) {
        self.rows.push(StepRow {
            step,
            episodes,
            wall_secs,
            values: values
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
        });
    }

    /// Latest value of a metric, if any step recorded it.
    pub fn last(&self, key: &str) -> Option<f32> {
        self.rows
            .iter()
            .rev()
            .find_map(|r| r.values.get(key).copied())
    }

    /// Mean of a metric over the last `n` steps that recorded it.
    pub fn recent_mean(&self, key: &str, n: usize) -> Option<f32> {
        let vals: Vec<f32> = self
            .rows
            .iter()
            .rev()
            .filter_map(|r| r.values.get(key).copied())
            .take(n)
            .collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f32>() / vals.len() as f32)
        }
    }

    /// All (step, value) points of one metric (for curves).
    pub fn series(&self, key: &str) -> Vec<(u64, f32)> {
        self.rows
            .iter()
            .filter_map(|r| r.values.get(key).map(|v| (r.step, *v)))
            .collect()
    }

    fn columns(&self) -> Vec<String> {
        let mut cols: Vec<String> = Vec::new();
        for r in &self.rows {
            for k in r.values.keys() {
                if !cols.contains(k) {
                    cols.push(k.clone());
                }
            }
        }
        cols
    }

    pub fn to_csv(&self) -> String {
        let cols = self.columns();
        let mut out = String::from("step,episodes,wall_secs");
        for c in &cols {
            let _ = write!(out, ",{c}");
        }
        out.push('\n');
        for r in &self.rows {
            let _ = write!(out, "{},{},{:.3}", r.step, r.episodes, r.wall_secs);
            for c in &cols {
                match r.values.get(c) {
                    Some(v) => {
                        let _ = write!(out, ",{v}");
                    }
                    None => out.push(','),
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                let mut obj = vec![
                    ("step", Json::num(r.step as f64)),
                    ("episodes", Json::num(r.episodes as f64)),
                    ("wall_secs", Json::num(r.wall_secs)),
                ];
                for (k, v) in &r.values {
                    obj.push((k.as_str(), Json::num(*v as f64)));
                }
                Json::Obj(
                    obj.into_iter()
                        .map(|(k, v)| (k.to_string(), v))
                        .collect(),
                )
            })
            .collect();
        Json::obj(vec![
            (
                "meta",
                Json::Obj(
                    self.meta
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
            ("rows", Json::Arr(rows)),
        ])
    }

    pub fn save(&self, dir: &Path, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{name}.csv")))?;
        f.write_all(self.to_csv().as_bytes())?;
        let mut f = std::fs::File::create(dir.join(format!("{name}.json")))?;
        f.write_all(self.to_json().to_string().as_bytes())?;
        Ok(())
    }
}

/// Phase timeline for overhead analysis (paper A.2) and Fig 2/6 rendering.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    Generate,
    Score,
    Train,
    Publish,
    Eval,
    Idle,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Generate => "generate",
            Phase::Score => "score",
            Phase::Train => "train",
            Phase::Publish => "publish",
            Phase::Eval => "eval",
            Phase::Idle => "idle",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Span {
    pub phase: Phase,
    pub start: f64,
    pub end: f64,
}

/// Records (phase, start, end) spans against a common origin.
#[derive(Debug, Clone)]
pub struct Timeline {
    origin: Instant,
    pub spans: Vec<Span>,
}

impl Default for Timeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline { origin: Instant::now(), spans: Vec::new() }
    }

    pub fn origin(&self) -> Instant {
        self.origin
    }

    pub fn record<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let start = self.origin.elapsed().as_secs_f64();
        let out = f();
        let end = self.origin.elapsed().as_secs_f64();
        self.spans.push(Span { phase, start, end });
        out
    }

    pub fn push_span(&mut self, phase: Phase, start: f64, end: f64) {
        self.spans.push(Span { phase, start, end });
    }

    /// Total seconds spent in one phase (e.g. trainer idle time while
    /// waiting on generation workers).
    pub fn total(&self, phase: Phase) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.phase == phase)
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Total seconds spent per phase.
    pub fn totals(&self) -> BTreeMap<Phase, f64> {
        let mut m = BTreeMap::new();
        for s in &self.spans {
            *m.entry(s.phase).or_insert(0.0) += s.end - s.start;
        }
        m
    }

    pub fn wall(&self) -> f64 {
        self.spans
            .iter()
            .map(|s| s.end)
            .fold(0.0, f64::max)
    }

    /// ASCII rendering of the first `width`-seconds window, one lane per
    /// phase (Fig 2-style visualization in the terminal).
    pub fn render_ascii(&self, width: usize) -> String {
        let wall = self.wall().max(1e-9);
        let mut out = String::new();
        for phase in [Phase::Generate, Phase::Score, Phase::Train,
                      Phase::Publish, Phase::Eval] {
            let mut lane = vec![b'.'; width];
            for s in self.spans.iter().filter(|s| s.phase == phase) {
                let a = ((s.start / wall) * width as f64) as usize;
                let b = (((s.end / wall) * width as f64).ceil() as usize)
                    .min(width);
                for c in lane.iter_mut().take(b).skip(a.min(width)) {
                    *c = b'#';
                }
            }
            let _ = writeln!(
                out,
                "{:>9} |{}|",
                phase.name(),
                String::from_utf8(lane).unwrap()
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runlog_roundtrip() {
        let mut log = RunLog::new();
        log.push(1, 32, 0.5, &[("loss", 1.5), ("win", 0.25)]);
        log.push(2, 64, 1.0, &[("loss", 1.2)]);
        assert_eq!(log.last("win"), Some(0.25));
        assert_eq!(log.last("loss"), Some(1.2));
        assert_eq!(log.recent_mean("loss", 2), Some(1.35));
        let csv = log.to_csv();
        assert!(csv.starts_with("step,episodes,wall_secs,loss,win"));
        assert_eq!(csv.lines().count(), 3);
        // json parses back
        let j = Json::parse(&log.to_json().to_string()).unwrap();
        assert_eq!(j.req("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn series_extracts_curve() {
        let mut log = RunLog::new();
        for i in 0..5 {
            log.push(i, 0, 0.0, &[("x", i as f32)]);
        }
        let s = log.series("x");
        assert_eq!(s.len(), 5);
        assert_eq!(s[3], (3, 3.0));
    }

    #[test]
    fn timeline_totals() {
        let mut t = Timeline::new();
        t.push_span(Phase::Generate, 0.0, 1.0);
        t.push_span(Phase::Train, 1.0, 3.0);
        t.push_span(Phase::Generate, 3.0, 3.5);
        let totals = t.totals();
        assert!((totals[&Phase::Generate] - 1.5).abs() < 1e-9);
        assert!((totals[&Phase::Train] - 2.0).abs() < 1e-9);
        assert!((t.total(Phase::Generate) - 1.5).abs() < 1e-9);
        assert_eq!(t.total(Phase::Idle), 0.0);
        assert!((t.wall() - 3.5).abs() < 1e-9);
        let art = t.render_ascii(40);
        assert!(art.contains("generate"));
        assert!(art.contains('#'));
    }
}
