//! Tiny benchmark harness (substrate: no criterion in the offline crate
//! set). Used by `rust/benches/*` with `harness = false`.
//!
//! Reports min/mean/p50/p95 over timed iterations after warmup, in a
//! stable, grep-friendly format that EXPERIMENTS.md records.

use std::time::Instant;

use super::{mean, percentile};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Vec<f32>,
}

impl BenchResult {
    pub fn mean(&self) -> f32 {
        mean(&self.secs)
    }

    pub fn min(&self) -> f32 {
        self.secs.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn p(&self, p: f32) -> f32 {
        percentile(&self.secs, p)
    }

    pub fn print(&self) {
        println!(
            "bench {:<40} iters {:>3}  mean {:>9.4}s  min {:>9.4}s  \
             p50 {:>9.4}s  p95 {:>9.4}s",
            self.name,
            self.iters,
            self.mean(),
            self.min(),
            self.p(50.0),
            self.p(95.0),
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        secs.push(t0.elapsed().as_secs_f32());
    }
    let r = BenchResult { name: name.to_string(), iters, secs };
    r.print();
    r
}

/// Nearest-rank percentile over integer samples (retire steps, latency
/// sweeps): `q` in [0, 1]. Sorts in place; empty input reports 0. One
/// shared implementation for the gen-speed and serving benches plus the
/// serving run metas.
pub fn pct(samples: &mut [u64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_unstable();
    let idx = ((samples.len() - 1) as f64 * q).round() as usize;
    samples[idx] as f64
}

/// Shared bench preamble: resolve the artifacts root and skip politely when
/// a config is missing (benches must not fail on fresh checkouts).
pub fn artifact_dir_or_skip(model: &str) -> Option<std::path::PathBuf> {
    let root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"));
    let dir = root.join(model);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        println!("SKIP bench: artifacts/{model} missing (run `make artifacts`)");
        None
    }
}

#[cfg(test)]
mod tests {
    use super::pct;

    #[test]
    fn pct_singleton_is_the_sample() {
        let mut s = [7u64];
        assert_eq!(pct(&mut s, 0.0), 7.0);
        assert_eq!(pct(&mut s, 0.5), 7.0);
        assert_eq!(pct(&mut s, 1.0), 7.0);
    }

    #[test]
    fn pct_odd_length_median_is_the_middle() {
        let mut s = [5u64, 1, 9, 3, 7]; // sorted: 1 3 5 7 9
        assert_eq!(pct(&mut s, 0.5), 5.0);
        assert_eq!(pct(&mut s, 0.0), 1.0);
        assert_eq!(pct(&mut s, 1.0), 9.0);
    }

    #[test]
    fn pct_even_length_uses_nearest_rank() {
        let mut s = [4u64, 2, 8, 6]; // sorted: 2 4 6 8
        // (len-1) * 0.5 = 1.5 rounds to rank 2
        assert_eq!(pct(&mut s, 0.5), 6.0);
        assert_eq!(pct(&mut s, 0.99), 8.0);
    }

    #[test]
    fn pct_empty_reports_zero() {
        assert_eq!(pct(&mut [], 0.5), 0.0);
    }
}
