//! Tiny benchmark harness (substrate: no criterion in the offline crate
//! set). Used by `rust/benches/*` with `harness = false`.
//!
//! Reports min/mean/p50/p95 over timed iterations after warmup, in a
//! stable, grep-friendly format that EXPERIMENTS.md records.

use std::time::Instant;

use super::{mean, percentile};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Vec<f32>,
}

impl BenchResult {
    pub fn mean(&self) -> f32 {
        mean(&self.secs)
    }

    pub fn min(&self) -> f32 {
        self.secs.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn p(&self, p: f32) -> f32 {
        percentile(&self.secs, p)
    }

    pub fn print(&self) {
        println!(
            "bench {:<40} iters {:>3}  mean {:>9.4}s  min {:>9.4}s  \
             p50 {:>9.4}s  p95 {:>9.4}s",
            self.name,
            self.iters,
            self.mean(),
            self.min(),
            self.p(50.0),
            self.p(95.0),
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        secs.push(t0.elapsed().as_secs_f32());
    }
    let r = BenchResult { name: name.to_string(), iters, secs };
    r.print();
    r
}

/// Shared bench preamble: resolve the artifacts root and skip politely when
/// a config is missing (benches must not fail on fresh checkouts).
pub fn artifact_dir_or_skip(model: &str) -> Option<std::path::PathBuf> {
    let root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"));
    let dir = root.join(model);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        println!("SKIP bench: artifacts/{model} missing (run `make artifacts`)");
        None
    }
}
