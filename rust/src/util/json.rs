//! Minimal JSON parser/writer (substrate: no serde in the offline crate set).
//!
//! Supports the full JSON grammar needed by the artifact manifests and the
//! experiment logs: objects, arrays, strings (with escapes), numbers, bools,
//! null. Numbers are kept as f64; helper accessors convert.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifests are trusted but
    /// mistakes should fail loudly, not with unwrap panics.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `[1, 2, 3]` -> `vec![1usize, 2, 3]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    /// Compact serialization. Round-trips through `parse`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[derive(Debug, Clone)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while self
                        .peek()
                        .is_some_and(|c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",false,null],"obj":{"k":"v \"q\""}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[3, 2, 8]").unwrap();
        assert_eq!(j.as_usize_vec(), Some(vec![3, 2, 8]));
    }
}
