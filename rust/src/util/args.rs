//! Tiny CLI argument parser (substrate: no clap in the offline crate set).
//!
//! Grammar: `binary <subcommand> [positional ...] [--flag] [--key value]`.
//! Flags may also be written `--key=value`. Unknown keys are an error so
//! typos fail loudly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

#[derive(Debug)]
pub struct ArgError(pub String);

impl fmt::Display for ArgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse raw argv (without the binary name). `bool_flags` lists keys
    /// that take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args, ArgError> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&stripped) {
                    args.flags.push(stripped.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        ArgError(format!("--{stripped} needs a value"))
                    })?;
                    args.options.insert(stripped.to_string(), v.clone());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a.clone());
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, ArgError> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                ArgError(format!("--{key}: cannot parse '{s}'"))
            }),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Parse a comma-separated list, e.g. `--n 1,2,4,8`.
    pub fn get_list<T: std::str::FromStr>(
        &self,
        key: &str,
        default: &[T],
    ) -> Result<Vec<T>, ArgError>
    where
        T: Clone,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| {
                        ArgError(format!("--{key}: cannot parse '{p}'"))
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn basic() {
        let a = Args::parse(
            &v(&["train", "tldr_s", "--steps", "100", "--verbose"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["tldr_s"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn eq_form_and_parse() {
        let a = Args::parse(&v(&["x", "--lr=0.5"]), &[]).unwrap();
        assert_eq!(a.get_parse("lr", 0.0f64).unwrap(), 0.5);
        assert_eq!(a.get_parse("missing", 7u32).unwrap(), 7);
    }

    #[test]
    fn list() {
        let a = Args::parse(&v(&["x", "--n", "1,2,4"]), &[]).unwrap();
        assert_eq!(a.get_list("n", &[9usize]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_list("m", &[9usize]).unwrap(), vec![9]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&v(&["x", "--steps"]), &[]).is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(&v(&["x", "--steps", "abc"]), &[]).unwrap();
        assert!(a.get_parse("steps", 0u32).is_err());
    }
}
