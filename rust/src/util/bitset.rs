//! Small fixed-capacity bitsets for lane ownership.
//!
//! The supervisor used to track which generation lanes a seat owns in a
//! single `AtomicU64`, which silently capped the pipeline at 64 seats.
//! Sharded runs multiply seat counts (gen workers + serve seats + trainer
//! shards all subscribe to the param bus), so lane masks are now a small
//! word-array bitset with the same lock-free operations the supervisor
//! relied on: per-bit set, whole-mask clear, and an OR-merge used when a
//! dead worker's lanes are re-strided onto an heir.
//!
//! Atomicity contract: each *word* is atomic, the set as a whole is not.
//! A snapshot taken concurrently with `merge` may observe only part of
//! the merged mask. That is benign for the supervisor's protocol — the
//! heir re-reads its mask at the top of every generation sweep, so a
//! partially-visible merge only delays the extra lanes by one beat; no
//! lane is ever *lost* because the merge source (`BitSet`) is immutable
//! and the per-word `fetch_or` is atomic.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

const WORD_BITS: usize = 64;

fn words_for(bits: usize) -> usize {
    bits.div_ceil(WORD_BITS).max(1)
}

/// Immutable snapshot of a lane mask (plain words, no atomics).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    /// Empty set with capacity for `bits` bits.
    pub fn new(bits: usize) -> BitSet {
        BitSet { words: vec![0; words_for(bits)] }
    }

    /// Set containing exactly `bit`, with capacity for `bits` bits.
    pub fn single(bit: usize, bits: usize) -> BitSet {
        let mut s = BitSet::new(bits.max(bit + 1));
        s.set(bit);
        s
    }

    /// Set from a legacy u64 mask (capacity 64). Test/compat helper.
    pub fn from_mask(mask: u64) -> BitSet {
        BitSet { words: vec![mask] }
    }

    pub fn set(&mut self, bit: usize) {
        let w = bit / WORD_BITS;
        assert!(w < self.words.len(), "bit {bit} out of bitset capacity");
        self.words[w] |= 1u64 << (bit % WORD_BITS);
    }

    pub fn contains(&self, bit: usize) -> bool {
        let w = bit / WORD_BITS;
        w < self.words.len() && self.words[w] & (1u64 << (bit % WORD_BITS)) != 0
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Indices of set bits, ascending.
    pub fn ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            (0..WORD_BITS)
                .filter(move |b| w & (1u64 << b) != 0)
                .map(move |b| wi * WORD_BITS + b)
        })
    }
}

impl fmt::Display for BitSet {
    /// `{0, 3, 70}` — lane indices, for supervisor log lines.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, bit) in self.ones().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{bit}")?;
        }
        write!(f, "}}")
    }
}

/// Shared lane mask: one atomic word per 64 bits.
pub struct AtomicBitSet {
    words: Box<[AtomicU64]>,
}

impl AtomicBitSet {
    /// Empty set with capacity for `bits` bits.
    pub fn new(bits: usize) -> AtomicBitSet {
        let words =
            (0..words_for(bits)).map(|_| AtomicU64::new(0)).collect();
        AtomicBitSet { words }
    }

    /// Set containing exactly `bit`, with capacity for `bits` bits.
    pub fn single(bit: usize, bits: usize) -> AtomicBitSet {
        let s = AtomicBitSet::new(bits.max(bit + 1));
        s.set(bit);
        s
    }

    pub fn set(&self, bit: usize) {
        let w = bit / WORD_BITS;
        assert!(w < self.words.len(), "bit {bit} out of bitset capacity");
        self.words[w].fetch_or(1u64 << (bit % WORD_BITS), Ordering::SeqCst);
    }

    /// Clear every bit (used when a dead seat's lanes are taken away).
    pub fn clear(&self) {
        for w in &self.words {
            w.store(0, Ordering::SeqCst);
        }
    }

    /// OR another mask in, word by word (lane re-striding onto an heir).
    /// Capacities must match — masks for one pool share one seat count.
    pub fn merge(&self, other: &BitSet) {
        assert_eq!(
            self.words.len(),
            other.words.len(),
            "bitset capacity mismatch in merge"
        );
        for (w, o) in self.words.iter().zip(&other.words) {
            w.fetch_or(*o, Ordering::SeqCst);
        }
    }

    /// Point-in-time copy. Word-atomic, not set-atomic (see module doc).
    pub fn snapshot(&self) -> BitSet {
        BitSet {
            words: self.words.iter().map(|w| w.load(Ordering::SeqCst)).collect(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|w| w.load(Ordering::SeqCst) == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitset_set_contains_and_ones_round_trip() {
        let mut s = BitSet::new(10);
        assert!(s.is_empty());
        s.set(0);
        s.set(3);
        s.set(9);
        assert!(s.contains(0) && s.contains(3) && s.contains(9));
        assert!(!s.contains(1) && !s.contains(8));
        assert_eq!(s.ones().collect::<Vec<_>>(), vec![0, 3, 9]);
        assert_eq!(s.count(), 3);
        assert_eq!(s.to_string(), "{0, 3, 9}");
    }

    #[test]
    fn bitset_from_mask_matches_the_legacy_u64_layout() {
        let s = BitSet::from_mask(0b101);
        assert_eq!(s.ones().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(BitSet::single(2, 64), {
            let mut t = BitSet::new(64);
            t.set(2);
            t
        });
    }

    #[test]
    fn bitset_lanes_past_64_cross_the_word_boundary() {
        // regression for the lifted 64-seat cap: bits above 63 must land
        // in the second word and survive set/snapshot/merge/iterate
        let a = AtomicBitSet::single(70, 80);
        assert!(!a.is_empty());
        let snap = a.snapshot();
        assert!(snap.contains(70));
        assert!(!snap.contains(6)); // not aliased into word 0
        assert_eq!(snap.ones().collect::<Vec<_>>(), vec![70]);

        // merge a word-0 mask and a word-1 mask onto one heir
        let heir = AtomicBitSet::single(1, 80);
        heir.merge(&BitSet::single(70, 80));
        heir.merge(&BitSet::single(79, 80));
        let m = heir.snapshot();
        assert_eq!(m.ones().collect::<Vec<_>>(), vec![1, 70, 79]);
        assert_eq!(m.to_string(), "{1, 70, 79}");

        heir.clear();
        assert!(heir.is_empty());
        assert!(heir.snapshot().is_empty());
    }

    #[test]
    fn bitset_display_of_empty_mask_is_braces() {
        assert_eq!(BitSet::new(128).to_string(), "{}");
    }
}
