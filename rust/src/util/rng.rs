//! Deterministic PCG-XSH-RR 64/32 PRNG (substrate: no `rand` crate offline).
//!
//! Everything stochastic in the framework — task generation, sampling,
//! schedules, property tests — draws from seeded `Pcg32` streams so every
//! experiment is exactly reproducible from its config seed.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (e.g. one per worker thread).
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream)
    }

    /// Raw generator cursor `(state, inc)` — the checkpoint payload.
    /// Restore with [`Pcg32::from_state`] to continue the exact stream.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at a cursor previously captured by
    /// [`Pcg32::state`] (crash-safe resume). Unlike [`Pcg32::new`] this
    /// performs no seeding scramble: the next draw is exactly the draw
    /// the captured generator would have produced.
    pub fn from_state(state: u64, inc: u64) -> Pcg32 {
        Pcg32 { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn gen_range(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    pub fn gen_usize(&mut self, n: usize) -> usize {
        self.gen_range(n as u32) as usize
    }

    /// Uniform f32 in [0, 1).
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn sample_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.gen_usize(weights.len());
        }
        let mut t = self.gen_f32() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn state_roundtrip_continues_the_stream_exactly() {
        let mut a = Pcg32::new(42, 7);
        for _ in 0..13 {
            a.next_u32();
        }
        let (state, inc) = a.state();
        let mut b = Pcg32::from_state(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg32::new(7, 0);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
            let f = r.gen_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Pcg32::new(3, 9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(123, 4);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_sampling_prefers_heavy() {
        let mut r = Pcg32::new(5, 5);
        let w = [0.0f32, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(11, 0);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
