//! Minimal property-based testing helper (substrate: no proptest offline).
//!
//! `prop_check` runs a property over many seeded random cases and, on
//! failure, reports the failing seed so the case can be replayed exactly:
//!
//! ```ignore
//! prop_check("queue never exceeds bound", 200, |rng| {
//!     let n = rng.gen_usize(64) + 1;
//!     ... build a random scenario, return Err(msg) if violated ...
//! });
//! ```

use super::rng::Pcg32;

pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `property`. Panics with the failing seed and
/// message on the first violation. Set `ASYNC_RLHF_PROP_SEED` to replay a
/// single failing case.
pub fn prop_check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Pcg32) -> PropResult,
{
    if let Ok(seed) = std::env::var("ASYNC_RLHF_PROP_SEED") {
        let seed: u64 = seed.parse().expect("bad ASYNC_RLHF_PROP_SEED");
        let mut rng = Pcg32::new(seed, 0xeb);
        if let Err(msg) = property(&mut rng) {
            panic!("property '{name}' failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for seed in 0..cases {
        let mut rng = Pcg32::new(seed, 0xeb);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at seed {seed} \
                 (ASYNC_RLHF_PROP_SEED={seed} to replay): {msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        prop_check("u32 addition commutes", 100, |rng| {
            let a = rng.next_u32() / 2;
            let b = rng.next_u32() / 2;
            prop_assert!(a + b == b + a, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn failing_property_reports_seed() {
        prop_check("always fails eventually", 50, |rng| {
            let x = rng.gen_usize(10);
            prop_assert!(x < 9, "drew {x}");
            Ok(())
        });
    }
}
