//! Minimal NumPy `.npy` reader/writer for f32 arrays (substrate module).
//!
//! The AOT pipeline emits seeded initial parameters as `.npy`; checkpoints
//! written by the Rust trainers use the same format so they can be inspected
//! from Python. Only little-endian f32, C-order — all this repo needs.

use std::fs;
use std::io::{self, Read, Write};
use std::path::Path;

pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

pub fn read_f32<P: AsRef<Path>>(path: P) -> io::Result<NpyArray> {
    let mut f = fs::File::open(&path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        return Err(bad("not an npy file"));
    }
    let (major, _minor) = (magic[6], magic[7]);
    let header_len = if major >= 2 {
        let mut b = [0u8; 4];
        f.read_exact(&mut b)?;
        u32::from_le_bytes(b) as usize
    } else {
        let mut b = [0u8; 2];
        f.read_exact(&mut b)?;
        u16::from_le_bytes(b) as usize
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);

    if !header.contains("'<f4'") && !header.contains("\"<f4\"") {
        return Err(bad(&format!("unsupported dtype in header: {header}")));
    }
    if header.contains("'fortran_order': True") {
        return Err(bad("fortran order not supported"));
    }
    let shape = parse_shape(&header).ok_or_else(|| bad("bad shape"))?;
    let count: usize = shape.iter().product::<usize>().max(1);

    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() < count * 4 {
        return Err(bad("truncated data"));
    }
    let data = raw[..count * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(NpyArray { shape, data })
}

pub fn write_f32<P: AsRef<Path>>(
    path: P,
    shape: &[usize],
    data: &[f32],
) -> io::Result<()> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut f = fs::File::create(path)?;
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for x in data {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn parse_shape(header: &str) -> Option<Vec<usize>> {
    let start = header.find("'shape':")? + 8;
    let rest = &header[start..];
    let open = rest.find('(')? + 1;
    let close = rest.find(')')?;
    let inner = &rest[open..close];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let t = part.trim();
        if t.is_empty() {
            continue;
        }
        out.push(t.parse().ok()?);
    }
    Some(out)
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("npy: {msg}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_1d() {
        let dir = std::env::temp_dir().join("async_rlhf_npy_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("a.npy");
        let data: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        write_f32(&p, &[100], &data).unwrap();
        let arr = read_f32(&p).unwrap();
        assert_eq!(arr.shape, vec![100]);
        assert_eq!(arr.data, data);
    }

    #[test]
    fn roundtrip_2d() {
        let dir = std::env::temp_dir().join("async_rlhf_npy_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("b.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        write_f32(&p, &[3, 4], &data).unwrap();
        let arr = read_f32(&p).unwrap();
        assert_eq!(arr.shape, vec![3, 4]);
        assert_eq!(arr.data, data);
    }

    #[test]
    fn reads_numpy_written_file() {
        // Byte-for-byte fixture produced by numpy 2.x: np.save of
        // np.arange(3, dtype='<f4'). Verifies cross-tool compatibility
        // without invoking python at test time.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"\x93NUMPY\x01\x00");
        let header =
            "{'descr': '<f4', 'fortran_order': False, 'shape': (3,), }";
        let mut h = header.to_string();
        let pad = (64 - (10 + h.len() + 1) % 64) % 64;
        h.push_str(&" ".repeat(pad));
        h.push('\n');
        bytes.extend_from_slice(&(h.len() as u16).to_le_bytes());
        bytes.extend_from_slice(h.as_bytes());
        for v in [0f32, 1.0, 2.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let dir = std::env::temp_dir().join("async_rlhf_npy_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.npy");
        fs::write(&p, &bytes).unwrap();
        let arr = read_f32(&p).unwrap();
        assert_eq!(arr.data, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn rejects_wrong_dtype() {
        let dir = std::env::temp_dir().join("async_rlhf_npy_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.npy");
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"\x93NUMPY\x01\x00");
        let h = "{'descr': '<i8', 'fortran_order': False, 'shape': (1,), }\n";
        bytes.extend_from_slice(&(h.len() as u16).to_le_bytes());
        bytes.extend_from_slice(h.as_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        fs::write(&p, &bytes).unwrap();
        assert!(read_f32(&p).is_err());
    }
}
