//! Substrate utilities built in-repo (the offline crate set has no serde /
//! clap / rand / proptest — see DESIGN.md §3).

pub mod args;
pub mod bench;
pub mod bitset;
pub mod json;
pub mod npy;
pub mod prop;
pub mod rng;

/// Simple statistics helpers used across metrics and benches.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

pub fn stddev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f32>() / (xs.len() - 1) as f32)
        .sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a copy.
pub fn percentile(xs: &[f32], p: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f32).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }
}
