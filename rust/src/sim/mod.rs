//! Discrete-event clock simulation of the sync/async schedules.
//!
//! The CPU testbed genuinely overlaps generation and training on separate
//! threads, but its gen:train time ratio differs from the paper's GPU
//! fleets. This simulator replays the *scheduling policy* under any phase
//! durations — e.g. the paper's measured №Robots numbers (gen 21 s,
//! train 33 s, A.2) or GSM8k (12.2 s / 12.8 s, A.3) — to reproduce Fig 2,
//! Fig 6 (training- vs generation-bound idle time) and the A.2 ideal-vs-
//! actual speedup analysis.

use crate::metrics::{Phase, Timeline};

/// Phase durations (seconds) of one RLHF step.
#[derive(Debug, Clone, Copy)]
pub struct StepCosts {
    pub gen: f64,
    pub score: f64,
    pub train: f64,
    /// Parameter-publication overhead paid by the trainer per step (async
    /// only; the paper's A.2 "communication between training and
    /// generation").
    pub publish: f64,
}

impl StepCosts {
    pub fn new(gen: f64, score: f64, train: f64) -> StepCosts {
        StepCosts { gen, score, train, publish: 0.0 }
    }

    pub fn with_publish(mut self, p: f64) -> StepCosts {
        self.publish = p;
        self
    }

    fn trainer_work(&self) -> f64 {
        self.score + self.train + self.publish
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub wall: f64,
    /// Seconds the generation resource spent idle.
    pub gen_idle: f64,
    /// Seconds the training resource spent idle.
    pub train_idle: f64,
    pub timeline: Timeline,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    GenerationBound,
    TrainingBound,
    Balanced,
}

/// Which resource limits the async schedule (paper Fig 6)?
pub fn classify(costs: &StepCosts) -> Bound {
    let g = costs.gen;
    let t = costs.trainer_work();
    if g > t * 1.05 {
        Bound::GenerationBound
    } else if t > g * 1.05 {
        Bound::TrainingBound
    } else {
        Bound::Balanced
    }
}

/// Synchronous schedule: gen -> score -> train, strictly sequential on the
/// same resources (paper Fig 2 top / Fig 12 top). While training runs the
/// generation resource idles, and vice versa.
pub fn simulate_sync(costs: &StepCosts, steps: u64) -> SimResult {
    let mut tl = Timeline::new();
    let mut t = 0.0;
    let mut gen_idle = 0.0;
    let mut train_idle = 0.0;
    for _ in 0..steps {
        tl.push_span(Phase::Generate, t, t + costs.gen);
        train_idle += costs.gen;
        t += costs.gen;
        tl.push_span(Phase::Score, t, t + costs.score);
        tl.push_span(Phase::Train, t + costs.score, t + costs.score + costs.train);
        gen_idle += costs.score + costs.train;
        t += costs.score + costs.train;
    }
    SimResult { wall: t, gen_idle, train_idle, timeline: tl }
}

/// Asynchronous schedule (paper Fig 2 bottom): the generation worker and
/// the trainer run concurrently; a bound-1 queue enforces one-step
/// off-policy. Discrete-event simulation of the exact producer/consumer
/// protocol implemented by `coordinator::pool::WorkerPool`.
pub fn simulate_async(costs: &StepCosts, steps: u64) -> SimResult {
    let mut tl = Timeline::new();
    let mut gen_idle = 0.0;
    let mut train_idle = 0.0;

    // round i finishes generating at g_done[i]; the trainer may start
    // consuming round i at max(g_done[i], trainer free); the generator may
    // start round i+1 only when the queue has space: round i has been
    // *taken* by the trainer (bound-1 queue => at most one finished,
    // untaken round).
    let mut gen_free = 0.0f64; // generator available
    let mut train_free = 0.0f64; // trainer available
    let mut queued_done: Option<f64> = None; // finish time of queued round

    let mut produced = 0u64;
    let mut consumed = 0u64;
    while consumed < steps {
        // generator produces whenever the queue is empty
        if queued_done.is_none() && produced < steps {
            let start = gen_free;
            let done = start + costs.gen;
            tl.push_span(Phase::Generate, start, done);
            queued_done = Some(done);
            produced += 1;
            gen_free = done;
        }
        // trainer consumes the queued round
        let done = queued_done.take().expect("deadlock in sim");
        let start = train_free.max(done);
        train_idle += start - train_free;
        // generator may begin the next round as soon as the queue frees:
        // i.e. when the trainer *takes* this round
        gen_idle += start.max(gen_free) - gen_free;
        gen_free = gen_free.max(start);
        let t_end = start + costs.trainer_work();
        tl.push_span(Phase::Score, start, start + costs.score);
        tl.push_span(
            Phase::Train,
            start + costs.score,
            start + costs.score + costs.train,
        );
        if costs.publish > 0.0 {
            tl.push_span(Phase::Publish, start + costs.score + costs.train, t_end);
        }
        train_free = t_end;
        consumed += 1;
    }
    SimResult {
        wall: train_free,
        gen_idle,
        train_idle,
        timeline: tl,
    }
}

/// Paper A.2-style analysis row: sync wall, async wall, ideal async wall
/// (= steps * max(gen, trainer)), speedup and overhead.
#[derive(Debug, Clone)]
pub struct SpeedupAnalysis {
    pub sync_wall: f64,
    pub async_wall: f64,
    pub ideal_wall: f64,
    pub speedup_pct: f64,
    pub ideal_speedup_pct: f64,
    pub overhead_per_step: f64,
}

pub fn analyze(costs: &StepCosts, steps: u64) -> SpeedupAnalysis {
    let sync = simulate_sync(costs, steps);
    let asy = simulate_async(costs, steps);
    let ideal = steps as f64 * costs.gen.max(costs.trainer_work() - costs.publish)
        + costs.gen.min(costs.trainer_work()); // pipeline fill
    SpeedupAnalysis {
        sync_wall: sync.wall,
        async_wall: asy.wall,
        ideal_wall: ideal,
        speedup_pct: (sync.wall / asy.wall - 1.0) * 100.0,
        ideal_speedup_pct: (sync.wall / ideal - 1.0) * 100.0,
        overhead_per_step: (asy.wall - ideal) / steps as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_wall_is_sum() {
        let c = StepCosts::new(2.0, 0.5, 3.0);
        let r = simulate_sync(&c, 10);
        assert!((r.wall - 55.0).abs() < 1e-9);
        assert!((r.gen_idle - 35.0).abs() < 1e-9);
        assert!((r.train_idle - 20.0).abs() < 1e-9);
    }

    #[test]
    fn async_wall_is_max_dominated() {
        // training-bound: trainer work 3.5 > gen 2.0
        let c = StepCosts::new(2.0, 0.5, 3.0);
        let r = simulate_async(&c, 100);
        // wall ≈ gen (pipeline fill) + 100 * 3.5
        assert!((r.wall - (2.0 + 100.0 * 3.5)).abs() < 1e-6, "wall={}", r.wall);
        assert!(r.wall < simulate_sync(&c, 100).wall);
    }

    #[test]
    fn async_generation_bound() {
        let c = StepCosts::new(5.0, 0.5, 1.0);
        let r = simulate_async(&c, 50);
        // generation dominates: wall ≈ 50 * 5 + trainer tail
        assert!(r.wall >= 250.0 && r.wall <= 250.0 + 2.0, "wall={}", r.wall);
        assert_eq!(classify(&c), Bound::GenerationBound);
    }

    #[test]
    fn classify_bounds() {
        assert_eq!(
            classify(&StepCosts::new(1.0, 0.1, 3.0)),
            Bound::TrainingBound
        );
        assert_eq!(
            classify(&StepCosts::new(1.0, 0.0, 1.0)),
            Bound::Balanced
        );
    }

    #[test]
    fn paper_norobots_numbers() {
        // A.2: gen 21 s, train 33 s, 233 steps -> sync ≈ 209 min, ideal
        // async ≈ 128 min (63% faster)
        let c = StepCosts::new(21.0, 0.0, 33.0);
        let a = analyze(&c, 233);
        assert!((a.sync_wall / 60.0 - 209.7).abs() < 1.0);
        assert!((a.ideal_wall / 60.0 - 128.5).abs() < 1.0);
        assert!(a.ideal_speedup_pct > 60.0 && a.ideal_speedup_pct < 66.0);
    }

    #[test]
    fn publish_overhead_slows_async() {
        let base = StepCosts::new(2.0, 0.2, 2.0);
        let slow = base.with_publish(0.5);
        let a = simulate_async(&base, 50).wall;
        let b = simulate_async(&slow, 50).wall;
        assert!(b > a);
    }
}
