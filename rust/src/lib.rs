//! # async-rlhf
//!
//! A Rust + JAX + Pallas reproduction of *Asynchronous RLHF: Faster and
//! More Efficient Off-Policy RL for Language Models* (ICLR 2025).
//!
//! Three layers (DESIGN.md):
//! - **L3 (this crate)**: the asynchronous RLHF coordinator — generation
//!   and training on separate threads/backends, one-step off-policy
//!   Cleanba-style scheduling, plus the synchronous baseline, the
//!   off-policyness schedules (N mini-batches, T epochs, best-of-K), task
//!   data generators, gold/proxy rewards, generation engines, metrics and
//!   experiment runners.
//! - **L2 (python/compile)**: the JAX transformer, RLHF loss zoo and Adam,
//!   AOT-lowered to HLO text executables.
//! - **L1 (python/compile/kernels)**: Pallas flash-attention kernels.
//!
//! Python never runs at training/serving time: `runtime::Engine` executes
//! the compiled artifacts through PJRT.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod gen;
pub mod metrics;
pub mod reward;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tokenizer;
pub mod util;
