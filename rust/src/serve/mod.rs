//! Serving front-end over the continuous slot pool — serve-while-training.
//!
//! The paper's separation of generation from learning means the two can
//! share one generation substrate: this module puts a *session serving*
//! face on the continuous engine's cohort pool and feeds the completed
//! traffic straight back into the trainer, so live traffic IS the prompt
//! stream (OpenRLHF's agent-deployment pattern over PipelineRL's inflight
//! weight swapping).
//!
//! - [`traffic`]: deterministic traffic replay — arrival sweeps, per-turn
//!   think delays and prompt uids, all pure in the run's seed.
//! - [`session`]: the session board — multi-turn state machines gating
//!   admission (a turn only queues after its predecessor completes plus a
//!   think delay) and accounting every retirement back to its session.
//! - [`frontend`]: the mux gluing a board to a slot [`Pool`] one sweep at
//!   a time, plus [`frontend::run_replay`] for training-off replay runs.
//!
//! The training loop closes in `coordinator::pipeline::SessionSource`:
//! M serving seats (one per `--gen-workers`, each owning the traffic
//! residues `session % M` in its control mask — one residue at spawn,
//! more after inheriting a dead seat's sessions) each run a mux against
//! the latest params published on their [`ParamBus`] seat and hand
//! assembled rounds to the one trainer loop, which extends its
//! exactly-once dedup/hole accounting to the served turn uids. Because
//! a board's schedule is a pure function of `(trace, delivered-turn
//! set)`, both session migration and `--resume` are the same move:
//! rebuild a board over some residues from the delivered set and serve
//! the remainder. [`run`] is the mode entry point behind `--mode serve`
//! / the `serve` subcommand.
//!
//! [`Pool`]: crate::gen::continuous::Pool
//! [`ParamBus`]: crate::coordinator::pipeline::ParamBus

pub mod frontend;
pub mod session;
pub mod traffic;

use anyhow::{bail, Result};

use crate::config::ExpConfig;
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::pipeline::{self, RoundSource, SessionSource};
use crate::coordinator::trainer::rounds_per_batch;
use crate::coordinator::{Prepared, RunOutput};

/// Optimizer steps a serve run takes: the traffic trace's, not
/// `--steps`. Every turn yields `k` candidates (one assembler group), a
/// round is `gen_batch / k` groups, and a batch is `rounds_per_batch`
/// rounds — so the geometry must tile exactly or the tail of the trace
/// would sit in an assembler forever. Bails with the arithmetic spelled
/// out rather than hanging.
pub fn derive_steps(cfg: &ExpConfig, gen_batch: u64) -> Result<u64> {
    let k = cfg.k_samples as u64;
    let m = cfg.gen_workers.max(1) as u64;
    let groups_per_round = gen_batch / k;
    let per_worker_turns = (cfg.serve_sessions / m) * cfg.serve_turns;
    if per_worker_turns % groups_per_round != 0 {
        bail!(
            "serve geometry does not tile: each worker serves {} turns \
             ({} sessions / {m} workers x {} turns) but a round needs \
             {groups_per_round} turns (gen_batch {gen_batch} / k {k}) — \
             the trace tail would never assemble into a round",
            per_worker_turns,
            cfg.serve_sessions,
            cfg.serve_turns
        );
    }
    let total_rounds = (cfg.serve_sessions * cfg.serve_turns) / groups_per_round;
    let rpb = rounds_per_batch(cfg.k_samples) as u64;
    if total_rounds % rpb != 0 {
        bail!(
            "serve geometry does not tile: the trace assembles \
             {total_rounds} rounds but a training batch consumes {rpb} — \
             the last rounds would never train"
        );
    }
    Ok(total_rounds / rpb)
}

/// Run serve-while-training: the unified [`pipeline`] trainer loop fed by
/// a [`SessionSource`] — M supervised serving seats multiplexing the
/// deterministic traffic trace onto their slot pools, with every
/// completed turn trained on exactly once.
pub fn run(
    cfg: &ExpConfig,
    prep: &Prepared,
    verbose: bool,
) -> Result<RunOutput> {
    let gen_batch = prep.engine.manifest.config.gen_batch as u64;
    let mut run_cfg = cfg.clone();
    run_cfg.steps = derive_steps(cfg, gen_batch)?;
    if verbose {
        eprintln!(
            "[serve] {} sessions x {} turns over {} workers -> {} steps",
            cfg.serve_sessions,
            cfg.serve_turns,
            cfg.gen_workers,
            run_cfg.steps
        );
    }
    pipeline::run(
        &run_cfg,
        prep,
        |origin, resume: Option<&Checkpoint>, bus| {
            let src: Box<dyn RoundSource> = Box::new(SessionSource::spawn(
                &run_cfg,
                prep,
                origin,
                resume,
                bus.clone(),
            )?);
            Ok(src)
        },
        verbose,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GenEngine, Mode};

    fn serve_cfg(sessions: u64, turns: u64, workers: usize) -> ExpConfig {
        ExpConfig {
            mode: Mode::Serve,
            gen_engine: GenEngine::Continuous,
            serve_sessions: sessions,
            serve_turns: turns,
            gen_workers: workers,
            ..ExpConfig::default()
        }
    }

    #[test]
    fn serving_steps_derive_from_the_trace() {
        // gen_batch 8, k 2 -> 4 turns per round; k=2 -> 1 round per batch
        let cfg = serve_cfg(8, 2, 1);
        assert_eq!(derive_steps(&cfg, 8).unwrap(), 4);
        // two workers: 4 sessions x 2 turns each = 8 turns per worker
        let cfg = serve_cfg(8, 2, 2);
        assert_eq!(derive_steps(&cfg, 8).unwrap(), 4);
    }

    #[test]
    fn serving_steps_reject_nontiling_geometry() {
        // 3 turns per worker does not tile 4-turn rounds
        let cfg = serve_cfg(3, 1, 1);
        let err = derive_steps(&cfg, 8).unwrap_err().to_string();
        assert!(err.contains("does not tile"), "err: {err}");
    }
}
