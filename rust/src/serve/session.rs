//! Per-session state for the serving front-end.
//!
//! A [`SessionBoard`] owns a worker's partition of the traffic trace
//! (`session % stride == lane`) and runs each session through a strict
//! turn chain: a turn becomes *admittable* at its arrival/think sweep,
//! its `k` candidate completions are queued for the slot pool, and the
//! next turn opens only once all `k` retire — so a respawned worker can
//! recompute the whole schedule from (trace, delivered-set) alone, with
//! no in-flight state to recover.
//!
//! The board is deliberately pool-agnostic: it never touches a backend.
//! [`SessionBoard::admission`] exposes the queued candidates as the same
//! `AdmitSeq` stream `TaskGen::admission` produces for the training
//! workers, and [`SessionBoard::on_completed`] consumes retirements and
//! converts them into latency samples ([`CompletionEvent`]) plus served
//! transcripts ([`TurnRecord`]). Every error path names the session id —
//! a dropped or duplicated turn must fail loudly, never silently.

use std::collections::{HashSet, VecDeque};
use std::fmt::Write as _;

use anyhow::{bail, Result};

use super::traffic::{turn_uid, uid_session_turn, TrafficGen};
use crate::data::TaskGen;
use crate::gen::continuous::{AdmitSeq, Completed};

/// Lifecycle of one session's *current* turn.
#[derive(Debug, Clone, PartialEq)]
enum Turn {
    /// Waiting for the arrival / think sweep before candidates queue.
    Waiting { ready_at: u64 },
    /// Candidates queued/in-flight; `outstanding` yet to retire.
    InFlight { outstanding: usize },
    /// All turns of the session completed.
    Done,
}

struct SessionState {
    id: u64,
    /// Turn currently being waited for or served.
    turn: u64,
    phase: Turn,
    /// Sweep the current turn's candidates were queued (latency epoch:
    /// time-to-first-token and time-to-retire count from here, so slot
    /// queueing delay is part of the measurement).
    ready_sweep: u64,
    /// Think delays for turns 1.. (copied from the trace so the board is
    /// self-contained after construction).
    thinks: Vec<u64>,
    /// Candidate 0's reply, stashed until the turn completes.
    reply: Option<(Vec<i32>, bool)>,
}

/// One completed served turn: what the session was actually shown
/// (candidate 0 of the `k` sampled — the remaining candidates exist for
/// the trainer's pairwise objective, not the user).
#[derive(Debug, Clone)]
pub struct TurnRecord {
    pub session: u64,
    pub turn: u64,
    pub uid: u64,
    /// Response tokens of candidate 0, EOS included when terminated.
    pub reply: Vec<i32>,
    pub terminated: bool,
}

/// Latency accounting for one retired candidate, in sweep units (sweeps
/// are the pool's clock, so these are deterministic at equal seeds; the
/// bench converts to wall time via the measured mean sweep duration).
#[derive(Debug, Clone, Copy)]
pub struct CompletionEvent {
    pub session: u64,
    pub turn: u64,
    /// Sweeps from turn-ready to this candidate's first sampled token
    /// (slot queueing + prefill).
    pub ttft: u64,
    /// Sweeps from turn-ready to retirement.
    pub retire: u64,
    /// This retirement completed the turn (all `k` candidates done).
    pub turn_done: bool,
}

/// A worker's view of the traffic trace: session scheduling, admission
/// queueing, completion accounting and the served transcript.
pub struct SessionBoard {
    turns: u64,
    k: usize,
    sessions: Vec<SessionState>,
    /// Queued admission candidates `(uid, dup)` in deterministic
    /// (sweep, session-id, dup) order.
    queue: VecDeque<(u64, usize)>,
    records: Vec<TurnRecord>,
}

impl SessionBoard {
    /// Board over the sessions this worker owns (`session % stride ==
    /// lane`). `delivered` is the set of turn uids already accepted into
    /// training rounds (the respawn skip set): those turns are not
    /// regenerated — each session resumes at its first undelivered turn.
    /// Because turns complete (and thus deliver) in order, the delivered
    /// set must be a per-session prefix; a hole means the exactly-once
    /// contract was already broken and the board refuses to start.
    pub fn new(
        traffic: &TrafficGen,
        k: usize,
        lane: u64,
        stride: u64,
        delivered: &HashSet<u64>,
    ) -> Result<SessionBoard> {
        SessionBoard::for_lanes(traffic, k, &[lane], stride, delivered)
    }

    /// Board over every session whose partition residue `session % stride`
    /// is in `lanes` — the migration form of [`SessionBoard::new`]: a
    /// takeover heir serves its own residue plus the dead seats'. The
    /// whole schedule is still a pure function of `(trace, delivered)`,
    /// so a migrated session resumes exactly where the accounts say it
    /// stopped, on whichever seat now owns its residue.
    pub fn for_lanes(
        traffic: &TrafficGen,
        k: usize,
        lanes: &[u64],
        stride: u64,
        delivered: &HashSet<u64>,
    ) -> Result<SessionBoard> {
        assert!(k >= 1);
        assert!(stride >= 1 && lanes.iter().all(|&l| l < stride));
        let cfg = traffic.cfg();
        let mut sessions = Vec::new();
        // ascending session id regardless of how many residues are owned:
        // single-lane boards keep their historical (bitwise) ordering
        for s in (0..cfg.sessions).filter(|s| lanes.contains(&(s % stride))) {
            let resumed = (0..cfg.turns)
                .take_while(|&t| delivered.contains(&traffic.uid(s, t)))
                .count() as u64;
            if let Some(t) = (resumed..cfg.turns)
                .find(|&t| delivered.contains(&traffic.uid(s, t)))
            {
                bail!(
                    "serving session {s}: delivered turns have a hole — \
                     turn {t} was delivered but turn {resumed} was not \
                     (exactly-once accounting violated)"
                );
            }
            let phase = if resumed == cfg.turns {
                Turn::Done
            } else if resumed == 0 {
                Turn::Waiting { ready_at: traffic.arrival(s) }
            } else {
                // resume clock restarts at sweep 0; the think delay still
                // gates the turn so the schedule stays deterministic in
                // (trace, delivered-set)
                Turn::Waiting { ready_at: traffic.think(s, resumed) }
            };
            sessions.push(SessionState {
                id: s,
                turn: resumed,
                phase,
                ready_sweep: 0,
                thinks: (1..cfg.turns).map(|t| traffic.think(s, t)).collect(),
                reply: None,
            });
        }
        Ok(SessionBoard {
            turns: cfg.turns,
            k,
            sessions,
            queue: VecDeque::new(),
            records: Vec::new(),
        })
    }

    /// Advance the clock: queue the candidates of every turn whose
    /// arrival / think delay has elapsed. Sessions are scanned in id
    /// order, so the queue order is deterministic.
    pub fn on_sweep(&mut self, sweep: u64) {
        for s in &mut self.sessions {
            if let Turn::Waiting { ready_at } = s.phase {
                if ready_at <= sweep {
                    s.phase = Turn::InFlight { outstanding: self.k };
                    s.ready_sweep = sweep;
                    let uid = turn_uid(s.id, s.turn, self.turns);
                    for dup in 0..self.k {
                        self.queue.push_back((uid, dup));
                    }
                }
            }
        }
    }

    /// The queued candidates as a slot-pool admission stream; prompts are
    /// regenerated from the pure example stream at the turn's uid, same
    /// as `TaskGen::admission` does for lane cursors.
    pub fn admission<'a>(&'a mut self, gen: &'a TaskGen) -> BoardAdmission<'a> {
        BoardAdmission { queue: &mut self.queue, gen }
    }

    /// Account one retirement back to its session. Errors name the
    /// session: a completion for an unowned session, a non-current turn
    /// or an over-delivered candidate means the mux dropped or duplicated
    /// a turn.
    pub fn on_completed(
        &mut self,
        c: &Completed,
        sweep: u64,
    ) -> Result<CompletionEvent> {
        let (session, turn) = uid_session_turn(c.index, self.turns);
        let Some(s) = self.sessions.iter_mut().find(|s| s.id == session)
        else {
            bail!(
                "serving session {session}: completion (uid {}) routed to \
                 a worker that does not own it",
                c.index
            );
        };
        if s.turn != turn {
            bail!(
                "serving session {session}: completion for turn {turn} \
                 while turn {} is current — a turn was dropped or replayed",
                s.turn
            );
        }
        let Turn::InFlight { outstanding } = &mut s.phase else {
            bail!(
                "serving session {session}: completion for turn {turn} \
                 which is not in flight (phase {:?})",
                s.phase
            );
        };
        if c.dup == 0 {
            let reply: Vec<i32> = c
                .tokens
                .iter()
                .zip(&c.resp_mask)
                .filter(|(_, &m)| m == 1.0)
                .map(|(&t, _)| t)
                .collect();
            s.reply = Some((reply, c.terminated));
        }
        *outstanding -= 1;
        let turn_done = *outstanding == 0;
        let first_token = (sweep + 1).saturating_sub(c.steps as u64);
        let ev = CompletionEvent {
            session,
            turn,
            ttft: first_token.saturating_sub(s.ready_sweep),
            retire: sweep.saturating_sub(s.ready_sweep),
            turn_done,
        };
        if turn_done {
            let Some((reply, terminated)) = s.reply.take() else {
                bail!(
                    "serving session {session}: turn {turn} completed \
                     without its candidate 0 (admission bug)"
                );
            };
            self.records.push(TurnRecord {
                session,
                turn,
                uid: c.index,
                reply,
                terminated,
            });
            s.turn += 1;
            s.phase = if s.turn == self.turns {
                Turn::Done
            } else {
                Turn::Waiting {
                    ready_at: sweep + s.thinks[(s.turn - 1) as usize],
                }
            };
        }
        Ok(ev)
    }

    /// Every owned session has completed all its turns.
    pub fn all_done(&self) -> bool {
        self.sessions.iter().all(|s| s.phase == Turn::Done)
    }

    /// Ids of sessions with turns still to serve — the loud-failure
    /// payload when a worker cannot make progress.
    pub fn incomplete(&self) -> Vec<u64> {
        self.sessions
            .iter()
            .filter(|s| s.phase != Turn::Done)
            .map(|s| s.id)
            .collect()
    }

    /// Candidates queued but not yet admitted.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Turns completed by this board incarnation.
    pub fn records(&self) -> &[TurnRecord] {
        &self.records
    }

    /// The served transcript, rendered deterministically (sorted by
    /// (session, turn)) for byte-identical comparison across runs.
    pub fn transcript(&self) -> String {
        let mut recs: Vec<&TurnRecord> = self.records.iter().collect();
        recs.sort_by_key(|r| (r.session, r.turn));
        let mut out = String::new();
        for r in recs {
            let _ = writeln!(
                out,
                "session {} turn {} uid {} term {} reply {:?}",
                r.session, r.turn, r.uid, r.terminated, r.reply
            );
        }
        out
    }
}

/// Iterator behind [`SessionBoard::admission`]: drains the candidate
/// queue into `AdmitSeq`s. Finite (unlike `TaskGen::admission`): the pool
/// admits whatever is queued and leaves its remaining slots free.
pub struct BoardAdmission<'a> {
    queue: &'a mut VecDeque<(u64, usize)>,
    gen: &'a TaskGen,
}

impl Iterator for BoardAdmission<'_> {
    type Item = AdmitSeq;

    fn next(&mut self) -> Option<AdmitSeq> {
        let (uid, dup) = self.queue.pop_front()?;
        Some(AdmitSeq { index: uid, dup, prompt: self.gen.example(uid).prompt })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Task;
    use crate::serve::traffic::TrafficCfg;

    fn traffic(sessions: u64, turns: u64) -> TrafficGen {
        TrafficGen::new(TrafficCfg {
            sessions,
            turns,
            arrival_rate: 0.5,
            seed: 42,
        })
    }

    fn completed(uid: u64, dup: usize, steps: usize) -> Completed {
        let s = 8;
        let mut resp_mask = vec![0.0; s];
        let mut tokens = vec![0; s];
        for i in 2..2 + steps {
            resp_mask[i] = 1.0;
            tokens[i] = 7;
        }
        Completed {
            index: uid,
            dup,
            tokens,
            resp_mask,
            blp: vec![0.0; s],
            terminated: true,
            steps,
            version_min: 0,
            version_max: 0,
            version_sum: 0.0,
        }
    }

    #[test]
    fn serving_board_turn_chain_gates_on_completion_and_think() {
        let t = traffic(1, 2);
        let mut b =
            SessionBoard::new(&t, 2, 0, 1, &HashSet::new()).unwrap();
        let arrive = t.arrival(0);
        b.on_sweep(arrive - 1);
        assert_eq!(b.queued(), 0, "turn 0 not admittable before arrival");
        b.on_sweep(arrive);
        assert_eq!(b.queued(), 2, "k candidates queue at arrival");
        let uid = t.uid(0, 0);
        let gen = TaskGen::new(Task::Tldr, 24, 12, 42);
        let admitted: Vec<AdmitSeq> = b.admission(&gen).collect();
        assert_eq!(admitted.len(), 2);
        assert!(admitted.iter().all(|a| a.index == uid));
        assert_eq!(admitted[0].prompt, gen.example(uid).prompt);
        // turn 1 stays gated until BOTH candidates retire + think elapses
        let done_sweep = arrive + 3;
        let ev = b.on_completed(&completed(uid, 0, 2), done_sweep).unwrap();
        assert!(!ev.turn_done);
        b.on_sweep(done_sweep + 1000);
        assert_eq!(b.queued(), 0, "turn 1 gated on turn 0 completion");
        let ev = b.on_completed(&completed(uid, 1, 3), done_sweep).unwrap();
        assert!(ev.turn_done);
        assert_eq!(ev.retire, 3);
        let think = t.think(0, 1);
        b.on_sweep(done_sweep + think - 1);
        assert_eq!(b.queued(), 0, "think delay not yet elapsed");
        b.on_sweep(done_sweep + think);
        assert_eq!(b.queued(), 2, "turn 1 opens after the think delay");
        assert!(!b.all_done());
        assert_eq!(b.incomplete(), vec![0]);
    }

    #[test]
    fn serving_board_latency_counts_from_turn_ready() {
        let t = traffic(1, 1);
        let mut b =
            SessionBoard::new(&t, 1, 0, 1, &HashSet::new()).unwrap();
        let arrive = t.arrival(0);
        b.on_sweep(arrive);
        let uid = t.uid(0, 0);
        // retired at arrive+5 after holding a slot for 3 sweeps: first
        // token sampled at arrive+3 → ttft 3, retire 5
        let ev = b.on_completed(&completed(uid, 0, 3), arrive + 5).unwrap();
        assert_eq!(ev.ttft, 3);
        assert_eq!(ev.retire, 5);
        assert!(ev.turn_done);
        assert!(b.all_done());
        assert_eq!(b.records().len(), 1);
        assert_eq!(b.records()[0].reply, vec![7, 7, 7]);
    }

    #[test]
    fn serving_board_partitions_sessions_by_lane() {
        let t = traffic(6, 1);
        let b0 = SessionBoard::new(&t, 2, 0, 2, &HashSet::new()).unwrap();
        let b1 = SessionBoard::new(&t, 2, 1, 2, &HashSet::new()).unwrap();
        assert_eq!(b0.incomplete(), vec![0, 2, 4]);
        assert_eq!(b1.incomplete(), vec![1, 3, 5]);
    }

    #[test]
    fn serving_board_migrates_merged_residues_from_the_delivered_set() {
        // a takeover heir's board: both residues of a 2-seat partition,
        // rebuilt mid-trace from (trace, delivered) alone
        let t = traffic(4, 2);
        // lane-0 sessions fully current; session 1 (dead seat's) already
        // delivered turn 0, session 3 nothing
        let delivered: HashSet<u64> = [t.uid(1, 0)].into();
        let b = SessionBoard::for_lanes(&t, 1, &[0, 1], 2, &delivered).unwrap();
        assert_eq!(b.incomplete(), vec![0, 1, 2, 3], "all sessions owned");
        let mut b = b;
        b.on_sweep(u64::MAX);
        let gen = TaskGen::new(Task::Tldr, 24, 12, 42);
        let uids: Vec<u64> = b.admission(&gen).map(|a| a.index).collect();
        assert!(uids.contains(&t.uid(0, 0)), "own residue starts fresh");
        assert!(uids.contains(&t.uid(1, 1)), "migrated session resumes");
        assert!(!uids.contains(&t.uid(1, 0)), "delivered turn not replayed");
        // the single-lane constructor is the one-residue special case
        let single = SessionBoard::new(&t, 1, 0, 2, &HashSet::new()).unwrap();
        assert_eq!(single.incomplete(), vec![0, 2]);
    }

    #[test]
    fn serving_board_rejects_unowned_and_stale_completions() {
        let t = traffic(4, 2);
        let mut b =
            SessionBoard::new(&t, 1, 0, 2, &HashSet::new()).unwrap();
        // session 1 belongs to lane 1
        let err = b
            .on_completed(&completed(t.uid(1, 0), 0, 1), 10)
            .unwrap_err()
            .to_string();
        assert!(err.contains("session 1"), "error must name the session: {err}");
        // session 0 turn 1 while turn 0 is current
        let err = b
            .on_completed(&completed(t.uid(0, 1), 0, 1), 10)
            .unwrap_err()
            .to_string();
        assert!(err.contains("session 0") && err.contains("turn 1"), "{err}");
    }

    #[test]
    fn serving_board_resumes_past_delivered_prefix_and_rejects_holes() {
        let t = traffic(2, 3);
        // session 0 delivered turns 0..2; session 1 nothing
        let delivered: HashSet<u64> = [t.uid(0, 0), t.uid(0, 1)].into();
        let mut b = SessionBoard::new(&t, 1, 0, 1, &delivered).unwrap();
        b.on_sweep(u64::MAX);
        let gen = TaskGen::new(Task::Tldr, 24, 12, 42);
        let uids: Vec<u64> =
            b.admission(&gen).map(|a| a.index).collect();
        assert!(uids.contains(&t.uid(0, 2)), "session 0 resumes at turn 2");
        assert!(uids.contains(&t.uid(1, 0)), "session 1 starts fresh");
        assert!(!uids.contains(&t.uid(0, 0)), "delivered turns not replayed");
        // a hole in the delivered set is an accounting violation
        let hole: HashSet<u64> = [t.uid(0, 2)].into();
        let err = SessionBoard::new(&t, 1, 0, 1, &hole)
            .err()
            .expect("a delivered-set hole must be rejected")
            .to_string();
        assert!(err.contains("session 0") && err.contains("hole"), "{err}");
    }

    #[test]
    fn serving_board_fully_delivered_partition_is_done() {
        let t = traffic(1, 2);
        let delivered: HashSet<u64> = [t.uid(0, 0), t.uid(0, 1)].into();
        let b = SessionBoard::new(&t, 2, 0, 1, &delivered).unwrap();
        assert!(b.all_done());
        assert!(b.incomplete().is_empty());
    }
}
