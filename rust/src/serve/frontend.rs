//! The serving front-end: session traffic multiplexed onto the
//! continuous slot pool.
//!
//! [`ServeMux`] glues a [`SessionBoard`] (who wants to decode, and when)
//! to a [`Pool`] (which slot decodes it): one [`ServeMux::step`] is one
//! pool sweep — advance the traffic clock, admit queued candidates into
//! freed slots, sample/retire, and route every retirement back to its
//! session with latency accounting. The mux never owns weights: the
//! caller passes the `ParamView` to decode under each sweep, so the
//! streaming seat swaps in freshly published params between sweeps
//! exactly as the training workers do.
//!
//! [`run_replay`] is the offline face: drive a whole traffic trace to
//! completion against any [`DecodeBackend`] at fixed params. It backs
//! the byte-identical-transcript determinism tests (scripted backend, no
//! artifacts needed) and the serving benchmark's training-off tier.

use std::collections::HashSet;

use anyhow::{bail, Result};

use super::session::{CompletionEvent, SessionBoard};
use super::traffic::TrafficGen;
use crate::data::TaskGen;
use crate::gen::continuous::{
    Completed, DecodeBackend, Pool, PoolCfg, PoolStats,
};
use crate::gen::SampleOpts;
use crate::runtime::ParamView;
use crate::util::rng::Pcg32;

/// RNG stream of the offline replay driver (the streaming seats use
/// their own per-worker streams).
const REPLAY_STREAM: u64 = 0x5e7e;

/// One worker's serving loop state: traffic board + slot pool + sweep
/// clock.
pub struct ServeMux {
    pool: Pool,
    board: SessionBoard,
    sweep: u64,
}

impl ServeMux {
    pub fn new(cfg: PoolCfg, board: SessionBoard) -> ServeMux {
        ServeMux { pool: Pool::new(cfg), board, sweep: 0 }
    }

    pub fn board(&self) -> &SessionBoard {
        &self.board
    }

    /// Mux sweeps elapsed — the traffic clock. Unlike the pool's sweep
    /// count this also advances while the pool idles waiting for the
    /// next arrival, so arrival gaps pass in bounded time.
    pub fn sweep(&self) -> u64 {
        self.sweep
    }

    pub fn stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Response tokens in flight inside the slot pool — what a seat death
    /// right now would abandon with its KV.
    pub fn inflight_tokens(&self) -> u64 {
        self.pool.inflight_tokens()
    }

    /// Every owned session served and nothing left in flight.
    pub fn is_done(&self) -> bool {
        self.board.all_done() && self.pool.is_drained()
    }

    /// One serving sweep under the given params/version. Returns the
    /// retirements of this sweep paired with their latency events; the
    /// caller forwards the `Completed`s to its `RoundAssembler` (training
    /// fan-in) or drops them (pure serving).
    pub fn step(
        &mut self,
        backend: &mut dyn DecodeBackend,
        gen: &TaskGen,
        params: ParamView<'_>,
        version: u64,
        opts: SampleOpts,
        rng: &mut Pcg32,
    ) -> Result<Vec<(Completed, CompletionEvent)>> {
        self.sweep += 1;
        self.board.on_sweep(self.sweep);
        {
            let mut admission = self.board.admission(gen);
            self.pool.step(backend, params, version, &mut admission, opts, rng)?;
        }
        let mut out = Vec::new();
        for c in self.pool.drain_completed() {
            let ev = self.board.on_completed(&c, self.sweep)?;
            out.push((c, ev));
        }
        Ok(out)
    }
}

/// What a finished replay run served, and how fast.
pub struct ServeReport {
    /// Deterministic transcript — byte-identical at equal seeds.
    pub transcript: String,
    /// Mux sweeps to drain the whole trace.
    pub sweeps: u64,
    pub stats: PoolStats,
    /// Per-candidate time-to-first-token samples (sweep units).
    pub ttft: Vec<u64>,
    /// Per-candidate time-to-retire samples (sweep units).
    pub retire: Vec<u64>,
    /// Turns served (each turn = one user-visible request).
    pub requests: u64,
    /// Response tokens emitted across all candidates.
    pub tokens: u64,
}

/// Drive a full traffic trace to completion at fixed params (training
/// disabled). `max_sweeps` bounds the run: exceeding it fails loudly with
/// the incomplete session ids rather than spinning forever.
#[allow(clippy::too_many_arguments)]
pub fn run_replay(
    backend: &mut dyn DecodeBackend,
    gen: &TaskGen,
    traffic: &TrafficGen,
    pool: PoolCfg,
    k: usize,
    opts: SampleOpts,
    params: ParamView<'_>,
    seed: u64,
    max_sweeps: u64,
) -> Result<ServeReport> {
    let board = SessionBoard::new(traffic, k, 0, 1, &HashSet::new())?;
    let mut mux = ServeMux::new(pool, board);
    let mut rng = Pcg32::new(seed, REPLAY_STREAM);
    let (mut ttft, mut retire) = (Vec::new(), Vec::new());
    while !mux.is_done() {
        if mux.sweep() >= max_sweeps {
            bail!(
                "serving replay stalled after {max_sweeps} sweeps: \
                 sessions {:?} incomplete",
                mux.board().incomplete()
            );
        }
        for (_, ev) in mux.step(backend, gen, params, 0, opts, &mut rng)? {
            ttft.push(ev.ttft);
            retire.push(ev.retire);
        }
    }
    let stats = mux.stats();
    Ok(ServeReport {
        transcript: mux.board().transcript(),
        sweeps: mux.sweep(),
        stats,
        ttft,
        retire,
        requests: mux.board().records().len() as u64,
        tokens: stats.tokens,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Task;
    use crate::serve::traffic::TrafficCfg;
    use crate::tokenizer as tk;

    const B: usize = 4;
    const P: usize = 24;
    const S: usize = 32;
    const V: usize = 16;

    /// Artifact-free scripted backend (mirrors the slot-pool unit tests):
    /// logits force token `script(row, pos)`; greedy sampling makes the
    /// output exact.
    struct Scripted<F: FnMut(usize, usize) -> i32> {
        script: F,
    }

    impl<F: FnMut(usize, usize) -> i32> Scripted<F> {
        fn logits_for(&mut self, pos: usize) -> Vec<f32> {
            let mut l = vec![0.0f32; B * V];
            for row in 0..B {
                let tok = (self.script)(row, pos);
                l[row * V + tok as usize] = 80.0;
            }
            l
        }
    }

    impl<F: FnMut(usize, usize) -> i32> DecodeBackend for Scripted<F> {
        fn prefill(
            &mut self,
            _params: ParamView<'_>,
            prompt_flat: &[i32],
        ) -> Result<(usize, Vec<f32>)> {
            assert_eq!(prompt_flat.len(), B * P);
            Ok((0, self.logits_for(P)))
        }

        fn decode(
            &mut self,
            _params: ParamView<'_>,
            _cache: usize,
            toks: &[i32],
            pos: usize,
        ) -> Result<Vec<f32>> {
            assert_eq!(toks.len(), B);
            Ok(self.logits_for(pos + 1))
        }

        fn retire_cache(&mut self, _cache: usize) {}
    }

    fn pool_cfg() -> PoolCfg {
        PoolCfg {
            slots: B,
            prompt_len: P,
            seq_len: S,
            vocab: V,
            max_cohorts: 4,
            admit_min: 1,
        }
    }

    const GREEDY: SampleOpts = SampleOpts { temperature: 0.7, greedy: true };

    fn replay(seed: u64) -> ServeReport {
        // row-varying response lengths so cohorts interleave
        let mut backend = Scripted {
            script: |row: usize, pos: usize| {
                let len = [2usize, 4, 3, 5][row % B];
                if pos >= P + len - 1 {
                    tk::EOS
                } else {
                    7
                }
            },
        };
        let traffic = TrafficGen::new(TrafficCfg {
            sessions: 4,
            turns: 2,
            arrival_rate: 0.5,
            seed,
        });
        let gen = TaskGen::new(Task::Tldr, P, 12, seed);
        run_replay(
            &mut backend,
            &gen,
            &traffic,
            pool_cfg(),
            2,
            GREEDY,
            ParamView::fresh(&[]),
            seed,
            10_000,
        )
        .expect("replay drains")
    }

    #[test]
    fn serving_replay_transcripts_are_byte_identical_at_equal_seeds() {
        let a = replay(42);
        let b = replay(42);
        assert!(!a.transcript.is_empty());
        assert_eq!(a.transcript, b.transcript, "equal seeds must replay");
        assert_eq!(a.sweeps, b.sweeps);
        assert_eq!(a.ttft, b.ttft);
        assert_eq!(a.retire, b.retire);
    }

    #[test]
    fn serving_replay_serves_every_turn_exactly_once() {
        let r = replay(7);
        assert_eq!(r.requests, 4 * 2, "every (session, turn) served");
        assert_eq!(r.ttft.len(), 4 * 2 * 2, "one sample per candidate");
        assert_eq!(r.stats.retired, 4 * 2 * 2);
        // transcript lines are unique per (session, turn)
        let lines: Vec<&str> = r.transcript.lines().collect();
        assert_eq!(lines.len(), 8);
        let uniq: std::collections::HashSet<&&str> = lines.iter().collect();
        assert_eq!(uniq.len(), 8, "no turn rendered twice");
        // latency epochs include queueing: every sample positive
        assert!(r.ttft.iter().all(|&t| t >= 1));
        assert!(r.retire.iter().zip(&r.ttft).all(|(r, t)| r >= t));
    }

    #[test]
    fn serving_replay_arrival_process_moves_with_the_seed() {
        let a = replay(1);
        let b = replay(2);
        // the scripted replies are seed-independent, but the arrival /
        // think schedule (and so the latency trace) must not be
        assert!(
            a.ttft != b.ttft || a.sweeps != b.sweeps,
            "seed change must move the traffic schedule"
        );
    }
}
