//! Deterministic traffic replay: the load generator behind the serving
//! front-end.
//!
//! Serving is only reproducible if the *traffic* is: arrival times, turn
//! counts and prompt content must all be pure functions of the run's
//! config seed. This module precomputes a replay trace — per-session
//! arrival sweeps (exponential inter-arrival gaps, a Poisson-ish open
//! arrival process in pool-sweep units) and per-turn think delays — from
//! dedicated [`Pcg32`] streams, so two runs at equal seeds see
//! byte-identical traffic and the serving integration tests can assert
//! bitwise-equal transcripts.
//!
//! Prompt content rides the same discipline for free: every (session,
//! turn) pair maps to a unique prompt-stream uid in [`SERVE_RANGE`]
//! (disjoint from the SFT / RM / RLHF / eval index ranges), and
//! `TaskGen::example(uid)` is pure in (seed, uid) — so the uid doubles as
//! the exactly-once accounting key *and* regenerates the served prompt
//! (plus its gold meta) wherever the round is consumed, exactly like the
//! round workers' lane cursors.

use crate::util::rng::Pcg32;

/// Prompt-stream index range owned by the serving front-end. Train /
/// eval ranges top out at `EVAL_RANGE` (10M) plus a few thousand lane
/// hops; served uids live far above so the exactly-once partition over
/// prompt indices extends across training and serving.
pub const SERVE_RANGE: u64 = 500_000_000;

/// RNG stream of the shared arrival process.
const ARRIVAL_STREAM: u64 = 0x7a11;
/// Base RNG stream of the per-session think-time processes.
const THINK_STREAM: u64 = 0x7a12_0000;

/// Traffic shape: how many sessions arrive, how many turns each runs,
/// and how fast they come.
#[derive(Debug, Clone, Copy)]
pub struct TrafficCfg {
    pub sessions: u64,
    /// Turns per session (every session runs the same count; per-session
    /// variety comes from arrival/think randomness, not ragged lengths,
    /// so round geometry stays exact).
    pub turns: u64,
    /// Mean session arrivals per pool sweep; also sets the think-time
    /// mean (`1 / rate` sweeps) between a session's turns.
    pub arrival_rate: f64,
    pub seed: u64,
}

/// The precomputed replay trace. Pure in [`TrafficCfg`]: equal configs
/// produce identical traces, and a respawned worker rebuilds the exact
/// schedule its predecessor was serving.
pub struct TrafficGen {
    cfg: TrafficCfg,
    /// Sweep at which session `s`'s first turn becomes admittable.
    arrivals: Vec<u64>,
    /// `thinks[s][t-1]`: delay between session `s` completing turn `t-1`
    /// and turn `t` becoming admittable.
    thinks: Vec<Vec<u64>>,
}

impl TrafficGen {
    pub fn new(cfg: TrafficCfg) -> TrafficGen {
        assert!(cfg.sessions >= 1, "traffic needs at least one session");
        assert!(cfg.turns >= 1, "sessions need at least one turn");
        assert!(cfg.arrival_rate > 0.0, "arrival rate must be positive");
        let mut arr = Pcg32::new(cfg.seed, ARRIVAL_STREAM);
        let mut at = 0u64;
        let arrivals = (0..cfg.sessions)
            .map(|_| {
                at += exp_gap(&mut arr, cfg.arrival_rate);
                at
            })
            .collect();
        let thinks = (0..cfg.sessions)
            .map(|s| {
                let mut rng = Pcg32::new(cfg.seed, THINK_STREAM + s);
                (1..cfg.turns)
                    .map(|_| exp_gap(&mut rng, cfg.arrival_rate))
                    .collect()
            })
            .collect();
        TrafficGen { cfg, arrivals, thinks }
    }

    pub fn cfg(&self) -> TrafficCfg {
        self.cfg
    }

    /// Sweep at which `session`'s first turn becomes admittable.
    pub fn arrival(&self, session: u64) -> u64 {
        self.arrivals[session as usize]
    }

    /// Think delay before `turn` (>= 1) of `session`, counted from the
    /// sweep its previous turn completed.
    pub fn think(&self, session: u64, turn: u64) -> u64 {
        debug_assert!(turn >= 1, "turn 0 is gated by arrival, not think");
        self.thinks[session as usize][(turn - 1) as usize]
    }

    /// Prompt-stream uid of (`session`, `turn`) under this trace's shape.
    pub fn uid(&self, session: u64, turn: u64) -> u64 {
        turn_uid(session, turn, self.cfg.turns)
    }
}

/// Encode (session, turn) as a prompt-stream uid: the accounting key the
/// served rounds carry in place of lane cursors.
pub fn turn_uid(session: u64, turn: u64, turns: u64) -> u64 {
    debug_assert!(turn < turns, "turn {turn} out of range {turns}");
    SERVE_RANGE + session * turns + turn
}

/// Decode a served uid back to (session, turn).
pub fn uid_session_turn(uid: u64, turns: u64) -> (u64, u64) {
    debug_assert!(uid >= SERVE_RANGE, "uid {uid} below SERVE_RANGE");
    let off = uid - SERVE_RANGE;
    (off / turns, off % turns)
}

/// One exponential inter-arrival gap in whole sweeps (mean `1 / rate`),
/// floored at 1 so time always advances.
fn exp_gap(rng: &mut Pcg32, rate: f64) -> u64 {
    let u = rng.gen_f64();
    let gap = -(1.0 - u).ln() / rate;
    (gap.ceil() as u64).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(seed: u64) -> TrafficCfg {
        TrafficCfg { sessions: 6, turns: 3, arrival_rate: 0.5, seed }
    }

    #[test]
    fn serving_traffic_is_deterministic_at_equal_seeds() {
        let a = TrafficGen::new(cfg(42));
        let b = TrafficGen::new(cfg(42));
        for s in 0..6 {
            assert_eq!(a.arrival(s), b.arrival(s));
            for t in 1..3 {
                assert_eq!(a.think(s, t), b.think(s, t));
            }
        }
    }

    #[test]
    fn serving_traffic_seeds_differ() {
        let a = TrafficGen::new(cfg(1));
        let b = TrafficGen::new(cfg(2));
        let same = (0..6).filter(|&s| a.arrival(s) == b.arrival(s)).count();
        assert!(same < 6, "seed change must move the arrival process");
    }

    #[test]
    fn serving_arrivals_are_strictly_increasing() {
        let g = TrafficGen::new(cfg(7));
        for s in 1..6 {
            assert!(g.arrival(s) > g.arrival(s - 1), "gaps floored at 1");
        }
        assert!(g.arrival(0) >= 1);
    }

    #[test]
    fn serving_uid_roundtrip_and_range_disjointness() {
        let turns = 5u64;
        for session in [0u64, 1, 99, 10_000] {
            for turn in 0..turns {
                let uid = turn_uid(session, turn, turns);
                assert_eq!(uid_session_turn(uid, turns), (session, turn));
                // above every train/eval index range (EVAL_RANGE = 10M)
                assert!(uid >= SERVE_RANGE && SERVE_RANGE > 10_000_000);
            }
        }
        // adjacent sessions never collide
        assert_eq!(
            turn_uid(3, turns - 1, turns) + 1,
            turn_uid(4, 0, turns),
            "uid blocks tile the range without gaps or overlap"
        );
    }
}
