//! async-rlhf CLI: the leader entrypoint.
//!
//! Subcommands:
//!   info   <model>          — show a config's manifest summary
//!   train  <model> [...]    — run one RLHF experiment (sync or async)
//!   serve  <model> [...]    — serve-while-training: session traffic as the
//!                             prompt stream over the continuous slot pool
//!   exp    <id> [...]       — regenerate a paper figure/table (see DESIGN.md §6)
//!   sim    [...]            — clock-simulate sync vs async schedules
//!   config show <model>     — print baked hyperparameters (paper Tables 4-7, 10)
//!
//! Examples:
//!   async-rlhf train tldr_s --algo dpo --mode async --steps 96
//!   async-rlhf train tldr_s --mode async --gen-workers 2 --staleness-bound 4
//!   async-rlhf train tldr_s --trainer-shards 2  # data-parallel trainer
//!   async-rlhf train tldr_s --gen-engine device   # KV chained on-device
//!   async-rlhf train tldr_s --mode async --gen-engine continuous \
//!                           --max-cohorts 4 --admit-min 1  # slot pool
//!   async-rlhf train tldr_s --checkpoint-every 8  # crash-safe snapshots
//!   async-rlhf train tldr_s --checkpoint-every 8 --resume  # continue run
//!   async-rlhf train tldr_s --mode async --gen-workers 2 \
//!                           --inject-fault worker=1,round=3,kind=panic
//!   async-rlhf serve tldr_s --serve-sessions 16 --serve-turns 2 \
//!                           --arrival-rate 0.5  # traffic-replay serving
//!   async-rlhf exp fig3 --steps 64
//!   async-rlhf exp staleness --steps 24           # K x M ladder
//!   async-rlhf sim --gen 21 --train 33 --steps 233

use anyhow::{anyhow, bail, Result};

use async_rlhf::config::ExpConfig;
use async_rlhf::coordinator;
use async_rlhf::data::Task;
use async_rlhf::eval::evaluate;
use async_rlhf::experiments;
use async_rlhf::runtime::{artifacts_root, Manifest};
use async_rlhf::sim::{analyze, simulate_async, simulate_sync, StepCosts};
use async_rlhf::util::args::Args;

const BOOL_FLAGS: &[&str] = &["quiet", "naive", "greedy", "force", "resume"];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, BOOL_FLAGS).map_err(|e| anyhow!("{e}"))?;
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("pretrain") => cmd_pretrain(&args),
        Some("exp") => experiments::run(&args),
        Some("sim") => cmd_sim(&args),
        Some("config") => cmd_config(&args),
        Some(other) => bail!("unknown subcommand '{other}'\n{}", usage()),
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn usage() -> &'static str {
    "usage: async-rlhf <info|train|serve|exp|sim|config> [options]\n\
     run `async-rlhf exp list` for the paper figure/table index"
}

fn cmd_info(args: &Args) -> Result<()> {
    let model = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: info <model>"))?;
    let dir = artifacts_root(args.get("artifacts")).join(model);
    let m = Manifest::load(&dir)?;
    println!("config   : {}", m.config.name);
    println!(
        "model    : d={} layers={} heads={} vocab={} ({} params)",
        m.config.d_model,
        m.config.n_layers,
        m.config.n_heads,
        m.config.vocab,
        m.param_count
    );
    println!(
        "task     : {} (prompt {}, resp {}, seq {})",
        m.config.task, m.config.prompt_len, m.config.resp_len, m.config.seq_len
    );
    println!(
        "batches  : gen {} / pairs {}",
        m.config.gen_batch, m.config.train_pairs
    );
    println!("artifacts:");
    for (name, a) in &m.artifacts {
        println!(
            "  {name:<14} {} in / {} out{}",
            a.inputs.len(),
            a.outputs.len(),
            if a.metrics.is_empty() {
                String::new()
            } else {
                format!("  metrics: {}", a.metrics.join(","))
            }
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args)?;
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&cfg, verbose)?;
    let task = prep.taskgen.task;

    eprintln!("[train] {}", cfg.label());
    let out = coordinator::run(&cfg, &prep, verbose)?;

    let result = evaluate(
        &prep.engine,
        &out.final_params,
        &prep.sft_params,
        &prep.taskgen,
        cfg.eval_prompts,
        cfg.temperature,
        cfg.seed,
    )?;
    println!("final  : {}", result.summary(task));
    println!(
        "wall   : {:.1}s for {} episodes ({} steps)",
        out.timeline.wall(),
        out.episodes,
        cfg.steps
    );
    let totals = out.timeline.totals();
    for (phase, secs) in &totals {
        println!("  {:<9} {secs:>8.2}s", phase.name());
    }

    // persist logs
    let run_dir = cfg.run_dir.join(cfg.label());
    out.log.save(&run_dir, "train")?;
    println!("logs   : {}", run_dir.display());
    if task == Task::Math {
        println!("pass@1 : {:.1}%", result.pass1 * 100.0);
    }
    Ok(())
}

/// Serve-while-training: `train` with serve-mode defaults (continuous
/// engine, live session traffic as the prompt stream) plus a serving
/// telemetry summary. The run's length is the traffic trace's, not
/// `--steps`.
fn cmd_serve(args: &Args) -> Result<()> {
    use async_rlhf::config::{GenEngine, Mode};
    let base = ExpConfig {
        mode: Mode::Serve,
        gen_engine: GenEngine::Continuous,
        ..ExpConfig::default()
    };
    let cfg = ExpConfig::from_args_with(args, base)?;
    if cfg.mode != Mode::Serve {
        bail!(
            "the serve subcommand runs --mode serve; use `train` for \
             sync/async runs"
        );
    }
    let verbose = !args.has_flag("quiet");
    let prep = coordinator::prepare(&cfg, verbose)?;

    eprintln!("[serve] {}", cfg.label());
    let out = coordinator::run(&cfg, &prep, verbose)?;

    println!(
        "served : {} sessions x {} turns over {} workers",
        cfg.serve_sessions, cfg.serve_turns, cfg.gen_workers
    );
    for key in [
        "serve_requests",
        "serve_tokens",
        "serve_ttft_p50",
        "serve_ttft_p99",
        "serve_retire_p50",
        "serve_retire_p99",
        "serve_lag_p50",
        "serve_lag_p99",
        "serve_lag_max",
        "serve_occupancy",
        "serve_occupancy_round_tier",
    ] {
        if let Some(v) = out.log.meta.get(key) {
            println!("  {key:<26} {v}");
        }
    }
    println!(
        "wall   : {:.1}s for {} episodes",
        out.timeline.wall(),
        out.episodes
    );
    let run_dir = cfg.run_dir.join(cfg.label());
    out.log.save(&run_dir, "serve")?;
    println!("logs   : {}", run_dir.display());
    Ok(())
}

/// Debug view of the SFT/RM pipeline: loss curves + sample generations.
fn cmd_pretrain(args: &Args) -> Result<()> {
    use async_rlhf::gen::{cached::CachedEngine, Generator, SampleOpts};
    use async_rlhf::metrics::RunLog;
    use async_rlhf::tokenizer::detok;
    use async_rlhf::util::rng::Pcg32;

    let cfg = ExpConfig::from_args(args)?;
    let prep_dir = cfg.run_dir.join("checkpoints");
    if args.has_flag("force") {
        let _ = std::fs::remove_dir_all(&prep_dir);
    }
    let engine = async_rlhf::runtime::Engine::load(&cfg.artifact_dir())?;
    let mcfg = engine.manifest.config.clone();
    let task = Task::from_name(&mcfg.task).unwrap();
    let taskgen = async_rlhf::data::TaskGen::new(
        task, mcfg.prompt_len, mcfg.resp_len, cfg.seed,
    );
    let mut log = RunLog::new();
    let sft = async_rlhf::coordinator::pretrain::sft_checkpoint(
        &engine, &taskgen, &cfg.run_dir, cfg.sft_steps, Some(&mut log),
    )?;
    println!("sft loss curve (every 20 steps):");
    for (step, loss) in log.series("sft_loss") {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    // sample generations vs references
    let examples = taskgen.batch(10_000_000, mcfg.gen_batch);
    let prompts: Vec<Vec<i32>> =
        examples.iter().map(|e| e.prompt.clone()).collect();
    let mut rng = Pcg32::new(0, 0);
    let gen = CachedEngine::default().generate(
        &engine,
        async_rlhf::runtime::ParamView::fresh(&sft),
        &prompts,
        SampleOpts::default(),
        &mut rng,
    )?;
    for i in 0..6.min(prompts.len()) {
        println!("prompt: {}", detok(&examples[i].prompt));
        println!("  ref : {}", detok(&examples[i].reference));
        println!("  gen : {}", detok(gen.response(i, mcfg.prompt_len)));
    }
    let ev = evaluate(&engine, &sft, &sft, &taskgen, cfg.eval_prompts,
                      cfg.temperature, cfg.seed)?;
    println!("eval: {}", ev.summary(task));

    if task != Task::Math && cfg.rm_steps > 0 {
        let mut rm_log = RunLog::new();
        let _rm = async_rlhf::coordinator::pretrain::rm_checkpoint(
            &engine, &taskgen, &sft, &cfg.run_dir, cfg.rm_steps, cfg.seed,
            Some(&mut rm_log),
        )?;
        println!("rm loss/acc curve:");
        for row in &rm_log.rows {
            println!(
                "  step {:>5}  loss {:.4}  acc {:.3}",
                row.step,
                row.values.get("rm_loss").unwrap_or(&f32::NAN),
                row.values.get("rm_acc").unwrap_or(&f32::NAN)
            );
        }
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let gen: f64 = args.get_parse("gen", 21.0)?;
    let score: f64 = args.get_parse("score", 0.0)?;
    let train: f64 = args.get_parse("train", 33.0)?;
    let publish: f64 = args.get_parse("publish", 0.0)?;
    let steps: u64 = args.get_parse("steps", 233)?;
    let costs = StepCosts::new(gen, score, train).with_publish(publish);

    let s = simulate_sync(&costs, steps);
    let a = simulate_async(&costs, steps);
    let an = analyze(&costs, steps);
    println!(
        "costs          : gen {gen}s score {score}s train {train}s publish {publish}s x{steps} steps"
    );
    println!("sync wall      : {:>10.1}s", s.wall);
    println!(
        "async wall     : {:>10.1}s  ({:+.1}% speedup)",
        a.wall, an.speedup_pct
    );
    println!(
        "ideal async    : {:>10.1}s  ({:+.1}% speedup, overhead {:.2}s/step)",
        an.ideal_wall, an.ideal_speedup_pct, an.overhead_per_step
    );
    println!("\nsync schedule (first steps):");
    println!("{}", s.timeline.render_ascii(72));
    println!("async schedule:");
    println!("{}", a.timeline.render_ascii(72));
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let model = args
        .positional
        .iter()
        .find(|p| p.as_str() != "show")
        .ok_or_else(|| anyhow!("usage: config show <model>"))?;
    let dir = artifacts_root(args.get("artifacts")).join(model);
    let text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let j = async_rlhf::util::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
    println!("{}", j.req("config").map_err(|e| anyhow!("{e}"))?);
    Ok(())
}
