//! Proxy reward model: scoring through the compiled `score_rm` executable
//! and preference-pair construction for RM training.
//!
//! Mirrors the paper's §3 setup: the feedback dataset is (re)labelled by
//! the gold scorer; the proxy RM is trained on those pairs from the SFT
//! checkpoint and is the only reward the RLHF loop sees. Gold is reserved
//! for evaluation (win-rate) — exactly Gao et al.'s controlled setup.

use anyhow::Result;

use super::gold;
use crate::data::{pack_sequence, Example, TaskGen};
use crate::runtime::{CallArg, DeviceBuffer, Engine, ParamView};
use crate::util::rng::Pcg32;

/// Score full sequences (prompt ++ response ++ EOS ++ PAD) with the proxy
/// RM. `seqs`/`masks` must be gen_batch rows (the executable's fixed batch);
/// masks cover the whole valid sequence (prompt + response) because the
/// score reads the last valid token.
///
/// The RM params are frozen for a run, so they live in the engine's device
/// cache under the `"rm"` key: uploaded on the first scoring call, reused
/// for every round after (don't score with two different RM param sets
/// through one engine — each run holds exactly one, cross-scale RMs get
/// their own engine).
pub fn score_batch(
    engine: &Engine,
    rm_params: &[f32],
    seqs: &[Vec<i32>],
    valid_masks: &[Vec<f32>],
) -> Result<Vec<f32>> {
    let b = engine.manifest.config.gen_batch;
    let s = engine.manifest.config.seq_len;
    assert_eq!(seqs.len(), b, "score_rm has fixed batch {b}");
    let mut toks = Vec::with_capacity(b * s);
    let mut mask = Vec::with_capacity(b * s);
    for (row, m) in seqs.iter().zip(valid_masks) {
        assert_eq!(row.len(), s);
        toks.extend_from_slice(row);
        mask.extend_from_slice(m);
    }
    let out = engine.call_with(
        "score_rm",
        &[
            CallArg::Param(ParamView::cached("rm", 0, rm_params)),
            CallArg::I32(&toks),
            CallArg::F32(&mask),
        ],
    )?;
    out.into_iter().next().unwrap().into_f32()
}

/// [`score_batch`] over a round's already-staged device tensors (the
/// resident labelling path): the tokens and validity mask arrive as
/// `CallArg::Device` inputs, so scoring uploads nothing — the RM params
/// are a device-cache hit after the first round and the only transfer is
/// the `[B]` score download. `tokens`/`valid_mask` must have been staged
/// on THIS engine (cross-scale RM bundles score via the host path).
pub fn score_batch_resident(
    engine: &Engine,
    rm_params: &[f32],
    tokens: &DeviceBuffer,
    valid_mask: &DeviceBuffer,
) -> Result<Vec<f32>> {
    let out = engine.call_with(
        "score_rm",
        &[
            CallArg::Param(ParamView::cached("rm", 0, rm_params)),
            CallArg::Device(tokens),
            CallArg::Device(valid_mask),
        ],
    )?;
    out.into_iter().next().unwrap().into_f32()
}

/// Whole-sequence validity mask (prompt + response incl. EOS), for RM
/// scoring: 1.0 until the last response token, 0 on trailing PAD.
pub fn valid_mask(prompt_len: usize, resp_mask: &[f32]) -> Vec<f32> {
    let mut m = vec![0.0f32; resp_mask.len()];
    let last_resp = resp_mask
        .iter()
        .rposition(|&x| x == 1.0)
        .unwrap_or(prompt_len.saturating_sub(1));
    for x in m.iter_mut().take(last_resp + 1) {
        *x = 1.0;
    }
    m
}

/// One preference pair: packed sequences + masks, gold-labelled.
pub struct PrefPair {
    pub chosen: (Vec<i32>, Vec<f32>),
    pub rejected: (Vec<i32>, Vec<f32>),
}

/// Build a gold-labelled preference dataset from the task stream: two
/// candidate responses per prompt at different corruption levels, ranked by
/// the gold scorer. (The paper samples from the SFT model and relabels with
/// the gold RM; corrupting references spans the same quality range without
/// needing the policy, and the *labels* still come from gold.)
pub fn build_pref_pairs(
    gen: &TaskGen,
    seq_len: usize,
    start: u64,
    n: usize,
    seed: u64,
) -> Vec<PrefPair> {
    let mut rng = Pcg32::new(seed, 0x9e);
    let mut out = Vec::with_capacity(n);
    let mut i = start;
    while out.len() < n {
        let ex = gen.example(i);
        i += 1;
        let (a, b) = candidate_pair(&ex, gen.resp_len, &mut rng);
        let sa = gold_score_resp(&ex, &a);
        let sb = gold_score_resp(&ex, &b);
        if (sa - sb).abs() < 0.3 {
            // skip low-margin pairs: like human labelling, near-ties are
            // noise; the RM learns discrimination from clear preferences
            continue;
        }
        let (chosen, rejected) = if sa > sb { (a, b) } else { (b, a) };
        out.push(PrefPair {
            chosen: pack_valid(&ex.prompt, &chosen, seq_len),
            rejected: pack_valid(&ex.prompt, &rejected, seq_len),
        });
    }
    out
}

fn candidate_pair(
    ex: &Example,
    resp_len: usize,
    rng: &mut Pcg32,
) -> (Vec<i32>, Vec<i32>) {
    use crate::data::tldr::perturb;
    // wide quality spread: near-clean vs heavily corrupted. The proxy RM
    // must learn *what quality is*, not split hairs between near-ties.
    let lo = rng.gen_f64() * 0.12;
    let hi = 0.35 + rng.gen_f64() * 0.5;
    (
        perturb(rng, &ex.reference, lo, resp_len),
        perturb(rng, &ex.reference, hi, resp_len),
    )
}

fn gold_score_resp(ex: &Example, resp: &[i32]) -> f32 {
    let mut with_eos = resp.to_vec();
    with_eos.push(crate::tokenizer::EOS);
    gold::score(&ex.meta, &with_eos)
}

fn pack_valid(prompt: &[i32], resp: &[i32], seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    let (toks, resp_mask) = pack_sequence(prompt, resp, seq_len, true);
    let vm = valid_mask(prompt.len(), &resp_mask);
    (toks, vm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Task;

    #[test]
    fn valid_mask_covers_prompt_and_response() {
        let resp_mask = vec![0., 0., 0., 1., 1., 1., 0., 0.];
        let vm = valid_mask(3, &resp_mask);
        assert_eq!(vm, vec![1., 1., 1., 1., 1., 1., 0., 0.]);
    }

    #[test]
    fn valid_mask_empty_response_covers_prompt() {
        let resp_mask = vec![0., 0., 0., 0.];
        let vm = valid_mask(3, &resp_mask);
        assert_eq!(vm, vec![1., 1., 1., 0.]);
    }

    #[test]
    fn pref_pairs_are_gold_consistent() {
        let gen = TaskGen::new(Task::Tldr, 32, 16, 5);
        let pairs = build_pref_pairs(&gen, 48, 0, 32, 7);
        assert_eq!(pairs.len(), 32);
        for p in &pairs {
            assert_eq!(p.chosen.0.len(), 48);
            assert_eq!(p.rejected.1.len(), 48);
            // masks are prefix-shaped
            for m in [&p.chosen.1, &p.rejected.1] {
                let first_zero =
                    m.iter().position(|&x| x == 0.0).unwrap_or(m.len());
                assert!(m[first_zero..].iter().all(|&x| x == 0.0));
                assert!(first_zero >= 32); // at least the prompt
            }
        }
    }

    #[test]
    fn pref_pairs_deterministic() {
        let gen = TaskGen::new(Task::Tldr, 32, 16, 5);
        let a = build_pref_pairs(&gen, 48, 0, 8, 7);
        let b = build_pref_pairs(&gen, 48, 0, 8, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.chosen.0, y.chosen.0);
            assert_eq!(x.rejected.0, y.rejected.0);
        }
    }
}
