//! Programmatic gold reward functions — the ground-truth labeller.
//!
//! Substitution for the paper's 6.7B "gold" reward model (DESIGN.md §3):
//! a fixed, hidden scorer used to (a) label preference pairs for proxy-RM
//! training, (b) compute gold win-rates at evaluation. The proxy RM only
//! ever sees finite samples of gold judgements, so proxy/gold divergence
//! (overoptimization, Gao et al. 2022) arises exactly as in the paper.

use crate::data::TaskMeta;
use crate::tokenizer as tk;

/// Score a raw response (resp_len tokens as generated, possibly containing
/// EOS) against the ground truth. Higher is better. Scores are roughly in
/// [-2, 2] for tldr/chat and {0, 1} for math.
pub fn score(meta: &TaskMeta, resp: &[i32]) -> f32 {
    match meta {
        TaskMeta::Tldr { salient } => score_tldr(salient, resp),
        TaskMeta::Math { answer } => score_math(answer, resp),
        TaskMeta::Chat { target } => score_chat(target, resp),
    }
}

/// TLDR: coverage of salient tokens, brevity, non-repetition, termination.
///
/// Designed so that the optimum is "exactly the distinct salient tokens,
/// then EOS", while leaving hackable slack (e.g. the proxy RM may not
/// notice repetition) to reproduce overoptimization dynamics.
fn score_tldr(salient: &[i32], resp: &[i32]) -> f32 {
    let (body, has_eos) = tk::trim_at_eos(resp);
    let n = salient.len().max(1) as f32;

    let mut covered = 0usize;
    let mut seen: Vec<i32> = Vec::new();
    let mut duplicates = 0usize;
    let mut extras = 0usize;
    for &t in body {
        if seen.contains(&t) {
            duplicates += 1;
        } else {
            seen.push(t);
            if salient.contains(&t) {
                covered += 1;
            } else {
                extras += 1;
            }
        }
    }
    let coverage = covered as f32 / n;
    let brevity = (body.len() as f32 - n).max(0.0) / n;
    let mut s = 2.0 * coverage
        - 0.6 * extras as f32 / n
        - 0.5 * duplicates as f32 / n
        - 0.3 * brevity;
    if has_eos {
        s += 0.4;
    } else {
        s -= 0.5;
    }
    s
}

/// Math: exact-match of the answer digit string, properly terminated.
fn score_math(answer: &[i32], resp: &[i32]) -> f32 {
    let (body, has_eos) = tk::trim_at_eos(resp);
    if has_eos && body == answer {
        1.0
    } else {
        0.0
    }
}

/// Chat: per-position accuracy against the target transformation, with a
/// length-mismatch penalty and a termination bonus.
fn score_chat(target: &[i32], resp: &[i32]) -> f32 {
    let (body, has_eos) = tk::trim_at_eos(resp);
    let tl = target.len().max(1) as f32;
    let matches = target
        .iter()
        .zip(body.iter())
        .filter(|(a, b)| a == b)
        .count() as f32;
    let len_gap = (body.len() as f32 - target.len() as f32).abs() / tl;
    let mut s = 2.0 * matches / tl - 0.5 * len_gap;
    if has_eos {
        s += 0.4;
    } else {
        s -= 0.5;
    }
    s
}

/// Gold judge for win-rate: does `ours` beat `reference`? Ties go to the
/// reference (conservative, like a judge preferring the incumbent).
pub fn wins(meta: &TaskMeta, ours: &[i32], reference_with_eos: &[i32]) -> bool {
    score(meta, ours) > score(meta, reference_with_eos)
}

/// Fractional win value: 1.0 win / 0.5 tie / 0.0 loss. The gold scorer is
/// discrete, so exact ties are common (unlike the paper's continuous 6.7B
/// gold RM); the standard judging convention credits ties at 1/2.
pub fn win_value(meta: &TaskMeta, ours: &[i32], reference_with_eos: &[i32]) -> f32 {
    let a = score(meta, ours);
    let b = score(meta, reference_with_eos);
    if a > b + 1e-6 {
        1.0
    } else if a > b - 1e-6 {
        0.5
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tldr_meta() -> TaskMeta {
        TaskMeta::Tldr { salient: vec![30, 31, 32] }
    }

    #[test]
    fn tldr_perfect_beats_partial() {
        let m = tldr_meta();
        let perfect = [30, 31, 32, tk::EOS];
        let partial = [30, 31, tk::EOS];
        assert!(score(&m, &perfect) > score(&m, &partial));
    }

    #[test]
    fn tldr_penalizes_repetition_and_extras() {
        let m = tldr_meta();
        let clean = [30, 31, 32, tk::EOS];
        let dup = [30, 30, 31, 32, tk::EOS];
        let extra = [30, 31, 32, 40, tk::EOS];
        assert!(score(&m, &clean) > score(&m, &dup));
        assert!(score(&m, &clean) > score(&m, &extra));
    }

    #[test]
    fn tldr_penalizes_missing_eos() {
        let m = tldr_meta();
        assert!(
            score(&m, &[30, 31, 32, tk::EOS]) > score(&m, &[30, 31, 32])
        );
    }

    #[test]
    fn math_exact_match_only() {
        let m = TaskMeta::Math { answer: vec![tk::digit(4), tk::digit(2)] };
        assert_eq!(score(&m, &[tk::digit(4), tk::digit(2), tk::EOS]), 1.0);
        assert_eq!(score(&m, &[tk::digit(4), tk::digit(2)]), 0.0); // no EOS
        assert_eq!(score(&m, &[tk::digit(4), tk::digit(3), tk::EOS]), 0.0);
        assert_eq!(
            score(&m, &[tk::digit(4), tk::digit(2), tk::digit(0), tk::EOS]),
            0.0
        );
    }

    #[test]
    fn chat_partial_credit_monotone() {
        let m = TaskMeta::Chat { target: vec![30, 31, 32, 33] };
        let full = [30, 31, 32, 33, tk::EOS];
        let three = [30, 31, 32, 29, tk::EOS];
        let two = [30, 31, 28, 29, tk::EOS];
        assert!(score(&m, &full) > score(&m, &three));
        assert!(score(&m, &three) > score(&m, &two));
    }

    #[test]
    fn wins_is_strict() {
        let m = TaskMeta::Math { answer: vec![tk::digit(7)] };
        let good = [tk::digit(7), tk::EOS];
        assert!(!wins(&m, &good, &good)); // tie -> reference holds
        assert!(wins(&m, &good, &[tk::digit(8), tk::EOS]));
    }
}
