//! Reward stack: programmatic gold scorer (ground truth) + learned proxy RM.
//!
//! Gold labels preference data and judges evaluation win-rates; the proxy
//! RM (trained on gold-labelled pairs, scored via the `score_rm`
//! executable) is what the RLHF loop optimizes — reproducing the
//! controlled-overoptimization setup of Gao et al. 2022 / paper §3.

pub mod gold;
pub mod proxy;

pub use proxy::{
    build_pref_pairs, score_batch, score_batch_resident, valid_mask, PrefPair,
};
