//! Held-out evaluation harness (paper's metrics):
//! - gold win-rate vs dataset references (TLDR §3.1, chat Tables 1/8),
//! - KL measured as reference-model perplexity on policy samples,
//! - pass@1 by greedy decoding (GSM8k §5.2),
//! - mean response length (Table 8).

use anyhow::Result;

use crate::coordinator::pretrain::EVAL_RANGE;
use crate::data::{Task, TaskGen};
use crate::gen::fused::FusedEngine;
use crate::gen::{Generator, SampleOpts};
use crate::reward::gold;
use crate::runtime::{CallArg, Engine, ParamView};
use crate::tokenizer as tk;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone, Default)]
pub struct EvalResult {
    pub n: usize,
    pub win_rate: f32,
    pub kl_ppl: f32,
    pub mean_gold: f32,
    pub mean_len: f32,
    /// Exact-match rate under greedy decoding (math tasks; 0 otherwise).
    pub pass1: f32,
}

/// Evaluate `params` on `n_prompts` held-out prompts (rounded up to whole
/// generation batches). Math tasks are decoded greedily (pass@1);
/// everything else samples at `temperature` like training.
///
/// Both param sets are frozen for the duration of the call, so they are
/// uploaded to the device once (under eval-private cache keys, invalidated
/// on entry since successive evals pass different vectors) and reused for
/// every round.
pub fn evaluate(
    engine: &Engine,
    params: &[f32],
    ref_params: &[f32],
    taskgen: &TaskGen,
    n_prompts: usize,
    temperature: f32,
    seed: u64,
) -> Result<EvalResult> {
    let cfg = &engine.manifest.config;
    let (bg, s, p) = (cfg.gen_batch, cfg.seq_len, cfg.prompt_len);
    let task = taskgen.task;
    let greedy = task == Task::Math;
    let generator = FusedEngine::default();
    let mut rng = Pcg32::new(seed, 0xe7a1);
    let opts = SampleOpts { temperature, greedy };

    // successive evaluate() calls pass arbitrary param vectors under the
    // same keys: drop any stale entries, then upload once per call
    engine.invalidate_params("eval_policy");
    engine.invalidate_params("eval_ref");
    let policy = ParamView::cached("eval_policy", 0, params);
    let reference = ParamView::cached("eval_ref", 0, ref_params);

    let rounds = n_prompts.div_ceil(bg);
    let mut win_sum = 0.0f32;
    let mut exact = 0usize;
    let mut gold_sum = 0.0f64;
    let mut len_sum = 0usize;
    let mut lp_sum = 0.0f64;
    let mut tok_sum = 0.0f64;
    let mut total = 0usize;
    let mut toks_flat = Vec::with_capacity(bg * s);
    let mut mask_flat = Vec::with_capacity(bg * s);

    for r in 0..rounds {
        let start = EVAL_RANGE + (r * bg) as u64;
        let examples = taskgen.batch(start, bg);
        let prompts: Vec<Vec<i32>> =
            examples.iter().map(|e| e.prompt.clone()).collect();
        let gen = generator.generate(engine, policy, &prompts, opts, &mut rng)?;

        // reference-model logprobs for the KL/ppl measurement
        gen.flatten_into(&mut toks_flat, &mut mask_flat);
        let args = [
            CallArg::Param(reference),
            CallArg::I32(&toks_flat),
            CallArg::F32(&mask_flat),
        ];
        // eval reads only the per-token logprobs: the untupled twin never
        // downloads the unused [B] sequence output (untupling clients
        // only — the fused generate above settled the capability)
        let rlp_tok = if engine.buffer_path_ready("logprob_dev") {
            let out = engine.execute_buffers("logprob_dev", &args)?;
            engine.download(&out[1])?.into_f32()?
        } else {
            let out = engine.call_with("logprob", &args)?;
            out.into_iter().nth(1).unwrap().into_f32()?
        };
        lp_sum += rlp_tok
            .iter()
            .zip(&mask_flat)
            .map(|(l, m)| (l * m) as f64)
            .sum::<f64>();
        tok_sum += mask_flat.iter().map(|&m| m as f64).sum::<f64>();

        for i in 0..bg {
            let ex = &examples[i];
            let resp = gen.response(i, p);
            len_sum += resp.len();
            let score = gold::score(&ex.meta, resp);
            gold_sum += score as f64;
            let mut ref_resp = ex.reference.clone();
            ref_resp.push(tk::EOS);
            win_sum += gold::win_value(&ex.meta, resp, &ref_resp);
            if task == Task::Math && score >= 1.0 {
                exact += 1;
            }
            total += 1;
        }
    }

    Ok(EvalResult {
        n: total,
        win_rate: win_sum / total as f32,
        kl_ppl: (-(lp_sum / tok_sum.max(1.0))).exp() as f32,
        mean_gold: (gold_sum / total as f64) as f32,
        mean_len: len_sum as f32 / total as f32,
        pass1: exact as f32 / total as f32,
    })
}

impl EvalResult {
    pub fn summary(&self, task: Task) -> String {
        match task {
            Task::Math => format!(
                "pass@1 {:.1}% | ppl {:.4} | len {:.1} (n={})",
                self.pass1 * 100.0,
                self.kl_ppl,
                self.mean_len,
                self.n
            ),
            _ => format!(
                "win-rate {:.1}% | kl-ppl {:.4} | gold {:.3} | len {:.1} (n={})",
                self.win_rate * 100.0,
                self.kl_ppl,
                self.mean_gold,
                self.mean_len,
                self.n
            ),
        }
    }
}
