//! Experiment configuration: the runtime knobs of the paper's study.
//!
//! Model geometry/hyperparameters live in the artifact manifest (baked at
//! AOT time, python/compile/configs.py — paper Tables 4-7, 10); this module
//! holds everything the Rust coordinator decides at runtime: algorithm,
//! sync/async mode, off-policyness N, updates-per-batch T, best-of-K,
//! learning rate, step counts, seeds. Presets mirror the paper's runs.

use std::fmt;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::util::args::Args;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Online DPO (paper's most off-policy-robust method).
    Dpo,
    /// PPO with value head (the classic baseline).
    Ppo,
    /// Vanilla RLOO, k=2.
    Rloo,
    /// Proximal RLOO (paper Appendix B: clipped IS ratio).
    Prloo,
    /// CoPG-style RLOO (Appendix B comparison; collapses off-policy).
    Copg,
    /// Best-of-2 SFT baseline (paper §3.3).
    BestOfN,
}

impl Algo {
    pub fn from_name(s: &str) -> Result<Algo> {
        Ok(match s {
            "dpo" => Algo::Dpo,
            "ppo" => Algo::Ppo,
            "rloo" => Algo::Rloo,
            "prloo" => Algo::Prloo,
            "copg" => Algo::Copg,
            "bon" | "best_of_n" => Algo::BestOfN,
            _ => bail!("unknown algorithm '{s}' (dpo|ppo|rloo|prloo|copg|bon)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Dpo => "dpo",
            Algo::Ppo => "ppo",
            Algo::Rloo => "rloo",
            Algo::Prloo => "prloo",
            Algo::Copg => "copg",
            Algo::BestOfN => "bon",
        }
    }

    /// Train-step artifact name in the manifest.
    pub fn artifact(&self) -> &'static str {
        match self {
            Algo::Dpo => "train_dpo",
            Algo::Ppo => "train_ppo",
            Algo::Rloo => "train_rloo",
            Algo::Prloo => "train_prloo",
            Algo::Copg => "train_copg",
            Algo::BestOfN => "train_bon",
        }
    }

    /// Pairwise algorithms consume 2 completions per prompt.
    pub fn pairwise(&self) -> bool {
        !matches!(self, Algo::Ppo)
    }
}

impl fmt::Display for Algo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Which generation engine the coordinators run (paper Fig 14 tiers; see
/// `gen/mod.rs`). `Fused` is the production default; `Cached` is the
/// deliberately-literal middle-tier baseline; `Device` is the step-wise
/// loop with the KV cache chained device-to-device (needs the
/// `prefill_dev`/`decode_dev` artifacts); `Naive` is the quadratic
/// full-recompute baseline; `Continuous` is the slot-pool engine over the
/// same `*_dev` twins — EOS retirement, mid-flight prompt admission and
/// between-step policy swaps in async mode (`--max-cohorts`,
/// `--admit-min` shape its admission).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GenEngine {
    Fused,
    Cached,
    Device,
    Naive,
    Continuous,
}

impl GenEngine {
    pub fn from_name(s: &str) -> Result<GenEngine> {
        Ok(match s {
            "fused" => GenEngine::Fused,
            "cached" => GenEngine::Cached,
            "device" => GenEngine::Device,
            "naive" => GenEngine::Naive,
            "continuous" => GenEngine::Continuous,
            _ => bail!(
                "unknown gen engine '{s}' \
                 (fused|cached|device|naive|continuous)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            GenEngine::Fused => "fused",
            GenEngine::Cached => "cached",
            GenEngine::Device => "device",
            GenEngine::Naive => "naive",
            GenEngine::Continuous => "continuous",
        }
    }

    /// Construct the generator. Each coordinator thread builds its own
    /// (generators are stateless or hold per-engine scratch only). The
    /// continuous engine's [`crate::gen::Generator`] face is its
    /// round-mode (admission-disabled) configuration; async workers
    /// drive its slot pool directly instead.
    pub fn build(&self) -> Box<dyn crate::gen::Generator> {
        match self {
            GenEngine::Fused => Box::<crate::gen::fused::FusedEngine>::default(),
            GenEngine::Cached => {
                Box::<crate::gen::cached::CachedEngine>::default()
            }
            GenEngine::Device => {
                Box::<crate::gen::device::DeviceCachedEngine>::default()
            }
            GenEngine::Naive => Box::new(crate::gen::naive::NaiveEngine),
            GenEngine::Continuous => {
                Box::<crate::gen::continuous::ContinuousEngine>::default()
            }
        }
    }
}

impl fmt::Display for GenEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Failure mode a scripted fault injects (`--inject-fault ...,kind=`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker thread panics (exercises `catch_unwind` + respawn).
    Panic,
    /// The worker sleeps past `--stall-timeout-secs` (exercises the
    /// heartbeat watchdog), then continues normally.
    Stall,
    /// The worker's generation call fails once with a synthetic engine
    /// error (exercises the retry policy, or respawn when retries = 0).
    EngineErr,
}

impl FaultKind {
    pub fn from_name(s: &str) -> Result<FaultKind> {
        Ok(match s {
            "panic" => FaultKind::Panic,
            "stall" => FaultKind::Stall,
            "engine_err" => FaultKind::EngineErr,
            _ => bail!("unknown fault kind '{s}' (panic|stall|engine_err)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Stall => "stall",
            FaultKind::EngineErr => "engine_err",
        }
    }
}

/// One scripted fault for the supervision tests: worker `worker` fires
/// `kind` when its local round counter reaches `round` — once per run,
/// so a respawned replacement replaying the same round does not re-crash.
/// Parsed from `--inject-fault worker=1,round=3,kind=panic`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub worker: usize,
    pub round: u64,
    pub kind: FaultKind,
}

impl FaultPlan {
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let (mut worker, mut round, mut kind) = (None, None, None);
        for part in s.split(',') {
            let Some((key, val)) = part.split_once('=') else {
                bail!(
                    "--inject-fault: expected key=value, got '{part}' \
                     (worker=W,round=R,kind=panic|stall|engine_err)"
                );
            };
            let val = val.trim();
            match key.trim() {
                "worker" => {
                    worker = Some(val.parse::<usize>().map_err(|e| {
                        anyhow::anyhow!("--inject-fault worker '{val}': {e}")
                    })?)
                }
                "round" => {
                    round = Some(val.parse::<u64>().map_err(|e| {
                        anyhow::anyhow!("--inject-fault round '{val}': {e}")
                    })?)
                }
                "kind" => kind = Some(FaultKind::from_name(val)?),
                other => bail!(
                    "--inject-fault: unknown key '{other}' \
                     (worker|round|kind)"
                ),
            }
        }
        match (worker, round, kind) {
            (Some(worker), Some(round), Some(kind)) => {
                Ok(FaultPlan { worker, round, kind })
            }
            _ => bail!(
                "--inject-fault needs all of worker=, round=, kind= \
                 (got '{s}')"
            ),
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "worker={},round={},kind={}",
            self.worker,
            self.round,
            self.kind.name()
        )
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Generate-then-train on the same resources (paper Fig 2 top):
    /// the pipeline's inline round source.
    Sync,
    /// Overlapped generation/training (paper Fig 2 bottom): the
    /// pipeline's worker pool, shaped by `gen_workers` (M) and
    /// `staleness_bound` (K). The defaults M=1, K=0 are the paper's
    /// Cleanba-style one-step off-policy coordinator.
    Async,
    /// Serve-while-training: the async pipeline with live session
    /// traffic as the prompt stream. Each worker multiplexes a
    /// deterministic traffic replay (`serve_sessions` / `serve_turns` /
    /// `arrival_rate`) onto its continuous slot pool and the completed
    /// turns assemble into training rounds. Requires
    /// `--gen-engine continuous`; the run's length is the trace's, not
    /// `--steps`.
    Serve,
}

impl Mode {
    pub fn from_name(s: &str) -> Result<Mode> {
        Ok(match s {
            "sync" => Mode::Sync,
            "async" => Mode::Async,
            "serve" => Mode::Serve,
            _ => bail!("unknown mode '{s}' (sync|async|serve)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Sync => "sync",
            Mode::Async => "async",
            Mode::Serve => "serve",
        }
    }
}

/// Full runtime configuration of one RLHF run.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Artifact config name, e.g. "tldr_s".
    pub model: String,
    pub artifacts_root: PathBuf,
    pub algo: Algo,
    pub mode: Mode,
    /// Generation engine tier (paper Fig 14; `--gen-engine`).
    pub gen_engine: GenEngine,
    /// RLHF optimizer steps (mini-batch updates).
    pub steps: u64,
    /// Off-policyness: mini-batches generated per generation round
    /// (paper §3.2; N=1 is on-policy).
    pub n_minibatches: usize,
    /// Updates per mini-batch, "ppo epochs" (paper §4.1; T=1 default).
    pub updates_per_batch: usize,
    /// Completions sampled per prompt for pairwise losses (paper §4.2;
    /// K=2 default, K=4 trains on best/worst).
    pub k_samples: usize,
    /// Generation workers M in async mode (`--gen-workers`): threads each
    /// owning their own engine, partitioning the prompt stream. Ignored
    /// in sync mode (generation runs inline on the trainer).
    pub gen_workers: usize,
    /// Data-parallel trainer shards S (`--trainer-shards`): threads each
    /// owning their own engine and training a disjoint 1/S slice of every
    /// batch, combined per step by a deterministic tree all-reduce
    /// (`runtime::reduce`). S=1 (default) is the unsharded trainer,
    /// bitwise. Publication fans out to S extra `ParamBus` seats, adding
    /// S-1 to the worst-case staleness bound (`coordinator::pipeline`).
    pub trainer_shards: usize,
    /// Async round-queue depth K (`--staleness-bound`): up to K rounds
    /// may sit queued between generation and training, so training data
    /// is at most K+1 policy versions stale (at the default
    /// `updates_per_batch` = 1; see `coordinator::pipeline`). K=0 is the
    /// paper's rendezvous handover — exactly one-step off-policy.
    pub staleness_bound: usize,
    /// Continuous engine only (`--max-cohorts`): concurrently live
    /// admission cohorts per worker's slot pool. Each live cohort costs
    /// one extra `decode_dev` call per sweep and one device KV-cache
    /// copy; 1 defers admission until the pool fully drains.
    pub max_cohorts: usize,
    /// Continuous engine only (`--admit-min`): admit fresh prompts only
    /// once at least this many slots are free (batches admissions so a
    /// cohort's prefill is amortized over more rows).
    pub admit_min: usize,
    /// Async mode (`--max-worker-restarts`): how many times a crashed
    /// generation worker may be respawned on a fresh engine. The
    /// replacement resumes the dead worker's exact prompt-partition
    /// position, so the strided stream stays no-drop/no-dup. Past the
    /// budget the seat's work moves to a survivor instead (lane
    /// re-stride / session migration); only a pool with no survivors
    /// fails the run.
    pub max_worker_restarts: usize,
    /// Async mode (`--engine-retries`): transparent re-attempts of a
    /// worker's generation call on engine errors, with deterministic
    /// jittered backoff (`runtime::retry`). 0 fails fast.
    pub engine_retries: u32,
    /// Async mode (`--stall-timeout-secs`): heartbeat watchdog threshold.
    /// A worker with no progress beat for this long is flagged in metrics
    /// (`stalled_workers`) — the case where measured staleness can exceed
    /// the M>1 fair-scheduling bound.
    pub stall_timeout_secs: f64,
    /// Checkpoint the trainer every N optimizer steps
    /// (`--checkpoint-every`, 0 = off) into
    /// `<run_dir>/checkpoints/<label>/step_*` — params/m/v npy tensors
    /// plus a JSON manifest of cursors, written atomically.
    pub checkpoint_every: u64,
    /// Restart from the newest checkpoint of this label (`--resume`).
    /// Sync-mode resume reproduces the uninterrupted run bitwise.
    pub resume: bool,
    /// Deterministic fault injection for the supervision tests
    /// (`--inject-fault worker=W,round=R,kind=panic|stall|engine_err`).
    pub inject_fault: Option<FaultPlan>,
    /// Serve mode (`--serve-sessions`): sessions in the traffic trace.
    /// Must divide evenly over `gen_workers` — seats serve the residues
    /// of `session % M`, one residue each at spawn; a takeover merges a
    /// dead seat's residues onto a survivor.
    pub serve_sessions: u64,
    /// Serve mode (`--serve-turns`): turns per session. Every session
    /// runs the same count so the round geometry stays exact.
    pub serve_turns: u64,
    /// Serve mode (`--arrival-rate`): mean session arrivals per pool
    /// sweep; also the mean think-rate between a session's turns.
    pub arrival_rate: f64,
    pub lr: f32,
    pub temperature: f32,
    /// Reward for completions without EOS (paper Table 4: -1.0).
    pub eos_penalty: f32,
    /// Optimize the learned proxy RM (paper setup) or the gold scorer
    /// directly (well-trained-RM limit; ablation).
    pub gold_reward: bool,
    pub seed: u64,
    /// SFT warm-start steps before RLHF (0 = load checkpoint if cached).
    pub sft_steps: u64,
    /// Proxy-RM training steps.
    pub rm_steps: u64,
    /// Evaluate every this many RLHF steps (0 = only final).
    pub eval_every: u64,
    /// Number of held-out prompts for final evaluation.
    pub eval_prompts: usize,
    /// Directory for logs/checkpoints.
    pub run_dir: PathBuf,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            model: "tldr_s".into(),
            artifacts_root: PathBuf::from("artifacts"),
            algo: Algo::Dpo,
            mode: Mode::Sync,
            gen_engine: GenEngine::Fused,
            steps: 96,
            n_minibatches: 1,
            updates_per_batch: 1,
            k_samples: 2,
            gen_workers: 1,
            trainer_shards: 1,
            staleness_bound: 0,
            max_cohorts: 4,
            admit_min: 1,
            max_worker_restarts: 2,
            engine_retries: 2,
            stall_timeout_secs: 30.0,
            checkpoint_every: 0,
            resume: false,
            inject_fault: None,
            serve_sessions: 8,
            serve_turns: 2,
            arrival_rate: 0.5,
            lr: 3e-5,
            temperature: 0.7,
            eos_penalty: -1.0,
            gold_reward: false,
            seed: 42,
            sft_steps: 1200,
            rm_steps: 300,
            eval_every: 16,
            eval_prompts: 128,
            run_dir: PathBuf::from("runs"),
        }
    }
}

impl ExpConfig {
    /// Parse CLI options on top of the defaults.
    pub fn from_args(args: &Args) -> Result<ExpConfig> {
        ExpConfig::from_args_with(args, ExpConfig::default())
    }

    /// Parse CLI options on top of `base` — subcommands that preset a
    /// mode (e.g. `serve`) start from their own defaults and still honor
    /// every explicit flag.
    pub fn from_args_with(args: &Args, base: ExpConfig) -> Result<ExpConfig> {
        let mut c = base;
        if let Some(m) = args.positional.first() {
            c.model = m.clone();
        }
        if let Some(m) = args.get("model") {
            c.model = m.to_string();
        }
        c.artifacts_root =
            crate::runtime::artifacts_root(args.get("artifacts"));
        if let Some(a) = args.get("algo") {
            c.algo = Algo::from_name(a)?;
        }
        if let Some(m) = args.get("mode") {
            c.mode = Mode::from_name(m)?;
        }
        if let Some(g) = args.get("gen-engine") {
            c.gen_engine = GenEngine::from_name(g)?;
        }
        c.steps = args.get_parse("steps", c.steps)?;
        c.n_minibatches = args.get_parse("n", c.n_minibatches)?;
        c.updates_per_batch = args.get_parse("t", c.updates_per_batch)?;
        c.k_samples = args.get_parse("k", c.k_samples)?;
        c.gen_workers = args.get_parse("gen-workers", c.gen_workers)?;
        c.trainer_shards =
            args.get_parse("trainer-shards", c.trainer_shards)?;
        c.staleness_bound =
            args.get_parse("staleness-bound", c.staleness_bound)?;
        c.max_cohorts = args.get_parse("max-cohorts", c.max_cohorts)?;
        c.admit_min = args.get_parse("admit-min", c.admit_min)?;
        c.max_worker_restarts =
            args.get_parse("max-worker-restarts", c.max_worker_restarts)?;
        c.engine_retries =
            args.get_parse("engine-retries", c.engine_retries)?;
        c.stall_timeout_secs =
            args.get_parse("stall-timeout-secs", c.stall_timeout_secs)?;
        c.checkpoint_every =
            args.get_parse("checkpoint-every", c.checkpoint_every)?;
        c.resume = args.has_flag("resume");
        if let Some(f) = args.get("inject-fault") {
            c.inject_fault = Some(FaultPlan::parse(f)?);
        }
        c.serve_sessions = args.get_parse("serve-sessions", c.serve_sessions)?;
        c.serve_turns = args.get_parse("serve-turns", c.serve_turns)?;
        c.arrival_rate = args.get_parse("arrival-rate", c.arrival_rate)?;
        c.lr = args.get_parse("lr", c.lr)?;
        c.temperature = args.get_parse("temperature", c.temperature)?;
        c.seed = args.get_parse("seed", c.seed)?;
        c.sft_steps = args.get_parse("sft-steps", c.sft_steps)?;
        c.rm_steps = args.get_parse("rm-steps", c.rm_steps)?;
        c.eval_every = args.get_parse("eval-every", c.eval_every)?;
        c.eval_prompts = args.get_parse("eval-prompts", c.eval_prompts)?;
        c.run_dir = PathBuf::from(args.get_or("run-dir", "runs"));
        c.gold_reward = matches!(args.get("reward"), Some("gold"));
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_minibatches == 0 || self.updates_per_batch == 0 {
            bail!("n and t must be >= 1");
        }
        if self.k_samples != 2 && self.k_samples != 4 {
            bail!("k must be 2 or 4 (gen_batch geometry)");
        }
        if self.mode != Mode::Sync && self.n_minibatches != 1 {
            bail!(
                "async/serve modes stream rounds (N=1); use sync mode to \
                 sweep the N-minibatch ladder, --staleness-bound to sweep K"
            );
        }
        if self.gen_workers == 0 {
            bail!("--gen-workers must be >= 1");
        }
        if self.mode == Mode::Sync
            && (self.gen_workers != 1 || self.staleness_bound != 0)
        {
            bail!(
                "--gen-workers/--staleness-bound shape the async worker \
                 pool; sync mode generates inline (use --mode async)"
            );
        }
        if self.max_cohorts == 0 || self.admit_min == 0 {
            bail!("--max-cohorts and --admit-min must be >= 1");
        }
        if self.gen_engine != GenEngine::Continuous
            && (self.max_cohorts, self.admit_min) != (4, 1)
        {
            bail!(
                "--max-cohorts/--admit-min shape the continuous engine's \
                 slot pool (use --gen-engine continuous)"
            );
        }
        if !(self.stall_timeout_secs > 0.0) {
            bail!("--stall-timeout-secs must be > 0");
        }
        if self.trainer_shards == 0 {
            bail!("--trainer-shards must be >= 1 (1 = unsharded)");
        }
        if self.mode == Mode::Sync {
            let d = ExpConfig::default();
            if self.inject_fault.is_some() {
                bail!(
                    "--inject-fault targets the async worker pool; sync \
                     mode generates inline (use --mode async)"
                );
            }
            if self.max_worker_restarts != d.max_worker_restarts
                || self.engine_retries != d.engine_retries
                || self.stall_timeout_secs != d.stall_timeout_secs
            {
                bail!(
                    "--max-worker-restarts/--engine-retries/\
                     --stall-timeout-secs supervise the async worker pool; \
                     sync mode generates inline (use --mode async)"
                );
            }
        }
        if let Some(fault) = &self.inject_fault {
            if fault.worker >= self.gen_workers {
                bail!(
                    "--inject-fault worker={} but the pool has only {} \
                     workers (0..{})",
                    fault.worker,
                    self.gen_workers,
                    self.gen_workers
                );
            }
        }
        if self.serve_sessions == 0 || self.serve_turns == 0 {
            bail!("--serve-sessions and --serve-turns must be >= 1");
        }
        if !(self.arrival_rate > 0.0) {
            bail!("--arrival-rate must be > 0");
        }
        let d = ExpConfig::default();
        if self.mode != Mode::Serve
            && (self.serve_sessions != d.serve_sessions
                || self.serve_turns != d.serve_turns
                || self.arrival_rate != d.arrival_rate)
        {
            bail!(
                "--serve-sessions/--serve-turns/--arrival-rate shape the \
                 serving traffic trace (use --mode serve)"
            );
        }
        if self.mode == Mode::Serve {
            if self.gen_engine != GenEngine::Continuous {
                bail!(
                    "serve mode multiplexes sessions onto the continuous \
                     slot pool (use --gen-engine continuous)"
                );
            }
            if self.serve_sessions % self.gen_workers as u64 != 0 {
                bail!(
                    "--serve-sessions {} must divide evenly over {} workers \
                     (the residue partition `session % M` must spread the \
                     trace evenly at spawn)",
                    self.serve_sessions,
                    self.gen_workers
                );
            }
        }
        Ok(())
    }

    pub fn artifact_dir(&self) -> PathBuf {
        self.artifacts_root.join(&self.model)
    }

    /// Label used in logs and run directories. The generation engine and
    /// the async pool shape (workers M / queue depth K) only appear when
    /// they deviate from the production defaults, so existing
    /// run/checkpoint directories keep their names. Supervision and
    /// checkpoint knobs (restarts, retries, stall timeout, checkpoint
    /// cadence, fault injection, `--resume`) deliberately never alter the
    /// label: they change *how* a run survives, not *what* it computes,
    /// and `--resume` must re-find the same run directory the crashed
    /// invocation was writing checkpoints under.
    pub fn label(&self) -> String {
        let gen = match self.gen_engine {
            GenEngine::Fused => String::new(),
            other => format!("_g{}", other.name()),
        };
        let pool = if (self.gen_workers, self.staleness_bound) == (1, 0) {
            String::new()
        } else {
            format!("_w{}q{}", self.gen_workers, self.staleness_bound)
        };
        // `d` (data-parallel), not `s`: the label's trailing _s segment
        // is the seed
        let shards = if self.trainer_shards == 1 {
            String::new()
        } else {
            format!("_d{}", self.trainer_shards)
        };
        let admit = if (self.max_cohorts, self.admit_min) == (4, 1) {
            String::new()
        } else {
            format!("_c{}a{}", self.max_cohorts, self.admit_min)
        };
        let d = ExpConfig::default();
        let serve = if (self.serve_sessions, self.serve_turns, self.arrival_rate)
            == (d.serve_sessions, d.serve_turns, d.arrival_rate)
        {
            String::new()
        } else {
            format!(
                "_v{}x{}r{}",
                self.serve_sessions, self.serve_turns, self.arrival_rate
            )
        };
        format!(
            "{}_{}_{}{pool}{shards}{gen}{admit}{serve}_n{}_t{}_k{}_s{}",
            self.model,
            self.algo,
            self.mode.name(),
            self.n_minibatches,
            self.updates_per_batch,
            self.k_samples,
            self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Result<ExpConfig> {
        let v: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&v, &[]).unwrap();
        ExpConfig::from_args(&args)
    }

    #[test]
    fn defaults_and_overrides() {
        let c = parse(&["train", "tldr_m", "--algo", "ppo", "--n", "4",
                        "--steps", "10"]).unwrap();
        assert_eq!(c.model, "tldr_m");
        assert_eq!(c.algo, Algo::Ppo);
        assert_eq!(c.n_minibatches, 4);
        assert_eq!(c.steps, 10);
        assert_eq!(c.mode, Mode::Sync);
    }

    #[test]
    fn async_rejects_n_gt_1() {
        assert!(parse(&["train", "--mode", "async", "--n", "4"]).is_err());
        assert!(parse(&["train", "--mode", "async", "--n", "1"]).is_ok());
    }

    #[test]
    fn bad_algo_rejected() {
        assert!(parse(&["train", "--algo", "nope"]).is_err());
        assert!(parse(&["train", "--k", "3"]).is_err());
    }

    #[test]
    fn label_is_unique_per_knob() {
        let a = parse(&["t", "--n", "1"]).unwrap().label();
        let b = parse(&["t", "--n", "2"]).unwrap().label();
        assert_ne!(a, b);
        let c = parse(&["t", "--gen-engine", "device"]).unwrap().label();
        assert_ne!(a, c);
    }

    #[test]
    fn worker_pool_knobs_parse_and_default_to_cleanba() {
        // defaults are the paper's one-step coordinator: M=1, K=0
        let c = parse(&["t", "--mode", "async"]).unwrap();
        assert_eq!((c.gen_workers, c.staleness_bound), (1, 0));
        let c = parse(&[
            "t", "--mode", "async", "--gen-workers", "2",
            "--staleness-bound", "4",
        ])
        .unwrap();
        assert_eq!((c.gen_workers, c.staleness_bound), (2, 4));
        // the pool shape names the run dir (and only when non-default)
        assert!(c.label().contains("_w2q4_"), "label: {}", c.label());
        assert!(!parse(&["t", "--mode", "async"])
            .unwrap()
            .label()
            .contains("_w"));
        // zero workers is meaningless
        assert!(
            parse(&["t", "--mode", "async", "--gen-workers", "0"]).is_err()
        );
    }

    #[test]
    fn sync_mode_rejects_worker_pool_knobs() {
        assert!(parse(&["t", "--gen-workers", "2"]).is_err());
        assert!(parse(&["t", "--staleness-bound", "1"]).is_err());
        assert!(parse(&[
            "t", "--mode", "async", "--staleness-bound", "1"
        ])
        .is_ok());
    }

    #[test]
    fn gen_engine_parses_all_tiers_and_rejects_unknown() {
        for (name, want) in [
            ("fused", GenEngine::Fused),
            ("cached", GenEngine::Cached),
            ("device", GenEngine::Device),
            ("naive", GenEngine::Naive),
            ("continuous", GenEngine::Continuous),
        ] {
            let c = parse(&["t", "--gen-engine", name]).unwrap();
            assert_eq!(c.gen_engine, want);
            assert_eq!(want.name(), name);
        }
        // default is the production fused path
        assert_eq!(parse(&["t"]).unwrap().gen_engine, GenEngine::Fused);
        assert!(parse(&["t", "--gen-engine", "vllm"]).is_err());
    }

    #[test]
    fn continuous_admission_knobs_parse_validate_and_label() {
        // defaults: 4 cohorts, admit into any single freed slot
        let c = parse(&["t", "--gen-engine", "continuous"]).unwrap();
        assert_eq!((c.max_cohorts, c.admit_min), (4, 1));
        assert!(!c.label().contains("_c4a1"), "defaults stay unlabelled");
        let c = parse(&[
            "t", "--gen-engine", "continuous", "--max-cohorts", "2",
            "--admit-min", "8",
        ])
        .unwrap();
        assert_eq!((c.max_cohorts, c.admit_min), (2, 8));
        assert!(c.label().contains("_c2a8"), "label: {}", c.label());
        // degenerate values fail loudly
        assert!(parse(&[
            "t", "--gen-engine", "continuous", "--max-cohorts", "0"
        ])
        .is_err());
        assert!(parse(&[
            "t", "--gen-engine", "continuous", "--admit-min", "0"
        ])
        .is_err());
        // the knobs are meaningless outside the continuous engine
        assert!(parse(&["t", "--max-cohorts", "2"]).is_err());
        assert!(parse(&["t", "--gen-engine", "device", "--admit-min", "4"])
            .is_err());
    }

    #[test]
    fn fault_plan_parses_and_rejects_malformed() {
        let f = FaultPlan::parse("worker=1,round=3,kind=panic").unwrap();
        assert_eq!(
            f,
            FaultPlan { worker: 1, round: 3, kind: FaultKind::Panic }
        );
        // order-insensitive, whitespace-tolerant
        let f = FaultPlan::parse("kind=engine_err, worker=0, round=2")
            .unwrap();
        assert_eq!(f.kind, FaultKind::EngineErr);
        assert_eq!(format!("{f}"), "worker=0,round=2,kind=engine_err");
        for bad in [
            "worker=1,round=3",              // missing kind
            "worker=1,round=3,kind=oom",     // unknown kind
            "worker=x,round=3,kind=stall",   // bad number
            "worker=1,round=3,kind=stall,x=1", // unknown key
            "panic",                         // no key=value at all
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn supervision_knobs_parse_and_guard_mode() {
        // defaults
        let c = parse(&["t", "--mode", "async"]).unwrap();
        assert_eq!(c.max_worker_restarts, 2);
        assert_eq!(c.engine_retries, 2);
        assert_eq!(c.stall_timeout_secs, 30.0);
        assert_eq!(c.inject_fault, None);
        // overrides
        let c = parse(&[
            "t", "--mode", "async", "--gen-workers", "2",
            "--max-worker-restarts", "0", "--engine-retries", "5",
            "--stall-timeout-secs", "0.5",
            "--inject-fault", "worker=1,round=2,kind=stall",
        ])
        .unwrap();
        assert_eq!(c.max_worker_restarts, 0);
        assert_eq!(c.engine_retries, 5);
        assert_eq!(c.stall_timeout_secs, 0.5);
        assert_eq!(
            c.inject_fault,
            Some(FaultPlan {
                worker: 1,
                round: 2,
                kind: FaultKind::Stall
            })
        );
        // supervision knobs shape the async pool only
        assert!(parse(&["t", "--max-worker-restarts", "1"]).is_err());
        assert!(parse(&["t", "--engine-retries", "1"]).is_err());
        assert!(parse(&["t", "--stall-timeout-secs", "5"]).is_err());
        assert!(parse(&[
            "t", "--inject-fault", "worker=0,round=1,kind=panic"
        ])
        .is_err());
        // the fault target must exist in the pool
        assert!(parse(&[
            "t", "--mode", "async",
            "--inject-fault", "worker=1,round=1,kind=panic",
        ])
        .is_err());
        // degenerate watchdog threshold fails loudly
        assert!(parse(&[
            "t", "--mode", "async", "--stall-timeout-secs", "0"
        ])
        .is_err());
        // the supervisor's lane bitset grows with the pool: worker
        // counts past the old u64-bitmask cap of 64 are legal now
        assert!(parse(&["t", "--mode", "async", "--gen-workers", "65"])
            .is_ok());
    }

    #[test]
    fn trainer_shard_knob_parses_validates_and_labels() {
        // default: unsharded, and the label stays untouched (existing
        // run/checkpoint directories keep their names) — an explicit
        // S=1 must name the same run directory as the default
        let c = parse(&["t"]).unwrap();
        assert_eq!(c.trainer_shards, 1);
        assert!(!c.label().contains("_d1"), "label: {}", c.label());
        let explicit = parse(&["t", "--trainer-shards", "1"]).unwrap();
        assert_eq!(explicit.label(), c.label());
        // sharding is mode-orthogonal: it shapes the trainer, not the
        // round source
        let c = parse(&["t", "--trainer-shards", "4"]).unwrap();
        assert_eq!(c.trainer_shards, 4);
        assert!(c.label().contains("_d4_"), "label: {}", c.label());
        let c = parse(&[
            "t", "--mode", "async", "--trainer-shards", "2",
            "--gen-workers", "2",
        ])
        .unwrap();
        assert!(c.label().contains("_w2q0_d2_"), "label: {}", c.label());
        // S=0 is meaningless
        assert!(parse(&["t", "--trainer-shards", "0"]).is_err());
    }

    #[test]
    fn serving_knobs_parse_validate_and_label() {
        // serve mode needs the continuous engine
        assert!(parse(&["t", "--mode", "serve"]).is_err());
        let c = parse(&["t", "--mode", "serve", "--gen-engine", "continuous"])
            .unwrap();
        assert_eq!(c.mode, Mode::Serve);
        assert_eq!(
            (c.serve_sessions, c.serve_turns, c.arrival_rate),
            (8, 2, 0.5)
        );
        assert!(c.label().contains("_serve"), "label: {}", c.label());
        // defaults stay out of the label; overrides name the run dir
        assert!(!c.label().contains("_v8x2"), "label: {}", c.label());
        let c = parse(&[
            "t", "--mode", "serve", "--gen-engine", "continuous",
            "--serve-sessions", "16", "--serve-turns", "3",
            "--arrival-rate", "0.25",
        ])
        .unwrap();
        assert_eq!(
            (c.serve_sessions, c.serve_turns, c.arrival_rate),
            (16, 3, 0.25)
        );
        assert!(c.label().contains("_v16x3r0.25"), "label: {}", c.label());
        // degenerate traffic shapes fail loudly (the --admit-min pattern)
        for bad in [
            vec!["t", "--mode", "serve", "--gen-engine", "continuous",
                 "--serve-sessions", "0"],
            vec!["t", "--mode", "serve", "--gen-engine", "continuous",
                 "--serve-turns", "0"],
            vec!["t", "--mode", "serve", "--gen-engine", "continuous",
                 "--arrival-rate", "0"],
        ] {
            assert!(parse(&bad).is_err(), "accepted {bad:?}");
        }
        // the knobs are meaningless outside serve mode
        assert!(parse(&["t", "--serve-sessions", "4"]).is_err());
        assert!(parse(&["t", "--mode", "async", "--serve-turns", "3"])
            .is_err());
        // sessions must tile the worker partition
        assert!(parse(&[
            "t", "--mode", "serve", "--gen-engine", "continuous",
            "--gen-workers", "3",
        ])
        .is_err());
        assert!(parse(&[
            "t", "--mode", "serve", "--gen-engine", "continuous",
            "--gen-workers", "2",
        ])
        .is_ok());
        // serve runs checkpoint like every other mode: the delivered-turn
        // set is the whole resumable source state
        assert!(parse(&[
            "t", "--mode", "serve", "--gen-engine", "continuous",
            "--checkpoint-every", "4",
        ])
        .is_ok());
        // streaming modes are N=1 (same contract as async)
        assert!(parse(&[
            "t", "--mode", "serve", "--gen-engine", "continuous", "--n", "2",
        ])
        .is_err());
    }

    #[test]
    fn checkpoint_knobs_parse_everywhere_and_stay_out_of_the_label() {
        // valid in sync mode too: kill-and-resume must reproduce bitwise
        let c = parse(&["t", "--checkpoint-every", "4"]).unwrap();
        assert_eq!(c.checkpoint_every, 4);
        assert!(!c.resume);
        let v: Vec<String> =
            ["t", "--checkpoint-every", "4", "--resume"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let args = Args::parse(&v, &["resume"]).unwrap();
        let c = ExpConfig::from_args(&args).unwrap();
        assert!(c.resume);
        // none of the fault-tolerance knobs may rename the run dir:
        // --resume has to re-find the crashed run's checkpoints
        let base = parse(&["t", "--mode", "async", "--gen-workers", "2"])
            .unwrap()
            .label();
        let tol = parse(&[
            "t", "--mode", "async", "--gen-workers", "2",
            "--checkpoint-every", "4", "--max-worker-restarts", "7",
            "--engine-retries", "1", "--stall-timeout-secs", "1",
            "--inject-fault", "worker=1,round=1,kind=panic",
        ])
        .unwrap()
        .label();
        assert_eq!(base, tol);
    }
}
