//! Symbolic vocabulary shared with python/compile/configs.py (VOCAB = 64).
//!
//! The synthetic tasks operate over a small closed vocabulary: special
//! tokens, digits, arithmetic operators, instruction verbs and 32 "content"
//! tokens standing in for words. `detok` renders sequences for logs.

pub const VOCAB_SIZE: i32 = 64;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;

/// Digits 0..=9 -> token ids 4..=13.
pub const DIGIT_BASE: i32 = 4;

/// Arithmetic operators.
pub const OP_PLUS: i32 = 14;
pub const OP_MINUS: i32 = 15;
pub const OP_TIMES: i32 = 16;
pub const OP_EQ: i32 = 17;

/// Chat instruction verbs (paper §5.1 analogue tasks).
pub const INSTR_COPY: i32 = 18;
pub const INSTR_REVERSE: i32 = 19;
pub const INSTR_SORT: i32 = 20;
pub const INSTR_FIRST: i32 = 21;
pub const INSTR_LAST: i32 = 22;

/// 32 content tokens ("words"): ids 24..56.
pub const CONTENT_BASE: i32 = 24;
pub const CONTENT_COUNT: i32 = 32;

pub fn digit(d: u32) -> i32 {
    debug_assert!(d < 10);
    DIGIT_BASE + d as i32
}

pub fn is_digit(tok: i32) -> bool {
    (DIGIT_BASE..DIGIT_BASE + 10).contains(&tok)
}

pub fn digit_value(tok: i32) -> Option<u32> {
    if is_digit(tok) {
        Some((tok - DIGIT_BASE) as u32)
    } else {
        None
    }
}

pub fn is_content(tok: i32) -> bool {
    (CONTENT_BASE..CONTENT_BASE + CONTENT_COUNT).contains(&tok)
}

pub fn content(i: i32) -> i32 {
    debug_assert!((0..CONTENT_COUNT).contains(&i));
    CONTENT_BASE + i
}

/// Encode a non-negative number as digit tokens (most significant first).
pub fn encode_number(mut n: u32) -> Vec<i32> {
    if n == 0 {
        return vec![digit(0)];
    }
    let mut out = Vec::new();
    while n > 0 {
        out.push(digit(n % 10));
        n /= 10;
    }
    out.reverse();
    out
}

/// Decode a digit-token run back to a number; None on any non-digit.
pub fn decode_number(toks: &[i32]) -> Option<u32> {
    if toks.is_empty() {
        return None;
    }
    let mut n: u32 = 0;
    for &t in toks {
        n = n.checked_mul(10)?.checked_add(digit_value(t)?)?;
    }
    Some(n)
}

/// Human-readable rendering for logs and examples.
pub fn detok(tokens: &[i32]) -> String {
    let mut out = String::new();
    for &t in tokens {
        let s = match t {
            PAD => "·".to_string(),
            BOS => "⟨bos⟩".to_string(),
            EOS => "⟨eos⟩".to_string(),
            SEP => "|".to_string(),
            OP_PLUS => "+".to_string(),
            OP_MINUS => "-".to_string(),
            OP_TIMES => "*".to_string(),
            OP_EQ => "=".to_string(),
            INSTR_COPY => "COPY".to_string(),
            INSTR_REVERSE => "REV".to_string(),
            INSTR_SORT => "SORT".to_string(),
            INSTR_FIRST => "FIRST".to_string(),
            INSTR_LAST => "LAST".to_string(),
            t if is_digit(t) => digit_value(t).unwrap().to_string(),
            t if is_content(t) => {
                // content tokens render as letters a..z then A..F
                let i = t - CONTENT_BASE;
                let c = if i < 26 {
                    (b'a' + i as u8) as char
                } else {
                    (b'A' + (i - 26) as u8) as char
                };
                c.to_string()
            }
            t => format!("<{t}>"),
        };
        out.push_str(&s);
        out.push(' ');
    }
    out.trim_end().to_string()
}

/// Trim a generated response at (and including) the first EOS; returns the
/// response body (without EOS) and whether EOS was present.
pub fn trim_at_eos(resp: &[i32]) -> (&[i32], bool) {
    match resp.iter().position(|&t| t == EOS) {
        Some(i) => (&resp[..i], true),
        None => (resp, false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn number_roundtrip() {
        for n in [0u32, 7, 10, 99, 123, 4096] {
            assert_eq!(decode_number(&encode_number(n)), Some(n));
        }
    }

    #[test]
    fn decode_rejects_non_digits() {
        assert_eq!(decode_number(&[digit(1), SEP]), None);
        assert_eq!(decode_number(&[]), None);
    }

    #[test]
    fn vocab_ranges_disjoint() {
        // specials, digits, ops, instrs, content must not overlap
        let mut seen = std::collections::HashSet::new();
        for t in [PAD, BOS, EOS, SEP, OP_PLUS, OP_MINUS, OP_TIMES, OP_EQ,
                  INSTR_COPY, INSTR_REVERSE, INSTR_SORT, INSTR_FIRST,
                  INSTR_LAST] {
            assert!(seen.insert(t), "duplicate token id {t}");
        }
        for d in 0..10 {
            assert!(seen.insert(digit(d)));
        }
        for i in 0..CONTENT_COUNT {
            assert!(seen.insert(content(i)));
        }
        assert!(seen.iter().all(|&t| (0..VOCAB_SIZE).contains(&t)));
    }

    #[test]
    fn trim_eos() {
        let (body, has) = trim_at_eos(&[5, 6, EOS, 7]);
        assert_eq!(body, &[5, 6]);
        assert!(has);
        let (body, has) = trim_at_eos(&[5, 6]);
        assert_eq!(body, &[5, 6]);
        assert!(!has);
    }

    #[test]
    fn detok_renders() {
        let s = detok(&[BOS, content(0), OP_PLUS, digit(3), EOS, PAD]);
        assert_eq!(s, "⟨bos⟩ a + 3 ⟨eos⟩ ·");
    }
}
