//! PJRT execution engine: loads HLO-text artifacts, compiles them once, and
//! exposes shape-checked typed calls.
//!
//! One `Engine` per OS thread: the `xla` crate's `PjRtClient` is `Rc`-based
//! (not `Send`), which matches the paper's architecture — the generation
//! worker and the trainer each own their own backend and exchange plain
//! host buffers (DESIGN.md §3).
//!
//! # Execution paths
//!
//! There are two ways through PJRT, chosen per artifact by the manifest's
//! `untupled` flag (set in python/compile/aot.py):
//!
//! - **Host-literal path** (`call` / `call_with`, tupled artifacts): every
//!   input becomes a device buffer for the call, the single tuple result
//!   is downloaded and split on the host. Used by prefill/decode/logprob/
//!   score_rm — the step-wise engines deliberately stay here as the
//!   Fig-14 middle tier.
//! - **Buffer path** (`execute_buffers`, untupled artifacts): PJRT returns
//!   one `DeviceBuffer` per output, so hot state (train params, Adam
//!   moments) stays device-resident across calls and only what the host
//!   actually needs (metrics, sampled tokens) is downloaded. Used by the
//!   fused `generate` and every `train_*` artifact.
//!
//! Both paths draw parameter inputs from the engine's **device cache**: a
//! [`ParamView::cached`] argument uploads its host vector once per
//! `(key, version)` and reuses the resident buffer until the version
//! changes. Frozen sets (the SFT reference, the proxy RM) therefore upload
//! exactly once per run, and the generation worker re-uploads only when
//! the trainer publishes a new policy version. All host↔device traffic is
//! accounted per artifact in [`CallStats`] (`bytes_up` / `bytes_down`).

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, IoSpec, Manifest};

/// Host-side tensor passed to/from executables.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I32(_) => DType::I32,
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        shaped(lit, shape)
    }

    fn from_literal(lit: &xla::Literal, dtype: DType) -> Result<HostTensor> {
        Ok(match dtype {
            DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
            DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
        })
    }
}

/// Reshape a rank-1 literal to the manifest shape (rank-1 stays as-is,
/// scalars become rank-0).
fn shaped(lit: xla::Literal, shape: &[usize]) -> Result<xla::Literal> {
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Scalar convenience constructors.
pub fn scalar_f32(x: f32) -> HostTensor {
    HostTensor::F32(vec![x])
}

pub fn scalar_i32(x: i32) -> HostTensor {
    HostTensor::I32(vec![x])
}

/// A parameter vector as seen by a call: plain host memory, a device-cache
/// slot keyed by `(key, version)`, or an already-resident buffer.
///
/// The cache contract: within one engine, `(key, version)` uniquely
/// identifies the vector's *content*. Callers that rebind a key with new
/// content must bump the version (the async trainer does) or invalidate
/// the key first ([`Engine::invalidate_params`], as `eval` does).
#[derive(Clone, Copy)]
pub enum ParamView<'a> {
    /// Upload fresh on every call — no caching (ad-hoc callers, benches).
    Fresh(&'a [f32]),
    /// Upload once per `(key, version)`, then reuse the device buffer.
    Cached { key: &'a str, version: u64, host: &'a [f32] },
    /// Already device-resident (e.g. the live training params in sync
    /// mode) — no transfer at all.
    Device(&'a DeviceBuffer),
}

impl<'a> ParamView<'a> {
    pub fn fresh(host: &'a [f32]) -> ParamView<'a> {
        ParamView::Fresh(host)
    }

    pub fn cached(key: &'a str, version: u64, host: &'a [f32]) -> ParamView<'a> {
        ParamView::Cached { key, version, host }
    }
}

/// One argument to an executable call. Slice variants are borrowed so
/// callers can reuse flattening scratch across rounds; `Param` goes
/// through the device cache; `Device` chains a previous call's output
/// without touching the host.
pub enum CallArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
    Param(ParamView<'a>),
    Device(&'a DeviceBuffer),
}

impl<'a> From<&'a HostTensor> for CallArg<'a> {
    fn from(t: &'a HostTensor) -> CallArg<'a> {
        match t {
            HostTensor::F32(v) => CallArg::F32(v),
            HostTensor::I32(v) => CallArg::I32(v),
        }
    }
}

/// A device-resident tensor: an output of `execute_buffers` or an upload.
/// Cloning shares the underlying PJRT buffer (cheap `Rc` bump). Download
/// through [`Engine::download`] so the transfer is accounted.
#[derive(Clone)]
pub struct DeviceBuffer {
    buf: Rc<xla::PjRtBuffer>,
    dtype: DType,
    numel: usize,
    /// Stats key the buffer's transfers are attributed to (the artifact
    /// or cache key that produced it).
    origin: String,
}

impl DeviceBuffer {
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn numel(&self) -> usize {
        self.numel
    }
}

/// Cumulative per-artifact timing and host↔device traffic, for the perf
/// pass and overhead analysis. On the buffer path `total_secs` covers
/// dispatch plus any accounted downloads; `bytes_*` count payload bytes
/// actually moved (cache hits and `Device` args move nothing).
#[derive(Debug, Default, Clone)]
pub struct CallStats {
    pub calls: u64,
    pub total_secs: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Engine-boundary retries charged to this origin (see
    /// [`crate::runtime::retry::RetryPolicy`]). A retried call's
    /// successful attempt still counts once under `calls`.
    pub retries: u64,
}

struct ParamEntry {
    version: u64,
    buffer: DeviceBuffer,
}

pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    /// Compiled executables keyed by HLO *file*, not artifact name:
    /// aliased artifacts (train_bon -> train_sft, the `*_dev` twins ->
    /// their tupled namesakes) share one compilation.
    executables: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<BTreeMap<String, CallStats>>,
    /// Named/versioned device-resident parameter sets (see [`ParamView`]).
    param_cache: RefCell<BTreeMap<String, ParamEntry>>,
    cache_hits: Cell<u64>,
    cache_misses: Cell<u64>,
    /// One-shot warning flag for clients that return untupled artifacts'
    /// root tuple as a single buffer (see `execute_buffers_spec`).
    tuple_fallback_warned: Cell<bool>,
    /// Whether this client hands untupled artifacts back as per-leaf
    /// buffers (`Some(true)`), or as one root-tuple buffer that the
    /// engine must split through the host (`Some(false)`). Unknown until
    /// the first untupled execution. Zero-copy paths that would move
    /// MORE bytes under the fallback gate on this (see
    /// [`Engine::client_untuples`]).
    untuple_capability: Cell<Option<bool>>,
}

fn check_input(name: &str, s: &IoSpec, dtype: DType, len: usize) -> Result<()> {
    if dtype != s.dtype {
        bail!("{name}: input '{}' dtype mismatch", s.name);
    }
    if len != s.numel() {
        bail!(
            "{name}: input '{}' has {} elements, expected {} {:?}",
            s.name,
            len,
            s.numel(),
            s.shape
        );
    }
    Ok(())
}

impl Engine {
    /// Load a config's artifact directory. Executables compile lazily on
    /// first call (compile-all via `warmup` for benchmarking).
    pub fn load(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            manifest,
            client,
            executables: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(BTreeMap::new()),
            param_cache: RefCell::new(BTreeMap::new()),
            cache_hits: Cell::new(0),
            cache_misses: Cell::new(0),
            tuple_fallback_warned: Cell::new(false),
            untuple_capability: Cell::new(None),
        })
    }

    /// `Some(true)` once an untupled execution has come back as per-leaf
    /// buffers, `Some(false)` once one has hit the root-tuple fallback,
    /// `None` before either. Callers choosing between a device-chaining
    /// path and a host-literal path should take the device path only on
    /// `Some(true)` — under the fallback it moves *more* bytes than the
    /// literal path it replaces.
    pub fn client_untuples(&self) -> Option<bool> {
        self.untuple_capability.get()
    }

    /// Single eligibility rule for opt-in zero-copy paths: the bundle
    /// ships `artifact` AND this client has been observed to untuple.
    /// Callers (resident labelling, eval's logprob_dev, benches) must use
    /// this rather than re-deriving the rule, so the gating policy can't
    /// drift between sites.
    pub fn buffer_path_ready(&self, artifact: &str) -> bool {
        self.manifest.has_artifact(artifact) && self.client_untuples() == Some(true)
    }

    pub fn config_name(&self) -> &str {
        &self.manifest.config.name
    }

    /// Compile `name`'s HLO file if this engine hasn't yet (aliases hit
    /// the cache); returns the executable-cache key (the file name).
    fn ensure_compiled(&self, name: &str) -> Result<String> {
        let file = self.manifest.artifact(name)?.file.clone();
        if self.executables.borrow().contains_key(&file) {
            return Ok(file);
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.stats
            .borrow_mut()
            .entry(format!("compile:{file}"))
            .or_default()
            .total_secs += t0.elapsed().as_secs_f64();
        self.executables.borrow_mut().insert(file.clone(), exe);
        Ok(file)
    }

    /// Compile every artifact up front.
    pub fn warmup(&self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.artifacts.keys().cloned().collect();
        for n in names {
            self.ensure_compiled(&n)?;
        }
        Ok(())
    }

    fn upload_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Resolve call arguments to device buffers: host slices upload, cached
    /// params hit or refill the device cache, `Device` args are reused
    /// as-is. Returns the buffers plus the bytes actually uploaded.
    fn resolve_args(
        &self,
        name: &str,
        spec: &ArtifactSpec,
        args: &[CallArg],
    ) -> Result<(Vec<Rc<xla::PjRtBuffer>>, u64)> {
        if args.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                args.len()
            );
        }
        let mut bufs = Vec::with_capacity(args.len());
        let mut bytes_up = 0u64;
        for (arg, s) in args.iter().zip(&spec.inputs) {
            let buf = match arg {
                CallArg::F32(v) => {
                    check_input(name, s, DType::F32, v.len())?;
                    bytes_up += 4 * v.len() as u64;
                    Rc::new(self.upload_literal(&shaped(
                        xla::Literal::vec1(v),
                        &s.shape,
                    )?)?)
                }
                CallArg::I32(v) => {
                    check_input(name, s, DType::I32, v.len())?;
                    bytes_up += 4 * v.len() as u64;
                    Rc::new(self.upload_literal(&shaped(
                        xla::Literal::vec1(v),
                        &s.shape,
                    )?)?)
                }
                CallArg::ScalarF32(x) => {
                    check_input(name, s, DType::F32, 1)?;
                    bytes_up += 4;
                    Rc::new(self.upload_literal(&shaped(
                        xla::Literal::vec1(&[*x]),
                        &s.shape,
                    )?)?)
                }
                CallArg::ScalarI32(x) => {
                    check_input(name, s, DType::I32, 1)?;
                    bytes_up += 4;
                    Rc::new(self.upload_literal(&shaped(
                        xla::Literal::vec1(&[*x]),
                        &s.shape,
                    )?)?)
                }
                CallArg::Param(view) => {
                    self.resolve_param(name, s, *view, &mut bytes_up)?
                }
                CallArg::Device(b) => {
                    check_input(name, s, b.dtype, b.numel)?;
                    b.buf.clone()
                }
            };
            bufs.push(buf);
        }
        Ok((bufs, bytes_up))
    }

    fn resolve_param(
        &self,
        name: &str,
        s: &IoSpec,
        view: ParamView,
        bytes_up: &mut u64,
    ) -> Result<Rc<xla::PjRtBuffer>> {
        match view {
            ParamView::Fresh(host) => {
                check_input(name, s, DType::F32, host.len())?;
                *bytes_up += 4 * host.len() as u64;
                Ok(Rc::new(self.upload_literal(&shaped(
                    xla::Literal::vec1(host),
                    &s.shape,
                )?)?))
            }
            ParamView::Device(b) => {
                check_input(name, s, b.dtype, b.numel)?;
                Ok(b.buf.clone())
            }
            ParamView::Cached { key, version, host } => {
                check_input(name, s, DType::F32, host.len())?;
                let mut cache = self.param_cache.borrow_mut();
                if let Some(e) = cache.get(key) {
                    if e.version == version && e.buffer.numel == host.len() {
                        self.cache_hits.set(self.cache_hits.get() + 1);
                        return Ok(e.buffer.buf.clone());
                    }
                }
                self.cache_misses.set(self.cache_misses.get() + 1);
                *bytes_up += 4 * host.len() as u64;
                let buffer = DeviceBuffer {
                    buf: Rc::new(self.upload_literal(&shaped(
                        xla::Literal::vec1(host),
                        &s.shape,
                    )?)?),
                    dtype: DType::F32,
                    numel: host.len(),
                    origin: format!("params:{key}"),
                };
                let rc = buffer.buf.clone();
                cache.insert(key.to_string(), ParamEntry { version, buffer });
                Ok(rc)
            }
        }
    }

    /// Execute artifact `name` with host-tensor inputs (legacy entry
    /// point). Untupled artifacts run on the buffer path and download
    /// every output.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let args: Vec<CallArg> = inputs.iter().map(CallArg::from).collect();
        self.call_with(name, &args)
    }

    /// Execute artifact `name` with mixed host/cached/device inputs,
    /// returning host outputs. Inputs are validated against the manifest
    /// (count, dtype, element count) before hitting PJRT.
    pub fn call_with(&self, name: &str, args: &[CallArg]) -> Result<Vec<HostTensor>> {
        let spec: ArtifactSpec = self.manifest.artifact(name)?.clone();
        if spec.untupled {
            // Host-bound call on a buffer-path artifact: take the raw
            // execution result so a fallback client's single root-tuple
            // buffer is split with ONE download (seed-equivalent), never
            // re-uploaded just to be downloaded again.
            let t0 = Instant::now();
            let (outs, bytes_up) = self.execute_raw(name, &spec, args)?;
            let mut bytes_down = 0u64;
            let out: Vec<HostTensor> = if outs.len() == spec.outputs.len() {
                self.untuple_capability.set(Some(true));
                let mut host = Vec::with_capacity(outs.len());
                for (b, s) in outs.iter().zip(&spec.outputs) {
                    host.push(HostTensor::from_literal(
                        &b.to_literal_sync()?,
                        s.dtype,
                    )?);
                    bytes_down += 4 * s.numel() as u64;
                }
                host
            } else if outs.len() == 1 && spec.outputs.len() > 1 {
                self.untuple_capability.set(Some(false));
                let parts = outs[0].to_literal_sync()?.to_tuple()?;
                if parts.len() != spec.outputs.len() {
                    bail!(
                        "{name}: tuple has {} parts, manifest says {}",
                        parts.len(),
                        spec.outputs.len()
                    );
                }
                let mut host = Vec::with_capacity(parts.len());
                for (lit, s) in parts.iter().zip(&spec.outputs) {
                    host.push(HostTensor::from_literal(lit, s.dtype)?);
                    bytes_down += 4 * s.numel() as u64;
                }
                host
            } else {
                bail!(
                    "{name}: executable returned {} outputs, manifest says {}",
                    outs.len(),
                    spec.outputs.len()
                );
            };
            let dt = t0.elapsed().as_secs_f64();
            let mut stats = self.stats.borrow_mut();
            let e = stats.entry(name.to_string()).or_default();
            e.calls += 1;
            e.total_secs += dt;
            e.bytes_up += bytes_up;
            e.bytes_down += bytes_down;
            return Ok(out);
        }
        let t0 = Instant::now();
        let (outs, bytes_up) = self.execute_raw(name, &spec, args)?;
        // aot.py lowers tupled artifacts with return_tuple=True: always a
        // single tuple result, even 1-ary (per-leaf on untupling clients).
        let parts: Vec<xla::Literal> = if outs.len() == 1 {
            outs[0].to_literal_sync()?.to_tuple()?
        } else {
            let mut lits = Vec::with_capacity(outs.len());
            for b in &outs {
                lits.push(b.to_literal_sync()?);
            }
            lits
        };
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: executable returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        let mut bytes_down = 0u64;
        for (lit, s) in parts.iter().zip(&spec.outputs) {
            out.push(HostTensor::from_literal(lit, s.dtype)?);
            bytes_down += 4 * s.numel() as u64;
        }
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += dt;
        e.bytes_up += bytes_up;
        e.bytes_down += bytes_down;
        Ok(out)
    }

    /// Execute an untupled artifact and keep the outputs device-resident:
    /// PJRT hands back one buffer per output, nothing is downloaded.
    /// Chain outputs into later calls with [`CallArg::Device`]; fetch the
    /// ones the host needs with [`Engine::download`].
    pub fn execute_buffers(
        &self,
        name: &str,
        args: &[CallArg],
    ) -> Result<Vec<DeviceBuffer>> {
        let spec: ArtifactSpec = self.manifest.artifact(name)?.clone();
        if !spec.untupled {
            bail!(
                "{name} is a tupled (host-literal) artifact; use call()/call_with()"
            );
        }
        self.execute_buffers_spec(name, &spec, args)
    }

    /// Resolve args and execute on device, returning PJRT's raw per-device
    /// result row (one buffer per output leaf on untupling clients, one
    /// root-tuple buffer otherwise) plus the bytes uploaded.
    fn execute_raw(
        &self,
        name: &str,
        spec: &ArtifactSpec,
        args: &[CallArg],
    ) -> Result<(Vec<xla::PjRtBuffer>, u64)> {
        let (bufs, bytes_up) = self.resolve_args(name, spec, args)?;
        let key = self.ensure_compiled(name)?;
        let execs = self.executables.borrow();
        let exe = execs.get(&key).ok_or_else(|| {
            anyhow!(
                "{name}: executable '{key}' vanished from the cache after \
                 compilation — this is a bug"
            )
        })?;
        let mut results = exe.execute_b(&bufs)?;
        if results.is_empty() {
            bail!("{name}: empty execution result");
        }
        Ok((results.swap_remove(0), bytes_up))
    }

    // NOTE: a 1-output untupled artifact is indistinguishable here from a
    // fallback client's 1-ary root tuple (both are outs.len() == 1), so
    // aot.py refuses to mark single-output artifacts untupled.
    fn execute_buffers_spec(
        &self,
        name: &str,
        spec: &ArtifactSpec,
        args: &[CallArg],
    ) -> Result<Vec<DeviceBuffer>> {
        let t0 = Instant::now();
        let (outs, mut bytes_up) = self.execute_raw(name, spec, args)?;
        let mut bytes_down = 0u64;
        let out: Vec<DeviceBuffer> = if outs.len() == spec.outputs.len() {
            // Client untuples the root: one buffer per output leaf.
            self.untuple_capability.set(Some(true));
            outs.into_iter()
                .zip(&spec.outputs)
                .map(|(b, s)| DeviceBuffer {
                    buf: Rc::new(b),
                    dtype: s.dtype,
                    numel: s.numel(),
                    origin: name.to_string(),
                })
                .collect()
        } else if outs.len() == 1 && spec.outputs.len() > 1 {
            // Client that never sets untuple_result: the root tuple comes
            // back as ONE buffer, and PJRT exposes no on-device tuple
            // split — split through the host once and re-upload, so
            // callers still see per-output device buffers. Correct on
            // every client; the zero-copy win needs an untupling one.
            self.untuple_capability.set(Some(false));
            if !self.tuple_fallback_warned.replace(true) {
                eprintln!(
                    "[engine] {name}: PJRT client returned the root tuple \
                     as one buffer; splitting untupled outputs via host \
                     (device-resident chaining degrades to per-call \
                     round-trips)"
                );
            }
            let lit = outs[0].to_literal_sync()?;
            let parts = lit.to_tuple()?;
            if parts.len() != spec.outputs.len() {
                bail!(
                    "{name}: tuple has {} parts, manifest says {}",
                    parts.len(),
                    spec.outputs.len()
                );
            }
            parts
                .iter()
                .zip(&spec.outputs)
                .map(|(part, s)| {
                    bytes_down += 4 * s.numel() as u64;
                    bytes_up += 4 * s.numel() as u64;
                    Ok(DeviceBuffer {
                        buf: Rc::new(self.upload_literal(part)?),
                        dtype: s.dtype,
                        numel: s.numel(),
                        origin: name.to_string(),
                    })
                })
                .collect::<Result<_>>()?
        } else {
            bail!(
                "{name}: executable returned {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            );
        };
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += dt;
        e.bytes_up += bytes_up;
        e.bytes_down += bytes_down;
        Ok(out)
    }

    /// Download a device buffer to the host (blocking), accounting the
    /// transfer against the buffer's origin artifact.
    pub fn download(&self, buffer: &DeviceBuffer) -> Result<HostTensor> {
        let t0 = Instant::now();
        let lit = buffer.buf.to_literal_sync()?;
        let out = HostTensor::from_literal(&lit, buffer.dtype)?;
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(buffer.origin.clone()).or_default();
        e.total_secs += t0.elapsed().as_secs_f64();
        e.bytes_down += 4 * buffer.numel as u64;
        Ok(out)
    }

    /// Upload a host f32 vector as a standalone device buffer (train-state
    /// seeding); transfer bytes *and time* are attributed to `origin`, so
    /// the batch-upload path shows up in [`CallStats`] like any call.
    pub fn upload_f32(&self, origin: &str, data: &[f32]) -> Result<DeviceBuffer> {
        let t0 = Instant::now();
        let buf = DeviceBuffer {
            buf: Rc::new(self.upload_literal(&xla::Literal::vec1(data))?),
            dtype: DType::F32,
            numel: data.len(),
            origin: origin.to_string(),
        };
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(origin.to_string()).or_default();
        e.total_secs += t0.elapsed().as_secs_f64();
        e.bytes_up += 4 * data.len() as u64;
        Ok(buf)
    }

    /// Upload host tensors destined for `name`'s inputs starting at
    /// position `offset` (e.g. 5 to skip params/m/v/step/lr on train
    /// artifacts), validating each against the manifest. Upload once,
    /// reuse across the `updates_per_batch` inner loop.
    pub fn upload_inputs(
        &self,
        name: &str,
        offset: usize,
        tensors: &[HostTensor],
    ) -> Result<Vec<DeviceBuffer>> {
        // borrow, don't clone (see upload_arg_as): called once per host
        // slot on the per-batch path, so the spec deep-clone would be
        // pure waste
        let spec = self.manifest.artifact(name)?;
        if offset + tensors.len() > spec.inputs.len() {
            bail!(
                "{name}: {} tensors at offset {offset} exceed the {}-input spec",
                tensors.len(),
                spec.inputs.len()
            );
        }
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(tensors.len());
        let mut bytes_up = 0u64;
        for (t, s) in tensors.iter().zip(&spec.inputs[offset..]) {
            check_input(name, s, t.dtype(), t.len())?;
            bytes_up += 4 * t.len() as u64;
            out.push(DeviceBuffer {
                buf: Rc::new(self.upload_literal(&t.to_literal(&s.shape)?)?),
                dtype: t.dtype(),
                numel: t.len(),
                origin: name.to_string(),
            });
        }
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_default();
        e.total_secs += t0.elapsed().as_secs_f64();
        e.bytes_up += bytes_up;
        Ok(out)
    }

    /// Upload one borrowed host slice destined for `name`'s input at
    /// position `index`, attributing the transfer to `origin`. The slice
    /// variant avoids moving callers' reusable flattening scratch into a
    /// [`HostTensor`]; only `F32`/`I32` slice args are uploadable.
    pub fn upload_arg_as(
        &self,
        origin: &str,
        name: &str,
        index: usize,
        arg: &CallArg,
    ) -> Result<DeviceBuffer> {
        // borrow, don't clone: the manifest is immutable for the engine's
        // lifetime and the upload only reads the input spec
        let spec = self.manifest.artifact(name)?;
        let s = spec.inputs.get(index).ok_or_else(|| {
            anyhow!("{name}: no input at position {index}")
        })?;
        let t0 = Instant::now();
        let (lit, dtype, numel) = match arg {
            CallArg::F32(v) => {
                check_input(name, s, DType::F32, v.len())?;
                (shaped(xla::Literal::vec1(v), &s.shape)?, DType::F32, v.len())
            }
            CallArg::I32(v) => {
                check_input(name, s, DType::I32, v.len())?;
                (shaped(xla::Literal::vec1(v), &s.shape)?, DType::I32, v.len())
            }
            _ => bail!("{name}: upload_arg_as takes host slice args only"),
        };
        let buf = DeviceBuffer {
            buf: Rc::new(self.upload_literal(&lit)?),
            dtype,
            numel,
            origin: origin.to_string(),
        };
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(origin.to_string()).or_default();
        e.total_secs += t0.elapsed().as_secs_f64();
        e.bytes_up += 4 * numel as u64;
        Ok(buf)
    }

    /// Drop a cached parameter set (callers that reuse a key with new
    /// content and no version to bump, e.g. `eval`).
    pub fn invalidate_params(&self, key: &str) {
        self.param_cache.borrow_mut().remove(key);
    }

    /// `(hits, misses)` of the device parameter cache since the last
    /// `reset_stats`.
    pub fn param_cache_counters(&self) -> (u64, u64) {
        (self.cache_hits.get(), self.cache_misses.get())
    }

    /// Total `(bytes_up, bytes_down)` moved host↔device across all
    /// artifacts since the last `reset_stats`.
    pub fn transfer_totals(&self) -> (u64, u64) {
        let stats = self.stats.borrow();
        stats
            .values()
            .fold((0, 0), |(u, d), s| (u + s.bytes_up, d + s.bytes_down))
    }

    /// Charge one engine-boundary retry to `origin` (the retry policy's
    /// `on_retry` hook calls this between attempts).
    pub fn note_retry(&self, origin: &str) {
        self.stats
            .borrow_mut()
            .entry(origin.to_string())
            .or_default()
            .retries += 1;
    }

    pub fn stats(&self) -> BTreeMap<String, CallStats> {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
        self.cache_hits.set(0);
        self.cache_misses.set(0);
    }

    /// Load the seeded initial policy parameters from the artifact dir.
    pub fn init_policy(&self) -> Result<Vec<f32>> {
        let arr = crate::util::npy::read_f32(self.manifest.init_policy_path())?;
        self.check_params(&arr.data)?;
        Ok(arr.data)
    }

    pub fn init_rm(&self) -> Result<Vec<f32>> {
        let arr = crate::util::npy::read_f32(self.manifest.init_rm_path())?;
        self.check_params(&arr.data)?;
        Ok(arr.data)
    }

    fn check_params(&self, p: &[f32]) -> Result<()> {
        if p.len() != self.manifest.param_count {
            bail!(
                "param vector has {} elements, manifest says {}",
                p.len(),
                self.manifest.param_count
            );
        }
        Ok(())
    }
}

/// Optimizer state threaded through train-step executables.
///
/// On untupled train artifacts the `(params, m, v)` triple lives as device
/// buffers across the `updates_per_batch` inner loop *and* across steps;
/// only the metrics vector is downloaded per update, and the host mirrors
/// refresh lazily at publish/eval/checkpoint boundaries (`params_host`,
/// `into_params`). On legacy tupled artifacts every call round-trips the
/// triple through host literals, exactly like the seed runtime.
///
/// Device buffers belong to the engine that created them: drive one
/// `TrainState` with one `Engine` (the trainer thread's own), as every
/// coordinator does.
#[derive(Clone)]
pub struct TrainState {
    params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    pub step: u64,
    device: Option<DeviceOptState>,
    /// True when the device triple is ahead of the host mirrors.
    host_stale: bool,
}

#[derive(Clone)]
struct DeviceOptState {
    params: DeviceBuffer,
    m: DeviceBuffer,
    v: DeviceBuffer,
}

impl TrainState {
    pub fn new(params: Vec<f32>) -> TrainState {
        let n = params.len();
        TrainState {
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            device: None,
            host_stale: false,
        }
    }

    /// Run one fused train step. `batch` holds the loss-specific tensors
    /// after (params, m, v, step, lr). Returns the metrics vector.
    ///
    /// Uploads the batch for this one call; loops over the same batch
    /// should upload once via [`Engine::upload_inputs`] and call
    /// [`TrainState::train_step_uploaded`] instead.
    pub fn train_step(
        &mut self,
        engine: &Engine,
        artifact: &str,
        lr: f32,
        batch: Vec<HostTensor>,
    ) -> Result<Vec<f32>> {
        let dev_batch = engine.upload_inputs(artifact, 5, &batch)?;
        self.train_step_uploaded(engine, artifact, lr, &dev_batch)
    }

    /// One fused train step over an already-uploaded batch.
    pub fn train_step_uploaded(
        &mut self,
        engine: &Engine,
        artifact: &str,
        lr: f32,
        batch: &[DeviceBuffer],
    ) -> Result<Vec<f32>> {
        self.step += 1;
        if engine.manifest.artifact(artifact)?.untupled {
            self.ensure_device(engine)?;
            let (params, m, v, metrics) = {
                let dev = self.device.as_ref().ok_or_else(|| {
                    anyhow!(
                        "{artifact}: optimizer triple not device-resident \
                         after ensure_device — this is a bug"
                    )
                })?;
                let mut args: Vec<CallArg> = Vec::with_capacity(batch.len() + 5);
                args.push(CallArg::Device(&dev.params));
                args.push(CallArg::Device(&dev.m));
                args.push(CallArg::Device(&dev.v));
                args.push(CallArg::ScalarF32(self.step as f32));
                args.push(CallArg::ScalarF32(lr));
                args.extend(batch.iter().map(CallArg::Device));
                let mut out = engine.execute_buffers(artifact, &args)?;
                if out.len() != 4 {
                    bail!("{artifact}: expected 4 outputs, got {}", out.len());
                }
                let metrics = engine.download(&out[3])?.into_f32()?;
                out.truncate(3);
                let (Some(v), Some(m), Some(params)) =
                    (out.pop(), out.pop(), out.pop())
                else {
                    bail!(
                        "{artifact}: optimizer-triple outputs vanished \
                         after the arity check — this is a bug"
                    );
                };
                (params, m, v, metrics)
            };
            self.device = Some(DeviceOptState { params, m, v });
            self.host_stale = true;
            Ok(metrics)
        } else {
            // Legacy host-literal path: the triple round-trips every call.
            self.sync_host(engine)?;
            self.device = None;
            let mut out = {
                let mut args: Vec<CallArg> = Vec::with_capacity(batch.len() + 5);
                args.push(CallArg::F32(&self.params));
                args.push(CallArg::F32(&self.m));
                args.push(CallArg::F32(&self.v));
                args.push(CallArg::ScalarF32(self.step as f32));
                args.push(CallArg::ScalarF32(lr));
                args.extend(batch.iter().map(CallArg::Device));
                engine.call_with(artifact, &args)?
            };
            if out.len() != 4 {
                bail!("{artifact}: expected 4 outputs, got {}", out.len());
            }
            let mut take = |what: &'static str| {
                out.pop().ok_or_else(|| {
                    anyhow!(
                        "{artifact}: missing {what} output after the arity \
                         check — this is a bug"
                    )
                })
            };
            let metrics = take("metrics")?.into_f32()?;
            self.v = take("v")?.into_f32()?;
            self.m = take("m")?.into_f32()?;
            self.params = take("params")?.into_f32()?;
            Ok(metrics)
        }
    }

    fn ensure_device(&mut self, engine: &Engine) -> Result<()> {
        if self.device.is_some() {
            return Ok(());
        }
        self.device = Some(DeviceOptState {
            params: engine.upload_f32("train_state", &self.params)?,
            m: engine.upload_f32("train_state", &self.m)?,
            v: engine.upload_f32("train_state", &self.v)?,
        });
        Ok(())
    }

    /// Rebuild a state from checkpointed host mirrors — the exact bytes
    /// [`TrainState::host_mirrors`] returned at the snapshot. The next
    /// train step re-uploads the triple, so a resumed run continues
    /// bitwise from the checkpoint (downloads and uploads are exact).
    pub fn from_host(
        params: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
        step: u64,
    ) -> Result<TrainState> {
        if m.len() != params.len() || v.len() != params.len() {
            bail!(
                "optimizer state size mismatch: params {} / m {} / v {}",
                params.len(),
                m.len(),
                v.len()
            );
        }
        Ok(TrainState { params, m, v, step, device: None, host_stale: false })
    }

    /// The full host triple `(params, m, v)`, synced from the device if
    /// it is ahead — the checkpoint payload.
    pub fn host_mirrors(
        &mut self,
        engine: &Engine,
    ) -> Result<(&[f32], &[f32], &[f32])> {
        self.sync_host(engine)?;
        Ok((&self.params, &self.m, &self.v))
    }

    /// Refresh the host mirrors from the device triple (checkpoint/final
    /// boundaries, and before falling back to the host-literal train
    /// path). No-op when already in sync.
    pub fn sync_host(&mut self, engine: &Engine) -> Result<()> {
        if !self.host_stale {
            return Ok(());
        }
        let dev = self.device.as_ref().expect("stale host without device state");
        self.params = engine.download(&dev.params)?.into_f32()?;
        self.m = engine.download(&dev.m)?.into_f32()?;
        self.v = engine.download(&dev.v)?.into_f32()?;
        self.host_stale = false;
        Ok(())
    }

    /// Current parameters on the host. Downloads ONLY the params when the
    /// device is ahead — publish boundaries don't need the Adam moments,
    /// so m/v stay device-resident until `sync_host`/`into_params`
    /// (a third of the per-publish device→host bytes).
    pub fn params_host(&mut self, engine: &Engine) -> Result<&[f32]> {
        if self.host_stale {
            let dev =
                self.device.as_ref().expect("stale host without device state");
            self.params = engine.download(&dev.params)?.into_f32()?;
            // host_stale stays set: the m/v mirrors are still behind
        }
        Ok(&self.params)
    }

    /// Consume the state, returning the final parameters.
    pub fn into_params(mut self, engine: &Engine) -> Result<Vec<f32>> {
        self.sync_host(engine)?;
        Ok(self.params)
    }

    /// Parameter view for same-engine consumers (sync-mode generation):
    /// the live device buffer when one exists — zero transfer — else the
    /// host mirror under the given cache identity.
    pub fn param_view<'a>(&'a self, key: &'a str, version: u64) -> ParamView<'a> {
        match &self.device {
            Some(dev) => ParamView::Device(&dev.params),
            None => ParamView::cached(key, version, &self.params),
        }
    }
}

/// Named metric lookup against the manifest's metric table.
pub fn metric(
    engine: &Engine,
    artifact: &str,
    metrics: &[f32],
    name: &str,
) -> Result<f32> {
    let spec = engine.manifest.artifact(artifact)?;
    let idx = spec
        .metrics
        .iter()
        .position(|m| m == name)
        .ok_or_else(|| anyhow!("{artifact} has no metric '{name}'"))?;
    Ok(metrics[idx])
}
