//! PJRT execution engine: loads HLO-text artifacts, compiles them once, and
//! exposes shape-checked typed calls.
//!
//! One `Engine` per OS thread: the `xla` crate's `PjRtClient` is `Rc`-based
//! (not `Send`), which matches the paper's architecture — the generation
//! worker and the trainer each own their own backend and exchange plain
//! host buffers (DESIGN.md §3).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest};

/// Host-side tensor passed to/from executables.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I32(_) => DType::I32,
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        if shape.len() == 1 {
            return Ok(lit);
        }
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal, dtype: DType) -> Result<HostTensor> {
        Ok(match dtype {
            DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
            DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
        })
    }
}

/// Scalar convenience constructors.
pub fn scalar_f32(x: f32) -> HostTensor {
    HostTensor::F32(vec![x])
}

pub fn scalar_i32(x: i32) -> HostTensor {
    HostTensor::I32(vec![x])
}

/// Cumulative per-artifact timing, for the perf pass and overhead analysis.
#[derive(Debug, Default, Clone)]
pub struct CallStats {
    pub calls: u64,
    pub total_secs: f64,
}

pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<BTreeMap<String, CallStats>>,
}

impl Engine {
    /// Load a config's artifact directory. Executables compile lazily on
    /// first call (compile-all via `warmup` for benchmarking).
    pub fn load(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            manifest,
            client,
            executables: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(BTreeMap::new()),
        })
    }

    pub fn config_name(&self) -> &str {
        &self.manifest.config.name
    }

    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.executables.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.stats
            .borrow_mut()
            .entry(format!("compile:{name}"))
            .or_default()
            .total_secs += t0.elapsed().as_secs_f64();
        self.executables.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Compile every artifact up front.
    pub fn warmup(&self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.artifacts.keys().cloned().collect();
        for n in names {
            self.ensure_compiled(&n)?;
        }
        Ok(())
    }

    /// Execute artifact `name`. Inputs are validated against the manifest
    /// (count, dtype, element count) before hitting PJRT.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec: ArtifactSpec = self.manifest.artifact(name)?.clone();
        if spec.untupled {
            bail!("{name} is an untupled (buffer hot-path) artifact; use execute_buffers()");
        }
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            if t.dtype() != s.dtype {
                bail!("{name}: input '{}' dtype mismatch", s.name);
            }
            if t.len() != s.numel() {
                bail!(
                    "{name}: input '{}' has {} elements, expected {} {:?}",
                    s.name,
                    t.len(),
                    s.numel(),
                    s.shape
                );
            }
            literals.push(t.to_literal(&s.shape)?);
        }

        self.ensure_compiled(name)?;
        let t0 = Instant::now();
        let execs = self.executables.borrow();
        let exe = execs.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: executable returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, s) in parts.iter().zip(&spec.outputs) {
            out.push(HostTensor::from_literal(lit, s.dtype)?);
        }
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += dt;
        Ok(out)
    }

    pub fn stats(&self) -> BTreeMap<String, CallStats> {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }

    /// Load the seeded initial policy parameters from the artifact dir.
    pub fn init_policy(&self) -> Result<Vec<f32>> {
        let arr = crate::util::npy::read_f32(self.manifest.init_policy_path())?;
        self.check_params(&arr.data)?;
        Ok(arr.data)
    }

    pub fn init_rm(&self) -> Result<Vec<f32>> {
        let arr = crate::util::npy::read_f32(self.manifest.init_rm_path())?;
        self.check_params(&arr.data)?;
        Ok(arr.data)
    }

    fn check_params(&self, p: &[f32]) -> Result<()> {
        if p.len() != self.manifest.param_count {
            bail!(
                "param vector has {} elements, manifest says {}",
                p.len(),
                self.manifest.param_count
            );
        }
        Ok(())
    }
}

/// Optimizer state threaded through train-step executables.
#[derive(Clone)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
}

impl TrainState {
    pub fn new(params: Vec<f32>) -> TrainState {
        let n = params.len();
        TrainState {
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
        }
    }

    /// Run one fused train step. `batch` holds the loss-specific tensors
    /// after (params, m, v, step, lr). Returns the metrics vector.
    pub fn train_step(
        &mut self,
        engine: &Engine,
        artifact: &str,
        lr: f32,
        batch: Vec<HostTensor>,
    ) -> Result<Vec<f32>> {
        self.step += 1;
        let mut inputs = Vec::with_capacity(batch.len() + 5);
        inputs.push(HostTensor::F32(std::mem::take(&mut self.params)));
        inputs.push(HostTensor::F32(std::mem::take(&mut self.m)));
        inputs.push(HostTensor::F32(std::mem::take(&mut self.v)));
        inputs.push(scalar_f32(self.step as f32));
        inputs.push(scalar_f32(lr));
        inputs.extend(batch);
        let mut out = engine.call(artifact, &inputs)?;
        if out.len() != 4 {
            bail!("{artifact}: expected 4 outputs, got {}", out.len());
        }
        let metrics = out.pop().unwrap().into_f32()?;
        self.v = out.pop().unwrap().into_f32()?;
        self.m = out.pop().unwrap().into_f32()?;
        self.params = out.pop().unwrap().into_f32()?;
        Ok(metrics)
    }
}

/// Named metric lookup against the manifest's metric table.
pub fn metric(
    engine: &Engine,
    artifact: &str,
    metrics: &[f32],
    name: &str,
) -> Result<f32> {
    let spec = engine.manifest.artifact(artifact)?;
    let idx = spec
        .metrics
        .iter()
        .position(|m| m == name)
        .ok_or_else(|| anyhow!("{artifact} has no metric '{name}'"))?;
    Ok(metrics[idx])
}
