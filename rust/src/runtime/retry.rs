//! Retry with deterministic jittered backoff at the engine boundary.
//!
//! A transient PJRT fault (allocation hiccup, client glitch) inside one
//! generation call must not kill an hours-long run. The supervision layer
//! wraps the engine boundary in a [`RetryPolicy`]: up to `--engine-retries`
//! re-attempts, sleeping an exponentially growing, *jittered* delay between
//! them. The jitter is drawn from a dedicated [`Pcg32`] stream derived from
//! the run seed ([`RETRY_STREAM`] + worker id), so a replayed run with the
//! same scripted faults sleeps the same schedule — retries stay inside the
//! determinism contract instead of outside it.
//!
//! Counters: the caller passes an `on_retry` hook; workers use it to bump
//! their per-run retry tally and the engine's per-origin
//! [`CallStats::retries`](crate::runtime::CallStats) counter.

use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::rng::Pcg32;

/// RNG stream id for backoff jitter: `RETRY_STREAM + worker` keeps each
/// worker's retry schedule independent of its sampling stream (a retry
/// must not shift the tokens a healthy run would have sampled).
pub const RETRY_STREAM: u64 = 0xbac0;

/// Retry policy for one fallible engine-boundary call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Re-attempts after the first try (0 = fail fast, the pre-supervision
    /// behaviour).
    pub retries: u32,
    /// Backoff before retry `a` is `base_delay · 2^a`, jittered into
    /// `[½, 1)` of itself.
    pub base_delay: Duration,
}

impl RetryPolicy {
    pub fn new(retries: u32) -> RetryPolicy {
        RetryPolicy { retries, base_delay: Duration::from_millis(50) }
    }

    /// The jittered delay before 0-based retry `attempt`. Deterministic in
    /// (`rng` cursor, `attempt`): exponential growth capped at 2^16·base,
    /// scaled by a uniform draw in [½, 1) so concurrent workers retrying
    /// the same fault don't thundering-herd the backend in lockstep.
    pub fn backoff(&self, attempt: u32, rng: &mut Pcg32) -> Duration {
        let exp = self.base_delay.saturating_mul(1 << attempt.min(16));
        exp.mul_f64(0.5 + 0.5 * rng.gen_f64())
    }

    /// Run `f`, re-attempting up to `self.retries` times on `Err`.
    /// `on_retry(attempt)` fires before each backoff sleep (stat
    /// counters / logging); the terminal error carries the give-up count.
    pub fn run<T>(
        &self,
        rng: &mut Pcg32,
        mut on_retry: impl FnMut(u32),
        mut f: impl FnMut(u32) -> Result<T>,
    ) -> Result<T> {
        let mut attempt = 0u32;
        loop {
            match f(attempt) {
                Ok(v) => return Ok(v),
                Err(_) if attempt < self.retries => {
                    on_retry(attempt);
                    std::thread::sleep(self.backoff(attempt, rng));
                    attempt += 1;
                }
                Err(e) if self.retries > 0 => {
                    return Err(e).with_context(|| {
                        format!("gave up after {} engine retries", self.retries)
                    });
                }
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    fn tiny(retries: u32) -> RetryPolicy {
        // keep test sleeps in the microsecond range
        RetryPolicy { retries, base_delay: Duration::from_micros(10) }
    }

    #[test]
    fn first_success_needs_no_retry() {
        let mut rng = Pcg32::new(1, RETRY_STREAM);
        let mut retries = 0;
        let out = tiny(3)
            .run(&mut rng, |_| retries += 1, |_| Ok(7))
            .unwrap();
        assert_eq!(out, 7);
        assert_eq!(retries, 0);
    }

    #[test]
    fn transient_errors_are_retried_and_counted() {
        let mut rng = Pcg32::new(1, RETRY_STREAM);
        let mut retries = 0;
        let mut failures_left = 2;
        let out = tiny(3)
            .run(
                &mut rng,
                |_| retries += 1,
                |attempt| {
                    if failures_left > 0 {
                        failures_left -= 1;
                        Err(anyhow!("transient"))
                    } else {
                        Ok(attempt)
                    }
                },
            )
            .unwrap();
        assert_eq!(out, 2, "succeeded on the third attempt");
        assert_eq!(retries, 2);
    }

    #[test]
    fn gives_up_after_budget_with_descriptive_context() {
        let mut rng = Pcg32::new(1, RETRY_STREAM);
        let mut calls = 0;
        let err = tiny(2)
            .run(&mut rng, |_| {}, |_: u32| -> Result<()> {
                calls += 1;
                Err(anyhow!("persistent"))
            })
            .unwrap_err();
        assert_eq!(calls, 3, "1 attempt + 2 retries");
        let msg = format!("{err:#}");
        assert!(msg.contains("gave up after 2 engine retries"), "{msg}");
        assert!(msg.contains("persistent"), "{msg}");
    }

    #[test]
    fn zero_retries_is_fail_fast_with_untouched_error() {
        let mut rng = Pcg32::new(1, RETRY_STREAM);
        let err = tiny(0)
            .run(&mut rng, |_| {}, |_: u32| -> Result<()> {
                Err(anyhow!("original"))
            })
            .unwrap_err();
        assert_eq!(format!("{err:#}"), "original");
    }

    #[test]
    fn backoff_is_deterministic_jittered_and_exponential() {
        let p = RetryPolicy::new(3);
        let mut a = Pcg32::new(42, RETRY_STREAM + 1);
        let mut b = Pcg32::new(42, RETRY_STREAM + 1);
        for attempt in 0..4 {
            let da = p.backoff(attempt, &mut a);
            assert_eq!(da, p.backoff(attempt, &mut b), "same stream, same delay");
            let full = p.base_delay * (1 << attempt);
            assert!(da >= full / 2 && da < full, "attempt {attempt}: {da:?}");
        }
        // a different stream jitters differently
        let mut c = Pcg32::new(42, RETRY_STREAM + 2);
        let differs = (0..4).any(|n| {
            p.backoff(n, &mut c) != p.backoff(n, &mut Pcg32::new(42, RETRY_STREAM + 1))
        });
        assert!(differs);
    }
}
