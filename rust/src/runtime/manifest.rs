//! Artifact manifest: the contract between the AOT pipeline and the runtime.
//!
//! `manifest.json` (written by python/compile/aot.py) describes every HLO
//! executable's I/O signature plus the model geometry. The runtime loads it
//! once and validates every call against it, so shape bugs surface as
//! errors with names instead of PJRT crashes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub metrics: Vec<String>,
    /// Buffer-path artifact: executed via `Engine::execute_buffers`, its
    /// outputs stay device-resident until downloaded (one buffer per
    /// output on untupling PJRT clients; the engine splits the root
    /// tuple through the host on clients that return one tuple buffer).
    /// Tupled artifacts return a single tuple literal via `Engine::call`.
    pub untupled: bool,
}

/// Model geometry + hyperparameters mirrored from python configs.py.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub size: String,
    pub task: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub prompt_len: usize,
    pub resp_len: usize,
    pub seq_len: usize,
    pub gen_batch: usize,
    pub train_pairs: usize,
    pub beta_kl: f64,
    pub ppo_clip: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub param_count: usize,
    pub kv_cache_shape: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    let dtype = match j.req("dtype")?.as_str() {
        Some("f32") => DType::F32,
        Some("i32") => DType::I32,
        other => bail!("bad dtype {other:?}"),
    };
    Ok(IoSpec {
        name: j.req("name")?.as_str().unwrap_or("").to_string(),
        shape: j
            .req("shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("bad shape"))?,
        dtype,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;

        let c = j.req("config")?;
        let gets = |k: &str| -> Result<String> {
            Ok(c.req(k)?
                .as_str()
                .ok_or_else(|| anyhow!("bad {k}"))?
                .to_string())
        };
        let getn = |k: &str| -> Result<usize> {
            c.req(k)?.as_usize().ok_or_else(|| anyhow!("bad {k}"))
        };
        let getf = |k: &str| -> Result<f64> {
            c.req(k)?.as_f64().ok_or_else(|| anyhow!("bad {k}"))
        };
        let config = ModelConfig {
            name: gets("name")?,
            size: gets("size")?,
            task: gets("task")?,
            d_model: getn("d_model")?,
            n_layers: getn("n_layers")?,
            n_heads: getn("n_heads")?,
            head_dim: getn("head_dim")?,
            vocab: getn("vocab")?,
            prompt_len: getn("prompt_len")?,
            resp_len: getn("resp_len")?,
            seq_len: getn("seq_len")?,
            gen_batch: getn("gen_batch")?,
            train_pairs: getn("train_pairs")?,
            beta_kl: getf("beta_kl")?,
            ppo_clip: getf("ppo_clip")?,
        };

        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs not array"))?
                .iter()
                .map(io_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("outputs not array"))?
                .iter()
                .map(io_spec)
                .collect::<Result<Vec<_>>>()?;
            let metrics = a
                .get("metrics")
                .and_then(|m| m.as_arr())
                .map(|v| {
                    v.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a
                        .req("file")?
                        .as_str()
                        .ok_or_else(|| anyhow!("bad file"))?
                        .to_string(),
                    inputs,
                    outputs,
                    metrics,
                    untupled: a
                        .get("untupled")
                        .and_then(|u| u.as_bool())
                        .unwrap_or(false),
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            config,
            param_count: j
                .req("param_count")?
                .as_usize()
                .ok_or_else(|| anyhow!("bad param_count"))?,
            kv_cache_shape: j
                .req("kv_cache_shape")?
                .as_usize_vec()
                .ok_or_else(|| anyhow!("bad kv_cache_shape"))?,
            artifacts,
        })
    }

    /// Whether the bundle ships an artifact — used to feature-gate paths
    /// that need the newer buffer-path twins (`prefill_dev` etc.) while
    /// staying loadable against older artifact directories.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact '{name}' in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    pub fn init_policy_path(&self) -> PathBuf {
        self.dir.join("init_policy.npy")
    }

    pub fn init_rm_path(&self) -> PathBuf {
        self.dir.join("init_rm.npy")
    }

    pub fn kv_cache_len(&self) -> usize {
        self.kv_cache_shape.iter().product()
    }
}

/// Locate the artifacts root: `--artifacts` flag value, else
/// `$ASYNC_RLHF_ARTIFACTS`, else ./artifacts.
pub fn artifacts_root(cli: Option<&str>) -> PathBuf {
    if let Some(p) = cli {
        return PathBuf::from(p);
    }
    if let Ok(p) = std::env::var("ASYNC_RLHF_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from("artifacts")
}
