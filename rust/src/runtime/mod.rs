//! PJRT runtime: artifact manifests + compiled-executable engine.
//!
//! The AOT boundary (DESIGN.md §1): python lowers every model computation
//! to HLO text under `artifacts/<config>/`; this module loads, compiles
//! (once, per thread-local client) and executes them.
//!
//! Two execution paths, chosen per artifact by the manifest's `untupled`
//! flag: the **host-literal path** (`Engine::call` / `call_with`) for
//! tupled artifacts, which downloads the single tuple result, and the
//! **buffer path** (`Engine::execute_buffers`) for untupled artifacts,
//! which keeps every output device-resident until explicitly downloaded.
//! Parameter inputs go through the engine's device cache ([`ParamView`])
//! so frozen sets upload once per run and the policy re-uploads only on
//! version bumps.

pub mod engine;
pub mod manifest;
pub mod reduce;
pub mod retry;

pub use engine::{
    metric, scalar_f32, scalar_i32, CallArg, CallStats, DeviceBuffer, Engine,
    HostTensor, ParamView, TrainState,
};
pub use retry::{RetryPolicy, RETRY_STREAM};
pub use manifest::{artifacts_root, ArtifactSpec, DType, IoSpec, Manifest, ModelConfig};
