//! PJRT runtime: artifact manifests + compiled-executable engine.
//!
//! The AOT boundary (DESIGN.md §1): python lowers every model computation
//! to HLO text under `artifacts/<config>/`; this module loads, compiles
//! (once, per thread-local client) and executes them with host buffers.

pub mod engine;
pub mod manifest;

pub use engine::{metric, scalar_f32, scalar_i32, Engine, HostTensor, TrainState};
pub use manifest::{artifacts_root, ArtifactSpec, DType, IoSpec, Manifest, ModelConfig};
