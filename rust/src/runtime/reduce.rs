//! Deterministic tree all-reduce for data-parallel trainer shards.
//!
//! Floating-point addition is not associative, so the *order* in which
//! shard contributions are combined decides the final bits. The trainer
//! needs two reproducibility properties from its reduce:
//!
//! 1. **Order-stable across runs**: reducing the same S vectors must
//!    yield the same bits every time, regardless of which shard thread
//!    finished first. We get this by collecting contributions into a
//!    rank-indexed vector and reducing as a pure function of rank order.
//! 2. **Fixed pairwise shape**: the summation tree is the classic
//!    adjacent-pairs reduction — layer k pairs element 2i with 2i+1, an
//!    odd tail carries up unchanged — so the result at a given S is a
//!    deterministic function of the inputs, bitwise, on every host.
//!
//! Note this does NOT promise the same bits at *different* S (a 4-leaf
//! tree and a 2-leaf tree sum in different orders); the S=1 path is an
//! exact identity so an unsharded run is never perturbed.

use anyhow::{bail, Result};

/// Sum `parts[0] + parts[1] + ...` with a fixed adjacent-pairs tree.
///
/// The input order is the reduction order: callers must index by shard
/// rank, never by completion order. All parts must share one length.
pub fn tree_sum(mut parts: Vec<Vec<f32>>) -> Result<Vec<f32>> {
    if parts.is_empty() {
        bail!("tree_sum of zero shards");
    }
    let n = parts[0].len();
    if let Some(bad) = parts.iter().find(|p| p.len() != n) {
        bail!(
            "tree_sum shard length mismatch: expected {n}, got {}",
            bad.len()
        );
    }
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(mut a) = it.next() {
            match it.next() {
                Some(b) => {
                    for (x, y) in a.iter_mut().zip(&b) {
                        *x += *y;
                    }
                    next.push(a);
                }
                // odd tail carries up to the next layer unchanged
                None => next.push(a),
            }
        }
        parts = next;
    }
    Ok(parts.pop().expect("non-empty by construction"))
}

/// Tree-sum then divide by the shard count (the data-parallel average).
///
/// S=1 is an exact identity — the single part is returned untouched, no
/// `* 1.0` rounding trip — which is what makes the unsharded and the
/// `--trainer-shards 1` paths bitwise-comparable.
pub fn tree_average(parts: Vec<Vec<f32>>) -> Result<Vec<f32>> {
    let s = parts.len();
    if s == 1 {
        return Ok(parts.into_iter().next().expect("s == 1"));
    }
    let mut sum = tree_sum(parts)?;
    let inv = 1.0 / s as f32;
    for x in &mut sum {
        *x *= inv;
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_sum_matches_the_adjacent_pairs_shape_not_a_left_fold() {
        // catastrophic cancellation distinguishes the orders: the tree
        // computes (1e8 + 1) + (-1e8 + 1) = 2 exactly (1e8 + 1 rounds to
        // 1e8 in f32, so the tree yields 1.0 + 1.0... walk it):
        //   layer 0: [1e8, 1, -1e8, 1]
        //   layer 1: [(1e8 + 1), (-1e8 + 1)] = [1e8, -1e8 + 1]
        //   layer 2: [1e8 + (-1e8 + 1)]
        // f32(1e8 + 1) == 1e8 (ulp at 1e8 is 8), f32(-1e8 + 1) == -1e8,
        // so the tree yields 0.0; a left fold ((1e8+1)-1e8)+1 yields 1.0.
        let parts =
            vec![vec![1e8f32], vec![1.0], vec![-1e8], vec![1.0]];
        let tree = tree_sum(parts.clone()).unwrap();
        let fold = parts
            .iter()
            .fold(0.0f32, |acc, p| acc + p[0]);
        assert_eq!(tree[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(fold.to_bits(), 1.0f32.to_bits());
        assert_ne!(tree[0].to_bits(), fold.to_bits());
    }

    #[test]
    fn tree_sum_is_a_pure_function_of_rank_order() {
        let parts: Vec<Vec<f32>> = (0..5)
            .map(|r| (0..17).map(|i| (r * 31 + i) as f32 * 0.37).collect())
            .collect();
        let a = tree_sum(parts.clone()).unwrap();
        let b = tree_sum(parts).unwrap();
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // permuting ranks changes the tree (cancellation makes the
        // difference observable) — callers must index by rank
        let parts =
            vec![vec![1e8f32], vec![1.0], vec![-1e8], vec![1.0]];
        let mut perm = parts.clone();
        perm.swap(1, 2); // pairs become (1e8, -1e8) and (1, 1)
        let c = tree_sum(parts).unwrap();
        let d = tree_sum(perm).unwrap();
        assert_eq!(c[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(d[0].to_bits(), 2.0f32.to_bits());
    }

    #[test]
    fn tree_sum_handles_odd_shard_counts() {
        // 3 shards: layer 1 = [a+b, c], layer 2 = [(a+b)+c]
        let out = tree_sum(vec![vec![1.0], vec![2.0], vec![4.0]]).unwrap();
        assert_eq!(out, vec![7.0]);
        // 1 shard: identity
        let one = tree_sum(vec![vec![3.5, -1.25]]).unwrap();
        assert_eq!(one, vec![3.5, -1.25]);
    }

    #[test]
    fn tree_average_at_one_shard_is_an_exact_identity() {
        // a value whose bits would move under * (1.0 / 1.0) rounding is
        // impossible, but the identity also skips NaN canonicalisation
        // and denormal flushes — check bits survive verbatim
        let raw = vec![f32::from_bits(0x0000_0001), -0.0, 3.1415927];
        let bits: Vec<u32> = raw.iter().map(|x| x.to_bits()).collect();
        let out = tree_average(vec![raw]).unwrap();
        assert_eq!(
            out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            bits
        );
    }

    #[test]
    fn tree_average_divides_by_the_shard_count() {
        let out =
            tree_average(vec![vec![1.0, 10.0], vec![3.0, 30.0]]).unwrap();
        assert_eq!(out, vec![2.0, 20.0]);
    }

    #[test]
    fn tree_sum_rejects_mismatched_lengths_and_empty_input() {
        assert!(tree_sum(vec![]).is_err());
        assert!(tree_sum(vec![vec![1.0], vec![1.0, 2.0]]).is_err());
    }
}
