//! Integration tests for the continuous in-flight batching engine on the
//! dev artifact bundle.
//!
//! Two faces are exercised end-to-end: the round-mode [`Generator`]
//! (one cohort at full occupancy, admission disabled — contractually
//! BITWISE-equal to the device-KV tier at equal seeds, the anchor that
//! pins the pool's sampling/RNG/retirement semantics to an
//! already-verified engine), and the streaming face driven by the async
//! coordinator (`--gen-engine continuous`), checked for episode
//! accounting and the per-token staleness telemetry only the slot pool
//! can produce.
//!
//! Requires `make artifacts` (skips, loudly, when artifacts/dev is
//! absent — CI always builds artifacts first).

use std::path::PathBuf;

use async_rlhf::config::{Algo, ExpConfig, GenEngine, Mode};
use async_rlhf::coordinator;
use async_rlhf::data::{Task, TaskGen};
use async_rlhf::gen::continuous::ContinuousEngine;
use async_rlhf::gen::{device::DeviceCachedEngine, Generator, SampleOpts};
use async_rlhf::runtime::{Engine, ParamView};
use async_rlhf::util::rng::Pcg32;

fn dev_dir() -> Option<PathBuf> {
    let root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let dir = root.join("dev");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/dev missing — run `make artifacts`");
        None
    }
}

fn test_cfg(name: &str) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.model = "dev".into();
    cfg.artifacts_root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    cfg.steps = 10;
    cfg.sft_steps = 80;
    cfg.rm_steps = 60;
    cfg.eval_prompts = 32;
    cfg.run_dir = std::env::temp_dir().join(format!("async_rlhf_test_{name}"));
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

#[test]
fn continuous_round_mode_bitwise_matches_device_tier() {
    // At full occupancy with admission disabled the pool must make the
    // exact call sequence the device tier makes (one prefill, one decode
    // per surviving sweep) and walk the host RNG identically (one draw
    // per slot per sweep, sample or skip): sequences, masks, behaviour
    // logprobs, termination flags and step counts all bitwise equal.
    let Some(dir) = dev_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    if !ContinuousEngine::supported(&engine) {
        eprintln!(
            "SKIP: bundle lacks prefill_dev/decode_dev — rebuild artifacts"
        );
        return;
    }
    let cfg = engine.manifest.config.clone();
    let params = engine.init_policy().unwrap();
    let taskgen = TaskGen::new(Task::Tldr, cfg.prompt_len, cfg.resp_len, 7);
    let prompts: Vec<Vec<i32>> = taskgen
        .batch(0, cfg.gen_batch)
        .iter()
        .map(|e| e.prompt.clone())
        .collect();
    let opts = SampleOpts { temperature: 0.7, greedy: false };

    let mut rng1 = Pcg32::new(99, 1);
    let a = DeviceCachedEngine::default()
        .generate(
            &engine,
            ParamView::cached("p", 0, &params),
            &prompts,
            opts,
            &mut rng1,
        )
        .unwrap();
    let mut rng2 = Pcg32::new(99, 1);
    let b = ContinuousEngine::default()
        .generate(
            &engine,
            ParamView::cached("p", 0, &params),
            &prompts,
            opts,
            &mut rng2,
        )
        .unwrap();
    assert_eq!(a.tokens, b.tokens, "sequences diverged");
    assert_eq!(a.resp_mask, b.resp_mask);
    assert_eq!(a.blp, b.blp, "behaviour logprobs must be bitwise equal");
    assert_eq!(a.terminated, b.terminated);
    assert_eq!(a.steps, b.steps, "early-exit behaviour diverged");
    // and the host RNG cursors agree, so downstream sampling stays in
    // lockstep no matter which engine ran the round
    assert_eq!(rng1.next_u64(), rng2.next_u64(), "RNG walks diverged");
}

#[test]
fn async_continuous_end_to_end_smoke() {
    // Full RLHF run through the streaming face: the worker drives
    // Pool::step directly (mid-flight admission, between-step weight
    // swaps), rounds are assembled from retirement order, and the
    // per-token staleness telemetry lands in the log.
    let Some(dir) = dev_dir() else { return };
    {
        let engine = Engine::load(&dir).unwrap();
        if !ContinuousEngine::supported(&engine) {
            eprintln!(
                "SKIP: bundle lacks prefill_dev/decode_dev — rebuild artifacts"
            );
            return;
        }
    }
    let mut cfg = test_cfg("continuous_smoke");
    cfg.algo = Algo::Dpo;
    cfg.mode = Mode::Async;
    cfg.gen_engine = GenEngine::Continuous;
    cfg.steps = 8;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    // episode accounting is engine-independent: every trained round is
    // gen_batch sequences regardless of how they were scheduled
    assert_eq!(out.log.rows.len(), cfg.steps as usize);
    assert_eq!(
        out.episodes,
        cfg.steps * prep.engine.manifest.config.gen_batch as u64
    );

    // per-token staleness telemetry: present on every row, internally
    // consistent (max >= mean >= 0, and the per-round staleness — the
    // NEWEST token's age — never exceeds the oldest token's age)
    for row in &out.log.rows {
        let tok_max = row.values["staleness_tok_max"];
        let tok_mean = row.values["staleness_tok_mean"];
        let round = row.values["staleness"];
        assert!(tok_max >= 0.0 && tok_mean >= 0.0);
        assert!(
            tok_max + 1e-6 >= tok_mean,
            "token staleness max {tok_max} < mean {tok_mean}"
        );
        assert!(
            tok_max + 1e-6 >= round,
            "oldest-token staleness {tok_max} < round staleness {round}"
        );
    }
    assert!(out.log.meta.contains_key("mean_staleness_tok"));
    assert!(out.log.meta.contains_key("max_staleness_tok"));
}
