//! Sharded data-parallel trainer integration tests.
//!
//! Two tiers:
//!
//! - **Artifact-free**: the discrete worst-case model of the sharded
//!   staleness bound — the bounded-queue model of `pipeline`'s tests
//!   extended with an adversarial ParamBus seat lag — proving
//!   `staleness_bound_sharded` holds and is tight at lag = S − 1.
//! - **Dev-artifact-gated** (skip, loudly, when `artifacts/dev` is
//!   missing): the S = 1 bitwise guarantees against real executables —
//!   the `--trainer-shards 1` run equals the default run, and the
//!   `ShardPool` machinery at one rank equals `train_on_batch` — plus
//!   S = 2 run-to-run determinism in sync mode and the re-derived bound
//!   hard-checked on a real S = 2 async run.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Arc;

use async_rlhf::config::{ExpConfig, Mode};
use async_rlhf::coordinator;
use async_rlhf::coordinator::pipeline::{
    staleness_bound_sharded, staleness_bound_updates, ParamBus,
};
use async_rlhf::coordinator::shard::ShardPool;
use async_rlhf::coordinator::trainer::{
    staleness, train_on_batch, BatchSlot, TrainBatch,
};
use async_rlhf::runtime::{DType, Engine, HostTensor, TrainState};
use async_rlhf::util::rng::Pcg32;

fn dev_dir() -> Option<PathBuf> {
    let root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let dir = root.join("dev");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/dev missing — run `make artifacts`");
        None
    }
}

fn test_cfg(name: &str) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.model = "dev".into();
    cfg.artifacts_root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    cfg.steps = 6;
    cfg.sft_steps = 80;
    cfg.rm_steps = 60;
    cfg.eval_prompts = 32;
    cfg.run_dir = std::env::temp_dir().join(format!("async_rlhf_test_{name}"));
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

fn assert_params_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: param count diverged");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: param {i} diverged: {x} vs {y}"
        );
    }
}

fn assert_rows_bitwise(
    a: &async_rlhf::metrics::RunLog,
    b: &async_rlhf::metrics::RunLog,
    what: &str,
) {
    assert_eq!(a.rows.len(), b.rows.len(), "{what}: step count diverged");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.step, rb.step);
        for (key, va) in &ra.values {
            // wall-clock metrics are timing, not computation
            if key.contains("secs") || key.contains("wall") {
                continue;
            }
            let vb = rb.values.get(key).unwrap_or_else(|| {
                panic!("{what}: step {} missing metric {key}", ra.step)
            });
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: step {} metric {key} diverged: {va} vs {vb}",
                ra.step
            );
        }
    }
}

/// Discrete worst-case model of the sharded publish fan-out, layered on
/// the bounded-queue model proven for the unsharded pipeline: one worker
/// with instantaneous generation behind a K-bounded queue, but its
/// ParamBus seat observes each publish up to `lag ≤ S − 1` update units
/// late (the fan-out is S separate pointer swaps, not one atomic
/// broadcast). Staleness must stay within `staleness_bound_sharded`,
/// and the bound must be tight at the adversarial lag S − 1.
#[test]
fn shard_fanout_model_staleness_is_tight_at_the_sharded_bound() {
    for s in 1..=4usize {
        for k_bound in 0..3usize {
            for t in 1..4u64 {
                for lag in 0..s as u64 {
                    let mut queue: VecDeque<u64> = VecDeque::new();
                    let mut blocked: Option<u64> = None;
                    let mut published = 0u64;
                    let mut version = 0u64;
                    let mut max_seen = 0u64;
                    let refill = |queue: &mut VecDeque<u64>,
                                  blocked: &mut Option<u64>,
                                  published: u64,
                                  lag: u64| {
                        // the worker's seat sees the publish front lag
                        // update units late
                        let seen = published.saturating_sub(lag);
                        while queue.len() < k_bound {
                            queue.push_back(seen);
                        }
                        if blocked.is_none() {
                            *blocked = Some(seen);
                        }
                    };
                    refill(&mut queue, &mut blocked, published, lag);
                    for _ in 0..50 {
                        let data = match queue.pop_front() {
                            Some(front) => {
                                if let Some(b) = blocked.take() {
                                    queue.push_back(b);
                                }
                                front
                            }
                            None => {
                                blocked.take().expect("rendezvous handover")
                            }
                        };
                        refill(&mut queue, &mut blocked, published, lag);
                        version += t;
                        published = version;
                        let st = staleness(version, data);
                        let bound = staleness_bound_sharded(
                            k_bound, 1, t as usize, s,
                        );
                        assert!(
                            st <= bound,
                            "S={s} lag={lag} K={k_bound} T={t}: staleness \
                             {st} > sharded bound {bound}"
                        );
                        max_seen = max_seen.max(st);
                    }
                    if lag == s as u64 - 1 {
                        assert_eq!(
                            max_seen,
                            staleness_bound_sharded(k_bound, 1, t as usize, s),
                            "S={s} K={k_bound} T={t}: the sharded bound \
                             should be tight at the adversarial lag S-1"
                        );
                    } else {
                        // milder lags stay within the unsharded bound
                        // plus their own lag — the fan-out term is the
                        // lag, not a blanket S-1 penalty
                        assert_eq!(
                            max_seen,
                            staleness_bound_updates(k_bound, 1, t as usize)
                                + lag,
                            "S={s} lag={lag} K={k_bound} T={t}"
                        );
                    }
                }
            }
        }
    }
}

/// Deterministic pseudo-random host batch matching `artifact`'s input
/// geometry — semantics don't matter for bitwise-equivalence checks,
/// only that both trainer paths consume identical bits.
fn synthetic_batch(
    engine: &Engine,
    artifact: &'static str,
    seed: u64,
) -> TrainBatch {
    let spec = engine.manifest.artifact(artifact).unwrap();
    let vocab = engine.manifest.config.vocab as u32;
    let mut rng = Pcg32::new(seed, 0x5a4d);
    let tensors = spec.inputs[5..]
        .iter()
        .map(|input| {
            let n = input.numel();
            BatchSlot::Host(match input.dtype {
                DType::I32 => HostTensor::I32(
                    (0..n)
                        .map(|_| rng.gen_range(vocab) as i32)
                        .collect(),
                ),
                DType::F32 => HostTensor::F32(
                    (0..n).map(|_| rng.gen_f32() - 0.5).collect(),
                ),
            })
        })
        .collect();
    TrainBatch { artifact, tensors, episodes: 0 }
}

#[test]
fn shard_pool_at_one_rank_matches_train_on_batch_bitwise() {
    // The full sharded machinery at S = 1 — slice (whole batch), tile
    // (×1), ship to a shard thread with its own engine, reduce (exact
    // identity), reinstall via from_host — must reproduce the in-thread
    // trainer bit for bit: same params, same optimizer moments, same
    // metric rows.
    let Some(dir) = dev_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let artifact = ExpConfig::default().algo.artifact();
    let n = engine.manifest.param_count;
    let mut rng = Pcg32::new(7, 0x1eaf);
    let params: Vec<f32> =
        (0..n).map(|_| 0.02 * (rng.gen_f32() - 0.5)).collect();
    let batch = synthetic_batch(&engine, artifact, 11);
    let (lr, t_updates) = (1e-4f32, 2usize);

    let mut plain = TrainState::new(params.clone());
    let plain_metrics =
        train_on_batch(&engine, &mut plain, &batch, lr, t_updates).unwrap();

    let bus = Arc::new(ParamBus::new(1, 0, Arc::from(&params[..])));
    let mut pool =
        ShardPool::spawn(dir.clone(), &engine, artifact, 1, bus, 0).unwrap();
    let mut sharded = TrainState::new(params);
    let sharded_metrics = pool
        .train(&engine, &mut sharded, &batch, lr, t_updates, 0)
        .unwrap();
    pool.finish().unwrap();

    assert_eq!(plain.step, sharded.step, "optimizer step count");
    let (pp, pm, pv) = plain.host_mirrors(&engine).unwrap();
    let (pp, pm, pv) = (pp.to_vec(), pm.to_vec(), pv.to_vec());
    let (sp, sm, sv) = sharded.host_mirrors(&engine).unwrap();
    assert_params_bitwise(&pp, sp, "params");
    assert_params_bitwise(&pm, sm, "adam m");
    assert_params_bitwise(&pv, sv, "adam v");
    assert_eq!(plain_metrics.len(), sharded_metrics.len());
    for (u, (a, b)) in
        plain_metrics.iter().zip(&sharded_metrics).enumerate()
    {
        assert_params_bitwise(a, b, &format!("metrics row {u}"));
    }
}

#[test]
fn shard_flag_at_one_is_bitwise_identical_to_the_default_run() {
    // `--trainer-shards 1` must not perturb the unsharded trainer in any
    // mode: same final params, same per-step metrics, bit for bit.
    let Some(_dir) = dev_dir() else { return };
    let cfg = test_cfg("shard_s1");
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let base = coordinator::run(&cfg, &prep, false).unwrap();

    let mut cfg1 = cfg.clone();
    cfg1.trainer_shards = 1;
    let sharded = coordinator::run(&cfg1, &prep, false).unwrap();

    assert_params_bitwise(
        &base.final_params,
        &sharded.final_params,
        "final params",
    );
    assert_rows_bitwise(&base.log, &sharded.log, "metrics");
    assert!(
        !sharded.log.meta.contains_key("trainer_shards"),
        "S=1 must not engage the shard pool"
    );
}

#[test]
fn shard_sync_run_at_two_ranks_is_deterministic() {
    // S = 2 sync: two full runs at the same seed must agree bitwise —
    // the barrier plus rank-indexed tree reduce leaves no scheduling
    // nondeterminism (shard threads race, the reduce order doesn't).
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("shard_s2_det");
    cfg.trainer_shards = 2;
    cfg.steps = 4;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let a = coordinator::run(&cfg, &prep, false).unwrap();
    assert_eq!(
        a.log.meta.get("trainer_shards").map(String::as_str),
        Some("2"),
        "shard pool engaged"
    );

    let b = coordinator::run(&cfg, &prep, false).unwrap();

    assert_params_bitwise(&a.final_params, &b.final_params, "final params");
    assert_rows_bitwise(&a.log, &b.log, "metrics");
}

#[test]
fn shard_async_run_staleness_stays_within_the_sharded_bound() {
    // The re-derived bound on a real S = 2 async run: the trainer
    // barriers all shards before each publish, so measured staleness
    // must sit within `staleness_bound_sharded(K, M, T, 2)` (and in
    // fact within the unsharded bound — the fan-out term is headroom
    // for the adversarial schedule real runs never exhibit).
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("shard_s2_async");
    cfg.mode = Mode::Async;
    cfg.trainer_shards = 2;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    assert_eq!(out.log.rows.len(), cfg.steps as usize);
    let bound = staleness_bound_sharded(
        cfg.staleness_bound,
        cfg.gen_workers,
        cfg.updates_per_batch,
        cfg.trainer_shards,
    );
    for row in &out.log.rows {
        let stale = row.values["staleness"] as u64;
        assert!(
            stale <= bound,
            "step {}: staleness {stale} escaped the sharded bound {bound}",
            row.step
        );
    }
}
