//! Fault-injection and checkpoint/resume integration tests on the dev
//! artifact bundle.
//!
//! Each test scripts one failure mode through `--inject-fault` (the
//! deterministic fault plan: a chosen worker fails at a chosen round in a
//! chosen way) and asserts the supervision layer's contract: panics are
//! recovered by respawn with no dropped or duplicated rounds, engine
//! errors are retried at the boundary, stalls are flagged by the
//! watchdog, unrecoverable pools fail loudly (never silently skip), and
//! `--checkpoint-every` + `--resume` restarts a killed run — bitwise
//! identically in sync mode.
//!
//! Requires `make artifacts` (skips, loudly, when artifacts/dev is
//! absent — CI always builds artifacts first).

use std::path::PathBuf;

use async_rlhf::config::{ExpConfig, FaultKind, FaultPlan, GenEngine, Mode};
use async_rlhf::coordinator;
use async_rlhf::coordinator::pipeline::staleness_bound_updates;
use async_rlhf::coordinator::trainer::rounds_per_batch;

fn dev_dir() -> Option<PathBuf> {
    let root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let dir = root.join("dev");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/dev missing — run `make artifacts`");
        None
    }
}

fn test_cfg(name: &str) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.model = "dev".into();
    cfg.artifacts_root = std::env::var("ASYNC_RLHF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    cfg.steps = 6;
    cfg.sft_steps = 80;
    cfg.rm_steps = 60;
    cfg.eval_prompts = 32;
    cfg.run_dir = std::env::temp_dir().join(format!("async_rlhf_test_{name}"));
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

fn meta_u64(out: &coordinator::RunOutput, key: &str) -> u64 {
    out.log
        .meta
        .get(key)
        .unwrap_or_else(|| panic!("meta '{key}' missing"))
        .parse::<u64>()
        .unwrap_or_else(|e| panic!("meta '{key}' not a count: {e}"))
}

/// Full-run episode count: every trained step consumed its rounds
/// exactly once — the no-silent-skip check.
fn expect_episodes(cfg: &ExpConfig, prep: &coordinator::Prepared) -> u64 {
    cfg.steps
        * rounds_per_batch(cfg.k_samples) as u64
        * prep.engine.manifest.config.gen_batch as u64
}

#[test]
fn fault_injected_worker_panic_recovers() {
    // A scripted panic in the only worker: the supervisor must respawn it
    // on a fresh engine, the replacement resumes the dead worker's exact
    // prompt-partition position, and the run completes with full episode
    // accounting and staleness still within the queue bound.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("fault_panic");
    cfg.mode = Mode::Async;
    cfg.inject_fault = Some(FaultPlan {
        worker: 0,
        round: 2,
        kind: FaultKind::Panic,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    assert_eq!(meta_u64(&out, "worker_restarts"), 1);
    assert_eq!(out.log.rows.len(), cfg.steps as usize);
    assert_eq!(out.episodes, expect_episodes(&cfg, &prep));
    // the lost in-flight round was regenerated, not skipped: staleness
    // stays within the proven single-worker bound
    let bound = staleness_bound_updates(
        cfg.staleness_bound,
        cfg.gen_workers,
        cfg.updates_per_batch,
    );
    for row in &out.log.rows {
        let stale = row.values["staleness"] as u64;
        assert!(
            stale <= bound,
            "staleness {stale} escaped bound {bound} across a respawn"
        );
    }
}

#[test]
fn fault_injected_engine_error_is_retried() {
    // A scripted error at the engine boundary must be absorbed by the
    // retry policy: the worker retries with backoff, never dies, and the
    // retry is visible in the run meta.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("fault_engine_err");
    cfg.mode = Mode::Async;
    cfg.inject_fault = Some(FaultPlan {
        worker: 0,
        round: 1,
        kind: FaultKind::EngineErr,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    assert_eq!(meta_u64(&out, "worker_restarts"), 0, "retry escalated");
    assert!(meta_u64(&out, "engine_retries") >= 1, "retry not recorded");
    assert_eq!(out.log.rows.len(), cfg.steps as usize);
    assert_eq!(out.episodes, expect_episodes(&cfg, &prep));
}

#[test]
fn fault_worker_unrecoverable_with_m1_fails_loudly() {
    // One worker, zero restarts: the pool is unrecoverable, and the run
    // must surface a descriptive error naming the dead worker — never
    // hang on an empty queue or return a truncated log as success.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("fault_unrecoverable");
    cfg.mode = Mode::Async;
    cfg.max_worker_restarts = 0;
    cfg.inject_fault = Some(FaultPlan {
        worker: 0,
        round: 1,
        kind: FaultKind::Panic,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let err = coordinator::run(&cfg, &prep, false).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("gen-worker-0"),
        "error does not name the dead worker: {msg}"
    );
}

#[test]
fn fault_m2_dead_worker_lane_takeover() {
    // Two workers, zero restarts, one dies: the survivor must inherit the
    // orphaned lane via cursor re-striding and the run completes with
    // every round delivered exactly once — a silently halved prompt
    // stream would show up as an episode shortfall or a partition bail.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("fault_takeover");
    cfg.mode = Mode::Async;
    cfg.gen_workers = 2;
    cfg.max_worker_restarts = 0;
    cfg.inject_fault = Some(FaultPlan {
        worker: 1,
        round: 1,
        kind: FaultKind::Panic,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    assert_eq!(meta_u64(&out, "worker_restarts"), 0);
    assert_eq!(out.log.rows.len(), cfg.steps as usize);
    assert_eq!(out.episodes, expect_episodes(&cfg, &prep));
    let errs = out.log.meta.get("worker_errors").expect("death unrecorded");
    assert!(
        errs.contains("gen-worker-1"),
        "worker_errors does not name the dead worker: {errs}"
    );
}

#[test]
fn fault_continuous_m2_restart_exhausted_takeover_completes() {
    // The continuous engine's takeover: two streaming seats, zero
    // restarts, one dies mid-decode. Its in-flight KV is abandoned, its
    // lane is merged onto the survivor (which is forcibly retired and
    // respawned over both lanes, re-admitting from the trainer-accepted
    // frontier + skip set), and the run completes with exactly-once
    // prompt accounting at degraded capacity.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("fault_cont_takeover");
    cfg.mode = Mode::Async;
    cfg.gen_engine = GenEngine::Continuous;
    cfg.gen_workers = 2;
    cfg.max_worker_restarts = 0;
    cfg.inject_fault = Some(FaultPlan {
        worker: 1,
        round: 1,
        kind: FaultKind::Panic,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    assert_eq!(meta_u64(&out, "worker_restarts"), 0);
    assert_eq!(out.log.rows.len(), cfg.steps as usize);
    assert_eq!(
        out.episodes,
        expect_episodes(&cfg, &prep),
        "takeover dropped or duplicated prompts"
    );
    assert!(
        meta_u64(&out, "lanes_reassigned") >= 1,
        "no lane recorded as reassigned"
    );
    assert!(
        meta_u64(&out, "degraded_capacity_steps") >= 1,
        "no step recorded at degraded capacity"
    );
    let errs = out.log.meta.get("worker_errors").expect("death unrecorded");
    assert!(
        errs.contains("gen-worker-1"),
        "worker_errors does not name the dead worker: {errs}"
    );
}

#[test]
fn fault_injected_stall_flags_watchdog() {
    // A scripted stall (sleep past twice the timeout) must be flagged by
    // the heartbeat watchdog — advisory, not fatal: the run completes and
    // the stall is counted in the meta the staleness bench reports.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("fault_stall");
    cfg.mode = Mode::Async;
    cfg.stall_timeout_secs = 0.2;
    cfg.inject_fault = Some(FaultPlan {
        worker: 0,
        round: 1,
        kind: FaultKind::Stall,
    });
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let out = coordinator::run(&cfg, &prep, false).unwrap();

    assert!(
        meta_u64(&out, "stalled_workers") >= 1,
        "watchdog missed a {}s stall at --stall-timeout-secs {}",
        cfg.stall_timeout_secs * 2.0,
        cfg.stall_timeout_secs
    );
    assert_eq!(meta_u64(&out, "worker_restarts"), 0, "stall was fatal");
    assert_eq!(out.log.rows.len(), cfg.steps as usize);
}

#[test]
fn resume_sync_matches_uninterrupted_bitwise() {
    // Crash-safe resume in sync mode is bitwise: run A trains 6 steps,
    // checkpointing at step 4; run B resumes from that snapshot and
    // trains steps 5-6. Because the snapshot captures the optimizer
    // triple, the RNG cursor and the prompt cursor exactly, B's final
    // params must equal A's bit for bit.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("resume_sync");
    cfg.mode = Mode::Sync;
    cfg.checkpoint_every = 4;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let full = coordinator::run(&cfg, &prep, false).unwrap();

    let mut cfg2 = cfg.clone();
    cfg2.resume = true;
    let resumed = coordinator::run(&cfg2, &prep, false).unwrap();

    assert_eq!(
        resumed.log.meta.get("resumed_from_step").map(String::as_str),
        Some("4"),
        "resume did not pick up the step-4 snapshot"
    );
    assert_eq!(resumed.log.rows.len(), 2, "resume re-trained early steps");
    assert_eq!(full.final_params.len(), resumed.final_params.len());
    for (i, (a, b)) in full
        .final_params
        .iter()
        .zip(&resumed.final_params)
        .enumerate()
    {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "param {i} diverged after resume: {a} vs {b}"
        );
    }
}

#[test]
fn resume_async_completes_exactly_once() {
    // Async resume is exactly-once, not bitwise (worker RNG re-enters
    // under a fresh epoch): the resumed run must finish the remaining
    // steps with the prompt partition intact — total episodes equal the
    // uninterrupted count, and no partition bail fires.
    let Some(_dir) = dev_dir() else { return };
    let mut cfg = test_cfg("resume_async");
    cfg.mode = Mode::Async;
    cfg.steps = 5;
    cfg.checkpoint_every = 2;
    let prep = coordinator::prepare(&cfg, false).unwrap();
    let full = coordinator::run(&cfg, &prep, false).unwrap();
    assert_eq!(full.episodes, expect_episodes(&cfg, &prep));

    let mut cfg2 = cfg.clone();
    cfg2.resume = true;
    let resumed = coordinator::run(&cfg2, &prep, false).unwrap();

    assert_eq!(
        resumed.log.meta.get("resumed_from_step").map(String::as_str),
        Some("4"),
        "resume did not pick up the step-4 snapshot"
    );
    assert_eq!(resumed.log.rows.len(), 1, "resume re-trained early steps");
    assert_eq!(
        resumed.episodes,
        expect_episodes(&cfg, &prep),
        "resumed run dropped or duplicated rounds"
    );
}
